//! Serving smoke: drives the fleet DES end-to-end and asserts the
//! properties the serving study rests on — conservation, determinism,
//! a saturation knee, and graceful degradation through a scripted
//! outage — then prints the latency–throughput tables for a 1-device
//! ZCU102 and a 4-device U280 fleet.
//!
//! Uses pinned hardware configurations (no HAS) so the smoke stays
//! fast; the full searched study is `ubimoe serve` / `examples/
//! fleet_serve.rs`.
//!
//! `cargo bench --bench serve_smoke`

use std::time::Duration;

use ubimoe::models::m3vit_small;
use ubimoe::report::serving::{
    autoscale_study, autoscale_table, curve_table, demo_device, fleet_curve, shard_study,
    shard_table, DEFAULT_UTILS,
};
use ubimoe::resources::Platform;
use ubimoe::serve::dispatch::DispatchPolicy;
use ubimoe::serve::{
    simulate_fleet, FaultConfig, FaultPlan, FaultSpan, ServeConfig, Workload,
};
use ubimoe::util::bench::{bench_quick, black_box};

fn main() {
    let horizon = Duration::from_secs(8);
    let experts = m3vit_small().num_experts;

    // ---- curves -----------------------------------------------------
    let z = demo_device(&Platform::zcu102());
    let z_pts =
        fleet_curve(&z, 1, DispatchPolicy::JoinShortestQueue, experts, DEFAULT_UTILS, horizon, 7);
    println!(
        "{}",
        curve_table(
            &format!(
                "Serving: ZCU102 x1, m3vit-small (b1 {:.2} ms, peak {:.1} req/s)",
                z.unloaded_latency().as_secs_f64() * 1e3,
                z.peak_rps()
            ),
            &z_pts
        )
        .render()
    );

    let u = demo_device(&Platform::u280());
    let u_pts =
        fleet_curve(&u, 4, DispatchPolicy::JoinShortestQueue, experts, DEFAULT_UTILS, horizon, 7);
    println!(
        "{}",
        curve_table(
            &format!(
                "Serving: U280 x4, m3vit-small (b1 {:.2} ms, peak {:.1} req/s/device)",
                u.unloaded_latency().as_secs_f64() * 1e3,
                u.peak_rps()
            ),
            &u_pts
        )
        .render()
    );

    // ---- invariants the study rests on ------------------------------
    // Saturation knee: p99 past the knee dwarfs p99 below it.
    let below = u_pts.iter().find(|p| p.util_target <= 0.5).unwrap();
    let past = u_pts.iter().find(|p| p.util_target >= 1.1).unwrap();
    assert!(
        past.p99_ms > 3.0 * below.p99_ms,
        "no saturation knee: p99 {:.2} ms @{} vs {:.2} ms @{}",
        below.p99_ms,
        below.util_target,
        past.p99_ms,
        past.util_target
    );
    // Subcritical points serve at the offered rate.
    for p in u_pts.iter().filter(|p| p.util_target <= 0.7) {
        let ratio = p.achieved_rps / p.offered_rps;
        assert!(ratio > 0.9, "achieved/offered {ratio:.3} at load {}", p.util_target);
    }

    // Determinism + conservation on a mid-load run (reusing the
    // already-built device model — no extra cycle-sim runs).
    let mk = || {
        let mut cfg = ServeConfig::uniform(
            u.clone(),
            4,
            Workload::Poisson { rate_rps: 0.8 * 4.0 * u.peak_rps() },
        );
        cfg.num_experts = experts;
        cfg.horizon = horizon;
        cfg
    };
    let a = simulate_fleet(&mk());
    let b = simulate_fleet(&mk());
    assert_eq!(a, b, "fixed seed must be bit-identical");
    assert_eq!(a.fleet.completed, a.admitted, "conservation");
    println!("mid-load check: {}\n", a.summary());

    // ---- closed loop ------------------------------------------------
    // Zero-think users pin the fleet at `users` requests in flight:
    // with enough of them to keep every largest batch full, the
    // sustained rate must sit on the fleet's capacity plateau.
    let mut closed_cfg = ServeConfig::uniform(
        u.clone(),
        4,
        Workload::ClosedLoop { users: 64, think_time: Duration::ZERO },
    );
    closed_cfg.num_experts = experts;
    closed_cfg.horizon = horizon;
    let closed = simulate_fleet(&closed_cfg);
    assert_eq!(closed.fleet.completed, closed.admitted, "closed-loop conservation");
    let sat = closed.achieved_rps() / (4.0 * u.peak_rps());
    assert!(sat > 0.8, "64 zero-think users reached only {sat:.2} of fleet peak");
    assert_eq!(
        closed,
        simulate_fleet(&closed_cfg),
        "closed loop must rerun bit-identically"
    );
    println!("closed loop: 64 zero-think users -> {}\n", closed.summary());

    // ---- autoscaling ------------------------------------------------
    // The economics table on the pinned U280 demo design (the searched
    // version is in `ubimoe serve --study`): controller vs statics on
    // the same bursty MMPP traffic.
    let study = autoscale_study(&u, 5, Duration::from_secs(60), 7);
    println!("{}", autoscale_table(&study).render());
    let ctl = study.controller();
    assert_eq!(ctl.label, "autoscaler");
    assert!(
        ctl.peak_devices > 1,
        "bursts must have grown the fleet (peak {})",
        ctl.peak_devices
    );

    // ---- scripted faults --------------------------------------------
    // Chaos smoke on the pinned design: two of three devices scripted
    // down for 12 largest-batch service times under real load, with
    // per-attempt deadlines and a 4-attempt budget. The DES hard-
    // asserts conservation internally; here we close the loop on the
    // report side and check the retry machinery actually fired.
    let largest = *u.batch_sizes.last().unwrap();
    let svc_l = u.service_time(largest);
    let outage_from = horizon / 3;
    let mut chaos_cfg = ServeConfig::uniform(
        u.clone(),
        3,
        Workload::Poisson { rate_rps: 0.6 * 3.0 * u.peak_rps() },
    );
    chaos_cfg.num_experts = experts;
    chaos_cfg.horizon = horizon;
    chaos_cfg.faults = Some(FaultConfig {
        plan: FaultPlan::new(vec![
            FaultSpan::new(0, outage_from, outage_from + svc_l * 12),
            FaultSpan::new(1, outage_from, outage_from + svc_l * 12),
        ]),
        deadline: Some(svc_l * 6),
        max_attempts: 4,
        backoff_base: svc_l,
        backoff_cap: svc_l * 4,
        ..FaultConfig::none()
    });
    let chaos = simulate_fleet(&chaos_cfg);
    assert_eq!(
        chaos.fleet.completed + chaos.dropped,
        chaos.admitted,
        "chaos conservation: completed + dropped must equal admitted"
    );
    let fs = chaos.faults.as_ref().expect("faulted run must carry a summary");
    assert_eq!(fs.device_failures, 2, "both scripted outages must fire");
    assert!(fs.retries > 0, "a two-device outage must force retries");
    assert!(
        chaos.goodput_fraction() >= 0.95,
        "retry+failover goodput {:.3} below the graceful-degradation bar",
        chaos.goodput_fraction()
    );
    assert_eq!(chaos, simulate_fleet(&chaos_cfg), "chaos rerun must be bit-identical");
    println!(
        "chaos: outage 2/3 devices for {:?} -> goodput {:.1}% retries {} failovers {} dropped {}\n",
        svc_l * 12,
        100.0 * chaos.goodput_fraction(),
        fs.retries,
        fs.failovers,
        chaos.dropped
    );

    // ---- expert sharding --------------------------------------------
    // Failover smoke on the pinned design: the hottest expert's home
    // device dies for the middle third of the run. With one replica
    // its traffic has nowhere to go; with the hot expert replicated
    // the second copy carries it through the outage.
    let shards = shard_study(&u, Duration::from_secs(30), 7);
    println!("{}", shard_table(&shards).render());
    let rf1 = shards.row("rf=1 outage");
    let rf2 = shards.row("rf=2 outage");
    assert!(rf1.no_replica_drops > 0, "RF=1 outage must drop hot-expert traffic");
    assert!(
        rf1.goodput < 0.95,
        "RF=1 goodput {:.3} unexpectedly survived the hot-expert outage",
        rf1.goodput
    );
    assert!(
        rf2.goodput >= 0.95,
        "RF=2 failover goodput {:.3} below the graceful-degradation bar",
        rf2.goodput
    );
    let shards_b = shard_study(&u, Duration::from_secs(30), 7);
    for (x, y) in shards.rows.iter().zip(&shards_b.rows) {
        assert_eq!(x.offered, y.offered, "{}: shard study rerun diverged", x.label);
        assert_eq!(x.dropped, y.dropped, "{}: shard study rerun diverged", x.label);
    }
    println!(
        "sharding: RF=1 goodput {:.1}% ({} no-replica drops) vs RF=2 {:.1}% through the outage\n",
        100.0 * rf1.goodput,
        rf1.no_replica_drops,
        100.0 * rf2.goodput
    );

    // ---- DES cost ---------------------------------------------------
    let cfg = mk();
    let m = bench_quick("simulate_fleet (U280 x4, 0.8 peak, 8s)", || {
        black_box(simulate_fleet(&cfg).fleet.completed);
    });
    println!(
        "  ≈ {:.0} simulated requests/s of DES wall time",
        a.admitted as f64 / m.median.as_secs_f64()
    );
    println!("serve_smoke OK");
}
