//! Regenerates Fig. 4: K/V off-chip traffic and bandwidth pressure,
//! naive single-q dataflow (Fig. 4a) vs patch-reordered Q-stationary
//! dataflow (Fig. 4b), across N_a and model sizes.
//!
//! `cargo bench --bench fig4_reorder`

use ubimoe::models::{bert_b, m3vit_small, vit_t};
use ubimoe::report::figures::fig4_reorder;
use ubimoe::sim::attention::{
    kv_streams, naive_kv_traffic_bytes, reordered_kv_traffic_bytes, score_buffer_elems,
};

fn main() {
    for model in [vit_t(), m3vit_small(), bert_b()] {
        println!("model: {} (N={}, F={})", model.name, model.patches, model.dim);
        println!("{}", fig4_reorder(&model, 32).render());
    }

    // Bandwidth pressure (the other half of the Fig. 4 argument): the
    // naive dataflow needs one K stream per PE; reordering broadcasts.
    println!("K-broadcast streams needed (N_a PEs):");
    for n_a in [2usize, 8, 32] {
        println!(
            "  N_a={n_a:<3} naive: {:>3} streams   reordered: {} stream",
            kv_streams(n_a, false),
            kv_streams(n_a, true)
        );
    }

    // Fused-softmax score storage (the §III-B companion claim).
    let m = m3vit_small();
    println!(
        "\nscore storage per PE group (N={}): non-fused {} elems, fused {} elems",
        m.patches,
        score_buffer_elems(m.patches, 8, false),
        score_buffer_elems(m.patches, 8, true)
    );

    // Shape assertion: reduction ≈ N_a on divisible sizes.
    let naive = naive_kv_traffic_bytes(192, 384, 32);
    let reord = reordered_kv_traffic_bytes(192, 384, 32, 8);
    assert!(naive > 6 * reord, "patch reorder must cut K/V traffic ~N_a x");
    println!("\nfig4 OK");
}
