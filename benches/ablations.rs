//! Ablation benches for the design choices DESIGN.md calls out:
//!  A. double buffering on/off (Fig. 3's contribution);
//!  B. round-robin router vs static partitioning under gate skew;
//!  C. fused streaming softmax vs multi-pass (Edge-MoE style) attention;
//!  D. skip-idle-experts (future-work extension: §II's uncertain expert
//!     counts make some experts idle — skipping their weight loads).
//!
//! `cargo bench --bench ablations`

use ubimoe::models::m3vit_small;
use ubimoe::report::deploy;
use ubimoe::resources::{LinearParams, Platform};
use ubimoe::sim::engine::{simulate, simulate_sequential, SimConfig};
use ubimoe::sim::linear::{compute_cycles, static_partition_cycles, LinearTask};
use ubimoe::sim::memory::MemorySystem;
use ubimoe::sim::moe::{moe_block_cycles, GateHistogram};
use ubimoe::util::rng::Rng;
use ubimoe::util::table::Table;

fn main() {
    let model = m3vit_small();

    // ---------------- A: double buffering
    println!("== A. double buffering (Fig. 3) ==");
    for plat in [Platform::zcu102(), Platform::u280()] {
        let d = deploy(&model, &plat, 16, 32);
        let sc = SimConfig::new(model.clone(), d.platform.clone(), d.has.hw);
        let on = simulate(&sc);
        let off = simulate_sequential(&sc);
        println!(
            "  {:<11} on: {:>8.2} ms   off: {:>8.2} ms   speedup {:.2}x  (overlap {:.0}%)",
            plat.name,
            on.latency_ms,
            off.latency_ms,
            off.latency_ms / on.latency_ms,
            on.overlap_fraction * 100.0
        );
        assert!(on.latency_ms < off.latency_ms);
    }

    // ---------------- B: router vs static partitioning
    println!("\n== B. round-robin router vs static partitioning (III-C) ==");
    let p = LinearParams { t_in: 16, t_out: 16, n_l: 4 };
    let mut rng = Rng::new(99);
    let mut t = Table::new(
        "per-expert latency under skew (cycles, 394 tokens over 4 CUs)",
        &["skew", "router", "static", "static/router"],
    );
    for (label, conc) in [("balanced", 1.0f64), ("mild", 2.0), ("heavy", 6.0)] {
        // Draw a random static split with increasing concentration.
        let tokens = 394usize;
        let mut split = vec![0usize; 4];
        for _ in 0..tokens {
            let i = if rng.f64() < (conc - 1.0) / conc { 0 } else { rng.below(4) };
            split[i] += 1;
        }
        let task = LinearTask { tokens, f_in: 384, f_out: 1536, weight_bytes: 0 };
        let routed = compute_cycles(&task, &p);
        let fixed = static_partition_cycles(&split, 384, 1536, &p);
        t.row(&[
            label.into(),
            format!("{routed:.0}"),
            format!("{fixed:.0}"),
            format!("{:.2}x", fixed / routed),
        ]);
        assert!(fixed >= routed - 1e-9);
    }
    println!("{}", t.render());

    // ---------------- C: fused vs multi-pass attention
    println!("== C. fused streaming softmax vs multi-pass attention ==");
    {
        use ubimoe::baselines::edge_moe::simulate_edge_moe;
        use ubimoe::baselines::gpu::simulate_gpu;
        let d = deploy(&model, &Platform::zcu102(), 16, 32);
        let ours = simulate(&SimConfig::new(model.clone(), Platform::zcu102(), d.has.hw));
        let edge = simulate_edge_moe(&model);
        let gpu = simulate_gpu(&model);
        println!(
            "  fused streaming (ours): {:>8.2} ms   multi-pass shared engine (Edge-MoE): {:>8.2} ms   GPU: {:>8.2} ms",
            ours.latency_ms, edge.latency_ms, gpu.latency_ms
        );
        assert!(ours.latency_ms < edge.latency_ms);
    }

    // ---------------- D: skip idle experts
    println!("\n== D. skip-idle-experts extension ==");
    let mem = MemorySystem::new(1, 19.2, 300.0);
    let p2 = LinearParams { t_in: 16, t_out: 16, n_l: 4 };
    for (label, alpha) in [("balanced", 0.0), ("zipf 1.2", 1.2), ("zipf 2.5", 2.5)] {
        let hist = if alpha == 0.0 {
            GateHistogram::balanced(&model)
        } else {
            GateHistogram::skewed(&model, alpha, 7)
        };
        let with_idle = moe_block_cycles(&model, &hist, &p2, &mem, 0.75);
        // Skipping: drop zero-token experts from the stream entirely.
        let skipped = GateHistogram {
            tokens_per_expert: hist
                .tokens_per_expert
                .iter()
                .copied()
                .filter(|&t| t > 0)
                .collect(),
        };
        let mut m2 = model.clone();
        m2.num_experts = skipped.tokens_per_expert.len();
        let without_idle = moe_block_cycles(&m2, &skipped, &p2, &mem, 0.75);
        println!(
            "  {label:<10} all-experts: {with_idle:>10.0} cyc   skip-idle: {without_idle:>10.0} cyc   saved {:.1}%",
            100.0 * (1.0 - without_idle / with_idle)
        );
        assert!(without_idle <= with_idle + 1.0);
    }
    // ---------------- E: expert-weight cache (larger-models extension)
    println!("\n== E. expert-weight cache (III-C off-chip pressure extension) ==");
    {
        use ubimoe::sim::cache::{streamed_bytes_with_cache, ExpertCache, Policy};
        let tiny = ubimoe::models::m3vit_tiny();
        let full = (tiny.num_experts * 2 * tiny.dim * tiny.expert_dim()) as u64 * 2;
        for slots in [0usize, 2, 4, 8] {
            let mut cache = ExpertCache::new(slots, Policy::Lru);
            // Warm pass + 7 steady passes (consecutive MoE blocks/frames).
            let mut total = 0u64;
            for _ in 0..8 {
                total += streamed_bytes_with_cache(&tiny, &mut cache, 16);
            }
            println!(
                "  slots={slots}: streamed {:>6.1} MB over 8 blocks ({:>5.1}% of uncached), \
                 hit rate {:>5.1}%, BRAM18 cost {:>5.0}",
                total as f64 / 1e6,
                100.0 * total as f64 / (8 * full) as f64,
                100.0 * cache.hit_rate(),
                cache.bram18_cost(&tiny, 16)
            );
        }
        // m3vit-small experts are ~4.7 MB each — the model quantifies
        // why the paper streams rather than caches at ViT-S scale.
        let small = ubimoe::models::m3vit_small();
        let c = ExpertCache::new(1, Policy::Lru);
        println!(
            "  (m3vit-small: ONE expert costs {:.0} BRAM18 — more than the whole ZCU102; \
             caching only pays at tiny scale or with INT8 experts)",
            c.bram18_cost(&small, 16)
        );
    }

    println!("\nablations OK");
}
