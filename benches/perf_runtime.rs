//! Runtime hot-path benches: per-block PJRT execution latency and the
//! coordinator pipeline throughput on the real m3vit-tiny artifacts.
//! The §Perf pass targets: coordination overhead < 5% of block compute;
//! device-resident weights (no per-call weight upload).
//!
//! `make artifacts && cargo bench --bench perf_runtime`

use ubimoe::coordinator::{run_pipeline, run_sequential, Blk2Stage, MsaStage};
use ubimoe::runtime::model::{RuntimeModel, BLK2_KINDS, MSA_KINDS};
use ubimoe::runtime::tensor::Tensor;
use ubimoe::runtime::{artifacts_available, artifacts_dir};
use ubimoe::util::bench::{bench, black_box};

const CFG: &str = "m3vit-tiny";

fn main() {
    if !artifacts_available() {
        eprintln!("SKIP perf_runtime: no artifacts (run `make artifacts`)");
        return;
    }
    let dir = artifacts_dir();
    let rt = RuntimeModel::load(&dir, CFG).expect("load artifacts");
    let x1 = Tensor::random(vec![1, rt.cfg.patches, rt.cfg.dim], 0.5, 1);
    let x4 = Tensor::random(vec![4, rt.cfg.patches, rt.cfg.dim], 0.5, 2);
    let img = Tensor::random(vec![1, 3, 64, 64], 0.5, 3);

    // Per-block execution latency (device-resident weights).
    let m_msa = bench("msa_block b1", || {
        black_box(rt.msa(0, &x1).unwrap());
    });
    bench("msa_block b4", || {
        black_box(rt.msa(0, &x4).unwrap());
    });
    let m_moe = bench("moe_block b1", || {
        black_box(rt.ffn_or_moe(1, &x1).unwrap());
    });
    bench("dense_ffn b1", || {
        black_box(rt.ffn_or_moe(0, &x1).unwrap());
    });
    bench("gate_probe b1", || {
        black_box(rt.gate(1, &x1).unwrap());
    });
    bench("patch_embed b1", || {
        black_box(rt.embed(&img).unwrap());
    });

    // Literal (host round-trip) path, to quantify what device-resident
    // weights buy.
    let m_lit = bench("msa_block b1 via literals", || {
        black_box(rt.msa_via_literals(0, &x1).unwrap());
    });
    println!(
        "\ndevice-resident weights speedup on MSA: {:.2}x",
        m_lit.median.as_secs_f64() / m_msa.median.as_secs_f64()
    );

    // Whole-inference paths.
    let m_fwd = bench("forward (sequential blocks)", || {
        black_box(rt.forward(&img).unwrap());
    });

    // Pipeline throughput over 8 in-flight requests.
    let inputs: Vec<Tensor> =
        (0..8).map(|i| rt.embed(&Tensor::random(vec![1, 3, 64, 64], 0.5, 50 + i)).unwrap()).collect();
    let (dir_a, dir_b) = (dir.clone(), dir.clone());
    let t0 = std::time::Instant::now();
    let (_, report) = run_pipeline(
        rt.cfg.depth,
        inputs.clone(),
        move || Ok(MsaStage(RuntimeModel::load_subset(&dir_a, CFG, MSA_KINDS)?)),
        move || Ok(Blk2Stage(RuntimeModel::load_subset(&dir_b, CFG, BLK2_KINDS)?)),
    )
    .unwrap();
    let pipe_total = t0.elapsed();
    let msa = MsaStage(RuntimeModel::load_subset(&dir, CFG, MSA_KINDS).unwrap());
    let blk2 = Blk2Stage(RuntimeModel::load_subset(&dir, CFG, BLK2_KINDS).unwrap());
    let (_, seq_wall) = run_sequential(rt.cfg.depth, inputs, &msa, &blk2).unwrap();

    println!(
        "\npipeline: 8 req in {:?} compute window (total {:?} incl. per-thread \
         PJRT compilation; total wall {pipe_total:?}); engine busy {:?}",
        report.wall,
        report.total_with_setup,
        report.msa_busy + report.blk2_busy
    );
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "sequential: {seq_wall:?} → pipeline/sequential {:.2}x on a {cores}-core host",
        seq_wall.as_secs_f64() / report.wall.as_secs_f64(),
    );
    if cores < 2 {
        println!(
            "NOTE: single-core host — two engines timeslice one CPU, so the \n\
             double-buffer pipeline cannot show wallclock speedup here; its \n\
             FPGA-level benefit is measured by the simulator (ablations bench \n\
             A: 1.6–1.7x). This bench still validates scheduling + numerics."
        );
    } else {
        // On multicore, coordination overhead must stay small.
        let busy = report.msa_busy.max(report.blk2_busy);
        let overhead = report.wall.saturating_sub(busy);
        println!(
            "coordination overhead: {:?} ({:.1}% of wall; target < 10%)",
            overhead,
            100.0 * overhead.as_secs_f64() / report.wall.as_secs_f64()
        );
    }

    // Sanity: block times should roughly compose into forward time.
    let per_layer = m_msa.median.as_secs_f64() + m_moe.median.as_secs_f64();
    println!(
        "\nper-layer (msa+moe) ≈ {:.2} ms; forward/depth = {:.2} ms",
        per_layer * 1e3,
        m_fwd.median.as_secs_f64() * 1e3 / rt.cfg.depth as f64
    );
    println!("perf_runtime OK");
}
