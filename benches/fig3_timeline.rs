//! Regenerates Fig. 3b: the double-buffered timeline of the first
//! MoE-ViT layers, with the sequential counterfactual, on both
//! platforms.
//!
//! `cargo bench --bench fig3_timeline`

use ubimoe::report::figures::fig3_timeline;
use ubimoe::resources::Platform;

fn main() {
    for plat in [Platform::zcu102(), Platform::u280()] {
        let (overlapped, sequential, speedup) = fig3_timeline(&plat);
        println!("== Fig. 3b on {} ==\n", plat.name);
        println!("double-buffered (MSA of stream B under MoE of stream A):\n");
        println!("{}", overlapped.render(100));
        println!("sequential (no double buffering):\n");
        println!("{}", sequential.render(100));
        println!("speedup from double buffering: {speedup:.3}x");
        println!(
            "MSA/MoE overlap: {:.1} kcycles, MSA/FFN overlap: {:.1} kcycles\n",
            overlapped.overlap("MSA", "MoE"),
            overlapped.overlap("MSA", "FFN"),
        );
        assert!(speedup > 1.0, "double buffering must help on {}", plat.name);
        assert!(overlapped.overlap("MSA", "MoE") > 0.0, "Fig. 3b overlap missing");
        // CSV series for external plotting.
        let csv = overlapped.to_csv();
        println!("(csv: {} spans)", csv.lines().count() - 1);
    }
    println!("fig3 OK");
}
