//! DES scale smoke: the acceptance run for the streaming-metrics /
//! indexed-dispatch / lean-event-queue rebuild of the fleet DES.
//!
//! Drives a **60 s-horizon, 16-device, ≥1M-request** sweep through
//! `simulate_fleet`, asserts conservation and the bounded-heap
//! contract, reports **events/s** and **requests/s** of DES wall time
//! (the EXPERIMENTS.md §DES-throughput figures), and writes one
//! machine-readable row to `BENCH_serve.json` so CI populates the
//! perf trajectory. Also times the parallel vs sequential
//! `fleet_curve` sweep.
//!
//! Uses a synthetic (fill, period) device — the point is DES hot-path
//! cost, not the cycle model (that is `serve_smoke`'s job).
//!
//! `cargo bench --bench serve_scale`

use std::time::{Duration, Instant};

use ubimoe::obs::json::JsonObj;
use ubimoe::report::serving::{fleet_curve, fleet_curve_seq};
use ubimoe::serve::device::DeviceModel;
use ubimoe::serve::dispatch::DispatchPolicy;
use ubimoe::serve::{simulate_fleet, ServeConfig, Workload};
use ubimoe::util::bench::black_box;

const DEVICES: usize = 16;
const HORIZON_S: u64 = 60;

fn scale_device() -> DeviceModel {
    // fill 2 ms, period 0.5 ms, up to batch 16:
    // service(16) = 10 ms → peak 1600 req/s/device, 25.6k req/s fleet.
    DeviceModel::from_latencies(
        "scale-syn".into(),
        Duration::from_millis(2),
        Duration::from_micros(500),
        &[1, 2, 4, 8, 16],
    )
}

fn main() {
    let dev = scale_device();
    let fleet_peak = dev.peak_rps() * DEVICES as f64;
    // 0.7 × fleet peak over 60 s ≈ 1.07M Poisson arrivals.
    let rate = 0.7 * fleet_peak;
    let mut cfg = ServeConfig::uniform(dev.clone(), DEVICES, Workload::Poisson { rate_rps: rate });
    cfg.horizon = Duration::from_secs(HORIZON_S);

    println!(
        "serve_scale: {DEVICES} devices, {HORIZON_S} s horizon, offered {:.0} req/s \
         (0.70 x fleet peak {:.0} req/s)",
        rate, fleet_peak
    );
    let t0 = Instant::now();
    let r = black_box(simulate_fleet(&cfg));
    let wall = t0.elapsed();

    // ---- acceptance invariants -------------------------------------
    assert!(r.admitted >= 1_000_000, "need >=1M requests, admitted {}", r.admitted);
    assert_eq!(r.fleet.completed, r.admitted, "conservation");
    assert!(
        r.peak_events <= 8 * DEVICES as u64 + 16,
        "event heap must stay O(devices): peak {} for {} admitted",
        r.peak_events,
        r.admitted
    );
    // Budget backstop: the target is single-digit seconds (see the
    // printed wall time); 20 s catches a complexity regression while
    // tolerating slow CI runners.
    assert!(wall < Duration::from_secs(20), "DES wall {wall:?} blew the scale budget");

    let events_per_s = r.events as f64 / wall.as_secs_f64();
    let requests_per_s = r.admitted as f64 / wall.as_secs_f64();
    println!("  admitted       : {}", r.admitted);
    println!("  events         : {}", r.events);
    println!("  peak heap len  : {} entries (flat in request count)", r.peak_events);
    println!("  DES wall       : {wall:?}");
    println!("  events/s       : {events_per_s:.0}");
    println!("  sim requests/s : {requests_per_s:.0}");
    println!("  fleet          : {}", r.summary());

    // ---- parallel sweep: fleet_curve par vs seq --------------------
    let utils = [0.5, 0.7, 0.9, 1.1];
    let horizon = Duration::from_secs(8);
    let t_seq = Instant::now();
    let seq = fleet_curve_seq(
        &dev, DEVICES, DispatchPolicy::JoinShortestQueue, 16, &utils, horizon, 7,
    );
    let t_seq = t_seq.elapsed();
    let t_par = Instant::now();
    let par = fleet_curve(
        &dev, DEVICES, DispatchPolicy::JoinShortestQueue, 16, &utils, horizon, 7,
    );
    let t_par = t_par.elapsed();
    assert_eq!(par, seq, "parallel sweep must match sequential bit-for-bit");
    println!(
        "  fleet_curve ({} pts): sequential {t_seq:?}, parallel {t_par:?} ({:.2}x)",
        utils.len(),
        t_seq.as_secs_f64() / t_par.as_secs_f64().max(1e-9)
    );

    // ---- perf-trajectory row (shared JSON writer: obs::json) -------
    let mut o = JsonObj::new();
    o.str("bench", "serve_scale")
        .u64("devices", DEVICES as u64)
        .u64("horizon_s", HORIZON_S)
        .u64("requests", r.admitted)
        .u64("events", r.events)
        .u64("peak_heap", r.peak_events)
        .f64("wall_s", wall.as_secs_f64(), 3)
        .f64("events_per_s", events_per_s, 0)
        .f64("requests_per_s", requests_per_s, 0)
        .f64("curve_seq_s", t_seq.as_secs_f64(), 3)
        .f64("curve_par_s", t_par.as_secs_f64(), 3);
    let row = o.finish();
    // Anchor at the repo root (CARGO_MANIFEST_DIR), not the cwd: the
    // perf-trajectory tooling and the CI artifact upload both look for
    // the file there regardless of where the bench is launched from.
    let bench_path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_serve.json");
    std::fs::write(bench_path, format!("{row}\n")).expect("write BENCH_serve.json");
    println!("BENCH_serve.json: {row}");
    println!("serve_scale OK");
}
