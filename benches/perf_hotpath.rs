//! L3 hot-path microbenches: the simulator inner loop and the HAS
//! search, which the GA calls ~10^4–10^5 times per deployment. Used by
//! the §Perf pass in EXPERIMENTS.md (before/after numbers).
//!
//! `cargo bench --bench perf_hotpath`

use ubimoe::has::{search, HasConfig, HasEngine};
use ubimoe::models::m3vit_small;
use ubimoe::resources::{AttnParams, LinearParams, Platform};
use ubimoe::sim::engine::{msa_block_cycles_model, simulate, SimConfig};
use ubimoe::sim::memory::MemorySystem;
use ubimoe::sim::moe::{moe_block_cycles, GateHistogram};
use ubimoe::sim::HwChoice;
use ubimoe::util::bench::{bench, black_box};

fn main() {
    let model = m3vit_small();
    let hw = HwChoice {
        num: 2,
        attn: AttnParams { t_a: 16, n_a: 8 },
        lin: LinearParams { t_in: 16, t_out: 16, n_l: 4 },
        q_bits: 16,
        a_bits: 32,
    };
    let mem = MemorySystem::new(1, 19.2, 300.0);
    let hist = GateHistogram::balanced(&model);

    // The three GA fitness ingredients (uncached path — what the
    // evaluation tables are built from).
    let m1 = bench("msa_block_cycles_model", || {
        black_box(msa_block_cycles_model(&model, &hw, &mem, 0.15));
    });
    let m2 = bench("moe_block_cycles (E=16)", || {
        black_box(moe_block_cycles(&model, &hist, &hw.lin, &mem, 0.75));
    });
    let m3 = bench("hw.resources (Eq. 2-3)", || {
        black_box(hw.resources(model.heads, model.patches, model.dim));
    });

    // Whole-model event simulation (per table cell).
    let sc = SimConfig::new(model.clone(), Platform::zcu102(), hw);
    let m4 = bench("simulate (full event sim)", || {
        black_box(simulate(&sc).total_cycles);
    });

    // Full HAS (per deployment — the expensive report-layer call).
    let mut cfg = HasConfig::paper(16, 32);
    cfg.ga.generations = 40;
    let m5 = bench("HAS search (40 gen x 4 num)", || {
        black_box(search(&model, &Platform::zcu102(), &cfg).l_bound);
    });

    // Decomposition of the memoized engine: the one-time table build
    // (288 L_MoE + 252 L_MSA entries) vs a warm-table search — what a
    // report-layer derate/platform sweep pays per additional cell.
    let m6 = bench("HasEngine::new (eval tables)", || {
        black_box(HasEngine::new(&model, &Platform::zcu102(), &cfg));
    });
    let engine = HasEngine::new(&model, &Platform::zcu102(), &cfg);
    let m7 = bench("HasEngine::search (warm tables)", || {
        black_box(engine.search(&Platform::zcu102()).l_bound);
    });

    let r = engine.search(&Platform::zcu102());
    println!(
        "\nGA accounting: {} fitness calls = {} true evals + {} memo hits ({:.1}% cached)",
        r.ga_evaluations,
        r.ga_true_evaluations,
        r.ga_cache_hits,
        100.0 * r.ga_cache_hits as f64 / r.ga_evaluations.max(1) as f64
    );

    println!("\nthroughput view:");
    println!(
        "  GA fitness evals/s (uncached) ≈ {:.0}",
        1.0 / (m1.median + m2.median + m3.median).as_secs_f64()
    );
    println!("  simulate/s        ≈ {:.0}", m4.per_sec(1.0));
    println!("  HAS searches/s    ≈ {:.2}", m5.per_sec(1.0));
    println!("  warm searches/s   ≈ {:.2}", m7.per_sec(1.0));
    println!(
        "  table build ≈ {:.3} ms (amortized across every search on the fabric)",
        m6.median.as_secs_f64() * 1e3
    );

    // Ready-to-paste rows for the EXPERIMENTS.md §Perf table (CI is
    // the machine of record; see §Perf for the analytic expectations).
    println!("\nEXPERIMENTS.md §Perf medians (paste into the table):");
    for m in [&m1, &m2, &m3, &m4, &m5, &m7] {
        println!("| {:<28} | {:>12?} |", m.name, m.median);
    }
    println!("perf_hotpath OK");
}
