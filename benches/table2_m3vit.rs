//! Regenerates Table II (GPU vs Edge-MoE vs UbiMoE on M3ViT) and the
//! paper's headline ratios, asserting the *shape* holds: ordering,
//! who-wins, and rough factors.
//!
//! `cargo bench --bench table2_m3vit`

use ubimoe::report::{headline, tables};
use ubimoe::util::table::Table;

fn main() {
    let (t, points) = tables::table2();
    println!("{}", t.render());

    let mut p = Table::new(
        "Paper Table II (for comparison — 2.5-GOP op-count convention)",
        &["Attribute", "GPU", "Edge-MoE", "UbiMoE ZCU102", "UbiMoE U280"],
    );
    p.row_str(&["Power (W)", "51", "14.54", "11.50", "32.49"]);
    p.row_str(&["Latency (ms)", "40.1", "34.64", "25.76", "10.33"]);
    p.row_str(&["Throughput (GOPS)", "54.86", "72.15", "97.04", "242.01"]);
    p.row_str(&["Efficiency (GOPS/W)", "1.075", "4.83", "8.438", "7.451"]);
    println!("{}", p.render());

    let h = headline::headline(&points);
    println!("{}", headline::headline_table(&h).render());

    // Shape assertions (the reproduction contract).
    let (gpu, edge, ubi_z, ubi_u) = (&points[0], &points[1], &points[2], &points[3]);
    assert!(ubi_u.gops > ubi_z.gops && ubi_z.gops > edge.gops && edge.gops > gpu.gops,
        "throughput ordering broken");
    assert!(ubi_z.gops_per_w() > edge.gops_per_w(), "efficiency vs Edge-MoE broken");
    assert!(ubi_z.gops_per_w() > ubi_u.gops_per_w(), "ZCU102 must lead efficiency");
    assert!(gpu.gops_per_w() < edge.gops_per_w(), "GPU efficiency must trail");
    assert!(h.speedup_zcu102_vs_edge > 1.2 && h.speedup_zcu102_vs_edge < 2.2,
        "ZCU102-vs-Edge speedup {} off-shape (paper 1.34x)", h.speedup_zcu102_vs_edge);
    assert!(h.speedup_u280_vs_edge > 2.0,
        "U280-vs-Edge speedup {} off-shape (paper 3.35x)", h.speedup_u280_vs_edge);
    assert!(h.eff_zcu102_vs_gpu > 5.0,
        "ZCU102-vs-GPU efficiency {} off-shape (paper 7.85x)", h.eff_zcu102_vs_gpu);
    println!("table2 OK — ordering and factors in class");
}
