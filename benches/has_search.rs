//! Algorithm 1 study: HAS convergence, block balance across DSP
//! budgets, and search cost — the DSE contribution of the paper.
//!
//! The derate sweep shares one set of memoized evaluation tables
//! (budget-independent) and runs its searches on scoped threads; the
//! cold-vs-warm rows make the cache's payoff visible in the perf
//! trajectory.
//!
//! `cargo bench --bench has_search`

use std::time::Instant;
use ubimoe::has::{search, HasConfig, HasEngine, HasResult, HasStage};
use ubimoe::models::m3vit_small;
use ubimoe::resources::Platform;
use ubimoe::util::table::Table;

fn main() {
    let model = m3vit_small();
    let cfg = HasConfig::paper(16, 32);

    // Cold: build the evaluation tables AND search.
    let t_cold = Instant::now();
    let engine = HasEngine::new(&model, &Platform::zcu102(), &cfg);
    let r_cold = engine.search(&Platform::zcu102());
    let cold = t_cold.elapsed();
    assert!(r_cold.l_bound.is_finite() && r_cold.l_bound > 0.0);

    // Warm: memoized re-search at a perturbed derate (the tables only
    // depend on the memory fabric, not the budget).
    let mut perturbed = Platform::zcu102();
    perturbed.derate = 0.70;
    let t_warm = Instant::now();
    let r_warm = engine.search(&perturbed);
    let warm = t_warm.elapsed();
    println!(
        "cold search (tables + search): {cold:?}   warm re-search (derate 0.75→0.70): \
         {warm:?}   ({:.2}x)",
        cold.as_secs_f64() / warm.as_secs_f64().max(1e-12)
    );
    // The warm path is a pure optimization: identical result to a
    // fresh search at the same budget.
    let fresh = search(&model, &perturbed, &cfg);
    assert_eq!(r_warm.hw, fresh.hw, "warm search must match a fresh search");
    assert_eq!(r_warm.l_bound, fresh.l_bound);

    // Sweep DSP budgets by scaling the ZCU102 derate: shows how HAS
    // re-balances L_MSA vs L_MoE as resources grow. One engine, four
    // budgets, scoped threads — results land in input order.
    let derates = [0.35, 0.45, 0.55, 0.75];
    let results: Vec<(f64, HasResult)> = std::thread::scope(|s| {
        let engine = &engine;
        let handles: Vec<_> = derates
            .iter()
            .map(|&derate| {
                s.spawn(move || {
                    let mut plat = Platform::zcu102();
                    plat.derate = derate;
                    (derate, engine.search(&plat))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });
    let mut t = Table::new(
        "HAS balance across DSP budgets (m3vit-small, ZCU102 fabric; infeasible budgets report inf)",
        &["DSP budget", "F_c", "stage", "L_MSA kcyc", "L_MoE kcyc", "balance", "DSP used"],
    );
    for (derate, r) in &results {
        let mut plat = Platform::zcu102();
        plat.derate = *derate;
        t.row(&[
            format!("{:.0}", plat.budget().dsp),
            format!("{}", r.hw),
            format!("{:?}", r.stage),
            format!("{:.0}", r.l_msa / 1e3),
            format!("{:.0}", r.l_moe / 1e3),
            format!("{:.2}", r.l_msa / r.l_moe),
            format!("{:.0}", r.resources.dsp),
        ]);
    }
    println!("{}", t.render());

    // Search cost (wall time + evaluations) — HAS must stay cheap
    // enough to run per-deployment. NOTE: ga_evaluations counts the
    // sequential-equivalent fitness calls (the fold stops at the
    // fit ≥ 1 early exit), while the wall time covers the speculative
    // parallel GAs too — so no calls-per-ms ratio is printed; the two
    // numbers answer different questions.
    let t0 = Instant::now();
    let r = search(&model, &Platform::u280(), &cfg);
    let dt = t0.elapsed();
    println!(
        "search cost (U280): {:?} wall; {} sequential-equivalent GA fitness calls \
         ({} true evals, {} memo hits)",
        dt, r.ga_evaluations, r.ga_true_evaluations, r.ga_cache_hits
    );
    println!("chosen: {} → {:?}", r.hw, r.stage);

    // Convergence: fitness must be non-decreasing (elitism) and the
    // final balance near 1 when resources allow.
    for w in r.ga_history.windows(2) {
        assert!(w[1] >= w[0] - 1e-12, "GA fitness regressed");
    }
    let balance = r.l_msa / r.l_moe;
    assert!(
        (0.2..=5.0).contains(&balance),
        "HAS failed to balance the blocks: {balance}"
    );
    if r.stage == HasStage::MsaBoundMinimized {
        assert!(r.l_moe <= r.l_msa * 1.001, "stage-2 must not raise the bound");
    }
    println!("has_search OK");
}
