//! Algorithm 1 study: HAS convergence, block balance across DSP
//! budgets, and search cost — the DSE contribution of the paper.
//!
//! `cargo bench --bench has_search`

use std::time::Instant;
use ubimoe::has::{search, HasConfig, HasStage};
use ubimoe::models::m3vit_small;
use ubimoe::resources::Platform;
use ubimoe::util::table::Table;

fn main() {
    let model = m3vit_small();

    // Sweep DSP budgets by scaling the ZCU102 derate: shows how HAS
    // re-balances L_MSA vs L_MoE as resources grow.
    let mut t = Table::new(
        "HAS balance across DSP budgets (m3vit-small, ZCU102 fabric; infeasible budgets report inf)",
        &["DSP budget", "F_c", "stage", "L_MSA kcyc", "L_MoE kcyc", "balance", "DSP used"],
    );
    for derate in [0.35, 0.45, 0.55, 0.75] {
        let mut plat = Platform::zcu102();
        plat.derate = derate;
        let cfg = HasConfig::paper(16, 32);
        let r = search(&model, &plat, &cfg);
        t.row(&[
            format!("{:.0}", plat.budget().dsp),
            format!("{}", r.hw),
            format!("{:?}", r.stage),
            format!("{:.0}", r.l_msa / 1e3),
            format!("{:.0}", r.l_moe / 1e3),
            format!("{:.2}", r.l_msa / r.l_moe),
            format!("{:.0}", r.resources.dsp),
        ]);
    }
    println!("{}", t.render());

    // Search cost (wall time + evaluations) — HAS must stay cheap
    // enough to run per-deployment.
    let t0 = Instant::now();
    let cfg = HasConfig::paper(16, 32);
    let r = search(&model, &Platform::u280(), &cfg);
    let dt = t0.elapsed();
    println!(
        "search cost (U280): {:?} wall, {} GA evaluations ({:.0} evals/ms)",
        dt,
        r.ga_evaluations,
        r.ga_evaluations as f64 / dt.as_secs_f64() / 1e3
    );
    println!("chosen: {} → {:?}", r.hw, r.stage);

    // Convergence: fitness must be non-decreasing (elitism) and the
    // final balance near 1 when resources allow.
    for w in r.ga_history.windows(2) {
        assert!(w[1] >= w[0] - 1e-12, "GA fitness regressed");
    }
    let balance = r.l_msa / r.l_moe;
    assert!(
        (0.2..=5.0).contains(&balance),
        "HAS failed to balance the blocks: {balance}"
    );
    if r.stage == HasStage::MsaBoundMinimized {
        assert!(r.l_moe <= r.l_msa * 1.001, "stage-2 must not raise the bound");
    }
    println!("has_search OK");
}
