//! Algorithm 1 study: HAS convergence, block balance across DSP
//! budgets, and search cost — the DSE contribution of the paper.
//!
//! The derate sweep shares one set of memoized evaluation tables
//! (budget-independent) and runs its searches on scoped threads. Two
//! cold-vs-warm comparisons make the caching layers' payoff visible in
//! the perf trajectory: the in-process `HasEngine` table reuse, and
//! the persistent on-disk design cache (`has::cache`) whose warm path
//! must perform **zero** GA evaluations and **zero** cycle-sim walks
//! and come in ≥ 10x faster (both asserted). The measured rows are
//! written to `BENCH_has.json` at the repo root for CI to upload.
//!
//! `cargo bench --bench has_search`

use std::time::Instant;
use ubimoe::has::{cache, search, HasConfig, HasEngine, HasResult, HasStage};
use ubimoe::obs::json::JsonObj;
use ubimoe::models::m3vit_small;
use ubimoe::resources::Platform;
use ubimoe::serve::device::DeviceModel;
use ubimoe::util::counters;
use ubimoe::util::table::Table;

fn main() {
    let model = m3vit_small();
    let cfg = HasConfig::paper(16, 32);

    // Cold: build the evaluation tables AND search.
    let t_cold = Instant::now();
    let engine = HasEngine::new(&model, &Platform::zcu102(), &cfg);
    let r_cold = engine.search(&Platform::zcu102());
    let cold = t_cold.elapsed();
    assert!(r_cold.l_bound.is_finite() && r_cold.l_bound > 0.0);

    // Warm: memoized re-search at a perturbed derate (the tables only
    // depend on the memory fabric, not the budget).
    let mut perturbed = Platform::zcu102();
    perturbed.derate = 0.70;
    let t_warm = Instant::now();
    let r_warm = engine.search(&perturbed);
    let warm = t_warm.elapsed();
    println!(
        "cold search (tables + search): {cold:?}   warm re-search (derate 0.75→0.70): \
         {warm:?}   ({:.2}x)",
        cold.as_secs_f64() / warm.as_secs_f64().max(1e-12)
    );
    // The warm path is a pure optimization: identical result to a
    // fresh search at the same budget.
    let fresh = search(&model, &perturbed, &cfg);
    assert_eq!(r_warm.hw, fresh.hw, "warm search must match a fresh search");
    assert_eq!(r_warm.l_bound, fresh.l_bound);

    // Sweep DSP budgets by scaling the ZCU102 derate: shows how HAS
    // re-balances L_MSA vs L_MoE as resources grow. One engine, four
    // budgets, scoped threads — results land in input order.
    let derates = [0.35, 0.45, 0.55, 0.75];
    let results: Vec<(f64, HasResult)> = std::thread::scope(|s| {
        let engine = &engine;
        let handles: Vec<_> = derates
            .iter()
            .map(|&derate| {
                s.spawn(move || {
                    let mut plat = Platform::zcu102();
                    plat.derate = derate;
                    (derate, engine.search(&plat))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });
    let mut t = Table::new(
        "HAS balance across DSP budgets (m3vit-small, ZCU102 fabric; infeasible budgets report inf)",
        &["DSP budget", "F_c", "stage", "L_MSA kcyc", "L_MoE kcyc", "balance", "DSP used"],
    );
    for (derate, r) in &results {
        let mut plat = Platform::zcu102();
        plat.derate = *derate;
        t.row(&[
            format!("{:.0}", plat.budget().dsp),
            format!("{}", r.hw),
            format!("{:?}", r.stage),
            format!("{:.0}", r.l_msa / 1e3),
            format!("{:.0}", r.l_moe / 1e3),
            format!("{:.2}", r.l_msa / r.l_moe),
            format!("{:.0}", r.resources.dsp),
        ]);
    }
    println!("{}", t.render());

    // Search cost (wall time + evaluations) — HAS must stay cheap
    // enough to run per-deployment. NOTE: ga_evaluations counts the
    // sequential-equivalent fitness calls (the fold stops at the
    // fit ≥ 1 early exit), while the wall time covers the speculative
    // parallel GAs too — so no calls-per-ms ratio is printed; the two
    // numbers answer different questions.
    let t0 = Instant::now();
    let r = search(&model, &Platform::u280(), &cfg);
    let dt = t0.elapsed();
    println!(
        "search cost (U280): {:?} wall; {} sequential-equivalent GA fitness calls \
         ({} true evals, {} memo hits)",
        dt, r.ga_evaluations, r.ga_true_evaluations, r.ga_cache_hits
    );
    println!("chosen: {} → {:?}", r.hw, r.stage);

    // Convergence: fitness must be non-decreasing (elitism) and the
    // final balance near 1 when resources allow.
    for w in r.ga_history.windows(2) {
        assert!(w[1] >= w[0] - 1e-12, "GA fitness regressed");
    }
    let balance = r.l_msa / r.l_moe;
    assert!(
        (0.2..=5.0).contains(&balance),
        "HAS failed to balance the blocks: {balance}"
    );
    if r.stage == HasStage::MsaBoundMinimized {
        assert!(r.l_moe <= r.l_msa * 1.001, "stage-2 must not raise the bound");
    }

    // ---- persistent design cache: cold vs warm ---------------------
    // The full production pipeline (`DeviceModel::from_search`: HAS +
    // operating point + latency surface) against an empty then warm
    // on-disk cache. Work counters prove the warm path does zero GA /
    // sim work; the result must be bit-identical.
    let cache_dir = std::env::temp_dir()
        .join(format!("ubimoe-bench-design-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    cache::set_global_dir(Some(cache_dir.clone()));

    let before_cold = counters::snapshot();
    let t0 = Instant::now();
    let cold_dev = DeviceModel::from_search(&model, &Platform::zcu102(), 16, 32, &[1, 2, 4, 8]);
    let cold_wall = t0.elapsed();
    let cold_work = counters::snapshot().delta(&before_cold);
    assert!(
        cold_work.ga_true_evals > 0 && cold_work.sim_walks > 0,
        "cold run must pay for search + simulation: {cold_work:?}"
    );

    let before_warm = counters::snapshot();
    let t0 = Instant::now();
    let warm_dev = DeviceModel::from_search(&model, &Platform::zcu102(), 16, 32, &[1, 2, 4, 8]);
    let warm_wall = t0.elapsed();
    let warm_work = counters::snapshot().delta(&before_warm);
    assert_eq!(warm_dev, cold_dev, "warm-cache device must be bit-identical to cold");
    assert!(
        warm_work.no_search_work(),
        "warm run performed search/sim work: {warm_work:?}"
    );
    assert_eq!(warm_work.cache_hits, 1, "warm run must be served by the artifact");
    let cache_speedup = cold_wall.as_secs_f64() / warm_wall.as_secs_f64().max(1e-12);
    println!(
        "design cache: cold {cold_wall:?} ({} GA evals, {} sim walks, {} table builds) \
         → warm {warm_wall:?} (0 GA evals, 0 sim walks; {cache_speedup:.0}x)",
        cold_work.ga_true_evals, cold_work.sim_walks, cold_work.table_builds
    );
    assert!(
        cache_speedup >= 10.0,
        "warm design cache must be >=10x faster than cold: {cache_speedup:.2}x"
    );

    // Engine-level integration: a HasEngine built for the same
    // (model, platform, cfg) key is served by the artifact from_search
    // just stored — the search itself costs zero GA evaluations. (The
    // engine still pays its in-process table build at construction.)
    let deploy_cfg = HasConfig::deployment(16, 32);
    let engine_cached = HasEngine::new(&model, &Platform::zcu102(), &deploy_cfg);
    let before_engine = counters::snapshot();
    let r_cached = engine_cached.search_cached(&Platform::zcu102());
    let engine_work = counters::snapshot().delta(&before_engine);
    assert_eq!(
        engine_work.ga_true_evals, 0,
        "engine search_cached must hit the shared artifact: {engine_work:?}"
    );
    assert_eq!(engine_work.cache_hits, 1);
    assert!(r_cached.l_bound.is_finite() && r_cached.l_bound > 0.0);
    println!("engine search_cached: artifact hit, 0 GA evals ({})", r_cached.hw);

    cache::set_global_dir(None);
    let _ = std::fs::remove_dir_all(&cache_dir);

    // ---- perf-trajectory row (shared JSON writer: obs::json) -------
    let mut o = JsonObj::new();
    o.str("bench", "has_search")
        .f64("engine_cold_s", cold.as_secs_f64(), 6)
        .f64("engine_warm_s", warm.as_secs_f64(), 6)
        .f64("cache_cold_s", cold_wall.as_secs_f64(), 6)
        .f64("cache_warm_s", warm_wall.as_secs_f64(), 6)
        .f64("cache_speedup", cache_speedup, 1)
        .u64("cold_ga_evals", cold_work.ga_true_evals)
        .u64("cold_sim_walks", cold_work.sim_walks)
        .u64("warm_ga_evals", warm_work.ga_true_evals)
        .u64("warm_sim_walks", warm_work.sim_walks);
    let row = o.finish();
    let bench_path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_has.json");
    std::fs::write(bench_path, format!("{row}\n")).expect("write BENCH_has.json");
    println!("BENCH_has.json: {row}");
    println!("has_search OK");
}
