//! Regenerates Table III (prior transformer accelerators vs UbiMoE-E /
//! UbiMoE-C on plain ViTs, INT16).
//!
//! `cargo bench --bench table3_prior`

use ubimoe::report::tables;
use ubimoe::util::table::Table;

fn main() {
    let (t, points) = tables::table3();
    println!("{}", t.render());

    let mut p = Table::new(
        "Paper Table III (for comparison)",
        &["Attribute", "HeatViT", "UbiMoE-E", "TECS'23", "UbiMoE-C"],
    );
    p.row_str(&["Freq. (MHz)", "300", "300", "300", "250"]);
    p.row_str(&["Power (W)", "10.697", "9.94", "77.168", "31.36"]);
    p.row_str(&["Latency (ms)", "9.15", "8.20", "-", "11.66"]);
    p.row_str(&["Throughput (GOPS)", "220.6", "304.84", "1800", "789.72"]);
    p.row_str(&["Efficiency (GOPS/W)", "20.62", "30.66", "23.32", "25.16"]);
    println!("{}", p.render());

    // Shape assertions: UbiMoE-E beats HeatViT on efficiency (paper:
    // 30.66 vs 20.62); UbiMoE-C beats TECS'23 on efficiency (25.16 vs
    // 23.32); INT16 throughput on U280 well above the W16A32 M3ViT
    // point (paper: 789.72 vs 242.01).
    let (heat, ubi_e, tecs, ubi_c) = (&points[0], &points[1], &points[2], &points[3]);
    assert!(
        ubi_e.gops_per_w() > heat.gops_per_w(),
        "UbiMoE-E {:.2} !> HeatViT {:.2} GOPS/W",
        ubi_e.gops_per_w(),
        heat.gops_per_w()
    );
    assert!(
        ubi_c.gops_per_w() > tecs.gops_per_w(),
        "UbiMoE-C {:.2} !> TECS'23 {:.2} GOPS/W",
        ubi_c.gops_per_w(),
        tecs.gops_per_w()
    );
    let (_, t2) = tables::table2();
    assert!(
        ubi_c.gops > t2[3].gops,
        "INT16 ViT-S U280 must out-throughput W16A32 M3ViT U280"
    );
    println!("table3 OK — efficiency ordering matches the paper");
}
