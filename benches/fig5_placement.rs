//! Regenerates Fig. 5: the implementation floorplan of M3ViT on both
//! platforms (SLR assignment, §III-A placement rules).
//!
//! `cargo bench --bench fig5_placement`

use ubimoe::report::figures::fig5_placement;
use ubimoe::resources::Platform;

fn main() {
    for plat in [Platform::zcu102(), Platform::u280()] {
        let (txt, plan) = fig5_placement(&plat);
        println!("{txt}");
        if plat.slrs == 1 {
            assert_eq!(plan.crossings, 0, "single-die design cannot cross SLRs");
        } else {
            // §III-A: the MoE block sits next to the HBM (SLR0) and
            // crossings stay bounded.
            let moe_on_mem = txt
                .lines()
                .filter(|l| l.contains("[MEM]"))
                .any(|l| l.contains("MoE.cu"));
            assert!(moe_on_mem, "MoE must be placed on the memory SLR");
            assert!(plan.crossings <= plan.slr_of.len(), "crossing count exploded");
        }
    }
    println!("fig5 OK");
}
