//! Fleet-planner bench: cold vs warm `plan_fleet` on a GA-sized
//! synthetic spec against an on-disk design cache.
//!
//! The cold pass pays one memoized DES grid run per distinct feasible
//! genome the GA visits; the warm pass replays the identical search
//! with every fitness served from fleet artifacts — the work counters
//! prove it performs **zero DES event loops** and the frontier is
//! bit-identical. The wall-clock ratio is the number EXPERIMENTS.md
//! §Co-design quotes, and one machine-readable row lands in
//! `BENCH_plan.json` at the repo root for CI to upload.
//!
//! Uses synthetic (fill, period) devices — the point is search + memo
//! cost, not the cycle model (`ubimoe plan` runs the searched demo).
//!
//! `cargo bench --bench plan_bench`

use std::time::{Duration, Instant};

use ubimoe::has::cache::DesignCache;
use ubimoe::has::fleet::{
    plan_fleet, AutoscalePreset, FleetPlanOutcome, FleetSpec, PlanTemplate, PlanVariant,
    Scenario, EXHAUSTIVE_LIMIT,
};
use ubimoe::has::ga::GaParams;
use ubimoe::obs::json::JsonObj;
use ubimoe::report::plan::frontier_table;
use ubimoe::serve::device::DeviceModel;
use ubimoe::serve::dispatch::DispatchPolicy;
use ubimoe::serve::Workload;
use ubimoe::util::counters;

fn ms(x: u64) -> Duration {
    Duration::from_millis(x)
}

fn template(name: &str, fill_ms: u64, period_us: u64, watts: [f64; 2]) -> PlanTemplate {
    let mk = |tier: u64| {
        DeviceModel::from_latencies(
            format!("{name}-w{}", 32 >> tier),
            ms(fill_ms),
            Duration::from_micros(period_us << tier),
            &[1, 2, 4, 8],
        )
    };
    PlanTemplate {
        name: name.into(),
        variants: vec![
            PlanVariant { label: "w32".into(), device: mk(0), watts: watts[0] },
            PlanVariant { label: "w16".into(), device: mk(1), watts: watts[1] },
        ],
        max_count: 3,
    }
}

/// GA-sized spec (space > EXHAUSTIVE_LIMIT) whose fitness is dominated
/// by real DES work: a 2 s Poisson horizon puts thousands of events
/// behind every cold evaluation, so the warm/cold ratio measures the
/// fleet memo, not fixed overheads.
fn bench_spec() -> FleetSpec {
    let probe = template("edge", 1, 500, [9.0, 6.0]);
    let rate = 0.5 * probe.variants[0].device.peak_rps();
    FleetSpec {
        name: "plan-bench".into(),
        templates: vec![probe, template("core", 2, 250, [24.0, 16.0])],
        scenarios: vec![Scenario {
            label: "steady".into(),
            workload: Workload::Poisson { rate_rps: rate },
            horizon: Duration::from_secs(2),
            seed: 17,
        }],
        policies: vec![
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::ShortestExpectedDelay,
        ],
        autoscale_presets: vec![AutoscalePreset {
            label: "as".into(),
            slo_factor: 3,
            rho_target: 0.7,
            target_attainment: 0.95,
            scale_down_patience: 2,
            min_devices: 1,
            max_devices: 4,
        }],
        num_experts: 0,
        ga: GaParams { population: 12, generations: 8, ..GaParams::default() },
        weight_profiles: vec![[1.0, 1.0, 1.0], [1.0, 4.0, 1.0], [4.0, 1.0, 1.0]],
    }
}

fn frontier_bits(out: &FleetPlanOutcome) -> Vec<(Vec<usize>, [u64; 3])> {
    out.frontier
        .iter()
        .map(|p| {
            (
                p.candidate.counts.clone(),
                [
                    p.objectives.device_seconds.to_bits(),
                    p.objectives.p99_ms.to_bits(),
                    p.objectives.energy_j.to_bits(),
                ],
            )
        })
        .collect()
}

fn main() {
    let spec = bench_spec();
    assert!(
        spec.space_size() > EXHAUSTIVE_LIMIT,
        "bench spec must exercise the GA path (space {})",
        spec.space_size()
    );

    let dir = std::env::temp_dir().join(format!("ubimoe-plan-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = DesignCache::at(&dir);

    // ---- cold: every distinct feasible genome pays its DES grid ----
    let before_cold = counters::snapshot();
    let t0 = Instant::now();
    let cold = plan_fleet(&spec, &cache).expect("bench spec is valid");
    let cold_wall = t0.elapsed();
    let cold_work = counters::snapshot().delta(&before_cold);
    assert!(!cold.exhaustive, "bench spec must run the GA, not the odometer");
    assert!(!cold.frontier.is_empty(), "GA search found no feasible plan");
    assert!(
        cold_work.des_runs > 0 && cold_work.des_events > 0,
        "cold plan must pay for DES fitness: {cold_work:?}"
    );

    // ---- warm: identical search, zero DES event loops --------------
    let before_warm = counters::snapshot();
    let t0 = Instant::now();
    let warm = plan_fleet(&spec, &cache).expect("bench spec is valid");
    let warm_wall = t0.elapsed();
    let warm_work = counters::snapshot().delta(&before_warm);
    assert!(
        warm_work.no_des_work(),
        "warm plan performed DES work: {warm_work:?}"
    );
    assert_eq!(
        warm_work.ga_true_evals, 0,
        "warm plan must not re-run the device search: {warm_work:?}"
    );
    assert_eq!(
        frontier_bits(&warm),
        frontier_bits(&cold),
        "warm frontier must be bit-identical to cold"
    );
    assert_eq!(warm.evaluated, cold.evaluated, "memo must not change the search walk");

    let speedup = cold_wall.as_secs_f64() / warm_wall.as_secs_f64().max(1e-12);
    println!("{}", frontier_table(&spec, &cold).render());
    println!(
        "plan: space={} evaluated={} feasible={} frontier={} ga_fitness_calls={}",
        cold.space,
        cold.evaluated,
        cold.feasible,
        cold.frontier.len(),
        cold.ga_evaluations
    );
    println!(
        "fleet memo: cold {cold_wall:?} ({} DES runs, {} events) -> warm {warm_wall:?} \
         (0 DES runs; {speedup:.0}x)",
        cold_work.des_runs, cold_work.des_events
    );
    // Conservative backstop: warm replays a few hundred small artifact
    // reads against seconds of cold DES; anything under 2x means the
    // memo stopped carrying the fitness loop.
    assert!(
        speedup >= 2.0,
        "warm plan must be >=2x faster than cold: {speedup:.2}x"
    );

    // ---- perf-trajectory row (shared JSON writer: obs::json) -------
    let mut o = JsonObj::new();
    o.str("bench", "plan_bench")
        .u64("space", cold.space as u64)
        .u64("evaluated", cold.evaluated as u64)
        .u64("feasible", cold.feasible as u64)
        .u64("frontier", cold.frontier.len() as u64)
        .f64("cold_s", cold_wall.as_secs_f64(), 3)
        .f64("warm_s", warm_wall.as_secs_f64(), 3)
        .f64("speedup", speedup, 1)
        .u64("cold_des_runs", cold_work.des_runs)
        .u64("cold_des_events", cold_work.des_events)
        .u64("warm_des_runs", warm_work.des_runs)
        .u64("warm_des_events", warm_work.des_events);
    let row = o.finish();
    let bench_path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_plan.json");
    std::fs::write(bench_path, format!("{row}\n")).expect("write BENCH_plan.json");
    println!("BENCH_plan.json: {row}");

    let _ = std::fs::remove_dir_all(&dir);
    println!("plan_bench OK");
}
