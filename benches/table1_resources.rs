//! Regenerates Table I (resource consumption of deploying M3ViT on
//! ZCU102 and Alveo U280) and reports paper-vs-measured per cell.
//!
//! `cargo bench --bench table1_resources`

use ubimoe::report::tables;
use ubimoe::util::table::Table;

fn main() {
    let (t, deps) = tables::table1();
    println!("{}", t.render());

    // Paper's Table I for comparison.
    let mut p = Table::new(
        "Paper Table I (for comparison)",
        &["Platform", "DSPs", "BRAMs (36Kb)", "LUTs", "FFs"],
    );
    p.row_str(&["ZCU102", "1850", "458", "123.4K", "142.6K"]);
    p.row_str(&["Alveo U280", "3413", "974", "316.1K", "385.9K"]);
    println!("{}", p.render());

    let paper_dsp = [1850.0, 3413.0];
    for (d, paper) in deps.iter().zip(paper_dsp) {
        let rel = d.has.resources.dsp / paper;
        println!(
            "{}: measured/paper DSP = {:.2} ({} fits budget: {})",
            d.platform.name,
            rel,
            d.has.hw,
            d.has.resources.fits(&d.platform.budget())
        );
        assert!(
            (0.5..=2.0).contains(&rel),
            "DSP count out of class vs the paper"
        );
    }
    println!("table1 OK");
}
