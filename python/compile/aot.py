"""AOT compile path: lower every model block to HLO *text* artifacts.

This is the only place Python touches the system — `make artifacts` runs
it once; the Rust binary is self-contained afterwards. Interchange is
HLO text, NOT `lowered.compile()`/`.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per config (default m3vit-tiny) under artifacts/:
  <cfg>.<block>.b<batch>.hlo.txt   one per (block, batch) variant
  <cfg>.<block>.b<batch>.meta     input/output names+shapes (k=v lines)
  <cfg>.weights.bin               all parameters, raw little-endian f32
  <cfg>.weights.manifest          name:dtype:shape:byte_offset per tensor
  <cfg>.golden.bin / .meta        seeded input batch + reference
                                  activations/logits for the Rust
                                  integration tests
"""

import argparse
import functools
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import MoEViTConfig, get as get_config


# ---------------------------------------------------------------------------
# HLO text emission
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple, see runtime/executable.rs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_and_write(fn, example_args, out_base, name, cfg_name, batch,
                    input_names, output_names):
    """Lower `fn(*example_args)`, write .hlo.txt and .meta."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    hlo_path = f"{out_base}.hlo.txt"
    with open(hlo_path, "w") as f:
        f.write(text)

    def fmt(spec):
        dims = ",".join(str(d) for d in spec.shape)
        return f"{np.dtype(spec.dtype).name}:{dims}"

    out_specs = jax.eval_shape(fn, *example_args)
    flat_out, _ = jax.tree_util.tree_flatten(out_specs)
    assert len(flat_out) == len(output_names), (name, output_names, flat_out)
    lines = [f"name={name}", f"config={cfg_name}", f"batch={batch}"]
    lines += [f"input={n}:{fmt(s)}" for n, s in zip(input_names, example_args)]
    lines += [f"output={n}:{fmt(s)}" for n, s in zip(output_names, flat_out)]
    with open(f"{out_base}.meta", "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"  wrote {hlo_path} ({len(text)} chars)")


# ---------------------------------------------------------------------------
# Weights dump (manifest order == the order Rust feeds executables)
# ---------------------------------------------------------------------------

def flatten_params(params, cfg: MoEViTConfig):
    """Yield (name, array) in a stable, documented order."""
    emb = params["embed"]
    for k in ["w", "b", "cls", "pos"]:
        yield f"embed.{k}", emb[k]
    for i, lp in enumerate(params["layers"]):
        for k in ["ln_g", "ln_b", "w_qkv", "b_qkv", "w_proj", "b_proj"]:
            yield f"layers.{i}.msa.{k}", lp["msa"][k]
        if cfg.is_moe_layer(i):
            for k in ["ln_g", "ln_b", "wg", "w1", "b1", "w2", "b2"]:
                yield f"layers.{i}.moe.{k}", lp["ffn"][k]
        else:
            for k in ["ln_g", "ln_b", "w1", "b1", "w2", "b2"]:
                yield f"layers.{i}.ffn.{k}", lp["ffn"][k]
    for k in ["ln_g", "ln_b", "w", "b"]:
        yield f"head.{k}", params["head"][k]


def write_weights(params, cfg, out_dir):
    bin_path = os.path.join(out_dir, f"{cfg.name}.weights.bin")
    man_path = os.path.join(out_dir, f"{cfg.name}.weights.manifest")
    offset = 0
    lines = []
    with open(bin_path, "wb") as f:
        for name, arr in flatten_params(params, cfg):
            a = np.asarray(arr, dtype=np.float32)
            raw = a.tobytes()  # C order, little-endian on this platform
            dims = ",".join(str(d) for d in a.shape)
            lines.append(f"{name}:float32:{dims}:{offset}")
            f.write(raw)
            offset += len(raw)
    with open(man_path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"  wrote {bin_path} ({offset} bytes, {len(lines)} tensors)")


# ---------------------------------------------------------------------------
# Golden reference (Rust integration tests replay this end-to-end)
# ---------------------------------------------------------------------------

def write_golden(params, cfg, out_dir, batch, seed=1234):
    img = 0.5 * jax.random.normal(
        jax.random.PRNGKey(seed),
        (batch, cfg.in_chans, cfg.img_size, cfg.img_size), jnp.float32)
    embed = jax.vmap(lambda s: M.patch_embed(s, params["embed"], cfg))(img)
    # Per-layer activations let Rust pinpoint which block diverges.
    acts = [embed]
    x = embed
    for i in range(cfg.depth):
        lp = params["layers"][i]
        x = jax.vmap(lambda s: M.msa_block(s, lp["msa"], cfg.heads))(x)
        if cfg.is_moe_layer(i):
            x = jax.vmap(lambda s: M.moe_block(s, lp["ffn"], cfg.top_k))(x)
        else:
            x = jax.vmap(lambda s: M.ffn_block(s, lp["ffn"]))(x)
        acts.append(x)
    logits = jax.vmap(lambda s: M.head(s, params["head"]))(x)

    tensors = [("input", img), ("embed", embed)] + \
              [(f"layer{i}", a) for i, a in enumerate(acts[1:])] + \
              [("logits", logits)]
    bin_path = os.path.join(out_dir, f"{cfg.name}.golden.bin")
    man = []
    offset = 0
    with open(bin_path, "wb") as f:
        for name, arr in tensors:
            a = np.asarray(arr, np.float32)
            dims = ",".join(str(d) for d in a.shape)
            man.append(f"{name}:float32:{dims}:{offset}")
            f.write(a.tobytes())
            offset += a.nbytes
    with open(os.path.join(out_dir, f"{cfg.name}.golden.meta"), "w") as f:
        f.write("\n".join(man) + "\n")
    print(f"  wrote {bin_path} ({offset} bytes)")


# ---------------------------------------------------------------------------
# Per-config emission
# ---------------------------------------------------------------------------

MSA_INPUTS = ["x", "ln_g", "ln_b", "w_qkv", "b_qkv", "w_proj", "b_proj"]
FFN_INPUTS = ["x", "ln_g", "ln_b", "w1", "b1", "w2", "b2"]
MOE_INPUTS = ["x", "ln_g", "ln_b", "wg", "w1", "b1", "w2", "b2"]
GATE_INPUTS = ["x", "ln_g", "ln_b", "wg"]
EMBED_INPUTS = ["img", "w", "b", "cls", "pos"]
HEAD_INPUTS = ["x", "ln_g", "ln_b", "w", "b"]


def spec_of(tree):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def emit_config(cfg: MoEViTConfig, out_dir: str, batches, seed: int,
                full_model: bool):
    print(f"[aot] config={cfg.name} batches={batches}")
    params = M.init_params(cfg, seed)
    write_weights(params, cfg, out_dir)
    write_golden(params, cfg, out_dir, batch=max(batches))

    lp0 = params["layers"][0]
    moe_i = cfg.moe_layers[0] if cfg.moe_layers else None
    moe_p = params["layers"][moe_i]["ffn"] if moe_i is not None else None

    for b in batches:
        x = jax.ShapeDtypeStruct((b, cfg.patches, cfg.dim), jnp.float32)
        img = jax.ShapeDtypeStruct(
            (b, cfg.in_chans, cfg.img_size, cfg.img_size), jnp.float32)
        base = functools.partial(os.path.join, out_dir)

        msa = functools.partial(M.msa_block_batched, heads=cfg.heads)
        margs = [x] + [spec_of(lp0["msa"][k]) for k in MSA_INPUTS[1:]]
        lower_and_write(msa, margs, base(f"{cfg.name}.msa_block.b{b}"),
                        "msa_block", cfg.name, b, MSA_INPUTS, ["y"])

        # Layer 0 is always dense (MoE layers sit at odd indices).
        fargs = [x] + [spec_of(lp0["ffn"][k]) for k in FFN_INPUTS[1:]]
        lower_and_write(M.ffn_block_batched, fargs,
                        base(f"{cfg.name}.dense_ffn.b{b}"),
                        "dense_ffn", cfg.name, b, FFN_INPUTS, ["y"])

        if moe_p is not None:
            moe = functools.partial(M.moe_block_batched, top_k=cfg.top_k)
            moargs = [x] + [spec_of(moe_p[k]) for k in MOE_INPUTS[1:]]
            lower_and_write(moe, moargs, base(f"{cfg.name}.moe_block.b{b}"),
                            "moe_block", cfg.name, b, MOE_INPUTS, ["y"])

            gp = functools.partial(M.gate_probe_batched, top_k=cfg.top_k)
            gargs = [x] + [spec_of(moe_p[k]) for k in GATE_INPUTS[1:]]
            lower_and_write(gp, gargs, base(f"{cfg.name}.gate_probe.b{b}"),
                            "gate_probe", cfg.name, b, GATE_INPUTS,
                            ["gate_w", "gate_i"])

        pe = functools.partial(M.patch_embed_batched, cfg=cfg)
        eargs = [img] + [spec_of(params["embed"][k]) for k in EMBED_INPUTS[1:]]
        lower_and_write(pe, eargs, base(f"{cfg.name}.patch_embed.b{b}"),
                        "patch_embed", cfg.name, b, EMBED_INPUTS, ["tokens"])

        hargs = [x] + [spec_of(params["head"][k]) for k in HEAD_INPUTS[1:]]
        lower_and_write(M.head_batched, hargs, base(f"{cfg.name}.head.b{b}"),
                        "head", cfg.name, b, HEAD_INPUTS, ["logits"])

        if full_model:
            # Monolithic variant (ablation vs the block-pipelined
            # coordinator): whole forward in one executable, weights as
            # one flat arg list in manifest order.
            names = [n for n, _ in flatten_params(params, cfg)]
            specs = [spec_of(a) for _, a in flatten_params(params, cfg)]

            def full(img_, *flat):
                tree = dict(zip(names, flat))
                p = rebuild_params(tree, cfg)
                return jax.vmap(lambda s: M.forward(s, p, cfg))(img_)

            lower_and_write(full, [img] + specs,
                            base(f"{cfg.name}.full_model.b{b}"),
                            "full_model", cfg.name, b,
                            ["img"] + names, ["logits"])


def rebuild_params(tree, cfg: MoEViTConfig):
    """Inverse of flatten_params (used by the full_model artifact)."""
    p = {"embed": {}, "head": {}, "layers": []}
    for k in ["w", "b", "cls", "pos"]:
        p["embed"][k] = tree[f"embed.{k}"]
    for i in range(cfg.depth):
        msa = {k: tree[f"layers.{i}.msa.{k}"]
               for k in ["ln_g", "ln_b", "w_qkv", "b_qkv", "w_proj", "b_proj"]}
        if cfg.is_moe_layer(i):
            ffn = {k: tree[f"layers.{i}.moe.{k}"]
                   for k in ["ln_g", "ln_b", "wg", "w1", "b1", "w2", "b2"]}
        else:
            ffn = {k: tree[f"layers.{i}.ffn.{k}"]
                   for k in ["ln_g", "ln_b", "w1", "b1", "w2", "b2"]}
        p["layers"].append({"msa": msa, "ffn": ffn})
    for k in ["ln_g", "ln_b", "w", "b"]:
        p["head"][k] = tree[f"head.{k}"]
    return p


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=None,
                    help="artifacts directory (default <repo>/artifacts)")
    ap.add_argument("--out", default=None,
                    help="compat alias: a path inside the artifacts dir")
    ap.add_argument("--config", action="append", default=None,
                    help="config name(s); default m3vit-tiny")
    ap.add_argument("--batch", type=int, action="append", default=None,
                    help="batch size(s); default 1 and 4")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-full-model", action="store_true")
    args = ap.parse_args(argv)

    out_dir = args.out_dir
    if out_dir is None and args.out is not None:
        out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    if out_dir is None:
        out_dir = os.path.join(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))), "artifacts")
    os.makedirs(out_dir, exist_ok=True)

    cfgs = args.config or ["m3vit-tiny"]
    batches = args.batch or [1, 4]
    for name in cfgs:
        emit_config(get_config(name), out_dir, batches, args.seed,
                    full_model=not args.no_full_model)
    # Stamp file: Makefile freshness target.
    with open(os.path.join(out_dir, "STAMP"), "w") as f:
        f.write("ok\n")
    print("[aot] done")


if __name__ == "__main__":
    main()
