"""L2: MoE-ViT (M3ViT-style) forward pass in JAX, calling the L1 kernels.

The model is decomposed into the same *blocks* the accelerator is
(Fig. 2): patch embedding, MSA block, dense-FFN block, MoE block,
classifier head. aot.py lowers each block to its own HLO artifact so the
Rust coordinator can double-buffer MSA and MoE exactly as Fig. 3
describes — MSA of layer i+1 overlapping MoE of layer i, buffers
swapped at the barrier.

All parameters are runtime inputs (never baked constants): aot.py dumps
them to artifacts/<cfg>.weights.bin and the Rust runtime feeds them back
as PJRT literals, which keeps HLO text small and makes the Rust binary a
real model-loading runtime.

Every linear in the model goes through the reusable pallas kernel and
attention through the streaming pallas kernel — the "hybrid computation
pattern" of the title: latency-optimized streaming attention + resource-
efficient reusable linear, composed per block.
"""

import jax
import jax.numpy as jnp

from .configs import MoEViTConfig
from .kernels import expert_linear as kl
from .kernels import streaming_attention as ka


# ---------------------------------------------------------------------------
# Parameter construction (deterministic, seeded — see DESIGN.md 9: shapes
# are what matter for the accelerator study; values only need to be real
# numbers that numerics can be validated on).
# ---------------------------------------------------------------------------

def _init(key, shape, scale=0.02):
    return scale * jax.random.normal(key, shape, jnp.float32)


def init_params(cfg: MoEViTConfig, seed: int = 0):
    """Build the full parameter pytree. Layout (dicts with sorted, stable
    key order) is mirrored by aot.py's weight manifest and the Rust
    runtime's loader — change all three together."""
    key = jax.random.PRNGKey(seed)
    f, e, dh = cfg.dim, cfg.num_experts, cfg.expert_dim
    n_patch = (cfg.img_size // cfg.patch_size) ** 2
    patch_in = cfg.in_chans * cfg.patch_size ** 2
    keys = iter(jax.random.split(key, 16 + 32 * cfg.depth))

    params = {
        "embed": {
            "w": _init(next(keys), (patch_in, f)),
            "b": jnp.zeros((f,), jnp.float32),
            "cls": _init(next(keys), (1, f)),
            "pos": _init(next(keys), (n_patch + 1, f)),
        },
        "head": {
            "ln_g": jnp.ones((f,), jnp.float32),
            "ln_b": jnp.zeros((f,), jnp.float32),
            "w": _init(next(keys), (f, cfg.num_classes)),
            "b": jnp.zeros((cfg.num_classes,), jnp.float32),
        },
        "layers": [],
    }
    for i in range(cfg.depth):
        msa = {
            "ln_g": jnp.ones((f,), jnp.float32),
            "ln_b": jnp.zeros((f,), jnp.float32),
            "w_qkv": _init(next(keys), (f, 3 * f)),
            "b_qkv": jnp.zeros((3 * f,), jnp.float32),
            "w_proj": _init(next(keys), (f, f)),
            "b_proj": jnp.zeros((f,), jnp.float32),
        }
        if cfg.is_moe_layer(i):
            ffn = {
                "ln_g": jnp.ones((f,), jnp.float32),
                "ln_b": jnp.zeros((f,), jnp.float32),
                "wg": _init(next(keys), (f, e)),
                "w1": _init(next(keys), (e, f, dh)),
                "b1": jnp.zeros((e, dh), jnp.float32),
                "w2": _init(next(keys), (e, dh, f)),
                "b2": jnp.zeros((e, f), jnp.float32),
            }
        else:
            hid = cfg.mlp_ratio * f
            ffn = {
                "ln_g": jnp.ones((f,), jnp.float32),
                "ln_b": jnp.zeros((f,), jnp.float32),
                "w1": _init(next(keys), (f, hid)),
                "b1": jnp.zeros((hid,), jnp.float32),
                "w2": _init(next(keys), (hid, f)),
                "b2": jnp.zeros((f,), jnp.float32),
            }
        params["layers"].append({"msa": msa, "ffn": ffn})
    return params


# ---------------------------------------------------------------------------
# Blocks. Single-sample versions operate on (N, F); the *_batched
# wrappers vmap over the leading batch axis and are what aot.py lowers.
# ---------------------------------------------------------------------------

def layernorm(x, g, b, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def patch_embed(img, p, cfg: MoEViTConfig):
    """img: (C, H, W) -> tokens (N, F). Patchify as reshape + reusable
    linear (a conv with stride=kernel=patch_size is exactly that)."""
    c, hh, ww = img.shape
    ps = cfg.patch_size
    gh, gw = hh // ps, ww // ps
    # (C, gh, ps, gw, ps) -> (gh, gw, ps, ps, C) -> (gh*gw, ps*ps*C)
    patches = img.reshape(c, gh, ps, gw, ps).transpose(1, 3, 2, 4, 0)
    patches = patches.reshape(gh * gw, ps * ps * c)
    tok = kl.linear(patches, p["w"], p["b"])
    tok = jnp.concatenate([p["cls"], tok], axis=0)
    return tok + p["pos"]


def msa_block(x, p, heads: int):
    """Pre-LN MSA encoder half (streaming attention kernel inside)."""
    n, f = x.shape
    d = f // heads
    h = layernorm(x, p["ln_g"], p["ln_b"])
    qkv = kl.linear(h, p["w_qkv"], p["b_qkv"])            # QKV generate
    q, k, v = jnp.split(qkv, 3, axis=-1)
    to_heads = lambda t: t.reshape(n, heads, d).transpose(1, 0, 2)
    o = ka.streaming_attention(to_heads(q), to_heads(k), to_heads(v))
    o = o.transpose(1, 0, 2).reshape(n, f)
    return x + kl.linear(o, p["w_proj"], p["b_proj"])     # projection


def ffn_block(x, p):
    """Pre-LN dense FFN encoder half (reusable linear kernel)."""
    h = layernorm(x, p["ln_g"], p["ln_b"])
    return x + kl.expert_ffn(h, p["w1"], p["b1"], p["w2"], p["b2"])


def moe_block(x, p, top_k: int):
    """Pre-LN MoE encoder half (gate + expert-by-expert reusable linear)."""
    h = layernorm(x, p["ln_g"], p["ln_b"])
    return x + kl.moe_ffn(h, p["wg"], p["w1"], p["b1"], p["w2"], p["b2"], top_k)


def gate_probe(x, p, top_k: int):
    """Gate decisions on the LN'd input of a MoE block — the router
    telemetry artifact (per-expert token histogram for the simulator)."""
    h = layernorm(x, p["ln_g"], p["ln_b"])
    return kl.gate_topk(h, p["wg"], top_k)


def head(x, p):
    """Final LN + classify on the cls token. x: (N, F) -> (classes,)."""
    h = layernorm(x, p["ln_g"], p["ln_b"])
    return kl.linear(h[:1], p["w"], p["b"])[0]


def forward(img, params, cfg: MoEViTConfig):
    """Full single-sample forward: image (C,H,W) -> logits (classes,)."""
    x = patch_embed(img, params["embed"], cfg)
    for i in range(cfg.depth):
        lp = params["layers"][i]
        x = msa_block(x, lp["msa"], cfg.heads)
        if cfg.is_moe_layer(i):
            x = moe_block(x, lp["ffn"], cfg.top_k)
        else:
            x = ffn_block(x, lp["ffn"])
    return head(x, params["head"])


# ---------------------------------------------------------------------------
# Batched entry points (what aot.py lowers; batch is static per artifact).
# ---------------------------------------------------------------------------

def msa_block_batched(x, ln_g, ln_b, w_qkv, b_qkv, w_proj, b_proj, *, heads):
    p = dict(ln_g=ln_g, ln_b=ln_b, w_qkv=w_qkv, b_qkv=b_qkv,
             w_proj=w_proj, b_proj=b_proj)
    return jax.vmap(lambda s: msa_block(s, p, heads))(x)


def ffn_block_batched(x, ln_g, ln_b, w1, b1, w2, b2):
    p = dict(ln_g=ln_g, ln_b=ln_b, w1=w1, b1=b1, w2=w2, b2=b2)
    return jax.vmap(lambda s: ffn_block(s, p))(x)


def moe_block_batched(x, ln_g, ln_b, wg, w1, b1, w2, b2, *, top_k):
    p = dict(ln_g=ln_g, ln_b=ln_b, wg=wg, w1=w1, b1=b1, w2=w2, b2=b2)
    return jax.vmap(lambda s: moe_block(s, p, top_k))(x)


def gate_probe_batched(x, ln_g, ln_b, wg, *, top_k):
    p = dict(ln_g=ln_g, ln_b=ln_b, wg=wg)
    return jax.vmap(lambda s: gate_probe(s, p, top_k))(x)


def patch_embed_batched(img, w, b, cls, pos, *, cfg):
    p = dict(w=w, b=b, cls=cls, pos=pos)
    return jax.vmap(lambda s: patch_embed(s, p, cfg))(img)


def head_batched(x, ln_g, ln_b, w, b):
    p = dict(ln_g=ln_g, ln_b=ln_b, w=w, b=b)
    return jax.vmap(lambda s: head(s, p))(x)
