"""Model configurations for the UbiMoE reproduction.

These mirror `rust/src/models/` — the Rust side owns the analytical
workload descriptions (op counts for the simulator); this file owns the
shapes used to author and AOT-lower the actual JAX/Pallas computation.
Keep the two in sync (tests/test_model.py cross-checks GOP counts against
the values baked into rust/src/models/ops.rs via artifacts/*.meta).
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEViTConfig:
    """A MoE-ViT (M3ViT-style) model: ViT backbone where every alternate
    encoder's FFN is replaced by a mixture-of-experts block (Fig. 1)."""

    name: str
    dim: int                  # embedding dim F
    heads: int                # attention heads h
    depth: int                # encoder layers
    patches: int              # N (incl. cls token)
    mlp_ratio: int = 4        # dense FFN hidden = mlp_ratio * dim
    num_experts: int = 0      # E (0 => plain ViT, no MoE layers)
    top_k: int = 2            # experts activated per token
    expert_hidden: int = 0    # expert MLP hidden dim (0 => dim * mlp_ratio)
    moe_every: int = 2        # MoE block in every `moe_every`-th encoder
    img_size: int = 224
    patch_size: int = 16
    in_chans: int = 3
    num_classes: int = 1000

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    @property
    def expert_dim(self) -> int:
        return self.expert_hidden or self.dim * self.mlp_ratio

    @property
    def moe_layers(self) -> list:
        """Indices of encoder layers whose FFN is a MoE block.

        M3ViT places MoE in every alternate encoder; we use odd indices
        (1, 3, 5, ...) so layer 0 is always a plain MSA+FFN encoder.
        """
        if self.num_experts == 0:
            return []
        return [i for i in range(self.depth) if i % self.moe_every == 1]

    def is_moe_layer(self, i: int) -> bool:
        return i in self.moe_layers


# -- Paper configurations ----------------------------------------------------
# m3vit-small: the M3ViT deployment evaluated in Table II (ViT-small
# backbone, 16 experts, top-2 routing, MoE in alternate encoders).
M3VIT_SMALL = MoEViTConfig(
    name="m3vit-small", dim=384, heads=6, depth=12, patches=197,
    num_experts=16, top_k=2,
)

# Plain ViTs used in Table III comparisons.
VIT_T = MoEViTConfig(name="vit-t", dim=192, heads=3, depth=12, patches=197)
VIT_S = MoEViTConfig(name="vit-s", dim=384, heads=6, depth=12, patches=197)

# m3vit-tiny: the end-to-end driver model (examples/e2e_inference.rs) —
# small enough that interpret-mode pallas + CPU PJRT runs hundreds of
# batched requests in seconds, while exercising every code path the
# full model uses (MSA, gate, expert-by-expert MoE, double buffering).
M3VIT_TINY = MoEViTConfig(
    name="m3vit-tiny", dim=192, heads=3, depth=6, patches=65,
    num_experts=8, top_k=2, img_size=64, patch_size=8, num_classes=10,
)

# m3vit-micro: used only by pytest to keep kernel-vs-ref sweeps fast.
M3VIT_MICRO = MoEViTConfig(
    name="m3vit-micro", dim=32, heads=2, depth=2, patches=17,
    num_experts=4, top_k=2, expert_hidden=64,
    img_size=16, patch_size=4, num_classes=10,
)

CONFIGS = {c.name: c for c in [M3VIT_SMALL, VIT_T, VIT_S, M3VIT_TINY, M3VIT_MICRO]}


def get(name: str) -> MoEViTConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown config {name!r}; have {sorted(CONFIGS)}") from None
