"""L1 Pallas kernel: the paper's reusable linear kernel (III-C).

The hardware kernel is a bank of N_L weight-sharing compute units fed by
a round-robin router; weights are stored as T_wt = T_in x T_out vectors
and broadcast to every CU. The TPU/Pallas adaptation keeps the two
properties that matter for the paper's analysis:

* weight tiles of shape (T_in, T_out) are the unit of weight traffic —
  each is loaded once per output pass and *shared* by all rows of the
  activation tile (the N_L-CU broadcast), so off-chip weight traffic is
  independent of how many tokens use the expert;

* the same kernel is reused for every linear in the model — QKV
  generation, attention projection, dense FFN, gate, and every expert —
  exactly the "ubiquitous" reuse the paper advertises.

Grid = (token tiles, out tiles, in tiles), in-tile innermost, classic
weight-stationary accumulation into the output block.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes = the T_in/T_out of the paper's T_wt weight vector.
# Perf note (EXPERIMENTS.md §Perf/L1): interpret-mode pallas lowers the
# grid to an XLA while-loop, so grid-step count is the dominant cost on
# the CPU runtime; 64-wide tiles cut steps ~12x vs the original 32s
# while a 64x64 f32 tile (16 KiB) still fits VMEM comfortably on real
# hardware.
DEFAULT_TN = 64    # token tile (rows routed across the N_L CUs)
DEFAULT_TIN = 64
DEFAULT_TOUT = 64


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _linear_kernel(x_ref, w_ref, o_ref):
    """One (token-tile, out-tile, in-tile) grid step.

    The (T_in, T_out) weight tile w_ref is the broadcast T_wt vector;
    every row of x_ref (a token assigned to some CU) multiplies the same
    tile. Accumulate over the in-tile grid axis.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32)
    ).astype(o_ref.dtype)


def linear(x, w, b=None, *, tn: int = DEFAULT_TN, tin: int = DEFAULT_TIN,
           tout: int = DEFAULT_TOUT):
    """Tiled linear y = x @ w (+ b). x: (N, F_in), w: (F_in, F_out).

    Pads every dimension to its tile multiple (zero padding contributes
    zero to the accumulation), runs the weight-stationary kernel, slices
    the result back. Matches ref.linear to f32 tolerance.
    """
    n, f_in = x.shape
    f_in2, f_out = w.shape
    assert f_in == f_in2, (f_in, f_in2)
    n_p, fi_p, fo_p = _ceil_to(n, tn), _ceil_to(f_in, tin), _ceil_to(f_out, tout)

    xp = jnp.pad(x, [(0, n_p - n), (0, fi_p - f_in)])
    wp = jnp.pad(w, [(0, fi_p - f_in), (0, fo_p - f_out)])

    out = pl.pallas_call(
        _linear_kernel,
        grid=(n_p // tn, fo_p // tout, fi_p // tin),
        in_specs=[
            pl.BlockSpec((tn, tin), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tin, tout), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tn, tout), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_p, fo_p), x.dtype),
        interpret=True,
    )(xp, wp)
    y = out[:n, :f_out]
    if b is not None:
        y = y + b
    return y


def expert_ffn(x, w1, b1, w2, b2, **tiles):
    """One expert MLP (Linear -> GELU -> Linear) on the reusable kernel."""
    h = jax.nn.gelu(linear(x, w1, b1, **tiles))
    return linear(h, w2, b2, **tiles)


def manual_topk(logits, k):
    """top-k via k argmax rounds (masking selected entries to -inf).

    jax.lax.top_k lowers to an HLO `topk(..., largest=true)` attribute
    that the xla_extension 0.5.1 text parser (our AOT consumer) rejects;
    argmax + select lower to plain reduce/compare/select and round-trip
    cleanly. Tie-breaking (lowest index) matches lax.top_k.
    """
    n, e = logits.shape
    x = logits
    vals, idxs = [], []
    for _ in range(k):
        i = jnp.argmax(x, axis=-1)                        # (N,)
        v = jnp.max(x, axis=-1)                           # (N,)
        vals.append(v)
        idxs.append(i.astype(jnp.int32))
        hit = jax.lax.iota(jnp.int32, e)[None, :] == i[:, None].astype(jnp.int32)
        x = jnp.where(hit, -jnp.inf, x)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def moe_ffn(x, wg, w1, b1, w2, b2, top_k, **tiles):
    """Expert-by-expert MoE FFN on the reusable linear kernel.

    Mirrors M3ViT's computation order (load expert e once, process every
    token routed to it): the python loop over experts is static, each
    iteration applies expert e with the shared-weight-tile kernel and
    masks by the gate coefficient. The *memory* consequences of this
    order (one weight load per expert, not per token) are what
    rust/src/sim/linear.rs models; numerically this matches ref.moe_ffn
    exactly (no capacity drop).
    """
    e = w1.shape[0]
    # Gate runs on the same reusable kernel (it is just another linear).
    logits = linear(x, wg, **tiles)
    vals, idx = manual_topk(logits, top_k)
    m = jnp.max(vals, axis=-1, keepdims=True)
    ex_w = jnp.exp(vals - m)
    gw = ex_w / jnp.sum(ex_w, axis=-1, keepdims=True)     # (N, k)
    gi = idx

    out = jnp.zeros_like(x)
    for ex in range(e):                                   # expert-by-expert
        coef = jnp.sum(jnp.where(gi == ex, gw, 0.0), axis=-1)  # (N,)
        y = expert_ffn(x, w1[ex], b1[ex], w2[ex], b2[ex], **tiles)
        out = out + coef[:, None] * y
    return out


def gate_topk(x, wg, top_k, **tiles):
    """Gate only: (weights (N,k), indices (N,k) int32). Used by the
    gate_probe artifact so the Rust coordinator can observe the real
    per-expert token histogram and feed it to the cycle simulator."""
    logits = linear(x, wg, **tiles)
    vals, idx = manual_topk(logits, top_k)
    m = jnp.max(vals, axis=-1, keepdims=True)
    e = jnp.exp(vals - m)
    return e / jnp.sum(e, axis=-1, keepdims=True), idx
