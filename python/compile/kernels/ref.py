"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Everything here is the *naive* formulation: materialize the full score
matrix, use the textbook safe softmax (Eq. 1 of the paper), dense
per-expert masking for MoE. The Pallas kernels must match these to
float32 tolerance under pytest (python/tests/test_kernels.py) — this is
the core correctness signal of the whole stack, because the AOT'd HLO
the Rust runtime executes is lowered from the same kernel functions.
"""

import jax
import jax.numpy as jnp


def safe_softmax(x, axis=-1):
    """Eq. 1: m(x)=max_i x_i, l(x)=sum e^(x_i-m), s=e^(x_i-m)/l."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def attention(q, k, v, scale=None):
    """Multi-head attention, naive. q,k,v: (H, N, d) -> (H, N, d)."""
    h, n, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    s = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    p = safe_softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def linear(x, w, b=None):
    """Dense linear. x: (N, F_in), w: (F_in, F_out)."""
    y = x @ w
    if b is not None:
        y = y + b
    return y


def gate_topk(x, wg, top_k):
    """MoE gate: logits -> top-k -> renormalized softmax weights.

    Returns (weights (N, k), indices (N, k) int32).
    """
    logits = x @ wg  # (N, E)
    vals, idx = jax.lax.top_k(logits, top_k)
    w = safe_softmax(vals, axis=-1)
    return w, idx.astype(jnp.int32)


def expert_ffn(x, w1, b1, w2, b2):
    """One expert: Linear -> GELU -> Linear."""
    return linear(jax.nn.gelu(linear(x, w1, b1)), w2, b2)


def moe_ffn(x, wg, w1, b1, w2, b2, top_k):
    """Dense-masked MoE reference (expert-by-expert, no token drop).

    x: (N, F); wg: (F, E); w1: (E, F, D); b1: (E, D); w2: (E, D, F);
    b2: (E, F). Every expert is applied to every token and masked by the
    gate — O(E x N) compute, but bit-faithful to the no-capacity-drop
    semantics the Pallas/gathered implementation must reproduce.
    """
    e = w1.shape[0]
    gw, gi = gate_topk(x, wg, top_k)  # (N,k), (N,k)
    out = jnp.zeros_like(x)
    for ex in range(e):
        hit = (gi == ex)                                  # (N, k)
        coef = jnp.sum(jnp.where(hit, gw, 0.0), axis=-1)  # (N,)
        y = expert_ffn(x, w1[ex], b1[ex], w2[ex], b2[ex])
        out = out + coef[:, None] * y
    return out


def layernorm(x, g, b, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def msa_block(x, params, heads):
    """Pre-LN MSA encoder half: x + proj(attn(qkv(ln(x))))."""
    n, f = x.shape
    d = f // heads
    h = layernorm(x, params["ln_g"], params["ln_b"])
    qkv = linear(h, params["w_qkv"], params["b_qkv"])  # (N, 3F)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    to_heads = lambda t: t.reshape(n, heads, d).transpose(1, 0, 2)
    o = attention(to_heads(q), to_heads(k), to_heads(v))
    o = o.transpose(1, 0, 2).reshape(n, f)
    return x + linear(o, params["w_proj"], params["b_proj"])


def ffn_block(x, params):
    """Pre-LN dense-FFN encoder half: x + mlp(ln(x))."""
    h = layernorm(x, params["ln_g"], params["ln_b"])
    return x + expert_ffn(h, params["w1"], params["b1"], params["w2"], params["b2"])


def moe_block(x, params, top_k):
    """Pre-LN MoE encoder half: x + moe(ln(x))."""
    h = layernorm(x, params["ln_g"], params["ln_b"])
    return x + moe_ffn(h, params["wg"], params["w1"], params["b1"],
                       params["w2"], params["b2"], top_k)
