"""L1 Pallas kernel: the paper's fully streaming attention (III-B).

Paper -> TPU/Pallas adaptation (see DESIGN.md 1):

* Patch reorder in the QK dot (Fig. 4b): the paper makes each PE
  Q-stationary — a fixed Q_i lives in a PE for the whole computation
  while K patches are broadcast block-by-block. Here a grid step owns a
  (T_q, d) Q tile that stays resident in VMEM while K/V are streamed
  through an inner loop — the same dataflow, with BlockSpec playing the
  role of the HLS array partition.

* Fused softmax with per-head max registers: the paper splits softmax
  into a max half and an exp/sum half running concurrently with the QK
  dot, keeps m(x) in registers, multiplies the numerator exp(x_i - m)
  straight into V (no score cache), and divides once per row at the
  end. That is exactly the online-softmax recurrence implemented below:
  running (m, l, acc) carried across K blocks, single division at the
  end.

Lowered with interpret=True: on CPU PJRT the pallas_call becomes plain
HLO (the real-TPU Mosaic custom-call cannot execute there), so the AOT
artifact the Rust runtime loads is a faithful, runnable lowering of this
kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (overridable per call). On real TPU hardware these
# would be tuned so that q/k/v tiles + the (T_q, T_k) score tile fit in
# VMEM; here they also bound the unpadded-N padding overhead. 64/64
# minimizes interpret-mode grid steps (see EXPERIMENTS.md §Perf/L1).
DEFAULT_TQ = 64
DEFAULT_TK = 64


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, n_valid, tk, scale):
    """One grid step: a Q tile of one head against all K/V blocks.

    q_ref: (1, T_q, d)   — Q-stationary tile (paper: Q_i fixed in PE)
    k_ref: (1, N_p, d)   — full K of this head (streamed in T_k blocks)
    v_ref: (1, N_p, d)
    o_ref: (1, T_q, d)
    """
    q = q_ref[0].astype(jnp.float32)          # (T_q, d)
    n_p = k_ref.shape[1]
    num_kb = n_p // tk
    tq, d = q.shape

    m0 = jnp.full((tq,), -jnp.inf, dtype=jnp.float32)   # max registers m(x)
    l0 = jnp.zeros((tq,), dtype=jnp.float32)            # denominator l(x)
    a0 = jnp.zeros((tq, d), dtype=jnp.float32)          # numerator @ V

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], j * tk, tk).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], j * tk, tk).astype(jnp.float32)
        s = jnp.dot(q, k.T) * scale                      # (T_q, T_k) QK dot
        # Mask padded key positions (N padded to a T_k multiple).
        kidx = j * tk + jax.lax.iota(jnp.int32, tk)
        s = jnp.where(kidx[None, :] < n_valid, s, -jnp.inf)
        # Online-softmax update == the paper's streaming max/exp pipeline.
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        p = jnp.exp(s - m_new[:, None])                  # numerator exp(x_i - m)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v)   # multiply into V directly
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, a0))
    # Single division per row (paper: "only one division operation").
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def streaming_attention(q, k, v, *, tq: int = DEFAULT_TQ, tk: int = DEFAULT_TK,
                        scale=None):
    """Streaming multi-head attention. q, k, v: (H, N, d) -> (H, N, d).

    Pads N to tile multiples, runs the fused kernel on a (H, ceil(N/T_q))
    grid, slices the padding back off. Numerically equivalent to
    ref.attention (pytest enforces allclose).
    """
    h, n, d = q.shape
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    nq_p = _ceil_to(n, tq)
    nk_p = _ceil_to(n, tk)

    pad_q = [(0, 0), (0, nq_p - n), (0, 0)]
    pad_k = [(0, 0), (0, nk_p - n), (0, 0)]
    qp = jnp.pad(q, pad_q)
    kp = jnp.pad(k, pad_k)
    vp = jnp.pad(v, pad_k)

    kernel = functools.partial(_attn_kernel, n_valid=n, tk=tk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(h, nq_p // tq),
        in_specs=[
            pl.BlockSpec((1, tq, d), lambda hh, i: (hh, i, 0)),   # Q tile
            pl.BlockSpec((1, nk_p, d), lambda hh, i: (hh, 0, 0)),  # full K
            pl.BlockSpec((1, nk_p, d), lambda hh, i: (hh, 0, 0)),  # full V
        ],
        out_specs=pl.BlockSpec((1, tq, d), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, nq_p, d), q.dtype),
        interpret=True,
    )(qp, kp, vp)
    return out[:, :n, :]


def naive_attention_pallas(q, k, v, *, tk: int = DEFAULT_TK, scale=None):
    """The *pre-optimization* dataflow of Fig. 4a, as a pallas kernel.

    Each grid step owns a single-q row and reloads every K block from
    scratch ("in each running cycle, every PE must reload K patches"),
    with the safe softmax computed only after the whole score row is
    materialized — i.e. no fusion, a score buffer of size N per row.
    Exists as the baseline for the Fig. 4 memory-traffic bench and as an
    independent numerical cross-check of the streaming kernel.
    """
    h, n, d = q.shape
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    nk_p = _ceil_to(n, tk)
    qp = q
    kp = jnp.pad(k, [(0, 0), (0, nk_p - n), (0, 0)])
    vp = jnp.pad(v, [(0, 0), (0, nk_p - n), (0, 0)])

    def kernel(q_ref, k_ref, v_ref, o_ref):
        qrow = q_ref[0].astype(jnp.float32)               # (1, d)
        kk = k_ref[0].astype(jnp.float32)                 # (N_p, d)
        vv = v_ref[0].astype(jnp.float32)
        s = jnp.dot(qrow, kk.T) * scale                   # full score row
        kidx = jax.lax.iota(jnp.int32, nk_p)
        s = jnp.where(kidx[None, :] < n, s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)            # safe softmax,
        e = jnp.exp(s - m)                                # post-hoc (Eq. 1)
        p = e / jnp.sum(e, axis=-1, keepdims=True)
        o_ref[0] = jnp.dot(p, vv).astype(o_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid=(h, n),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda hh, i: (hh, i, 0)),
            pl.BlockSpec((1, nk_p, d), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((1, nk_p, d), lambda hh, i: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, d), q.dtype),
        interpret=True,
    )(qp, kp, vp)
    return out
