"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core numerical signal for the whole stack — the HLO the
Rust runtime executes is lowered from exactly these kernel functions.
Hypothesis sweeps shapes/tiles; fixed cases pin the paper's dimensions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.expert_linear import (
    expert_ffn, gate_topk, linear, moe_ffn)
from compile.kernels.streaming_attention import (
    naive_attention_pallas, streaming_attention)

ATOL = 2e-5
RTOL = 2e-4


def rnd(seed, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Streaming attention
# ---------------------------------------------------------------------------

class TestStreamingAttention:
    @pytest.mark.parametrize("h,n,d", [
        (1, 8, 8),         # minimal
        (3, 65, 64),       # m3vit-tiny MSA shape
        (2, 17, 16),       # m3vit-micro
        (6, 197, 64),      # m3vit-small / ViT-S (N=197 is prime: padding path)
        (1, 16, 32),       # N == tile exactly
        (4, 33, 8),        # N % tq == 1 (max padding)
    ])
    def test_matches_ref(self, h, n, d):
        q, k, v = rnd(1, (h, n, d)), rnd(2, (h, n, d)), rnd(3, (h, n, d))
        got = streaming_attention(q, k, v)
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)

    @pytest.mark.parametrize("tq,tk", [(4, 4), (8, 16), (16, 8), (32, 32), (5, 7)])
    def test_tile_invariance(self, tq, tk):
        """Output must not depend on tiling (T_a is a pure perf knob)."""
        q, k, v = rnd(4, (2, 23, 16)), rnd(5, (2, 23, 16)), rnd(6, (2, 23, 16))
        got = streaming_attention(q, k, v, tq=tq, tk=tk)
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)

    def test_naive_matches_streaming(self):
        """Fig. 4a vs Fig. 4b dataflows are numerically identical."""
        q, k, v = rnd(7, (3, 21, 24)), rnd(8, (3, 21, 24)), rnd(9, (3, 21, 24))
        np.testing.assert_allclose(
            naive_attention_pallas(q, k, v), streaming_attention(q, k, v),
            atol=ATOL, rtol=RTOL)

    def test_softmax_rows_sum_to_one(self):
        """Implied invariant: out is a convex combination of V rows, so a
        constant V column must pass through unchanged."""
        h, n, d = 2, 19, 8
        q, k = rnd(10, (h, n, d)), rnd(11, (h, n, d))
        v = jnp.ones((h, n, d), jnp.float32) * 3.25
        got = streaming_attention(q, k, v)
        np.testing.assert_allclose(got, v[:, :n], atol=ATOL, rtol=RTOL)

    def test_large_logits_no_overflow(self):
        """Eq. 1's whole point: safe under large scores. The streaming
        max-register path must be as safe as the two-pass reference."""
        q = rnd(12, (1, 9, 4), scale=60.0)
        k = rnd(13, (1, 9, 4), scale=60.0)
        v = rnd(14, (1, 9, 4))
        got = streaming_attention(q, k, v)
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(got, ref.attention(q, k, v), atol=1e-4, rtol=1e-3)

    def test_scale_override(self):
        q, k, v = rnd(15, (2, 12, 8)), rnd(16, (2, 12, 8)), rnd(17, (2, 12, 8))
        got = streaming_attention(q, k, v, scale=0.1)
        want = ref.attention(q, k, v, scale=0.1)
        np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)

    @settings(max_examples=25, deadline=None)
    @given(h=st.integers(1, 4), n=st.integers(2, 40), d=st.sampled_from([4, 8, 16]),
           tq=st.sampled_from([4, 8, 16]), tk=st.sampled_from([4, 8, 16]),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, h, n, d, tq, tk, seed):
        q = rnd(seed, (h, n, d))
        k = rnd(seed + 1, (h, n, d))
        v = rnd(seed + 2, (h, n, d))
        got = streaming_attention(q, k, v, tq=tq, tk=tk)
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


# ---------------------------------------------------------------------------
# Reusable linear kernel
# ---------------------------------------------------------------------------

class TestReusableLinear:
    @pytest.mark.parametrize("n,fi,fo", [
        (1, 1, 1), (65, 192, 576), (17, 32, 64), (197, 384, 384),
        (32, 32, 32),            # exact tiles
        (33, 33, 33),            # +1 padding everywhere
    ])
    def test_matches_ref(self, n, fi, fo):
        x, w, b = rnd(20, (n, fi)), rnd(21, (fi, fo), 0.1), rnd(22, (fo,))
        np.testing.assert_allclose(
            linear(x, w, b), ref.linear(x, w, b), atol=ATOL, rtol=RTOL)

    def test_no_bias(self):
        x, w = rnd(23, (10, 12)), rnd(24, (12, 8))
        np.testing.assert_allclose(linear(x, w), ref.linear(x, w),
                                   atol=ATOL, rtol=RTOL)

    @pytest.mark.parametrize("tn,tin,tout", [(8, 8, 8), (16, 32, 8), (64, 16, 16)])
    def test_tile_invariance(self, tn, tin, tout):
        """T_in/T_out tiling (the T_wt weight vector shape) is a pure
        resource/perf knob; results must be identical."""
        x, w = rnd(25, (29, 31)), rnd(26, (31, 37), 0.1)
        got = linear(x, w, tn=tn, tin=tin, tout=tout)
        np.testing.assert_allclose(got, ref.linear(x, w), atol=ATOL, rtol=RTOL)

    def test_expert_ffn(self):
        x = rnd(27, (17, 32))
        w1, b1 = rnd(28, (32, 64), 0.1), rnd(29, (64,))
        w2, b2 = rnd(30, (64, 32), 0.1), rnd(31, (32,))
        np.testing.assert_allclose(
            expert_ffn(x, w1, b1, w2, b2), ref.expert_ffn(x, w1, b1, w2, b2),
            atol=ATOL, rtol=RTOL)

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 48), fi=st.integers(1, 48), fo=st.integers(1, 48),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, n, fi, fo, seed):
        x, w = rnd(seed, (n, fi)), rnd(seed + 1, (fi, fo), 0.1)
        np.testing.assert_allclose(linear(x, w), ref.linear(x, w),
                                   atol=ATOL, rtol=RTOL)


# ---------------------------------------------------------------------------
# Gate + expert-by-expert MoE
# ---------------------------------------------------------------------------

class TestMoE:
    def _params(self, seed, n, f, e, dh):
        return dict(
            x=rnd(seed, (n, f)),
            wg=rnd(seed + 1, (f, e), 0.5),
            w1=rnd(seed + 2, (e, f, dh), 0.1),
            b1=rnd(seed + 3, (e, dh), 0.1),
            w2=rnd(seed + 4, (e, dh, f), 0.1),
            b2=rnd(seed + 5, (e, f), 0.1),
        )

    @pytest.mark.parametrize("n,f,e,dh,k", [
        (17, 32, 4, 64, 2),     # m3vit-micro
        (65, 48, 8, 96, 2),     # tiny-ish
        (10, 16, 4, 16, 1),     # top-1
        (9, 16, 3, 8, 3),       # k == E (every expert active)
    ])
    def test_moe_matches_ref(self, n, f, e, dh, k):
        p = self._params(40, n, f, e, dh)
        got = moe_ffn(p["x"], p["wg"], p["w1"], p["b1"], p["w2"], p["b2"], k)
        want = ref.moe_ffn(p["x"], p["wg"], p["w1"], p["b1"], p["w2"], p["b2"], k)
        np.testing.assert_allclose(got, want, atol=2 * ATOL, rtol=RTOL)

    def test_gate_matches_ref(self):
        p = self._params(50, 21, 32, 8, 16)
        gw, gi = gate_topk(p["x"], p["wg"], 2)
        rw, ri = ref.gate_topk(p["x"], p["wg"], 2)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
        np.testing.assert_allclose(gw, rw, atol=ATOL, rtol=RTOL)

    def test_gate_weights_normalized(self):
        p = self._params(51, 33, 24, 8, 16)
        gw, gi = gate_topk(p["x"], p["wg"], 2)
        np.testing.assert_allclose(np.asarray(gw).sum(-1), 1.0, atol=1e-5)
        assert (np.asarray(gi) >= 0).all() and (np.asarray(gi) < 8).all()

    def test_gate_topk_distinct(self):
        """top-k must pick k distinct experts per token."""
        p = self._params(52, 29, 24, 8, 16)
        _, gi = gate_topk(p["x"], p["wg"], 3)
        gi = np.asarray(gi)
        for row in gi:
            assert len(set(row.tolist())) == 3

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(2, 24), e=st.sampled_from([2, 4, 8]),
           k=st.integers(1, 2), seed=st.integers(0, 10**6))
    def test_hypothesis_moe(self, n, e, k, seed):
        f, dh = 16, 24
        p = self._params(seed, n, f, e, dh)
        got = moe_ffn(p["x"], p["wg"], p["w1"], p["b1"], p["w2"], p["b2"], k)
        want = ref.moe_ffn(p["x"], p["wg"], p["w1"], p["b1"], p["w2"], p["b2"], k)
        np.testing.assert_allclose(got, want, atol=2 * ATOL, rtol=RTOL)
