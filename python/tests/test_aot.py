"""AOT pipeline tests: artifact emission, metadata consistency, and
HLO-text compatibility with the Rust consumer (xla_extension 0.5.1's
parser — the whole reason the interchange format is text).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.configs import M3VIT_MICRO, get
from compile.kernels.expert_linear import manual_topk


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    """Emit m3vit-micro artifacts (small and fast) into a tmp dir."""
    out = tmp_path_factory.mktemp("artifacts")
    aot.main([
        "--out-dir", str(out), "--config", "m3vit-micro",
        "--batch", "1", "--no-full-model",
    ])
    return out


def parse_manifest(path):
    entries = []
    for line in open(path):
        line = line.strip()
        if not line:
            continue
        head, off = line.rsplit(":", 1)
        name, dtype, dims = head.split(":")
        dims = [int(d) for d in dims.split(",")] if dims else []
        entries.append((name, dtype, dims, int(off)))
    return entries


class TestArtifacts:
    def test_expected_files_exist(self, artifacts):
        for kind in ["msa_block", "dense_ffn", "moe_block", "gate_probe",
                     "patch_embed", "head"]:
            assert (artifacts / f"m3vit-micro.{kind}.b1.hlo.txt").exists(), kind
            assert (artifacts / f"m3vit-micro.{kind}.b1.meta").exists(), kind
        assert (artifacts / "m3vit-micro.weights.bin").exists()
        assert (artifacts / "m3vit-micro.weights.manifest").exists()
        assert (artifacts / "m3vit-micro.golden.bin").exists()
        assert (artifacts / "STAMP").exists()

    def test_manifest_offsets_contiguous(self, artifacts):
        entries = parse_manifest(artifacts / "m3vit-micro.weights.manifest")
        expect = 0
        for name, dtype, dims, off in entries:
            assert dtype == "float32", name
            assert off == expect, f"{name}: offset {off} != {expect}"
            expect += 4 * int(np.prod(dims)) if dims else 4
        size = os.path.getsize(artifacts / "m3vit-micro.weights.bin")
        assert size == expect

    def test_meta_shapes_match_config(self, artifacts):
        cfg = M3VIT_MICRO
        text = (artifacts / "m3vit-micro.msa_block.b1.meta").read_text()
        assert f"input=x:float32:1,{cfg.patches},{cfg.dim}" in text
        assert f"output=y:float32:1,{cfg.patches},{cfg.dim}" in text
        gate = (artifacts / "m3vit-micro.gate_probe.b1.meta").read_text()
        assert f"output=gate_i:int32:1,{cfg.patches},{cfg.top_k}" in gate

    def test_hlo_parser_compat_no_topk_attribute(self, artifacts):
        """Regression: jax.lax.top_k emits `largest=true`, which the
        xla_extension 0.5.1 HLO text parser rejects. The gate must not
        produce it (we lower top-k as iterative argmax)."""
        for kind in ["moe_block", "gate_probe"]:
            text = (artifacts / f"m3vit-micro.{kind}.b1.hlo.txt").read_text()
            assert "largest" not in text, f"{kind} uses unparseable topk"
            # Pallas interpret mode must have produced plain HLO (no
            # TPU custom-calls the CPU runtime can't execute).
            assert "mosaic" not in text.lower(), kind

    def test_golden_selfconsistent(self, artifacts):
        entries = parse_manifest(artifacts / "m3vit-micro.golden.meta")
        names = [e[0] for e in entries]
        assert "input" in names and "logits" in names and "embed" in names
        raw = (artifacts / "m3vit-micro.golden.bin").read_bytes()
        # Recompute logits from the stored input; must match stored.
        by_name = {e[0]: e for e in entries}
        def load(name):
            _, _, dims, off = by_name[name]
            n = int(np.prod(dims))
            a = np.frombuffer(raw, np.float32, count=n, offset=off)
            return a.reshape(dims)
        img = jnp.asarray(load("input"))
        params = M.init_params(M3VIT_MICRO, seed=0)
        logits = jax.vmap(lambda s: M.forward(s, params, M3VIT_MICRO))(img)
        np.testing.assert_allclose(np.asarray(logits), load("logits"),
                                   atol=1e-5, rtol=1e-4)


class TestManualTopK:
    """The AOT-compatible top-k must agree with jax.lax.top_k."""

    @pytest.mark.parametrize("n,e,k", [(7, 4, 1), (16, 8, 2), (5, 6, 3)])
    def test_matches_lax_topk(self, n, e, k):
        x = jax.random.normal(jax.random.PRNGKey(n * e + k), (n, e))
        mv, mi = manual_topk(x, k)
        lv, li = jax.lax.top_k(x, k)
        np.testing.assert_allclose(np.asarray(mv), np.asarray(lv), atol=1e-6)
        np.testing.assert_array_equal(np.asarray(mi), np.asarray(li))

    def test_handles_ties_deterministically(self):
        x = jnp.zeros((3, 5))
        _, mi = manual_topk(x, 2)
        # lowest indices win on ties, and picks are distinct
        np.testing.assert_array_equal(np.asarray(mi),
                                      np.tile(np.array([0, 1]), (3, 1)))

    def test_values_sorted_descending(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (11, 9))
        mv, _ = manual_topk(x, 3)
        mv = np.asarray(mv)
        assert (mv[:, 0] >= mv[:, 1]).all() and (mv[:, 1] >= mv[:, 2]).all()


class TestHloText:
    def test_to_hlo_text_roundtrippable_ops_only(self):
        """Lower a tiny block and check the text contains an HLO module
        (ENTRY) and only standard ops."""
        cfg = get("m3vit-micro")
        params = M.init_params(cfg, seed=0)
        import functools
        gp = functools.partial(M.gate_probe_batched, top_k=cfg.top_k)
        x = jax.ShapeDtypeStruct((1, cfg.patches, cfg.dim), jnp.float32)
        args = [x] + [
            jax.ShapeDtypeStruct(params["layers"][1]["ffn"][kk].shape, jnp.float32)
            for kk in ["ln_g", "ln_b", "wg"]
        ]
        lowered = jax.jit(gp).lower(*args)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "largest" not in text
