"""L2 correctness: block functions vs ref oracle + full-model shape/sanity.

Uses m3vit-micro so interpret-mode pallas stays fast.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import CONFIGS, M3VIT_MICRO, M3VIT_TINY, get
from compile.kernels import ref

CFG = M3VIT_MICRO
ATOL = 5e-5
RTOL = 5e-4


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def tokens():
    return 0.5 * jax.random.normal(
        jax.random.PRNGKey(7), (CFG.patches, CFG.dim), jnp.float32)


class TestBlocks:
    def test_msa_block_matches_ref(self, params, tokens):
        p = params["layers"][0]["msa"]
        got = M.msa_block(tokens, p, CFG.heads)
        want = ref.msa_block(tokens, p, CFG.heads)
        np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)

    def test_ffn_block_matches_ref(self, params, tokens):
        p = params["layers"][0]["ffn"]
        np.testing.assert_allclose(
            M.ffn_block(tokens, p), ref.ffn_block(tokens, p),
            atol=ATOL, rtol=RTOL)

    def test_moe_block_matches_ref(self, params, tokens):
        i = CFG.moe_layers[0]
        p = params["layers"][i]["ffn"]
        got = M.moe_block(tokens, p, CFG.top_k)
        want = ref.moe_block(tokens, p, CFG.top_k)
        np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)

    def test_residuals_present(self, params):
        """Pre-LN blocks must be identity + f(LN(x)): with all-zero
        weight matrices the block output equals its input exactly."""
        p = {k: jnp.zeros_like(v) for k, v in params["layers"][0]["msa"].items()}
        x = jax.random.normal(jax.random.PRNGKey(3), (CFG.patches, CFG.dim))
        np.testing.assert_allclose(M.msa_block(x, p, CFG.heads), x, atol=1e-6)

    def test_gate_probe_histogram(self, params, tokens):
        """gate_probe must agree with the MoE block's internal routing."""
        i = CFG.moe_layers[0]
        p = params["layers"][i]["ffn"]
        gw, gi = M.gate_probe(tokens, p, CFG.top_k)
        assert gi.shape == (CFG.patches, CFG.top_k)
        h = ref.layernorm(tokens, p["ln_g"], p["ln_b"])
        rw, ri = ref.gate_topk(h, p["wg"], CFG.top_k)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))


class TestFullModel:
    def test_forward_shapes(self, params):
        img = jax.random.normal(
            jax.random.PRNGKey(1), (CFG.in_chans, CFG.img_size, CFG.img_size))
        logits = M.forward(img, params, CFG)
        assert logits.shape == (CFG.num_classes,)
        assert np.isfinite(np.asarray(logits)).all()

    def test_patch_count(self, params):
        img = jnp.zeros((CFG.in_chans, CFG.img_size, CFG.img_size))
        tok = M.patch_embed(img, params["embed"], CFG)
        assert tok.shape == (CFG.patches, CFG.dim)

    def test_batched_blocks_match_loop(self, params):
        """vmap'd block == per-sample loop (what the AOT artifact runs)."""
        b = 3
        x = 0.3 * jax.random.normal(
            jax.random.PRNGKey(5), (b, CFG.patches, CFG.dim), jnp.float32)
        p = params["layers"][0]["msa"]
        got = M.msa_block_batched(
            x, p["ln_g"], p["ln_b"], p["w_qkv"], p["b_qkv"],
            p["w_proj"], p["b_proj"], heads=CFG.heads)
        want = jnp.stack([M.msa_block(x[i], p, CFG.heads) for i in range(b)])
        np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)

    def test_deterministic_init(self):
        a = M.init_params(CFG, seed=0)
        b = M.init_params(CFG, seed=0)
        np.testing.assert_array_equal(
            np.asarray(a["embed"]["w"]), np.asarray(b["embed"]["w"]))
        c = M.init_params(CFG, seed=1)
        assert not np.array_equal(
            np.asarray(a["embed"]["w"]), np.asarray(c["embed"]["w"]))


class TestConfigs:
    def test_all_configs_valid(self):
        for name, cfg in CONFIGS.items():
            assert cfg.dim % cfg.heads == 0, name
            n_patch = (cfg.img_size // cfg.patch_size) ** 2
            assert cfg.patches == n_patch + 1, name

    def test_moe_layers_alternate(self):
        cfg = get("m3vit-tiny")
        assert cfg.moe_layers == [1, 3, 5]
        assert get("m3vit-small").moe_layers == [1, 3, 5, 7, 9, 11]
        assert get("vit-s").moe_layers == []

    def test_paper_gop_count(self):
        """Pin the analytical op count for m3vit-small to the value
        rust/src/models/ops.rs computes (11.88 GOP at 2 ops/MAC).

        Note: Table II implies ~2.2-2.5 GOP (54.86 GOPS x 40.1 ms); the
        paper evidently uses a different op-counting convention or a
        smaller M3ViT variant. All within-table ratios are unaffected
        because every compared system runs the same workload — see
        EXPERIMENTS.md 'Op-count convention'."""
        cfg = get("m3vit-small")
        n, f, h = cfg.patches, cfg.dim, cfg.heads
        gops = 0
        for i in range(cfg.depth):
            # MSA: qkv + attn (qk & pv) + proj, 2 ops per MAC
            gops += 2 * (n * f * 3 * f + 2 * n * n * f + n * f * f)
            if cfg.is_moe_layer(i):
                # top-k experts active per token + gate
                gops += 2 * (cfg.top_k * n * 2 * f * cfg.expert_dim
                             + n * f * cfg.num_experts)
            else:
                gops += 2 * (n * 2 * f * cfg.mlp_ratio * f)
        gops /= 1e9
        assert 11.5 < gops < 12.3, gops
