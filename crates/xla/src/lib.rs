//! Host-side stub of the `xla-rs` PJRT bindings.
//!
//! The build environment has neither the xla-rs crate nor a
//! `libxla_extension` shared library, so this crate reproduces the API
//! surface the `ubimoe` runtime uses with pure-host semantics:
//!
//! * [`Literal`] is a real host tensor (f32/i32/tuple) — conversions,
//!   reshapes and shape queries behave exactly like the original;
//! * [`PjRtClient`] / [`PjRtBuffer`] hold host copies; creating
//!   clients, uploading buffers and loading/compiling HLO-text
//!   artifacts all succeed (so model loading and inventory work);
//! * **executing** a compiled computation returns
//!   [`Error::ExecutionUnavailable`] — there is no HLO interpreter
//!   here. Everything execution-dependent in `ubimoe` already gates on
//!   `artifacts_available()`, and the analytic stack (simulator, HAS,
//!   report layer) never touches this crate.
//!
//! Swapping the real xla-rs back in is a one-line Cargo.toml change;
//! no `ubimoe` source references change.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type (mirrors xla-rs's `Error` in role).
#[derive(Debug)]
pub enum Error {
    /// Shape/element-count mismatch in a host-side literal operation.
    Shape(String),
    /// Artifact file could not be read.
    Io(String),
    /// Device execution requested on the stub backend.
    ExecutionUnavailable(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "xla-stub shape error: {m}"),
            Error::Io(m) => write!(f, "xla-stub io error: {m}"),
            Error::ExecutionUnavailable(m) => write!(
                f,
                "xla-stub: device execution unavailable ({m}); \
                 link the real xla-rs/libxla_extension to run artifacts"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
#[derive(Clone, Debug, PartialEq)]
enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Sealed-by-convention trait for host element types.
pub trait NativeType: Sized + Copy {
    fn wrap(data: Vec<Self>) -> Storage_;
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

/// Public alias so `NativeType` can name the private storage.
pub struct Storage_(Storage);

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Storage_ {
        Storage_(Storage::F32(data))
    }
    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.storage {
            Storage::F32(v) => Ok(v.clone()),
            other => Err(Error::Shape(format!("expected f32 literal, got {other:?}"))),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Storage_ {
        Storage_(Storage::I32(data))
    }
    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.storage {
            Storage::I32(v) => Ok(v.clone()),
            other => Err(Error::Shape(format!("expected i32 literal, got {other:?}"))),
        }
    }
}

/// A host tensor value (array or tuple), like xla-rs's `Literal`.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len() as i64], storage: Storage::F32(data.to_vec()) }
    }

    fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(v) => v.len(),
        }
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::Shape(format!(
                "reshape {:?} -> {dims:?}: {} elements",
                self.dims,
                self.element_count()
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Array shape (error on tuples, like the original).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.storage {
            Storage::Tuple(_) => Err(Error::Shape("array_shape on tuple literal".into())),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }

    /// Flat host copy of the elements.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    /// Unpack a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(parts) => Ok(parts),
            _ => Err(Error::Shape("to_tuple on non-tuple literal".into())),
        }
    }

    /// Build a tuple literal (test/fixture helper).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![parts.len() as i64], storage: Storage::Tuple(parts) }
    }
}

/// Array shape query result.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed (well: loaded) HLO module text.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Load HLO text from a file. The stub validates readability and
    /// non-emptiness only; real parsing happens in the real backend.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text =
            std::fs::read_to_string(path).map_err(|e| Error::Io(format!("{path}: {e}")))?;
        if text.trim().is_empty() {
            return Err(Error::Io(format!("{path}: empty HLO text")));
        }
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// A computation handle built from a proto.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    name: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        // First line of HLO text names the module; keep it for errors.
        let name = proto.text.lines().next().unwrap_or("<hlo>").trim().to_string();
        XlaComputation { name }
    }
}

/// Device-resident buffer (host copy in the stub).
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Synchronous device→host transfer.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A "compiled" executable. Execution is unavailable on the stub.
#[derive(Clone, Debug)]
pub struct PjRtLoadedExecutable {
    name: String,
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::ExecutionUnavailable(self.name.clone()))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::ExecutionUnavailable(self.name.clone()))
    }
}

/// The PJRT CPU client (host-only in the stub).
#[derive(Debug)]
pub struct PjRtClient {
    devices: usize,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { devices: 1 })
    }

    pub fn device_count(&self) -> usize {
        self.devices
    }

    /// "Compile" a computation: accepted (artifact inventory and load
    /// paths work); any later execute reports unavailability.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { name: comp.name.clone() })
    }

    /// Upload host data as a device buffer (host copy here).
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!("{} elements for dims {dims:?}", data.len())));
        }
        let Storage_(storage) = T::wrap(data.to_vec());
        Ok(PjRtBuffer {
            literal: Literal { storage, dims: dims.iter().map(|&d| d as i64).collect() },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn tuple_literals_unpack() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0]), Literal::vec1(&[2.0, 3.0])]);
        assert!(t.array_shape().is_err());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<f32>().unwrap(), vec![2.0, 3.0]);
    }

    #[test]
    fn client_and_buffers_work_execution_does_not() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.device_count(), 1);
        let buf = c.buffer_from_host_buffer(&[1.0f32, 2.0], &[2], None).unwrap();
        assert_eq!(buf.to_literal_sync().unwrap().to_vec::<f32>().unwrap(), vec![1.0, 2.0]);

        let proto = HloModuleProto { text: "HloModule stub_test".into() };
        let exe = c.compile(&XlaComputation::from_proto(&proto)).unwrap();
        let err = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(format!("{err}").contains("execution unavailable"), "{err}");
    }

    #[test]
    fn missing_hlo_file_errors() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
