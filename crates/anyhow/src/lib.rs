//! Offline, API-compatible subset of `anyhow` (the build environment
//! has no registry access, so the real crate cannot be fetched).
//!
//! Covers exactly the surface this workspace uses:
//! * [`Error`] — a context-chained error value ({} prints the
//!   outermost message, {:#} the whole chain, {:?} a Caused-by list);
//! * [`Result`] — `Result<T, Error>` alias with a default type param;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   (both std-error and `anyhow::Error` variants) and on `Option`;
//! * `anyhow!` / `bail!` — format-style constructors;
//! * `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts foreign errors.

use std::fmt::{self, Display};

/// A context-chained error: messages outermost-first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display + Send + Sync + 'static>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        self.chain.first().map(|s| s.as_str()).unwrap_or("")
    }

    fn from_std<E: std::error::Error>(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.to_string_outer())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_string_outer())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`;
// that is what keeps the blanket `From`/`ext` impls below coherent
// (the same trick the real anyhow uses).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::from_std(e)
    }
}

/// `anyhow::Result<T>` (second parameter defaultable, like the real crate).
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::{Display, Error};

    /// Anything that can become an [`Error`] while absorbing a context
    /// message. Implemented for std errors AND for `Error` itself —
    /// coherent because `Error` is not a `std::error::Error`.
    pub trait IntoError {
        fn ext_context<C: Display + Send + Sync + 'static>(self, context: C) -> Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display + Send + Sync + 'static>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl IntoError for Error {
        fn ext_context<C: Display + Send + Sync + 'static>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Attach context to `Result` / `Option` values.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("inner"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(format!("{}", f().unwrap_err()).contains("missing file"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading weights").unwrap_err();
        assert_eq!(format!("{e}"), "reading weights");
        assert!(format!("{e:#}").contains("missing file"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("no value {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "no value 7");
    }

    #[test]
    fn context_on_anyhow_result_rewraps() {
        let r: Result<()> = Err(anyhow!("base {}", 1));
        let e = r.with_context(|| "wrapped").unwrap_err();
        assert_eq!(format!("{e:#}"), "wrapped: base 1");
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: bool) -> Result<u32> {
            if x {
                bail!("rejected {x}");
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert!(format!("{}", f(true).unwrap_err()).contains("rejected"));
    }
}
