//! Hardware Accelerator Search walkthrough: run Algorithm 1 for every
//! (model, platform) pair the paper deploys, showing the chosen
//! configuration vector, block balance, GA convergence and resources.
//!
//! Run: `cargo run --release --example hw_search`

use ubimoe::has::{search, HasConfig};
use ubimoe::models::{by_name, m3vit_small};
use ubimoe::resources::Platform;
use ubimoe::sim::engine::{simulate, SimConfig};
use ubimoe::util::table::Table;

fn main() {
    println!("== 2-stage Hardware Accelerator Search (Algorithm 1) ==\n");

    let mut t = Table::new(
        "HAS results",
        &["model", "platform", "F_c", "stage", "L_MSA ms", "L_MoE ms", "DSP", "BRAM36", "e2e ms", "GOPS"],
    );

    let cases = [
        ("m3vit-small", "zcu102", 16u32, 32u32),
        ("m3vit-small", "u280", 16, 32),
        ("vit-t", "zcu102", 16, 16),
        ("vit-s", "u280", 16, 16),
    ];
    for (model_name, plat_name, q, a) in cases {
        let model = by_name(model_name).unwrap();
        let mut platform = Platform::by_name(plat_name).unwrap();
        if a <= 16 && plat_name == "u280" {
            platform.freq_mhz = 250.0; // Table III INT16 timing closure
        }
        let cfg = HasConfig::paper(q, a);
        let r = search(&model, &platform, &cfg);
        let sim = simulate(&SimConfig::new(model.clone(), platform.clone(), r.hw));
        t.row(&[
            model_name.into(),
            platform.name.into(),
            format!("{}", r.hw),
            format!("{:?}", r.stage),
            format!("{:.3}", platform.cycles_to_ms(r.l_msa)),
            format!("{:.3}", platform.cycles_to_ms(r.l_moe)),
            format!("{:.0}", r.resources.dsp),
            format!("{:.0}", r.resources.bram18 / 2.0),
            format!("{:.2}", sim.latency_ms),
            format!("{:.1}", sim.gops),
        ]);
    }
    println!("{}", t.render());

    // GA convergence curve for the headline case.
    let cfg = HasConfig::paper(16, 32);
    let r = search(&m3vit_small(), &Platform::zcu102(), &cfg);
    println!("GA convergence (m3vit-small @ ZCU102, {} evaluations):", r.ga_evaluations);
    let h = &r.ga_history;
    let step = (h.len() / 12).max(1);
    for (gen, fit) in h.iter().enumerate().step_by(step) {
        let bars = ((fit.clamp(0.0, 1.5)) * 40.0) as usize;
        println!("  gen {gen:>3}: {:<60} {fit:.4}", "#".repeat(bars));
    }
    println!("\nfit score (L_MoE*/L_MSA): {:.3} — {:?}", r.fit_score, r.stage);
}
