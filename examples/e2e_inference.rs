//! End-to-end driver (the repo's headline validation): batched MoE-ViT
//! inference through ALL THREE LAYERS — Pallas kernels → JAX model →
//! AOT HLO → Rust PJRT runtime → double-buffered coordinator — on a
//! real small workload, with numerics validated against the JAX golden
//! reference and measured routing fed back into the cycle simulator.
//!
//! Run: `make artifacts && cargo run --release --example e2e_inference [-- N]`
//! Results are recorded in EXPERIMENTS.md §E2E.

use anyhow::{bail, Result};
use std::time::Instant;
use ubimoe::coordinator::batcher::{Batcher, BatcherConfig};
use ubimoe::coordinator::{run_pipeline, run_sequential, Blk2Stage, MsaStage};
use ubimoe::report::deploy;
use ubimoe::resources::Platform;
use ubimoe::runtime::golden::Golden;
use ubimoe::runtime::model::{RuntimeModel, BLK2_KINDS, MSA_KINDS};
use ubimoe::runtime::tensor::Tensor;
use ubimoe::runtime::{artifacts_available, artifacts_dir};
use ubimoe::sim::engine::{simulate, SimConfig};
use ubimoe::sim::moe::GateHistogram;

const CFG: &str = "m3vit-tiny";

fn main() -> Result<()> {
    let n_requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(32);
    let dir = artifacts_dir();
    if !artifacts_available() {
        bail!("no artifacts under {} — run `make artifacts` first", dir.display());
    }

    println!("== UbiMoE end-to-end driver ({n_requests} requests) ==\n");

    // ------------------------------------------------------- load
    let t_load = Instant::now();
    let rt = RuntimeModel::load(&dir, CFG)?;
    println!(
        "[load] {} params, batches {:?}, {:?}",
        rt.weights.total_params(),
        rt.batches(),
        t_load.elapsed()
    );

    // -------------------------------------------- numeric validation
    let g = Golden::load(&dir, CFG)?;
    let logits = rt.forward(g.input()?)?;
    let diff = logits.max_abs_diff(g.logits()?);
    println!("[validate] max |Rust − JAX| = {diff:.3e} (tolerance 2e-4)");
    if diff > 2e-4 {
        bail!("golden validation failed");
    }

    // ------------------------------------------------ batched serving
    // Synthetic request stream through the dynamic batcher (batch-4
    // executables + batch-1 stragglers).
    let mut batcher = Batcher::new(BatcherConfig {
        sizes: rt.batches().to_vec(),
        max_wait: std::time::Duration::from_millis(1),
    });
    for i in 0..n_requests {
        let img = Tensor::random(
            vec![1, rt.cfg.in_chans, rt.cfg.img_size, rt.cfg.img_size],
            0.5,
            9000 + i as u64,
        );
        batcher.push(img);
    }
    let batches = batcher.drain();
    println!(
        "[batcher] {} requests → {} batches (padding slots: {})",
        n_requests,
        batches.len(),
        batches.iter().map(|b| b.padding).sum::<usize>()
    );

    // Embed every batch (host side), collect token tensors.
    let t_embed = Instant::now();
    let mut inputs = Vec::new();
    let mut batch_sizes = Vec::new();
    for b in &batches {
        let imgs = Tensor::cat_batch(
            &b.requests.iter().map(|r| r.payload.clone()).collect::<Vec<_>>(),
        )
        .pad_batch_to(b.batch_size);
        inputs.push(rt.embed(&imgs)?);
        batch_sizes.push(b.batch_size);
    }
    println!("[embed] {} batches in {:?}", inputs.len(), t_embed.elapsed());

    // --------------------------- pipelined vs sequential coordinator
    let depth = rt.cfg.depth;
    let (dir_a, dir_b) = (dir.clone(), dir.clone());
    let (pipe_out, report) = run_pipeline(
        depth,
        inputs.clone(),
        move || Ok(MsaStage(RuntimeModel::load_subset(&dir_a, CFG, MSA_KINDS)?)),
        move || Ok(Blk2Stage(RuntimeModel::load_subset(&dir_b, CFG, BLK2_KINDS)?)),
    )?;
    let msa = MsaStage(RuntimeModel::load_subset(&dir, CFG, MSA_KINDS)?);
    let blk2 = Blk2Stage(RuntimeModel::load_subset(&dir, CFG, BLK2_KINDS)?);
    let (seq_out, seq_wall) = run_sequential(depth, inputs, &msa, &blk2)?;

    for (a, b) in pipe_out.iter().zip(&seq_out) {
        assert!(a.max_abs_diff(b) < 1e-5, "pipeline/sequential mismatch");
    }
    let speedup = seq_wall.as_secs_f64() / report.wall.as_secs_f64();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "[pipeline]   {} batches ({} requests) in {:?} — {:.2} req/s, engine overlap {:.0}%",
        pipe_out.len(),
        n_requests,
        report.wall,
        n_requests as f64 / report.wall.as_secs_f64(),
        report.overlap_fraction * 100.0
    );
    println!(
        "[sequential] same work in {:?} — pipeline/sequential {speedup:.2}x on {cores} core(s){}",
        seq_wall,
        if cores < 2 {
            " (single core: engines timeslice; see ablations bench A for the FPGA-level 1.6-1.7x)"
        } else {
            ""
        }
    );

    // Classify + report a few argmaxes.
    let heads: Result<Vec<usize>> =
        pipe_out.iter().map(|x| Ok(rt.head(x)?.argmax())).collect();
    let heads = heads?;
    println!("[classify] first predictions: {:?}", &heads[..heads.len().min(8)]);

    // ------------------------------- measured routing → simulator
    let mut x = rt.embed(&Tensor::random(vec![1, 3, 64, 64], 0.5, 31337))?;
    let mut hists = Vec::new();
    for layer in 0..depth {
        x = rt.msa(layer, &x)?;
        if rt.cfg.is_moe_layer(layer) {
            let (_, gi) = rt.gate(layer, &x)?;
            hists.push(GateHistogram { tokens_per_expert: rt.histogram(&gi) });
        }
        x = rt.ffn_or_moe(layer, &x)?;
    }
    println!("\n[gate] measured per-expert token loads:");
    for (i, h) in hists.iter().enumerate() {
        println!("  MoE layer {}: {:?}", rt.cfg.moe_layers()[i], h.tokens_per_expert);
    }

    // Project this workload onto the paper's platforms with measured
    // routing (the accelerator-study half of the reproduction).
    println!("\n[sim] projected onto FPGA platforms (HAS-chosen designs, measured routing):");
    let model = ubimoe::models::m3vit_tiny();
    for plat in [Platform::zcu102(), Platform::u280()] {
        let d = deploy(&model, &plat, 16, 32);
        let mut sc = SimConfig::new(model.clone(), d.platform.clone(), d.has.hw);
        sc.histograms = hists.clone();
        let r = simulate(&sc);
        println!(
            "  {:<11} {:>7.3} ms/inf  {:>8.1} GOPS  {:>6.2} W  {:>7.3} GOPS/W  ({})",
            d.platform.name, r.latency_ms, r.gops, r.power_w, r.gops_per_w, d.has.hw
        );
    }

    println!("\ne2e OK");
    Ok(())
}
