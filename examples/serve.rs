//! Serving example: a Poisson request stream through the dynamic
//! batcher and the double-buffered pipeline, reporting p50/p99 request
//! latency and sustained throughput — the "accelerator as a service"
//! view of the system.
//!
//! Run: `make artifacts && cargo run --release --example serve [-- SECONDS]`

use anyhow::{bail, Result};
use std::time::{Duration, Instant};
use ubimoe::coordinator::batcher::{Batcher, BatcherConfig};
use ubimoe::coordinator::metrics::CoordinatorMetrics;
use ubimoe::runtime::model::RuntimeModel;
use ubimoe::runtime::tensor::Tensor;
use ubimoe::runtime::{artifacts_available, artifacts_dir};
use ubimoe::util::rng::Rng;

const CFG: &str = "m3vit-tiny";

fn main() -> Result<()> {
    let seconds: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(10.0);
    let dir = artifacts_dir();
    if !artifacts_available() {
        bail!("no artifacts under {} — run `make artifacts` first", dir.display());
    }

    println!("== UbiMoE serving loop ({seconds:.0}s) ==");
    let rt = RuntimeModel::load(&dir, CFG)?;
    let mut batcher = Batcher::new(BatcherConfig {
        sizes: rt.batches().to_vec(),
        max_wait: Duration::from_millis(5),
    });
    let mut metrics = CoordinatorMetrics::default();
    let mut rng = Rng::new(2024);

    // Offered load: Poisson arrivals at ~70% of measured capacity.
    // First, quickly estimate single-batch latency.
    let probe = Tensor::random(vec![1, 3, 64, 64], 0.5, 1);
    let t = Instant::now();
    let _ = rt.forward(&probe)?;
    let per_inf = t.elapsed().as_secs_f64();
    let rate = 0.7 / per_inf * rt.batches().last().copied().unwrap_or(1) as f64;
    println!("probe: {per_inf:.3}s/inference → offered rate {rate:.1} req/s");

    let t0 = Instant::now();
    let mut next_arrival = 0.0f64;
    let mut slots = 0u64;
    let mut pending_times: std::collections::HashMap<u64, Instant> = Default::default();

    while t0.elapsed().as_secs_f64() < seconds {
        // Admit arrivals up to now (Poisson via exponential gaps).
        while next_arrival <= t0.elapsed().as_secs_f64() {
            let img = Tensor::random(vec![1, 3, 64, 64], 0.5, 5000 + slots);
            let id = batcher.push(img);
            pending_times.insert(id, t0 + Duration::from_secs_f64(next_arrival));
            next_arrival += -(1.0 - rng.f64()).ln() / rate;
        }
        // Serve the next batch if policy allows (the batcher's own
        // wall clock decides timeouts).
        if let Some(batch) = batcher.next_batch() {
            let imgs = Tensor::cat_batch(
                &batch.requests.iter().map(|r| r.payload.clone()).collect::<Vec<_>>(),
            )
            .pad_batch_to(batch.batch_size);
            let t_b = Instant::now();
            let x = rt.embed(&imgs)?;
            let mut y = x;
            for layer in 0..rt.cfg.depth {
                let t_s = Instant::now();
                y = rt.msa(layer, &y)?;
                metrics.msa_stage.record(t_s.elapsed());
                let t_s = Instant::now();
                y = rt.ffn_or_moe(layer, &y)?;
                metrics.ffn_stage.record(t_s.elapsed());
            }
            let _ = rt.head(&y)?;
            let _ = t_b;
            metrics.batches_run += 1;
            metrics.padded_slots += batch.padding as u64;
            slots += batch.batch_size as u64;
            let now = Instant::now();
            for r in &batch.requests {
                if let Some(arr) = pending_times.remove(&r.id) {
                    metrics.request_latency.record(now.duration_since(arr));
                }
                metrics.requests_done += 1;
            }
        } else {
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    let wall = t0.elapsed();
    println!("\n{}", metrics.summary(wall));
    println!(
        "batching: {} slots, padding fraction {:.1}%",
        slots,
        100.0 * metrics.padding_fraction(slots)
    );
    println!(
        "queue left: {} (drained at shutdown in a real deployment)",
        batcher.pending()
    );
    Ok(())
}
