//! Quickstart: load the AOT-compiled m3vit-tiny model, run one
//! inference through the Rust PJRT runtime, and validate against the
//! JAX golden reference.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::{bail, Result};
use ubimoe::runtime::golden::Golden;
use ubimoe::runtime::model::RuntimeModel;
use ubimoe::runtime::{artifacts_available, artifacts_dir};

fn main() -> Result<()> {
    let dir = artifacts_dir();
    if !artifacts_available() {
        bail!("no artifacts under {} — run `make artifacts` first", dir.display());
    }

    println!("== UbiMoE quickstart ==");
    println!("artifacts: {}", dir.display());

    // 1. Load the compiled model (HLO-text blocks + weights).
    let t0 = std::time::Instant::now();
    let rt = RuntimeModel::load(&dir, "m3vit-tiny")?;
    println!(
        "loaded m3vit-tiny: {} parameters, block batches {:?} ({:?})",
        rt.weights.total_params(),
        rt.batches(),
        t0.elapsed()
    );
    println!(
        "model: dim={} heads={} depth={} patches={} experts={} top-{}",
        rt.cfg.dim, rt.cfg.heads, rt.cfg.depth, rt.cfg.patches, rt.cfg.num_experts, rt.cfg.top_k
    );

    // 2. Run the JAX-seeded golden input through the Rust runtime.
    let g = Golden::load(&dir, "m3vit-tiny")?;
    let input = g.input()?;
    let t1 = std::time::Instant::now();
    let logits = rt.forward(input)?;
    println!(
        "forward({}x{}x{}x{}) -> logits {:?} in {:?}",
        input.dims[0], input.dims[1], input.dims[2], input.dims[3],
        logits.dims,
        t1.elapsed()
    );

    // 3. Validate against the JAX reference.
    let want = g.logits()?;
    let diff = logits.max_abs_diff(want);
    println!("max |Rust - JAX| over logits: {diff:.3e}");
    if diff > 2e-4 {
        bail!("numerics diverge from the JAX golden reference");
    }

    // 4. Peek at the gate: which experts did the first MoE layer pick?
    let mut x = rt.embed(input)?;
    let moe_layer = rt.cfg.moe_layers()[0];
    for l in 0..moe_layer {
        x = rt.msa(l, &x)?;
        x = rt.ffn_or_moe(l, &x)?;
    }
    x = rt.msa(moe_layer, &x)?;
    let (_, gi) = rt.gate(moe_layer, &x)?;
    let hist = rt.histogram(&gi);
    println!("layer {moe_layer} expert load histogram: {hist:?}");

    println!("quickstart OK");
    Ok(())
}
