//! Fleet-serving driver: HAS-chosen UbiMoE devices under open-loop
//! load, on the deterministic discrete-event simulator (no artifacts
//! or PJRT needed — this is the deployment-scale companion to
//! `examples/serve.rs`, which drives the real runtime).
//!
//! Run: `cargo run --release --example fleet_serve -- \
//!         [--platform zcu102|u280] [--devices N] [--policy rr|wrr|jsq|affinity|sed] \
//!         [--workload poisson|bursty] [--seconds S]`

use std::time::Duration;

use ubimoe::models::m3vit_small;
use ubimoe::report::serving::{curve_table, fleet_curve, DEFAULT_UTILS, SLO_FACTOR};
use ubimoe::resources::Platform;
use ubimoe::serve::device::DeviceModel;
use ubimoe::serve::dispatch::DispatchPolicy;
use ubimoe::serve::{simulate_fleet, ServeConfig, Workload};

fn flag<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(|s| s.as_str())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let platform = Platform::by_name(flag(&args, "--platform").unwrap_or("u280"))
        .expect("unknown platform (zcu102|u280|u250)");
    let n_devices: usize = flag(&args, "--devices").unwrap_or("4").parse().expect("--devices N");
    let policy = DispatchPolicy::by_name(flag(&args, "--policy").unwrap_or("jsq"))
        .expect("unknown policy (rr|wrr|jsq|affinity|sed)");
    let horizon =
        Duration::from_secs_f64(flag(&args, "--seconds").unwrap_or("10").parse().expect("secs"));
    let bursty = flag(&args, "--workload").unwrap_or("poisson") == "bursty";

    let model = m3vit_small();
    println!(
        "== UbiMoE fleet serving: {} x{} on {}, {} dispatch ==",
        model.name, n_devices, platform.name, policy.name()
    );
    println!("running HAS for the per-device design (once per fleet)...");
    let device = DeviceModel::from_search(&model, &platform, 16, 32, &[1, 2, 4, 8]);
    println!(
        "device: {} — b1 latency {:.2} ms, peak {:.1} req/s, SLO {}x b1 = {:.2} ms\n",
        device.name,
        device.unloaded_latency().as_secs_f64() * 1e3,
        device.peak_rps(),
        SLO_FACTOR,
        (device.unloaded_latency() * SLO_FACTOR).as_secs_f64() * 1e3,
    );

    // Latency–throughput curve (Poisson).
    let pts =
        fleet_curve(&device, n_devices, policy, model.num_experts, DEFAULT_UTILS, horizon, 0xF1EE7);
    println!(
        "{}",
        curve_table(
            &format!("Serving: {} x{} fleet, {}", platform.name, n_devices, model.name),
            &pts
        )
        .render()
    );

    // One detailed run at 0.8x peak, optionally bursty, all policies.
    let peak = device.peak_rps() * n_devices as f64;
    let workload = if bursty {
        Workload::Mmpp2 {
            rate_low_rps: 0.3 * 0.8 * peak,
            rate_high_rps: 1.7 * 0.8 * peak,
            dwell_low: Duration::from_secs(2),
            dwell_high: Duration::from_secs(2),
        }
    } else {
        Workload::Poisson { rate_rps: 0.8 * peak }
    };
    println!(
        "policy comparison at 0.8x peak ({}):",
        if bursty { "bursty MMPP" } else { "Poisson" }
    );
    for p in [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::WeightedRoundRobin,
        DispatchPolicy::JoinShortestQueue,
        DispatchPolicy::ExpertAffinity,
        DispatchPolicy::ShortestExpectedDelay,
    ] {
        let mut cfg = ServeConfig::uniform(device.clone(), n_devices, workload.clone());
        cfg.dispatch = p;
        cfg.horizon = horizon;
        cfg.num_experts = model.num_experts;
        let r = simulate_fleet(&cfg);
        println!("  {:<16} {}", p.name(), r.summary());
    }
}
