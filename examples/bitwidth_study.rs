//! Bit-width study: the Ψ(q) resource function (Eq. 2) against the
//! quantization error each width costs — the trade Table III exploits
//! (INT16/INT8 designs fit more lanes per DSP).
//!
//! Run: `cargo run --release --example bitwidth_study`

use ubimoe::models::m3vit_small;
use ubimoe::report::deploy;
use ubimoe::resources::{psi, Platform};
use ubimoe::util::fixedpoint::Quantizer;
use ubimoe::util::rng::Rng;
use ubimoe::util::table::Table;

fn main() {
    // Synthetic weight population (normal, like trained transformers).
    let mut rng = Rng::new(7);
    let weights: Vec<f32> = (0..200_000).map(|_| rng.normal() as f32 * 0.05).collect();

    let mut t = Table::new(
        "Psi(q) vs quantization error (synthetic N(0, 0.05) weights)",
        &["q bits", "Psi(q) DSP/MAC", "lanes per 1850 DSP (A16)", "RMS error", "rel. error"],
    );
    let rms_ref = {
        let q = Quantizer::calibrate(32, &weights);
        q.rms_error(&weights).max(1e-12)
    };
    for bits in [4u32, 8, 12, 16, 24, 32] {
        let q = Quantizer::calibrate(bits, &weights);
        let rms = q.rms_error(&weights);
        let cost = psi(bits).max(0.125); // LUT-only MACs still cost fabric
        t.row(&[
            bits.to_string(),
            format!("{}", psi(bits)),
            format!("{:.0}", 1850.0 / cost),
            format!("{rms:.3e}"),
            format!("{:.1}x", rms / rms_ref),
        ]);
    }
    println!("{}", t.render());

    // What the extra lanes buy at the system level: deploy M3ViT at
    // W16A32 vs W16A16 on the same device.
    println!("System-level effect (m3vit-small @ ZCU102):");
    for (label, a_bits) in [("W16A32 (Table II)", 32u32), ("W16A16 (Table III class)", 16)] {
        let d = deploy(&m3vit_small(), &Platform::zcu102(), 16, a_bits);
        println!(
            "  {label:<24} {:>8.2} ms  {:>8.1} GOPS  {:>7.3} GOPS/W   {}",
            d.sim.latency_ms, d.sim.gops, d.sim.gops_per_w, d.has.hw
        );
    }
    println!(
        "\nINT16 activations halve the DSP cost per MAC (Eq. 2's leading factor),\n\
         which is how Table III's UbiMoE-E reaches ~3x the W16A32 throughput."
    );
}
