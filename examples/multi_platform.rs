//! Multi-platform study: reproduce the paper's full evaluation section
//! from the analytic stack — Tables I/II/III, the headline ratios, and
//! the figure data — in one run.
//!
//! Run: `cargo run --release --example multi_platform`

use ubimoe::models::m3vit_small;
use ubimoe::report::{figures, headline, tables};
use ubimoe::resources::Platform;

fn main() {
    let (t1, deps) = tables::table1();
    println!("{}", t1.render());
    for d in &deps {
        let b = d.platform.budget();
        println!(
            "  {}: utilization DSP {:.0}%  BRAM {:.0}%  LUT {:.0}%",
            d.platform.name,
            100.0 * d.has.resources.dsp / b.dsp,
            100.0 * d.has.resources.bram18 / b.bram18,
            100.0 * d.has.resources.lut / b.lut
        );
    }
    println!();

    let (t2, points) = tables::table2();
    println!("{}", t2.render());
    let (t3, _) = tables::table3();
    println!("{}", t3.render());

    let h = headline::headline(&points);
    println!("{}", headline::headline_table(&h).render());

    println!("{}", figures::fig4_reorder(&m3vit_small(), 32).render());

    for plat in [Platform::zcu102(), Platform::u280()] {
        let (txt, _) = figures::fig5_placement(&plat);
        println!("{txt}");
    }

    let (ov, _, speedup) = figures::fig3_timeline(&Platform::zcu102());
    println!("Fig. 3b (ZCU102), double-buffering speedup {speedup:.2}x:\n");
    println!("{}", ov.render(100));
}
