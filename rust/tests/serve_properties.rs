//! Property tests over the fleet-serving DES public surface:
//! conservation, causality and determinism must hold for ANY workload,
//! fleet shape and dispatch policy. (Cross-module, so they live in an
//! integration target, like sim_properties.rs.)

use std::time::Duration;

use ubimoe::obs::analyze::{self, SpanOutcome};
use ubimoe::obs::{JsonlSink, Observer, SamplerConfig, TimeSeries};
use ubimoe::serve::autoscale::AutoscaleConfig;
use ubimoe::serve::device::DeviceModel;
use ubimoe::serve::dispatch::{DispatchPolicy, Dispatcher};
use ubimoe::serve::workload::NUM_CLASSES;
use ubimoe::serve::{
    simulate_fleet, simulate_fleet_observed, AdmissionConfig, BreakerConfig, BrownoutConfig,
    ClassMix, FaultConfig, FaultPlan, FaultSpan, FleetReport, OverloadConfig, ServeConfig,
    Workload,
};
use ubimoe::util::proptest::{check, prop_assert, Gen};

/// A synthetic device drawn from a wide but sane (fill, period) range;
/// keeps each DES case millisecond-cheap while exercising every queue
/// regime from idle to deep overload.
fn random_device(g: &mut Gen) -> DeviceModel {
    let period = Duration::from_micros(g.usize(500, 20_000) as u64);
    let fill = Duration::from_micros(g.usize(0, 10_000) as u64);
    let sizes: Vec<usize> = match g.usize(0, 3) {
        0 => vec![1, 4],
        1 => vec![1, 2, 4, 8],
        2 => vec![4],
        _ => vec![2, 8],
    };
    DeviceModel::from_latencies("prop".into(), fill, period, &sizes)
}

fn random_config(g: &mut Gen) -> ServeConfig {
    let device = random_device(g);
    let n_dev = g.usize(1, 4);
    // Offered load from deep-subcritical to 1.6x overload.
    let util = g.f64(0.1, 1.6);
    let rate = (util * device.peak_rps() * n_dev as f64).max(1.0);
    let workload = if g.bool() {
        Workload::Poisson { rate_rps: rate }
    } else {
        Workload::Mmpp2 {
            rate_low_rps: (0.3 * rate).max(0.5),
            rate_high_rps: 1.7 * rate,
            dwell_low: Duration::from_millis(g.usize(100, 2000) as u64),
            dwell_high: Duration::from_millis(g.usize(100, 2000) as u64),
        }
    };
    let mut cfg = ServeConfig::uniform(device, n_dev, workload);
    cfg.dispatch = *g.pick(&[
        DispatchPolicy::RoundRobin,
        DispatchPolicy::JoinShortestQueue,
        DispatchPolicy::ExpertAffinity,
        DispatchPolicy::ShortestExpectedDelay,
    ]);
    cfg.horizon = Duration::from_millis(g.usize(200, 2000) as u64);
    cfg.seed = g.u64();
    cfg.num_experts = g.usize(0, 16);
    cfg
}

#[test]
fn prop_des_conserves_requests() {
    // Every admitted request completes exactly once (double completion
    // panics inside the DES; the counts close the loop), on every
    // device the sums agree, and causality holds: completion ≥ arrival
    // is enforced structurally — e2e/wait/service are computed as
    // unsigned Duration differences, which panic on any negative
    // interval — and the makespan covers the whole schedule.
    check(60, |g| {
        let cfg = random_config(g);
        let r = simulate_fleet(&cfg);
        prop_assert(r.fleet.completed == r.admitted, format!(
            "completed {} != admitted {}", r.fleet.completed, r.admitted
        ))?;
        prop_assert(
            r.fleet.e2e.count() as u64 == r.admitted
                && r.fleet.queue_wait.count() as u64 == r.admitted
                && r.fleet.service.count() as u64 == r.admitted,
            "one latency sample per request",
        )?;
        let per: u64 = r.per_device.iter().map(|d| d.completed).sum();
        prop_assert(per == r.admitted, "per-device completions must sum to admitted")?;
        let slots_ok = r.per_device.iter().all(|d| d.padded_slots <= d.slots);
        prop_assert(slots_ok, "padding cannot exceed executed slots")?;
        // Work conservation: a device is never busy longer than the run.
        let busy_ok = r.per_device.iter().all(|d| d.busy <= r.makespan);
        prop_assert(busy_ok, "device busy time exceeds makespan")
    });
}

#[test]
fn prop_fixed_seed_bit_identical_metrics() {
    check(25, |g| {
        let cfg = random_config(g);
        let a = simulate_fleet(&cfg);
        let b = simulate_fleet(&cfg);
        prop_assert(a == b, format!("non-deterministic run: {} vs {}", a.summary(), b.summary()))
    });
}

#[test]
fn prop_round_robin_fleet_admissions_balanced() {
    // The satellite invariant at fleet scope, end-to-end through the
    // DES: under round-robin dispatch the number of requests each
    // device ends up serving differs by at most one.
    check(40, |g| {
        let mut cfg = random_config(g);
        cfg.dispatch = DispatchPolicy::RoundRobin;
        let r = simulate_fleet(&cfg);
        let counts: Vec<u64> = r.per_device.iter().map(|d| d.completed).collect();
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        prop_assert(max - min <= 1, format!("unbalanced completions {counts:?}"))
    });
}

#[test]
fn prop_dispatcher_round_robin_balances_for_any_loads() {
    // The dispatcher alone, against adversarial load vectors.
    check(200, |g| {
        let n_dev = g.usize(1, 12);
        let n_req = g.usize(1, 300);
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let mut counts = vec![0u64; n_dev];
        for _ in 0..n_req {
            let loads = g.vec_usize(n_dev, 0, 64);
            counts[d.pick(&loads, g.usize(0, 31))] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        prop_assert(max - min <= 1, format!("{counts:?}"))
    });
}

#[test]
fn prop_trace_capture_replays_identically() {
    check(20, |g| {
        let cfg = random_config(g);
        let live = simulate_fleet(&cfg);
        let mut replay = cfg.clone();
        replay.workload = cfg
            .workload
            .to_trace(cfg.horizon, cfg.seed)
            .expect("random_config only generates open-loop workloads");
        replay.seed = cfg.seed; // hints must match too
        let replayed = simulate_fleet(&replay);
        prop_assert(live == replayed, "trace replay diverged from live run")
    });
}

/// Random autoscaling on top of a random open-loop config: window,
/// SLO, target, bounds and patience all fuzzed, so scale-ups, drains,
/// drain-cancellations and slot reuse all get exercised.
fn random_autoscale(g: &mut Gen, cfg: &ServeConfig) -> AutoscaleConfig {
    let device = cfg.devices[0].clone();
    let slo = device.unloaded_latency() * g.usize(1, 12) as u32;
    let mut ac = AutoscaleConfig::for_device(device, slo);
    ac.window = Duration::from_millis(g.usize(20, 400) as u64);
    ac.target_attainment = g.f64(0.5, 0.999);
    ac.min_devices = 1;
    ac.max_devices = cfg.devices.len() + g.usize(0, 4);
    ac.rho_target = g.f64(0.4, 0.95);
    ac.scale_down_patience = g.usize(1, 3) as u32;
    ac
}

#[test]
fn prop_request_conservation_holds_across_scale_events() {
    // The tentpole invariant: adding replicas mid-run and draining
    // them before removal must never lose, duplicate, or strand a
    // request — for ANY workload, fleet, policy and controller
    // configuration.
    check(40, |g| {
        let mut cfg = random_config(g);
        cfg.autoscale = Some(random_autoscale(g, &cfg));
        let r = simulate_fleet(&cfg);
        prop_assert(
            r.fleet.completed == r.admitted,
            format!("completed {} != admitted {}", r.fleet.completed, r.admitted),
        )?;
        prop_assert(
            r.fleet.e2e.count() as u64 == r.admitted,
            "one latency sample per request across scale events",
        )?;
        let per: u64 = r.per_device.iter().map(|d| d.completed).sum();
        prop_assert(per == r.admitted, "per-slot completions must sum to admitted")?;
        let s = r.autoscale.as_ref().expect("autoscaled run must carry a summary");
        prop_assert(
            s.peak_active <= cfg.autoscale.as_ref().unwrap().max_devices
                && s.min_active >= 1,
            format!("fleet left its bounds: {s:?}"),
        )?;
        // Availability accounting stays sane: at least one device the
        // whole run, never more than peak_active devices.
        let end = r.makespan.max(r.horizon).as_secs_f64();
        prop_assert(
            r.device_seconds >= end - 1e-9
                && r.device_seconds <= s.peak_active as f64 * end + 1e-9,
            format!("device-seconds {} outside [{end}, peak x end]", r.device_seconds),
        )
    });
}

#[test]
fn prop_autoscaled_runs_are_bit_identical_per_seed() {
    check(15, |g| {
        let mut cfg = random_config(g);
        cfg.autoscale = Some(random_autoscale(g, &cfg));
        let a = simulate_fleet(&cfg);
        let b = simulate_fleet(&cfg);
        prop_assert(a == b, "autoscaled rerun diverged")
    });
}

fn random_closed_config(g: &mut Gen) -> ServeConfig {
    let device = random_device(g);
    let n_dev = g.usize(1, 4);
    let users = g.usize(1, 64);
    let think = Duration::from_millis(g.usize(0, 200) as u64);
    let mut cfg = ServeConfig::uniform(
        device,
        n_dev,
        Workload::ClosedLoop { users, think_time: think },
    );
    cfg.dispatch = *g.pick(&[
        DispatchPolicy::RoundRobin,
        DispatchPolicy::WeightedRoundRobin,
        DispatchPolicy::JoinShortestQueue,
        DispatchPolicy::ExpertAffinity,
        DispatchPolicy::ShortestExpectedDelay,
    ]);
    cfg.horizon = Duration::from_millis(g.usize(200, 2000) as u64);
    cfg.seed = g.u64();
    cfg.num_experts = g.usize(0, 16);
    cfg
}

/// A random fault configuration targeting a fleet of `n_dev` devices:
/// scripted spans, a possible stochastic MTBF process, deadlines with
/// a random attempt budget, SEU corruption and hedging — every
/// mechanism flipped on independently.
fn random_faults(g: &mut Gen, n_dev: usize, horizon: Duration) -> FaultConfig {
    let h_ms = horizon.as_millis() as usize;
    let mut spans = Vec::new();
    for _ in 0..g.usize(0, 3) {
        let device = g.usize(0, n_dev - 1);
        let from_ms = g.usize(0, h_ms);
        let len_ms = g.usize(1, h_ms / 2 + 1);
        spans.push(FaultSpan::new(
            device,
            Duration::from_millis(from_ms as u64),
            Duration::from_millis((from_ms + len_ms) as u64),
        ));
    }
    FaultConfig {
        plan: FaultPlan::new(spans),
        mtbf: g
            .bool()
            .then(|| Duration::from_millis(g.usize(h_ms / 2 + 1, 4 * h_ms + 2) as u64)),
        mttr: Duration::from_millis(g.usize(1, h_ms / 4 + 2) as u64),
        seu_per_batch: if g.bool() { g.f64(0.0, 0.3) } else { 0.0 },
        deadline: g
            .bool()
            .then(|| Duration::from_millis(g.usize(5, h_ms / 2 + 6) as u64)),
        max_attempts: g.usize(1, 4) as u32,
        backoff_base: Duration::from_millis(g.usize(1, 20) as u64),
        backoff_cap: Duration::from_millis(g.usize(20, 200) as u64),
        hedge_delay: g
            .bool()
            .then(|| Duration::from_millis(g.usize(1, h_ms / 2 + 2) as u64)),
    }
}

#[test]
fn prop_fault_plan_spans_alternate_and_never_overlap() {
    // FaultPlan normalization invariants for scripted, stochastic and
    // merged plans: per device, spans are strictly ordered with gaps
    // between them (so fail/repair events strictly alternate), every
    // span has positive length, and the availability arithmetic closes
    // against the summed downtime.
    check(120, |g| {
        let n_dev = g.usize(1, 6);
        let horizon = Duration::from_millis(g.usize(100, 5000) as u64);
        let h_ms = horizon.as_millis() as usize;
        let mut scripted = Vec::new();
        for _ in 0..g.usize(0, 6) {
            let from_ms = g.usize(0, h_ms);
            scripted.push(FaultSpan::new(
                g.usize(0, n_dev - 1),
                Duration::from_millis(from_ms as u64),
                Duration::from_millis((from_ms + g.usize(1, h_ms + 1)) as u64),
            ));
        }
        let stochastic = FaultPlan::stochastic(
            n_dev,
            Duration::from_millis(g.usize(10, 2 * h_ms + 10) as u64),
            Duration::from_millis(g.usize(1, h_ms + 1) as u64),
            horizon,
            g.u64(),
        );
        let plan = FaultPlan::new(scripted).merged(&stochastic);
        for pair in plan.spans().windows(2) {
            let (a, b) = (pair[0], pair[1]);
            prop_assert(
                a.device < b.device || (a.device == b.device && a.to < b.from),
                format!("spans out of order or overlapping: {a:?} then {b:?}"),
            )?;
        }
        for s in plan.spans() {
            prop_assert(s.from < s.to, format!("degenerate span {s:?}"))?;
            prop_assert(s.device < n_dev, format!("span targets a ghost device: {s:?}"))?;
        }
        // Availability closes against downtime at an arbitrary window.
        let end = Duration::from_millis(g.usize(1, 2 * h_ms + 1) as u64);
        for d in 0..n_dev {
            let down = plan.downtime(d, end);
            prop_assert(down <= end, "downtime cannot exceed the window")?;
            let avail = plan.availability(d, end);
            let expect = 1.0 - down.as_secs_f64() / end.as_secs_f64();
            prop_assert(
                (avail - expect).abs() < 1e-12 && (0.0..=1.0).contains(&avail),
                format!("availability {avail} inconsistent with downtime {down:?}/{end:?}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_inert_fault_config_bit_identical_to_none() {
    // The tentpole zero-cost contract: `faults: Some(all knobs off)`
    // must be indistinguishable — bit-identical FleetReport — from
    // `faults: None`, for ANY workload, fleet and policy.
    check(25, |g| {
        let cfg = random_config(g);
        let plain = simulate_fleet(&cfg);
        let mut inert = cfg.clone();
        inert.faults = Some(FaultConfig::none());
        let r = simulate_fleet(&inert);
        prop_assert(
            r == plain,
            format!("inert fault config perturbed the DES: {} vs {}", r.summary(), plain.summary()),
        )?;
        prop_assert(r.faults.is_none(), "inert config must not report a fault summary")
    });
}

#[test]
fn prop_faulted_runs_conserve_requests_and_are_deterministic() {
    // Chaos conservation: with outages, retries, drops, SEU reruns and
    // hedges all active, every admitted request still settles exactly
    // once — completed + dropped == admitted, one latency sample per
    // completion — and fixed (config, seed) stays bit-identical.
    check(40, |g| {
        let mut cfg = random_config(g);
        cfg.faults = Some(random_faults(g, cfg.devices.len(), cfg.horizon));
        let r = simulate_fleet(&cfg);
        prop_assert(
            r.fleet.completed + r.dropped == r.admitted,
            format!(
                "conservation: completed {} + dropped {} != admitted {}",
                r.fleet.completed, r.dropped, r.admitted
            ),
        )?;
        prop_assert(
            r.fleet.e2e.count() as u64 == r.fleet.completed,
            "one latency sample per completed request",
        )?;
        if cfg.faults.as_ref().unwrap().is_inert() {
            prop_assert(r.faults.is_none(), "inert config must not report a summary")?;
        } else {
            let fs = r.faults.as_ref().expect("active fault config must report a summary");
            prop_assert(fs.dropped == r.dropped, "summary and report disagree on drops")?;
            prop_assert(fs.hedge_wins <= fs.hedges, "hedge wins exceed hedges")?;
            let end = r.makespan.max(r.horizon);
            let ok = (0..cfg.devices.len())
                .all(|d| (0.0..=1.0).contains(&fs.availability(d, end)));
            prop_assert(ok, "per-slot availability outside [0, 1]")?;
        }
        let b = simulate_fleet(&cfg);
        prop_assert(r == b, "faulted rerun diverged")
    });
}

#[test]
fn prop_closed_loop_conserves_and_is_deterministic() {
    // The satellite contract for ANY closed-loop population: every
    // issued request completes exactly once, a user never has two
    // requests in flight (admitted per user bounded by completions +
    // 1), and fixed (users, seed) ⇒ bit-identical reports.
    check(30, |g| {
        let cfg = random_closed_config(g);
        let users = match cfg.workload {
            Workload::ClosedLoop { users, .. } => users as u64,
            _ => unreachable!(),
        };
        let r = simulate_fleet(&cfg);
        prop_assert(
            r.fleet.completed == r.admitted,
            format!("completed {} != admitted {}", r.fleet.completed, r.admitted),
        )?;
        prop_assert(
            r.fleet.e2e.count() as u64 == r.admitted,
            "one latency sample per request",
        )?;
        // One request per user at a time, and a user cycle is at
        // least one service time (≥ 0.5 ms for these devices): the
        // admission count is structurally bounded.
        prop_assert(
            r.admitted <= users * (2 * r.makespan.as_millis() as u64 + 2),
            format!("absurd admission count {} for a closed loop", r.admitted),
        )?;
        let b = simulate_fleet(&cfg);
        prop_assert(r == b, "closed-loop rerun diverged")
    });
}

// ---- overload protection -------------------------------------------

/// A random overload configuration for `cfg`'s fleet: shadow flag,
/// per-class rate caps / resident limits / attempt budgets, breakers
/// and brownout all flipped on independently — including the inert
/// all-off corner.
fn random_overload(g: &mut Gen, cfg: &ServeConfig) -> OverloadConfig {
    let device = cfg.devices[0].clone();
    let n_dev = cfg.devices.len();
    let largest = *device.batch_sizes.last().unwrap();
    let floor = n_dev * largest;
    let mix = *g.pick(&[
        ClassMix::standard(),
        ClassMix::interactive_only(),
        ClassMix { interactive: 0.2, batch: 0.3, background: 0.5 },
    ]);
    let mut rate_caps = [None; NUM_CLASSES];
    let mut queue_limits = [None; NUM_CLASSES];
    let mut attempt_budget = [None; NUM_CLASSES];
    for c in 0..NUM_CLASSES {
        if g.bool() {
            rate_caps[c] = Some(g.f64(1.0, 2.0 * device.peak_rps() * n_dev as f64));
        }
        if g.bool() {
            // Deliberately includes limits below the in-flight floor:
            // miscalibrated limits shed traffic the fleet could have
            // served, but conservation must still close.
            queue_limits[c] = Some(g.usize(1, 4 * floor));
        }
        if g.bool() {
            attempt_budget[c] = Some(g.usize(1, 4) as u32);
        }
    }
    let admission = g
        .bool()
        .then(|| AdmissionConfig { rate_caps, burst: g.f64(1.0, 32.0), queue_limits, attempt_budget });
    let breaker = g.bool().then(|| BreakerConfig {
        trip_after: g.usize(1, 5) as u32,
        cooldown: Duration::from_millis(g.usize(1, 200) as u64),
    });
    let brownout = g.bool().then(|| BrownoutConfig {
        window: Duration::from_millis(g.usize(5, 200) as u64),
        slo: device.unloaded_latency() * g.usize(1, 8) as u32,
        enter_attainment: g.f64(0.5, 0.9),
        exit_attainment: g.f64(0.91, 0.999),
        enter_patience: g.usize(1, 3) as u32,
        exit_patience: g.usize(1, 6) as u32,
        degraded: vec![device.degraded(g.usize(1, 4) as u32, 4); n_dev],
        accuracy_cost_per_request: g.f64(0.0, 0.1),
    });
    OverloadConfig { mix, shadow: g.bool(), admission, breaker, brownout }
}

#[test]
fn prop_overload_runs_conserve_requests_and_are_deterministic() {
    // The tentpole invariant, extended: with admission control,
    // shedding, breakers, brownout AND the PR 6 fault machinery all
    // active at once, every arrival still settles exactly once —
    // completed + dropped + rejected == offered — the per-class
    // ledgers partition, and fixed (config, seed) stays bit-identical.
    check(40, |g| {
        let mut cfg = random_config(g);
        cfg.overload = Some(random_overload(g, &cfg));
        if g.bool() {
            cfg.faults = Some(random_faults(g, cfg.devices.len(), cfg.horizon));
        }
        let r = simulate_fleet(&cfg);
        prop_assert(
            r.fleet.completed + r.dropped + r.rejected == r.admitted,
            format!(
                "conservation: completed {} + dropped {} + rejected {} != offered {}",
                r.fleet.completed, r.dropped, r.rejected, r.admitted
            ),
        )?;
        prop_assert(
            r.fleet.e2e.count() as u64 == r.fleet.completed,
            "one latency sample per completed request",
        )?;
        if cfg.overload.as_ref().unwrap().is_inert() {
            prop_assert(r.overload.is_none(), "inert overload must not report a summary")?;
            prop_assert(r.rejected == 0, "inert overload cannot reject")?;
        } else {
            let ov = r.overload.as_ref().expect("active overload must report a summary");
            prop_assert(
                ov.offered_by_class.iter().sum::<u64>() == r.admitted,
                "class ledger must partition the offered count",
            )?;
            prop_assert(ov.rejected == r.rejected, "summary and report disagree on rejects")?;
            prop_assert(
                ov.rejected_rate + ov.rejected_queue == ov.rejected,
                "reject reasons must partition the rejects",
            )?;
            for c in 0..NUM_CLASSES {
                prop_assert(
                    ov.offered_by_class[c] == ov.admitted_by_class[c] + ov.rejected_by_class[c],
                    format!("class {c}: offered != admitted + rejected"),
                )?;
                prop_assert(
                    ov.completed_by_class[c] <= ov.admitted_by_class[c],
                    format!("class {c}: more completions than admissions"),
                )?;
                prop_assert(
                    ov.e2e_by_class[c].count() as u64 == ov.completed_by_class[c],
                    format!("class {c}: one latency sample per completion"),
                )?;
            }
            prop_assert(ov.breaker_closes <= ov.breaker_trips, "closes exceed trips")?;
            prop_assert(
                ov.degraded_completions <= r.fleet.completed,
                "degraded completions exceed completions",
            )?;
        }
        let b = simulate_fleet(&cfg);
        prop_assert(r == b, "overloaded rerun diverged")
    });
}

#[test]
fn prop_inert_overload_config_bit_identical_to_none() {
    // The zero-cost contract, same as PR 6's fault version:
    // `overload: Some(all knobs off)` must be indistinguishable —
    // bit-identical FleetReport, no class-RNG draws — from
    // `overload: None`, for ANY workload, fleet and policy.
    check(25, |g| {
        let cfg = random_config(g);
        let plain = simulate_fleet(&cfg);
        let mut inert = cfg.clone();
        inert.overload = Some(if g.bool() {
            OverloadConfig::default()
        } else {
            OverloadConfig { admission: Some(AdmissionConfig::unlimited()), ..OverloadConfig::default() }
        });
        let r = simulate_fleet(&inert);
        prop_assert(
            r == plain,
            format!(
                "inert overload config perturbed the DES: {} vs {}",
                r.summary(),
                plain.summary()
            ),
        )?;
        prop_assert(r.overload.is_none(), "inert config must not report an overload summary")?;
        prop_assert(r.rejected == 0, "inert config cannot reject")
    });
}

#[test]
fn prop_rate_cap_shedding_is_monotone_in_the_cap() {
    // Shedding monotonicity: tightening ONLY the background rate cap
    // (identical arrivals, identical class labels — the class stream
    // is drawn per arrival in arrival order regardless of the
    // verdict) can only shed more background, and must leave the
    // uncapped classes' admission ledgers untouched. Token-bucket
    // admission is monotone in the refill rate, so this holds
    // per-run, not just in expectation.
    check(30, |g| {
        let mut cfg = random_config(g);
        let bg_rate =
            0.2 * cfg.workload.offered_rps(cfg.horizon, cfg.seed).expect("open-loop workload");
        let cap_loose = (g.f64(0.05, 1.5) * bg_rate).max(0.5);
        let cap_tight = cap_loose * g.f64(0.1, 0.9);
        let burst = g.f64(1.0, 16.0);
        let with_cap = |cap: f64| OverloadConfig {
            mix: ClassMix::standard(),
            shadow: false,
            admission: Some(AdmissionConfig {
                rate_caps: [None, None, Some(cap)],
                burst,
                ..AdmissionConfig::unlimited()
            }),
            breaker: None,
            brownout: None,
        };
        cfg.overload = Some(with_cap(cap_loose));
        let loose = simulate_fleet(&cfg);
        cfg.overload = Some(with_cap(cap_tight));
        let tight = simulate_fleet(&cfg);
        let (lo, to) = (
            loose.overload.as_ref().expect("capped run reports a summary"),
            tight.overload.as_ref().expect("capped run reports a summary"),
        );
        prop_assert(
            lo.offered_by_class == to.offered_by_class,
            "same seed must label the same arrivals identically",
        )?;
        for c in 0..2 {
            prop_assert(
                lo.admitted_by_class[c] == to.admitted_by_class[c]
                    && to.rejected_by_class[c] == 0,
                format!("uncapped class {c} must admit identically"),
            )?;
        }
        prop_assert(
            to.admitted_by_class[2] <= lo.admitted_by_class[2],
            format!(
                "tighter cap admitted more background: {} (cap {cap_tight:.2}) > {} (cap {cap_loose:.2})",
                to.admitted_by_class[2], lo.admitted_by_class[2]
            ),
        )?;
        prop_assert(
            tight.rejected >= loose.rejected,
            "tighter cap must not reject less overall",
        )
    });
}

// ---- expert sharding -----------------------------------------------

use ubimoe::serve::{CapacityConfig, DriftConfig, RebalanceConfig, ShardConfig};

/// A random *valid* live shard configuration for `cfg`'s fleet:
/// top-k, skew, replication, drift, capacity windows and the
/// rebalancer all fuzzed independently (every window strictly
/// positive, bounds within `validate()`'s contract). The caller must
/// ensure `cfg.num_experts ≥ 1` and `cfg.autoscale == None`.
fn random_shard(g: &mut Gen, cfg: &ServeConfig) -> ShardConfig {
    let num_experts = cfg.num_experts;
    ShardConfig {
        top_k: g.usize(1, num_experts),
        zipf_s: g.f64(0.0, 2.5),
        replication: g.usize(1, cfg.devices.len()),
        hot_experts: g.usize(0, num_experts),
        drift: g.bool().then(|| DriftConfig {
            every: Duration::from_millis(g.usize(1, 500) as u64),
            shift: g.usize(0, num_experts),
        }),
        capacity: g.bool().then(|| CapacityConfig {
            window: Duration::from_millis(g.usize(1, 300) as u64),
            cap_tokens: g.usize(1, 64) as u64,
        }),
        rebalance: g.bool().then(|| RebalanceConfig {
            every: Duration::from_millis(g.usize(1, 500) as u64),
        }),
        transfer_cost: Duration::from_micros(g.usize(0, 2000) as u64),
        expert_drop_cost: g.f64(0.0, 0.1),
    }
}

#[test]
fn prop_sharded_runs_conserve_requests_and_are_deterministic() {
    // The tentpole invariant at full generality: with top-k routing,
    // capacity reroutes, expert drops, replication, drift, the
    // rebalancer AND the fault + overload machinery all active, every
    // routed token still settles exactly once —
    // (completed − degraded) + degraded + dropped + rejected == routed
    // — and fixed (config, seed) stays bit-identical.
    check(40, |g| {
        let mut cfg = random_config(g);
        cfg.num_experts = g.usize(1, 16);
        let shard = random_shard(g, &cfg);
        cfg.shard = Some(shard);
        if g.bool() {
            cfg.faults = Some(random_faults(g, cfg.devices.len(), cfg.horizon));
        }
        if g.bool() {
            cfg.overload = Some(OverloadConfig {
                mix: ClassMix::standard(),
                shadow: false,
                admission: Some(AdmissionConfig::tiered(g.usize(1, 64))),
                breaker: None,
                brownout: None,
            });
        }
        let r = simulate_fleet(&cfg);
        let ss = r.shard.as_ref().expect("live shard config must report a summary");
        prop_assert(
            ss.routed == r.admitted,
            format!("routed {} != offered {}", ss.routed, r.admitted),
        )?;
        prop_assert(
            ss.degraded_completions <= r.fleet.completed,
            "degraded completions exceed completions",
        )?;
        let settled = (r.fleet.completed - ss.degraded_completions)
            + ss.degraded_completions
            + r.dropped
            + r.rejected;
        prop_assert(
            settled == ss.routed,
            format!(
                "sharded conservation: (completed {} − degraded {}) + degraded + dropped {} \
                 + rejected {} != routed {}",
                r.fleet.completed, ss.degraded_completions, r.dropped, r.rejected, ss.routed
            ),
        )?;
        prop_assert(
            ss.no_replica_drops <= r.dropped,
            "no-replica drops exceed total drops",
        )?;
        prop_assert(
            ss.rerouted + ss.expert_drops <= ss.routed,
            "reroutes + expert drops exceed routed",
        )?;
        let b = simulate_fleet(&cfg);
        prop_assert(r == b, "sharded rerun diverged")
    });
}

#[test]
fn prop_inert_shard_config_bit_identical_to_none() {
    // The zero-cost contract, same as the fault and overload versions:
    // `shard: Some(inert)` must be indistinguishable — bit-identical
    // FleetReport, no router-RNG draws — from `shard: None`, for ANY
    // workload, fleet and policy.
    check(25, |g| {
        let cfg = random_config(g);
        let plain = simulate_fleet(&cfg);
        let mut inert = cfg.clone();
        inert.shard = Some(ShardConfig::default());
        let r = simulate_fleet(&inert);
        prop_assert(
            r == plain,
            format!(
                "inert shard config perturbed the DES: {} vs {}",
                r.summary(),
                plain.summary()
            ),
        )?;
        prop_assert(r.shard.is_none(), "inert config must not report a shard summary")
    });
}

#[test]
fn prop_sharded_runs_bit_identical_per_seed() {
    check(15, |g| {
        let mut cfg = random_config(g);
        cfg.num_experts = g.usize(1, 16);
        cfg.shard = Some(random_shard(g, &cfg));
        let a = simulate_fleet(&cfg);
        let b = simulate_fleet(&cfg);
        prop_assert(a == b, "sharded rerun diverged across identical (config, seed)")
    });
}

// ---- fleet-report memoization --------------------------------------

/// Per-case scratch cache directory (pid + case counter: unique even
/// though this binary's tests run concurrently).
fn memo_scratch() -> std::path::PathBuf {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join(format!(
        "ubimoe-fleet-memo-prop-{}-{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn prop_fleet_memo_warm_bit_identical_to_cold() {
    // The ISSUE 10 memo contract, identity half: for ANY (ServeConfig,
    // seed) across the shard × fault × overload × autoscale knobs, a
    // memo-warm `get_or_compute_fleet` — a disk round trip through the
    // `ubimoe-fleet` text schema — returns a `FleetReport` bit-identical
    // to both the cold run and a direct `simulate_fleet`. (The zero-DES
    // counter half lives in rust/tests/fleet_cache.rs, which serializes
    // on the process-global counters; this test binary runs its cases
    // concurrently, so it must not assert on them.)
    check(12, |g| {
        let mut cfg = random_config(g);
        if g.bool() {
            cfg.faults = Some(random_faults(g, cfg.devices.len(), cfg.horizon));
        }
        if g.bool() {
            cfg.overload = Some(random_overload(g, &cfg));
        }
        // Autoscale and shard are mutually exclusive by validate();
        // draw at most one of them.
        match g.usize(0, 2) {
            0 => cfg.autoscale = Some(random_autoscale(g, &cfg)),
            1 => {
                cfg.num_experts = g.usize(1, 16);
                cfg.shard = Some(random_shard(g, &cfg));
            }
            _ => {}
        }
        if cfg.validate().is_err() {
            // A randomly-inert corner that validate() rejects (e.g.
            // shard bounds vs fleet size) — skip, the DES would refuse.
            return Ok(());
        }
        let dir = memo_scratch();
        let cache = ubimoe::has::cache::DesignCache::at(&dir);
        let cold = cache.get_or_compute_fleet(&cfg);
        let direct = simulate_fleet(&cfg);
        let warm = cache.get_or_compute_fleet(&cfg);
        let _ = std::fs::remove_dir_all(&dir);
        prop_assert(
            cold == direct,
            format!("memoized cold run diverged from direct run: {}", cold.summary()),
        )?;
        prop_assert(
            warm == cold,
            format!(
                "disk round trip not bit-identical: {} vs {}",
                warm.summary(),
                cold.summary()
            ),
        )
    });
}

// ---- observability -------------------------------------------------

/// Run the DES fully observed — JSONL trace into memory plus a sampled
/// time series — returning the report and both rendered artifacts.
fn run_observed(cfg: &ServeConfig) -> (FleetReport, String, String) {
    let mut sink = JsonlSink::new(Vec::new());
    let mut series = TimeSeries::new();
    let r = simulate_fleet_observed(
        cfg,
        Observer { trace: Some(&mut sink), series: Some(&mut series) },
    );
    let bytes = sink.finish().expect("in-memory sink cannot fail");
    (r, String::from_utf8(bytes).expect("trace is ASCII"), series.to_csv())
}

/// A random sampling cadence, sometimes with an SLO for the windowed
/// attainment gauge.
fn random_sampler(g: &mut Gen, cfg: &ServeConfig) -> SamplerConfig {
    SamplerConfig {
        every: Duration::from_millis(g.usize(1, 300) as u64),
        slo: g
            .bool()
            .then(|| cfg.devices[0].unloaded_latency() * g.usize(1, 8) as u32),
    }
}

#[test]
fn prop_observation_never_perturbs_the_report() {
    // The tentpole zero-cost contract: running the same (config, seed)
    // with full tracing AND time-series sampling on must produce a
    // bit-identical `FleetReport` to the unobserved run — for ANY
    // workload, fleet, policy, fault and autoscale configuration. (The
    // sampler schedules real heap events; the DES compensates its own
    // event/peak bookkeeping, and this test is what holds it to that.)
    check(20, |g| {
        let mut cfg = random_config(g);
        if g.bool() {
            cfg.faults = Some(random_faults(g, cfg.devices.len(), cfg.horizon));
        }
        if g.bool() {
            cfg.autoscale = Some(random_autoscale(g, &cfg));
        }
        let plain = simulate_fleet(&cfg);
        let mut observed = cfg.clone();
        observed.sampler = Some(random_sampler(g, &cfg));
        let (r, trace, csv) = run_observed(&observed);
        prop_assert(
            r == plain,
            format!("observation perturbed the DES: {} vs {}", r.summary(), plain.summary()),
        )?;
        // The artifacts must actually carry data: meta + summary at
        // minimum, and the CSV its header.
        prop_assert(trace.lines().count() >= 2, "trace must carry records")?;
        prop_assert(csv.starts_with("t_ns,device,"), "csv must carry the schema header")
    });
}

#[test]
fn prop_trace_and_timeseries_byte_identical_per_seed() {
    // Fixed (config, seed) ⇒ byte-identical trace and time-series
    // files: no wall clock, no map iteration order, no float
    // formatting drift anywhere in the emission path.
    check(15, |g| {
        let mut cfg = random_config(g);
        cfg.sampler = Some(random_sampler(g, &cfg));
        if g.bool() {
            cfg.faults = Some(random_faults(g, cfg.devices.len(), cfg.horizon));
        }
        let (ra, trace_a, csv_a) = run_observed(&cfg);
        let (rb, trace_b, csv_b) = run_observed(&cfg);
        prop_assert(ra == rb, "observed rerun diverged")?;
        prop_assert(trace_a == trace_b, "trace files differ across identical runs")?;
        prop_assert(csv_a == csv_b, "time-series files differ across identical runs")
    });
}

#[test]
fn prop_span_reconstruction_conserves_requests() {
    // The analyzer must reconstruct every admitted request from the
    // trace alone — under random fault configs (outages, retries,
    // drops, SEU reruns, hedges): spans == admitted, attempts ≥ spans
    // (every request is dispatched at least once), span outcomes match
    // the report's completed/dropped split, and the reconstructed
    // latency components reconcile with `FleetReport`'s stats.
    check(25, |g| {
        let mut cfg = random_config(g);
        cfg.faults = Some(random_faults(g, cfg.devices.len(), cfg.horizon));
        let (r, trace, _csv) = run_observed(&cfg);
        let a = analyze::analyze(&trace).expect("simulator-written trace must parse");
        prop_assert(
            a.spans.len() as u64 == r.admitted,
            format!("span count {} != admitted {}", a.spans.len(), r.admitted),
        )?;
        prop_assert(
            a.completed_count() == r.fleet.completed && a.dropped_count() == r.dropped,
            format!(
                "span outcomes ({}/{}) disagree with report ({}/{})",
                a.completed_count(),
                a.dropped_count(),
                r.fleet.completed,
                r.dropped
            ),
        )?;
        prop_assert(a.total_attempts() >= r.admitted, "every request is dispatched at least once")?;
        prop_assert(
            a.admitted == r.admitted && a.completed == r.fleet.completed && a.dropped == r.dropped,
            "summary record disagrees with the report",
        )?;
        // Per-span component reconciliation: the winning attempt's
        // queue + service plus retry backoff never exceeds e2e (the
        // residual is the failover penalty, ≥ 0 by construction — this
        // checks the saturation never actually fires).
        for s in &a.spans {
            if let SpanOutcome::Done { e2e_ns, queue_ns, service_ns, .. } = s.outcome {
                prop_assert(
                    queue_ns + service_ns + s.backoff_ns <= e2e_ns,
                    format!(
                        "req {}: components {} + {} + {} exceed e2e {}",
                        s.req, queue_ns, service_ns, s.backoff_ns, e2e_ns
                    ),
                )?;
            }
        }
        // Aggregate reconciliation against the report's recorder. The
        // trace carries exact ns; LatencyStats truncates samples to µs
        // before an exact sum (≤ 2 µs total drift on the mean), and its
        // p99 reports a histogram bucket upper bound within 1/128 above
        // the exact nearest-rank sample.
        if r.fleet.completed > 0 {
            let mean = a.mean_e2e_ns();
            let report_mean = r.fleet.e2e.mean().as_nanos() as u64;
            prop_assert(
                mean.abs_diff(report_mean) <= 2_000,
                format!("analyzer mean {mean}ns vs report mean {report_mean}ns"),
            )?;
            let mut e2e: Vec<u64> = a
                .spans
                .iter()
                .filter_map(|s| match s.outcome {
                    SpanOutcome::Done { e2e_ns, .. } => Some(e2e_ns),
                    _ => None,
                })
                .collect();
            e2e.sort_unstable();
            let rank = ((0.99 * e2e.len() as f64).ceil() as usize).clamp(1, e2e.len());
            let exact_p99 = e2e[rank - 1];
            let report_p99 = r.fleet.e2e.p99().as_nanos() as u64;
            prop_assert(
                report_p99 + 2_000 >= exact_p99
                    && report_p99 <= exact_p99 + exact_p99 / 128 + 2_000,
                format!("analyzer p99 {exact_p99}ns vs report p99 {report_p99}ns"),
            )?;
        }
        Ok(())
    });
}
