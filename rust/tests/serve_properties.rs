//! Property tests over the fleet-serving DES public surface:
//! conservation, causality and determinism must hold for ANY workload,
//! fleet shape and dispatch policy. (Cross-module, so they live in an
//! integration target, like sim_properties.rs.)

use std::time::Duration;

use ubimoe::serve::device::DeviceModel;
use ubimoe::serve::dispatch::{DispatchPolicy, Dispatcher};
use ubimoe::serve::{simulate_fleet, ServeConfig, Workload};
use ubimoe::util::proptest::{check, prop_assert, Gen};

/// A synthetic device drawn from a wide but sane (fill, period) range;
/// keeps each DES case millisecond-cheap while exercising every queue
/// regime from idle to deep overload.
fn random_device(g: &mut Gen) -> DeviceModel {
    let period = Duration::from_micros(g.usize(500, 20_000) as u64);
    let fill = Duration::from_micros(g.usize(0, 10_000) as u64);
    let sizes: Vec<usize> = match g.usize(0, 3) {
        0 => vec![1, 4],
        1 => vec![1, 2, 4, 8],
        2 => vec![4],
        _ => vec![2, 8],
    };
    DeviceModel::from_latencies("prop".into(), fill, period, &sizes)
}

fn random_config(g: &mut Gen) -> ServeConfig {
    let device = random_device(g);
    let n_dev = g.usize(1, 4);
    // Offered load from deep-subcritical to 1.6x overload.
    let util = g.f64(0.1, 1.6);
    let rate = (util * device.peak_rps() * n_dev as f64).max(1.0);
    let workload = if g.bool() {
        Workload::Poisson { rate_rps: rate }
    } else {
        Workload::Mmpp2 {
            rate_low_rps: (0.3 * rate).max(0.5),
            rate_high_rps: 1.7 * rate,
            mean_dwell: Duration::from_millis(g.usize(100, 2000) as u64),
        }
    };
    let mut cfg = ServeConfig::uniform(device, n_dev, workload);
    cfg.dispatch = *g.pick(&[
        DispatchPolicy::RoundRobin,
        DispatchPolicy::JoinShortestQueue,
        DispatchPolicy::ExpertAffinity,
        DispatchPolicy::ShortestExpectedDelay,
    ]);
    cfg.horizon = Duration::from_millis(g.usize(200, 2000) as u64);
    cfg.seed = g.u64();
    cfg.num_experts = g.usize(0, 16);
    cfg
}

#[test]
fn prop_des_conserves_requests() {
    // Every admitted request completes exactly once (double completion
    // panics inside the DES; the counts close the loop), on every
    // device the sums agree, and causality holds: completion ≥ arrival
    // is enforced structurally — e2e/wait/service are computed as
    // unsigned Duration differences, which panic on any negative
    // interval — and the makespan covers the whole schedule.
    check(60, |g| {
        let cfg = random_config(g);
        let r = simulate_fleet(&cfg);
        prop_assert(r.fleet.completed == r.admitted, format!(
            "completed {} != admitted {}", r.fleet.completed, r.admitted
        ))?;
        prop_assert(
            r.fleet.e2e.count() as u64 == r.admitted
                && r.fleet.queue_wait.count() as u64 == r.admitted
                && r.fleet.service.count() as u64 == r.admitted,
            "one latency sample per request",
        )?;
        let per: u64 = r.per_device.iter().map(|d| d.completed).sum();
        prop_assert(per == r.admitted, "per-device completions must sum to admitted")?;
        let slots_ok = r.per_device.iter().all(|d| d.padded_slots <= d.slots);
        prop_assert(slots_ok, "padding cannot exceed executed slots")?;
        // Work conservation: a device is never busy longer than the run.
        let busy_ok = r.per_device.iter().all(|d| d.busy <= r.makespan);
        prop_assert(busy_ok, "device busy time exceeds makespan")
    });
}

#[test]
fn prop_fixed_seed_bit_identical_metrics() {
    check(25, |g| {
        let cfg = random_config(g);
        let a = simulate_fleet(&cfg);
        let b = simulate_fleet(&cfg);
        prop_assert(a == b, format!("non-deterministic run: {} vs {}", a.summary(), b.summary()))
    });
}

#[test]
fn prop_round_robin_fleet_admissions_balanced() {
    // The satellite invariant at fleet scope, end-to-end through the
    // DES: under round-robin dispatch the number of requests each
    // device ends up serving differs by at most one.
    check(40, |g| {
        let mut cfg = random_config(g);
        cfg.dispatch = DispatchPolicy::RoundRobin;
        let r = simulate_fleet(&cfg);
        let counts: Vec<u64> = r.per_device.iter().map(|d| d.completed).collect();
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        prop_assert(max - min <= 1, format!("unbalanced completions {counts:?}"))
    });
}

#[test]
fn prop_dispatcher_round_robin_balances_for_any_loads() {
    // The dispatcher alone, against adversarial load vectors.
    check(200, |g| {
        let n_dev = g.usize(1, 12);
        let n_req = g.usize(1, 300);
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let mut counts = vec![0u64; n_dev];
        for _ in 0..n_req {
            let loads = g.vec_usize(n_dev, 0, 64);
            counts[d.pick(&loads, g.usize(0, 31))] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        prop_assert(max - min <= 1, format!("{counts:?}"))
    });
}

#[test]
fn prop_trace_capture_replays_identically() {
    check(20, |g| {
        let cfg = random_config(g);
        let live = simulate_fleet(&cfg);
        let mut replay = cfg.clone();
        replay.workload = cfg.workload.to_trace(cfg.horizon, cfg.seed);
        replay.seed = cfg.seed; // hints must match too
        let replayed = simulate_fleet(&replay);
        prop_assert(live == replayed, "trace replay diverged from live run")
    });
}
