//! Golden-trace test: a tiny 2-device scripted-fault scenario whose
//! JSONL trace is checked in byte-for-byte (`golden/trace_small.jsonl`)
//! — any change to record kinds, field names, field order, number
//! formatting, or the DES's event interleaving shows up as a diff of
//! that file, not as a silent schema drift.
//!
//! The scenario is small enough to verify by hand (5 requests, one
//! mid-run outage that kills an in-flight batch and forces a failover)
//! yet touches arrival, dispatch, batch open/done, done, device
//! fail/repair and summary records. It draws from no RNG stream at
//! all: a `Workload::Trace` schedule, `num_experts: 0` (no hints) and
//! a scripted `FaultPlan` make the whole run a closed-form schedule.
//!
//! To re-bless after an *intentional* schema change:
//!
//! ```text
//! UBIMOE_BLESS_GOLDEN=1 cargo test --test trace_golden
//! ```
//!
//! then commit the updated golden alongside a `TRACE_SCHEMA` bump.

use std::time::Duration;

use ubimoe::obs::analyze::{analyze, SpanOutcome};
use ubimoe::obs::{JsonlSink, Observer};
use ubimoe::serve::device::DeviceModel;
use ubimoe::serve::{
    simulate_fleet_observed, FaultConfig, FaultPlan, FaultSpan, FleetReport, ServeConfig,
    Workload,
};

const GOLDEN: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/trace_small.jsonl");

fn ms(x: u64) -> Duration {
    Duration::from_millis(x)
}

/// The scripted scenario: 2 identical devices (service(1) = 3 ms),
/// JSQ, arrivals at 0/1/2/6/12 ms, device 0 down over [5 ms, 9 ms).
/// The outage kills device 0's in-flight batch (request 2), which
/// fails over to device 1 and completes there behind request 3.
fn golden_cfg() -> ServeConfig {
    let device =
        DeviceModel::from_latencies("golden".into(), ms(1), ms(2), &[1]);
    let mut cfg = ServeConfig::uniform(
        device,
        2,
        Workload::Trace { arrivals: vec![ms(0), ms(1), ms(2), ms(6), ms(12)] },
    );
    cfg.horizon = ms(20);
    cfg.seed = 7;
    cfg.num_experts = 0;
    cfg.faults = Some(FaultConfig {
        plan: FaultPlan::new(vec![FaultSpan::new(0, ms(5), ms(9))]),
        ..FaultConfig::none()
    });
    cfg
}

fn run_traced() -> (FleetReport, String) {
    let cfg = golden_cfg();
    let mut sink = JsonlSink::new(Vec::new());
    let r = simulate_fleet_observed(&cfg, Observer::with_trace(&mut sink));
    let bytes = sink.finish().expect("in-memory sink cannot fail");
    (r, String::from_utf8(bytes).expect("trace is ASCII"))
}

#[test]
fn golden_trace_is_byte_exact() {
    let (_, actual) = run_traced();
    if std::env::var_os("UBIMOE_BLESS_GOLDEN").is_some() {
        std::fs::write(GOLDEN, &actual).expect("bless golden trace");
        return;
    }
    let expected = std::fs::read_to_string(GOLDEN).expect("read checked-in golden trace");
    if actual != expected {
        // Line-level diff before the hard failure: schema drifts are
        // then obvious from the test log alone.
        for (i, (a, e)) in actual.lines().zip(expected.lines()).enumerate() {
            assert_eq!(a, e, "trace diverges from golden at line {}", i + 1);
        }
        assert_eq!(
            actual.lines().count(),
            expected.lines().count(),
            "trace length diverges from golden"
        );
        panic!("trace differs from golden in trailing bytes only");
    }
}

#[test]
fn truncated_golden_trace_analyzes_the_valid_prefix() {
    // Satellite contract: `ubimoe trace analyze` must tolerate a
    // JSONL file cut off mid-line (a run killed mid-write) — analyze
    // the valid prefix and warn, instead of erroring out.
    let full = std::fs::read_to_string(GOLDEN).expect("read checked-in golden trace");
    let clean = analyze(&full).expect("golden trace must parse");
    assert!(clean.truncation.is_none());
    assert_eq!(clean.skipped_lines, 0);
    // Cut inside the last record's "kind" key so the ragged tail is
    // genuinely unparseable ("t","kind" lead every record, so a cut
    // that keeps them still parses as a field-poor record).
    let cut = &full[..full.rfind("\"kind\"").unwrap() + 4];
    let a = analyze(cut).expect("truncated golden must still analyze");
    assert!(a.truncation.is_some(), "the ragged tail must be surfaced");
    assert_eq!(a.skipped_lines, 1);
    // The prefix still reconstructs every span the full trace has
    // (only the trailing summary record was damaged).
    assert_eq!(a.spans.len(), clean.spans.len());
    assert_eq!(a.completed_count(), clean.completed_count());
    assert_eq!(a.admitted, 0, "the summary record was the casualty");
    let out = a.render(None, 20);
    assert!(out.contains("WARNING: truncated trace"), "{out}");
    assert!(out.contains("1 line(s) skipped"), "{out}");
}

#[test]
fn golden_run_is_repeatable() {
    let (ra, ta) = run_traced();
    let (rb, tb) = run_traced();
    assert_eq!(ra, rb, "golden rerun diverged");
    assert_eq!(ta, tb, "golden trace not byte-deterministic");
}

#[test]
fn analyzer_reconciles_with_fleet_report() {
    // The acceptance contract: the offline breakdown derived from the
    // trace alone must reconcile with the FleetReport's own recorders.
    let (r, trace) = run_traced();
    let a = analyze(&trace).expect("golden trace must parse");

    assert_eq!(a.spans.len() as u64, r.admitted);
    assert_eq!(a.completed_count(), r.fleet.completed);
    assert_eq!(a.dropped_count(), r.dropped);
    // Request 2 was dispatched twice (arrival + failover).
    assert_eq!(a.total_attempts(), r.admitted + 1);
    assert_eq!(a.fault_spans, vec![(0, 5_000_000, 9_000_000)]);

    // e2e samples are 3/3/6/5/3 ms: the mean (4 ms) is exact in both
    // views; p99 hits the exactly-tracked max (6 ms); p50 (3 ms) is
    // reported by the report's histogram within its 1/128 bucket
    // resolution.
    assert_eq!(a.mean_e2e_ns(), 4_000_000);
    assert_eq!(r.fleet.e2e.mean().as_nanos(), 4_000_000);
    assert_eq!(r.fleet.e2e.p99().as_nanos(), 6_000_000);
    let p50 = r.fleet.e2e.p50().as_nanos() as u64;
    assert!(
        (3_000_000..=3_000_000 + 3_000_000 / 128).contains(&p50),
        "report p50 {p50}ns outside histogram tolerance of exact 3ms"
    );

    // The failed-over request carries the whole outage penalty: 6 ms
    // e2e − 0 queue − 3 ms service = 3 ms burned on the lost attempt.
    let s2 = &a.spans[2];
    assert_eq!(s2.attempts, 2);
    assert_eq!(s2.failover_penalty_ns(), 3_000_000);
    match s2.outcome {
        SpanOutcome::Done { device, e2e_ns, queue_ns, service_ns, .. } => {
            assert_eq!(device, 1);
            assert_eq!(e2e_ns, 6_000_000);
            assert_eq!(queue_ns, 0);
            assert_eq!(service_ns, 3_000_000);
        }
        ref o => panic!("request 2 must complete, got {o:?}"),
    }
    // Request 3 queued behind the failover on device 1.
    match a.spans[3].outcome {
        SpanOutcome::Done { queue_ns, .. } => assert_eq!(queue_ns, 2_000_000),
        ref o => panic!("request 3 must complete, got {o:?}"),
    }

    // The rendered report carries the reconciliation surface.
    let out = a.render(Some(ms(4)), 40);
    assert!(out.contains("5 completed requests"), "{out}");
    assert!(out.contains("failover penalty"), "{out}");
    assert!(out.contains("incident timeline"), "{out}");
}
