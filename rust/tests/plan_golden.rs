//! Golden-plan test: the `ubimoe plan --small` frontier table is
//! checked in byte-for-byte (`golden/plan_small.txt`) — any change to
//! the planner's candidate enumeration, objective arithmetic, frontier
//! sort, label format or the table renderer shows up as a diff of that
//! file, not as silent drift.
//!
//! The fixture ([`ubimoe::report::plan::small_spec`]) draws from no RNG
//! stream at all — trace arrivals, no experts, a 4-genome exhaustive
//! space — so every cell is a closed-form hand computation (spelled
//! out in the `small_spec` docs): three mutually non-dominated
//! compositions with exact (device-seconds, p99, energy).
//!
//! To re-bless after an *intentional* format change:
//!
//! ```text
//! UBIMOE_BLESS_GOLDEN=1 cargo test --test plan_golden
//! ```

use ubimoe::has::cache::DesignCache;
use ubimoe::has::fleet::plan_fleet;
use ubimoe::report::plan::{frontier_table, small_spec};

const GOLDEN: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/plan_small.txt");

fn render_small() -> String {
    let spec = small_spec();
    let out = plan_fleet(&spec, &DesignCache::disabled()).expect("small spec is valid");
    frontier_table(&spec, &out).render()
}

#[test]
fn golden_plan_table_is_byte_exact() {
    let actual = render_small();
    if std::env::var_os("UBIMOE_BLESS_GOLDEN").is_some() {
        std::fs::write(GOLDEN, &actual).expect("bless golden plan table");
        return;
    }
    let expected = std::fs::read_to_string(GOLDEN).expect("read checked-in golden plan table");
    if actual != expected {
        // Line-level diff before the hard failure: drifts are then
        // obvious from the test log alone.
        for (i, (a, e)) in actual.lines().zip(expected.lines()).enumerate() {
            assert_eq!(a, e, "plan table diverges from golden at line {}", i + 1);
        }
        assert_eq!(
            actual.lines().count(),
            expected.lines().count(),
            "plan table length diverges from golden"
        );
        panic!("plan table differs from golden in trailing bytes only");
    }
}

#[test]
fn golden_plan_is_repeatable() {
    assert_eq!(render_small(), render_small(), "plan table not byte-deterministic");
}

#[test]
fn golden_covers_three_non_dominated_points() {
    // The ISSUE 10 acceptance floor, pinned at the golden fixture: the
    // frontier carries at least 3 points and they are mutually
    // non-dominated.
    let spec = small_spec();
    let out = plan_fleet(&spec, &DesignCache::disabled()).expect("small spec is valid");
    assert!(out.frontier.len() >= 3, "frontier too small: {}", out.frontier.len());
    for (i, a) in out.frontier.iter().enumerate() {
        for (j, b) in out.frontier.iter().enumerate() {
            if i != j {
                assert!(
                    !a.objectives.dominates(&b.objectives),
                    "frontier point {i} dominates {j}"
                );
            }
        }
    }
}
