//! Property tests over the whole-simulator surface: invariants that
//! must hold for ANY hardware configuration the search might visit.
//! (These are cross-module, so they live in an integration target.)

use ubimoe::models::{m3vit_small, m3vit_tiny, vit_t};
use ubimoe::resources::{AttnParams, LinearParams, Platform};
use ubimoe::sim::engine::{simulate, simulate_sequential, SimConfig};
use ubimoe::sim::HwChoice;
use ubimoe::util::proptest::{check, prop_assert, Gen};

fn random_hw(g: &mut Gen) -> HwChoice {
    HwChoice {
        num: g.usize(1, 4),
        attn: AttnParams {
            t_a: *g.pick(&[2usize, 4, 8, 16, 32]),
            n_a: *g.pick(&[1usize, 2, 4, 8, 16]),
        },
        lin: LinearParams {
            t_in: *g.pick(&[2usize, 4, 8, 16, 32]),
            t_out: *g.pick(&[2usize, 4, 8, 16, 32]),
            n_l: g.usize(1, 8),
        },
        q_bits: 16,
        a_bits: *g.pick(&[16u32, 32]),
    }
}

#[test]
fn prop_latency_positive_finite_for_any_config() {
    check(120, |g| {
        let model = match g.usize(0, 2) {
            0 => m3vit_small(),
            1 => m3vit_tiny(),
            _ => vit_t(),
        };
        let plat = if g.bool() { Platform::zcu102() } else { Platform::u280() };
        let hw = random_hw(g);
        let r = simulate(&SimConfig::new(model, plat, hw));
        prop_assert(
            r.latency_ms.is_finite() && r.latency_ms > 0.0,
            format!("latency {} for {hw}", r.latency_ms),
        )?;
        prop_assert(r.gops > 0.0 && r.power_w > 0.0, "gops/power")?;
        prop_assert(
            (r.gops_per_w - r.gops / r.power_w).abs() < 1e-9,
            "efficiency identity",
        )
    });
}

#[test]
fn prop_double_buffering_never_hurts() {
    check(80, |g| {
        let hw = random_hw(g);
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), hw);
        let on = simulate(&sc);
        let off = simulate_sequential(&sc);
        prop_assert(
            on.total_cycles <= off.total_cycles * 1.001,
            format!("overlap slower: {} > {} for {hw}", on.total_cycles, off.total_cycles),
        )
    });
}

#[test]
fn prop_more_linear_lanes_never_slower() {
    check(80, |g| {
        let mut hw = random_hw(g);
        hw.lin.n_l = g.usize(1, 4);
        let sc1 = SimConfig::new(m3vit_small(), Platform::u280(), hw);
        let mut hw2 = hw;
        hw2.lin.n_l *= 2;
        let sc2 = SimConfig::new(m3vit_small(), Platform::u280(), hw2);
        let (a, b) = (simulate(&sc1), simulate(&sc2));
        prop_assert(
            b.total_cycles <= a.total_cycles * 1.001,
            format!("doubling N_L slowed: {} -> {} ({hw})", a.total_cycles, b.total_cycles),
        )
    });
}

#[test]
fn prop_attention_pes_never_slower() {
    check(80, |g| {
        let mut hw = random_hw(g);
        hw.attn.n_a = g.usize(1, 8);
        let sc1 = SimConfig::new(m3vit_small(), Platform::u280(), hw);
        let mut hw2 = hw;
        hw2.attn.n_a *= 2;
        let sc2 = SimConfig::new(m3vit_small(), Platform::u280(), hw2);
        prop_assert(
            simulate(&sc2).total_cycles <= simulate(&sc1).total_cycles * 1.001,
            format!("doubling N_a slowed ({hw})"),
        )
    });
}

#[test]
fn prop_resources_monotone_in_every_gene() {
    check(150, |g| {
        let hw = random_hw(g);
        let model = m3vit_small();
        let base = hw.resources(model.heads, model.patches, model.dim);
        // Bump one gene; every resource column must be >= the base.
        let mut bumped = hw;
        match g.usize(0, 4) {
            0 => bumped.num += 1,
            1 => bumped.attn.t_a *= 2,
            2 => bumped.attn.n_a *= 2,
            3 => bumped.lin.t_in *= 2,
            _ => bumped.lin.n_l += 1,
        }
        let up = bumped.resources(model.heads, model.patches, model.dim);
        prop_assert(
            up.dsp >= base.dsp - 1e-9 && up.bram18 >= base.bram18 - 1e-9,
            format!("resources shrank: {hw} -> {bumped}"),
        )
    });
}

#[test]
fn prop_faster_memory_never_slower() {
    check(60, |g| {
        let hw = random_hw(g);
        let mut slow_plat = Platform::zcu102();
        slow_plat.bw_gbs = 9.6;
        let fast_plat = Platform::zcu102(); // 19.2 GB/s
        let a = simulate(&SimConfig::new(m3vit_small(), slow_plat, hw));
        let b = simulate(&SimConfig::new(m3vit_small(), fast_plat, hw));
        prop_assert(
            b.total_cycles <= a.total_cycles * 1.001,
            format!("doubling BW slowed ({hw})"),
        )
    });
}

#[test]
fn prop_timeline_spans_well_formed() {
    check(60, |g| {
        let hw = random_hw(g);
        let r = simulate(&SimConfig::new(m3vit_tiny(), Platform::zcu102(), hw));
        for s in &r.timeline.spans {
            prop_assert(
                s.end >= s.start && s.start >= 0.0,
                format!("bad span {s:?} ({hw})"),
            )?;
        }
        prop_assert(!r.timeline.spans.is_empty(), "empty timeline")
    });
}
