//! Integration: measured gate routing (real PJRT gate_probe output)
//! feeds the cycle simulator — closing the loop between the numeric
//! runtime and the accelerator model, plus cross-module sanity on the
//! full report pipeline.

use ubimoe::coordinator::scheduler::MoeSchedule;
use ubimoe::models::m3vit_small;
use ubimoe::report::deploy;
use ubimoe::resources::Platform;
use ubimoe::runtime::model::RuntimeModel;
use ubimoe::runtime::tensor::Tensor;
use ubimoe::runtime::{artifacts_available, artifacts_dir};
use ubimoe::sim::engine::{simulate, SimConfig};
use ubimoe::sim::moe::GateHistogram;

const CFG: &str = "m3vit-tiny";

fn skip() -> bool {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return true;
    }
    false
}

#[test]
fn measured_histograms_drive_simulator() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let rt = RuntimeModel::load(&dir, CFG).unwrap();
    // Real forward up to each MoE layer, collecting real gate outputs.
    let img = Tensor::random(vec![1, 3, 64, 64], 0.5, 77);
    let mut x = rt.embed(&img).unwrap();
    let mut hists = Vec::new();
    for layer in 0..rt.cfg.depth {
        x = rt.msa(layer, &x).unwrap();
        if rt.cfg.is_moe_layer(layer) {
            let (_, gi) = rt.gate(layer, &x).unwrap();
            let h = rt.histogram(&gi);
            assert_eq!(h.iter().sum::<usize>(), rt.cfg.patches * rt.cfg.top_k);
            hists.push(GateHistogram { tokens_per_expert: h });
        }
        x = rt.ffn_or_moe(layer, &x).unwrap();
    }
    assert_eq!(hists.len(), rt.cfg.moe_layers().len());

    // Feed measured routing into the simulator and compare against the
    // synthetic balanced assumption: latency must be finite, positive,
    // and within a reasonable factor (the router bounds skew effects).
    let model = ubimoe::models::m3vit_tiny();
    let d = deploy(&model, &Platform::zcu102(), 16, 32);
    let mut sc = SimConfig::new(model.clone(), Platform::zcu102(), d.has.hw);
    let balanced = simulate(&sc);
    sc.histograms = hists;
    let measured = simulate(&sc);
    assert!(measured.total_cycles > 0.0);
    let ratio = measured.total_cycles / balanced.total_cycles;
    assert!(
        (0.8..=1.6).contains(&ratio),
        "measured routing changed latency by {ratio}x — router model broken?"
    );
}

#[test]
fn real_gate_schedule_balances_cus() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let rt = RuntimeModel::load(&dir, CFG).unwrap();
    let img = Tensor::random(vec![1, 3, 64, 64], 0.5, 88);
    let mut x = rt.embed(&img).unwrap();
    let moe_layer = rt.cfg.moe_layers()[0];
    for layer in 0..=moe_layer {
        x = rt.msa(layer, &x).unwrap();
        if layer < moe_layer {
            x = rt.ffn_or_moe(layer, &x).unwrap();
        }
    }
    let (_, gi) = rt.gate(moe_layer, &x).unwrap();
    let sched = MoeSchedule::from_gate(&gi.data, rt.cfg.num_experts, rt.cfg.top_k, 4);
    assert_eq!(sched.total_assignments(), rt.cfg.patches * rt.cfg.top_k);
    for w in &sched.items {
        // The round-robin router's invariant, on REAL gate data.
        assert!(w.cu_assignment.max_load() - w.cu_assignment.min_load() <= 1);
    }
}

#[test]
fn full_report_pipeline_smoke() {
    // No artifacts needed — the analytic path end to end.
    let d = deploy(&m3vit_small(), &Platform::zcu102(), 16, 32);
    assert!(d.sim.latency_ms > 1.0);
    assert!(d.has.resources.fits(&d.platform.budget()));
    let p = d.perf_point("UbiMoE");
    assert!(p.gops_per_w() > 1.0);
}
