//! Fleet-report memo + planner acceptance tests (ISSUE 10) — the
//! counter-asserting half of the contract:
//!
//! 1. **Zero DES work when warm** — a warm
//!    `DesignCache::get_or_compute_fleet` performs zero DES runs and
//!    zero DES events, proven by the `obs::registry` work counters.
//! 2. **Corruption ⇒ cold recompute** — a stale schema version, a key
//!    mismatch or arbitrary garbage in a fleet artifact reads as a
//!    miss: the DES reruns, the file is repaired, and the next call is
//!    a pure hit again (the PR 4 idiom at fleet scope).
//! 3. **Warm plan reruns do zero simulation** — `plan_fleet` over a
//!    warm cache (exhaustive *and* GA mode) re-derives a bit-identical
//!    frontier with zero DES event loops and zero GA true evals.
//!
//! The work counters are process-wide, so every test here serializes
//! on one mutex and the file is its own test binary (its own process)
//! — the `design_cache.rs` pattern.

use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Duration;

use ubimoe::has::cache::DesignCache;
use ubimoe::has::fleet::{plan_fleet, FleetSpec, PlanTemplate, PlanVariant, Scenario};
use ubimoe::has::ga::GaParams;
use ubimoe::obs::registry;
use ubimoe::report::plan::{run_grid, small_spec};
use ubimoe::serve::device::DeviceModel;
use ubimoe::serve::dispatch::DispatchPolicy;
use ubimoe::serve::{ServeConfig, Workload};

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn scratch_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("ubimoe-fleet-cache-it-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn ms(x: u64) -> Duration {
    Duration::from_millis(x)
}

/// A deterministic, millisecond-cheap DES config (no RNG streams:
/// trace arrivals, no experts).
fn tiny_cfg() -> ServeConfig {
    let device = DeviceModel::from_latencies("tiny".into(), ms(1), ms(2), &[1, 2]);
    let mut cfg = ServeConfig::uniform(
        device,
        2,
        Workload::Trace { arrivals: vec![ms(0), ms(1), ms(3), ms(4), ms(9)] },
    );
    cfg.horizon = ms(30);
    cfg.seed = 41;
    cfg.num_experts = 0;
    cfg
}

#[test]
fn warm_fleet_memo_performs_zero_des_work() {
    let _g = lock();
    let dir = scratch_dir("warm");
    let cache = DesignCache::at(&dir);
    let cfg = tiny_cfg();

    let before = registry::snapshot();
    let cold = cache.get_or_compute_fleet(&cfg);
    let cold_work = registry::snapshot().delta(&before);
    assert!(
        cold_work.des_runs >= 1 && cold_work.des_events > 0,
        "cold run must actually drive the event loop: {cold_work:?}"
    );
    assert!(cold_work.cache_stores >= 1, "cold run must persist the report: {cold_work:?}");

    let before = registry::snapshot();
    let warm = cache.get_or_compute_fleet(&cfg);
    let warm_work = registry::snapshot().delta(&before);
    assert!(
        warm_work.no_des_work(),
        "warm fleet memo ran the event loop: {warm_work:?}"
    );
    assert!(warm_work.cache_hits >= 1, "warm call must hit the artifact: {warm_work:?}");
    assert_eq!(warm, cold, "disk round trip must be bit-identical");

    // The scoped-thread grid runner over an all-warm grid is also free.
    let cfgs = vec![cfg.clone(), cfg.clone(), cfg];
    let before = registry::snapshot();
    let grid = run_grid(&cache, &cfgs);
    let grid_work = registry::snapshot().delta(&before);
    assert!(grid_work.no_des_work(), "warm run_grid ran the event loop: {grid_work:?}");
    for r in &grid {
        assert_eq!(*r, cold);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_or_stale_fleet_artifacts_fall_back_to_cold_run() {
    let _g = lock();
    let dir = scratch_dir("fallback");
    let cache = DesignCache::at(&dir);
    let cfg = tiny_cfg();
    let first = cache.get_or_compute_fleet(&cfg);

    let artifact_file = || -> PathBuf {
        let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
            .expect("cache dir exists")
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .map(|n| n.to_string_lossy().starts_with("fleet-"))
                    .unwrap_or(false)
            })
            .collect();
        files.sort();
        assert_eq!(files.len(), 1, "exactly one fleet artifact expected: {files:?}");
        files.remove(0)
    };

    // Stale schema version ⇒ miss ⇒ cold recompute + repair.
    let path = artifact_file();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, text.replacen("ubimoe-fleet v", "ubimoe-fleet v999", 1)).unwrap();
    let before = registry::snapshot();
    let again = cache.get_or_compute_fleet(&cfg);
    let work = registry::snapshot().delta(&before);
    assert_eq!(again, first, "recomputed report must match");
    assert!(
        work.cache_misses >= 1 && work.des_runs >= 1,
        "stale version must re-simulate: {work:?}"
    );

    // Key mismatch (simulated hash collision) ⇒ miss.
    let text = std::fs::read_to_string(&path).unwrap();
    let mangled = text
        .lines()
        .map(|l| if l.starts_with("key=") { "key=not-this-config".to_string() } else { l.to_string() })
        .collect::<Vec<_>>()
        .join("\n");
    std::fs::write(&path, mangled + "\n").unwrap();
    let before = registry::snapshot();
    let repaired = cache.get_or_compute_fleet(&cfg);
    let work = registry::snapshot().delta(&before);
    assert_eq!(repaired, first);
    assert!(work.cache_misses >= 1 && work.des_runs >= 1, "collision must miss: {work:?}");

    // Arbitrary garbage ⇒ still a miss, still no panic.
    std::fs::write(&path, b"\x00\xff not a fleet artifact \x7f").unwrap();
    let garbage = cache.get_or_compute_fleet(&cfg);
    assert_eq!(garbage, first);

    // After the repairs the artifact is valid again: pure hit.
    let before = registry::snapshot();
    let warm = cache.get_or_compute_fleet(&cfg);
    let work = registry::snapshot().delta(&before);
    assert_eq!(warm, first);
    assert!(work.no_des_work(), "repaired artifact must serve warm: {work:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_plan_rerun_performs_zero_des_work_exhaustive_mode() {
    let _g = lock();
    let dir = scratch_dir("plan-exhaustive");
    let cache = DesignCache::at(&dir);
    let spec = small_spec();

    let before = registry::snapshot();
    let cold = plan_fleet(&spec, &cache).expect("small spec is valid");
    let cold_work = registry::snapshot().delta(&before);
    assert!(cold.exhaustive);
    assert!(cold_work.des_runs >= 3, "cold plan must simulate every composition: {cold_work:?}");

    let before = registry::snapshot();
    let warm = plan_fleet(&spec, &cache).expect("small spec is valid");
    let warm_work = registry::snapshot().delta(&before);
    assert!(
        warm_work.no_des_work(),
        "warm plan rerun ran DES event loops: {warm_work:?}"
    );
    assert_eq!(
        warm_work.ga_true_evals, 0,
        "the planner must never charge GA true-eval work: {warm_work:?}"
    );
    assert_eq!(warm.frontier.len(), cold.frontier.len());
    for (a, b) in warm.frontier.iter().zip(&cold.frontier) {
        assert_eq!(a.candidate, b.candidate);
        assert_eq!(
            a.objectives.device_seconds.to_bits(),
            b.objectives.device_seconds.to_bits()
        );
        assert_eq!(a.objectives.p99_ms.to_bits(), b.objectives.p99_ms.to_bits());
        assert_eq!(a.objectives.energy_j.to_bits(), b.objectives.energy_j.to_bits());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A GA-sized spec (space > EXHAUSTIVE_LIMIT) over cheap synthetic
/// templates: 4 templates × counts 0..=3 (256) × 3 policies = 768
/// genomes on a 6-request trace.
fn ga_spec() -> FleetSpec {
    let dev = |name: &str, fill_ms: u64, period_ms: u64| {
        DeviceModel::from_latencies(name.into(), ms(fill_ms), ms(period_ms), &[1])
    };
    let tpl = |name: &str, fill_ms: u64, period_ms: u64, watts: f64| PlanTemplate {
        name: name.into(),
        variants: vec![PlanVariant {
            label: "w16".into(),
            device: dev(name, fill_ms, period_ms),
            watts,
        }],
        max_count: 3,
    };
    FleetSpec {
        name: "ga-tiny".into(),
        templates: vec![
            tpl("a", 1, 1, 4.0),
            tpl("b", 1, 2, 3.0),
            tpl("c", 2, 1, 6.0),
            tpl("d", 2, 3, 2.0),
        ],
        scenarios: vec![Scenario {
            label: "trace6".into(),
            workload: Workload::Trace {
                arrivals: vec![ms(0), ms(1), ms(2), ms(4), ms(6), ms(9)],
            },
            horizon: ms(40),
            seed: 5,
        }],
        policies: vec![
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::ShortestExpectedDelay,
        ],
        autoscale_presets: vec![],
        num_experts: 0,
        ga: GaParams { population: 10, generations: 6, ..GaParams::default() },
        weight_profiles: vec![[1.0, 1.0, 1.0], [1.0, 4.0, 1.0]],
    }
}

#[test]
fn warm_plan_rerun_performs_zero_des_work_ga_mode() {
    let _g = lock();
    let dir = scratch_dir("plan-ga");
    let cache = DesignCache::at(&dir);
    let spec = ga_spec();
    assert!(
        spec.space_size() > ubimoe::has::fleet::EXHAUSTIVE_LIMIT,
        "spec must exercise the GA path (space = {})",
        spec.space_size()
    );

    let before = registry::snapshot();
    let cold = plan_fleet(&spec, &cache).expect("ga spec is valid");
    let cold_work = registry::snapshot().delta(&before);
    assert!(!cold.exhaustive);
    assert!(cold.ga_evaluations > 0, "GA mode must report fitness invocations");
    assert!(cold_work.des_runs >= 1, "cold GA plan must simulate: {cold_work:?}");
    // The frontier size depends on which genomes the (seeded) GA
    // visits; non-emptiness is the structural guarantee here — the
    // ≥3-point acceptance check runs on the exhaustive small spec and
    // on the demo spec in CI.
    assert!(!cold.frontier.is_empty());

    // The GA is seeded, so a rerun revisits exactly the same genomes —
    // every DES run the search needs is already on disk.
    let before = registry::snapshot();
    let warm = plan_fleet(&spec, &cache).expect("ga spec is valid");
    let warm_work = registry::snapshot().delta(&before);
    assert!(
        warm_work.no_des_work(),
        "warm GA plan rerun ran DES event loops: {warm_work:?}"
    );
    assert_eq!(warm_work.ga_true_evals, 0);
    assert_eq!(warm.ga_evaluations, cold.ga_evaluations, "GA schedule must be deterministic");
    assert_eq!(warm.frontier.len(), cold.frontier.len());
    for (a, b) in warm.frontier.iter().zip(&cold.frontier) {
        assert_eq!(a.candidate, b.candidate);
        assert_eq!(
            a.objectives.device_seconds.to_bits(),
            b.objectives.device_seconds.to_bits()
        );
        assert_eq!(a.objectives.p99_ms.to_bits(), b.objectives.p99_ms.to_bits());
        assert_eq!(a.objectives.energy_j.to_bits(), b.objectives.energy_j.to_bits());
    }
    let _ = std::fs::remove_dir_all(&dir);
}
