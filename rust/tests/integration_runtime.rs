//! Integration: the Rust PJRT runtime must reproduce the JAX golden
//! reference end-to-end — the strongest evidence that all three layers
//! (Pallas kernel → JAX model → HLO → Rust runtime) compose correctly.
//!
//! These tests skip (with a note) when `make artifacts` has not run.

use ubimoe::runtime::golden::Golden;
use ubimoe::runtime::model::RuntimeModel;
use ubimoe::runtime::tensor::Tensor;
use ubimoe::runtime::{artifacts_available, artifacts_dir};

const CFG: &str = "m3vit-tiny";
/// f32 accumulation-order differences between XLA CPU and jax on CPU.
const ATOL: f32 = 2e-4;

fn skip() -> bool {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return true;
    }
    false
}

#[test]
fn forward_matches_golden_logits() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let rt = RuntimeModel::load(&dir, CFG).unwrap();
    let g = Golden::load(&dir, CFG).unwrap();
    let input = g.input().unwrap();
    let logits = rt.forward(input).unwrap();
    let want = g.logits().unwrap();
    let diff = logits.max_abs_diff(want);
    assert!(diff < ATOL, "logits diverge: max|Δ| = {diff}");
}

#[test]
fn per_layer_activations_match_golden() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let rt = RuntimeModel::load(&dir, CFG).unwrap();
    let g = Golden::load(&dir, CFG).unwrap();
    let mut x = rt.embed(g.input().unwrap()).unwrap();
    let emb_diff = x.max_abs_diff(g.get("embed").unwrap());
    assert!(emb_diff < ATOL, "embed diverges: {emb_diff}");
    for layer in 0..rt.cfg.depth {
        x = rt.msa(layer, &x).unwrap();
        x = rt.ffn_or_moe(layer, &x).unwrap();
        let want = g.layer(layer).unwrap();
        let diff = x.max_abs_diff(want);
        assert!(diff < ATOL, "layer {layer} diverges: max|Δ| = {diff}");
    }
}

#[test]
fn monolithic_executable_matches_block_pipeline() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let rt =
        RuntimeModel::load_subset(&dir, CFG, ubimoe::runtime::model::ALL_KINDS).unwrap();
    let g = Golden::load(&dir, CFG).unwrap();
    let input = g.input().unwrap();
    let blockwise = rt.forward(input).unwrap();
    let mono = rt.forward_monolithic(input).unwrap();
    let diff = blockwise.max_abs_diff(&mono);
    assert!(diff < ATOL, "block vs monolithic diverge: {diff}");
}

#[test]
fn batch4_equals_four_batch1() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let rt = RuntimeModel::load(&dir, CFG).unwrap();
    let g = Golden::load(&dir, CFG).unwrap();
    let input = g.input().unwrap(); // batch 4
    let b4 = rt.forward(input).unwrap();
    for i in 0..4 {
        let single = input.slice_batch(i, 1);
        let b1 = rt.forward(&single).unwrap();
        let diff = b1.max_abs_diff(&b4.slice_batch(i, 1));
        assert!(diff < ATOL, "sample {i}: batch-4 vs batch-1 diverge by {diff}");
    }
}

#[test]
fn gate_probe_consistent_and_conserving() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let rt = RuntimeModel::load(&dir, CFG).unwrap();
    let g = Golden::load(&dir, CFG).unwrap();
    let mut x = rt.embed(g.input().unwrap()).unwrap();
    let moe_layer = rt.cfg.moe_layers()[0];
    for layer in 0..moe_layer {
        x = rt.msa(layer, &x).unwrap();
        x = rt.ffn_or_moe(layer, &x).unwrap();
    }
    x = rt.msa(moe_layer, &x).unwrap();
    let (gw, gi) = rt.gate(moe_layer, &x).unwrap();
    let b = x.dims[0];
    let n = rt.cfg.patches;
    let k = rt.cfg.top_k;
    assert_eq!(gi.dims, vec![b, n, k]);
    assert_eq!(gw.dims, vec![b, n, k]);
    // Gate weights renormalized per token.
    for t in 0..b * n {
        let s: f32 = gw.data[t * k..(t + 1) * k].iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "token {t}: gate weights sum {s}");
    }
    // Indices in range and distinct per token.
    for t in 0..b * n {
        let row = &gi.data[t * k..(t + 1) * k];
        for &e in row {
            assert!((e as usize) < rt.cfg.num_experts);
        }
        assert_ne!(row[0], row[1], "top-2 must pick distinct experts");
    }
    // Histogram conserves assignments.
    let h = rt.histogram(&gi);
    assert_eq!(h.iter().sum::<usize>(), b * n * k);
}

#[test]
fn literal_and_buffer_paths_agree() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let rt = RuntimeModel::load(&dir, CFG).unwrap();
    let x = Tensor::random(vec![1, rt.cfg.patches, rt.cfg.dim], 0.5, 99);
    let via_buffers = rt.msa(0, &x).unwrap();
    let via_literals = rt.msa_via_literals(0, &x).unwrap();
    let diff = via_buffers.max_abs_diff(&via_literals);
    assert!(diff < 1e-6, "buffer vs literal paths diverge: {diff}");
}

#[test]
fn deterministic_across_runs() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let rt = RuntimeModel::load(&dir, CFG).unwrap();
    let img = Tensor::random(vec![1, 3, 64, 64], 0.5, 7);
    let a = rt.forward(&img).unwrap();
    let b = rt.forward(&img).unwrap();
    assert_eq!(a, b, "same input must give bit-identical logits");
}
