//! Property tests over the fleet planner's public surface
//! ([`ubimoe::has::fleet`]): for ANY spec the search can express, the
//! returned frontier must be a true Pareto set, bit-deterministic per
//! spec, and every point's objectives must reconcile with an
//! independent cold DES replay of the exact configs the search costed.
//!
//! These tests never touch the process-global work counters (they run
//! concurrently inside one binary); the counter-asserting memo
//! contract lives in `rust/tests/fleet_cache.rs`.

use std::time::Duration;

use ubimoe::has::cache::DesignCache;
use ubimoe::has::fleet::{
    fleet_configs, objectives_from_reports, plan_fleet, AutoscalePreset, FleetSpec,
    PlanTemplate, PlanVariant, Scenario, EXHAUSTIVE_LIMIT,
};
use ubimoe::has::ga::GaParams;
use ubimoe::serve::device::DeviceModel;
use ubimoe::serve::dispatch::DispatchPolicy;
use ubimoe::serve::{simulate_fleet, ServeConfigError, Workload};
use ubimoe::util::proptest::{check, prop_assert, Gen};

fn ms(x: usize) -> Duration {
    Duration::from_millis(x as u64)
}

/// A random synthetic template: 1–2 bit-width-tier variants of a
/// millisecond-scale device, each with a positive power figure.
fn random_template(g: &mut Gen, name: &str) -> PlanTemplate {
    let n_variants = g.usize(1, 2);
    let mut variants = Vec::new();
    for v in 0..n_variants {
        let fill = ms(g.usize(0, 3));
        let period = ms(g.usize(1, 4));
        let sizes: &[usize] = if g.bool() { &[1] } else { &[1, 2] };
        variants.push(PlanVariant {
            label: format!("w{}", 16 >> v),
            device: DeviceModel::from_latencies(format!("{name}-v{v}"), fill, period, sizes),
            watts: g.f64(1.0, 20.0),
        });
    }
    PlanTemplate { name: name.into(), variants, max_count: g.usize(1, 2) }
}

/// A random *valid* spec whose genome space stays exhaustively small
/// (≤ a few hundred genomes) so every case is a complete, cheap search
/// over millisecond-scale DES runs.
fn random_spec(g: &mut Gen) -> FleetSpec {
    let n_templates = g.usize(1, 2);
    let templates: Vec<PlanTemplate> = (0..n_templates)
        .map(|i| random_template(g, ["alpha", "beta"][i]))
        .collect();
    let workload = if g.bool() {
        // Ascending trace of 3–8 arrivals at 0–5 ms steps.
        let mut t = 0;
        let arrivals = (0..g.usize(3, 8))
            .map(|_| {
                t += g.usize(0, 5);
                ms(t)
            })
            .collect();
        Workload::Trace { arrivals }
    } else {
        Workload::Poisson { rate_rps: g.f64(50.0, 400.0) }
    };
    let n_scenarios = g.usize(1, 2);
    let scenarios = (0..n_scenarios)
        .map(|i| Scenario {
            label: format!("sc{i}"),
            workload: workload.clone(),
            horizon: ms(g.usize(20, 80)),
            seed: g.u64(),
        })
        .collect();
    let mut policies = vec![*g.pick(&[
        DispatchPolicy::RoundRobin,
        DispatchPolicy::JoinShortestQueue,
        DispatchPolicy::ShortestExpectedDelay,
    ])];
    if g.bool() {
        policies.push(DispatchPolicy::WeightedRoundRobin);
    }
    let autoscale_presets = if g.bool() {
        vec![AutoscalePreset {
            label: "as".into(),
            slo_factor: g.usize(2, 6) as u32,
            rho_target: g.f64(0.4, 0.95),
            target_attainment: g.f64(0.5, 0.99),
            scale_down_patience: g.usize(1, 3) as u32,
            min_devices: 1,
            max_devices: g.usize(1, 4),
        }]
    } else {
        vec![]
    };
    FleetSpec {
        name: "prop".into(),
        templates,
        scenarios,
        policies,
        autoscale_presets,
        num_experts: 0,
        ga: GaParams::default(),
        weight_profiles: vec![[1.0, 1.0, 1.0]],
    }
}

#[test]
fn prop_frontier_points_are_mutually_non_dominated() {
    check(20, |g| {
        let spec = random_spec(g);
        prop_assert(
            spec.space_size() <= EXHAUSTIVE_LIMIT,
            format!("generator must stay exhaustive (space = {})", spec.space_size()),
        )?;
        let out = plan_fleet(&spec, &DesignCache::disabled()).expect("generated spec is valid");
        prop_assert(out.exhaustive, "small spaces must enumerate")?;
        prop_assert(
            !out.frontier.is_empty(),
            "every spec has at least one feasible composition",
        )?;
        prop_assert(
            out.feasible >= out.frontier.len(),
            "frontier cannot exceed the feasible set",
        )?;
        for (i, a) in out.frontier.iter().enumerate() {
            for (j, b) in out.frontier.iter().enumerate() {
                prop_assert(
                    i == j || !a.objectives.dominates(&b.objectives),
                    format!(
                        "frontier point {i} {:?} dominates {j} {:?}",
                        a.objectives, b.objectives
                    ),
                )?;
            }
        }
        // Objective sanity: non-negative cost axes, positive energy
        // for any non-empty fleet.
        for p in &out.frontier {
            let o = &p.objectives;
            prop_assert(
                o.device_seconds > 0.0 && o.energy_j > 0.0 && o.p99_ms >= 0.0,
                format!("degenerate objectives {o:?}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_fixed_spec_bit_identical_frontier() {
    check(10, |g| {
        let spec = random_spec(g);
        let a = plan_fleet(&spec, &DesignCache::disabled()).expect("valid spec");
        let b = plan_fleet(&spec, &DesignCache::disabled()).expect("valid spec");
        prop_assert(
            a.frontier.len() == b.frontier.len()
                && a.evaluated == b.evaluated
                && a.feasible == b.feasible,
            "plan rerun changed shape",
        )?;
        for (x, y) in a.frontier.iter().zip(&b.frontier) {
            prop_assert(x.candidate == y.candidate, "frontier candidate order diverged")?;
            prop_assert(
                x.objectives.device_seconds.to_bits() == y.objectives.device_seconds.to_bits()
                    && x.objectives.p99_ms.to_bits() == y.objectives.p99_ms.to_bits()
                    && x.objectives.energy_j.to_bits() == y.objectives.energy_j.to_bits(),
                "frontier objectives not bit-identical across reruns",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_frontier_reconciles_with_cold_des_replay() {
    // Satellite 2's strongest clause: every frontier point's fitness
    // must be reproducible from scratch — rebuild the exact per-
    // scenario ServeConfigs via `fleet_configs`, run them through a
    // plain `simulate_fleet` (no cache anywhere), fold with
    // `objectives_from_reports`, and demand bit-equality.
    check(10, |g| {
        let spec = random_spec(g);
        let out = plan_fleet(&spec, &DesignCache::disabled()).expect("valid spec");
        for p in &out.frontier {
            let (cfgs, mean_watts) = fleet_configs(&spec, &p.candidate)
                .expect("frontier candidates are feasible by construction");
            let reports: Vec<_> = cfgs.iter().map(simulate_fleet).collect();
            let replayed = objectives_from_reports(&reports, mean_watts);
            prop_assert(
                replayed.device_seconds.to_bits() == p.objectives.device_seconds.to_bits()
                    && replayed.p99_ms.to_bits() == p.objectives.p99_ms.to_bits()
                    && replayed.energy_j.to_bits() == p.objectives.energy_j.to_bits(),
                format!(
                    "replay diverged for {}: {replayed:?} vs {:?}",
                    p.candidate.label(&spec),
                    p.objectives
                ),
            )?;
        }
        Ok(())
    });
}

#[test]
fn plan_config_errors_render_their_contract() {
    // Satellite 4: each plan-path ServeConfigError variant carries an
    // actionable message (the CLI prints these verbatim).
    assert_eq!(
        ServeConfigError::PlanEmptyTemplates.to_string(),
        "fleet planner needs at least one platform template"
    );
    assert_eq!(
        ServeConfigError::PlanEmptyScenarioGrid.to_string(),
        "fleet planner needs at least one scenario-grid point"
    );
    assert_eq!(
        ServeConfigError::PlanAutoscaleBounds("rho_target").to_string(),
        "plan autoscale preset: rho_target out of bounds"
    );
}
