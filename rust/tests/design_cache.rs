//! Persistent design-cache acceptance tests (ISSUE 4).
//!
//! These assert the cache's two contracts end-to-end:
//!
//! 1. **Bit-identity** — a warm-cache `DeviceModel::from_search` /
//!    `report::deploy` reproduces the cold result exactly (the
//!    artifact round trip stores floats as bit patterns).
//! 2. **Zero work when warm** — a warm `deploy_many` / `serving_study`
//!    performs zero GA evaluations, zero cycle-sim walks and zero
//!    evaluation-table builds, proven by the process-wide work
//!    counters (`util::counters`).
//!
//! The work counters and the global cache directory are process-wide,
//! so every test here serializes on one mutex. This file is its own
//! test binary (its own process): the library unit tests can never
//! interleave with these counters.

use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Duration;

use ubimoe::has::cache::{self, DesignCache};
use ubimoe::has::HasConfig;
use ubimoe::models::{m3vit_small, vit_t};
use ubimoe::report::{deploy_many, serving, DeploySpec};
use ubimoe::resources::Platform;
use ubimoe::serve::device::DeviceModel;
use ubimoe::util::counters;
use ubimoe::util::proptest::{check, prop_assert};

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // A panicking test must not wedge the rest of the file.
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ubimoe-design-cache-it-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Run `f` with the global cache pointed at a fresh scratch dir;
/// always restore the disabled default afterwards.
fn with_scratch_cache<T>(tag: &str, f: impl FnOnce() -> T) -> T {
    let dir = scratch_dir(tag);
    cache::set_global_dir(Some(dir.clone()));
    let out = f();
    cache::set_global_dir(None);
    let _ = std::fs::remove_dir_all(&dir);
    out
}

#[test]
fn prop_cold_vs_warm_from_search_bit_identical() {
    let _g = lock();
    with_scratch_cache("from-search", || {
        // Randomize over the study grid: model, platform, bit-widths.
        // Each case does one cold search (empty dir per case key) and
        // one warm load; the devices must compare equal field-by-field
        // (DeviceModel derives PartialEq over its Duration tables).
        check(4, |g| {
            let model = if g.bool() { m3vit_small() } else { vit_t() };
            let platform = if g.bool() { Platform::zcu102() } else { Platform::u280() };
            let (q, a) = *g.pick(&[(16u32, 32u32), (16, 16)]);
            let ctx = format!("{} on {} W{q}A{a}", model.name, platform.name);

            let before = counters::snapshot();
            let cold = DeviceModel::from_search(&model, &platform, q, a, &[1, 2, 4, 8]);
            let cold_work = counters::snapshot().delta(&before);

            let before = counters::snapshot();
            let warm = DeviceModel::from_search(&model, &platform, q, a, &[1, 2, 4, 8]);
            let warm_work = counters::snapshot().delta(&before);

            prop_assert(warm == cold, format!("cold/warm device diverged ({ctx})"))?;
            prop_assert(
                warm_work.no_search_work(),
                format!("warm from_search did work: {warm_work:?} ({ctx})"),
            )?;
            prop_assert(
                warm_work.cache_hits >= 1,
                format!("warm from_search missed the cache ({ctx})"),
            )?;
            // The first call either paid for a genuine search or this
            // case re-drew an earlier grid point (already warm).
            prop_assert(
                (cold_work.ga_true_evals > 0 && cold_work.sim_walks > 0)
                    || cold_work.cache_hits >= 1,
                format!("first call inconsistent: {cold_work:?} ({ctx})"),
            )
        });
    });
}

#[test]
fn warm_deploy_many_performs_zero_search_work() {
    let _g = lock();
    with_scratch_cache("deploy-many", || {
        let specs = vec![
            DeploySpec::new(m3vit_small(), Platform::zcu102(), 16, 32),
            DeploySpec::new(m3vit_small(), Platform::u280(), 16, 32),
        ];
        let cold = deploy_many(&specs);

        let before = counters::snapshot();
        let warm = deploy_many(&specs);
        let work = counters::snapshot().delta(&before);
        assert!(
            work.no_search_work(),
            "warm deploy_many performed search/sim work: {work:?}"
        );
        assert!(work.cache_hits >= 2, "both specs must be served warm: {work:?}");

        for (c, w) in cold.iter().zip(&warm) {
            assert_eq!(c.has, w.has, "{}", c.platform.name);
            assert_eq!(c.sim.latency_ms, w.sim.latency_ms);
            assert_eq!(c.sim.gops, w.sim.gops);
            assert_eq!(c.sim.power_w, w.sim.power_w);
            assert_eq!(c.sim.total_cycles, w.sim.total_cycles);
        }
    });
}

#[test]
fn warm_serving_study_performs_zero_search_work() {
    let _g = lock();
    with_scratch_cache("serving-study", || {
        let horizon = Duration::from_secs(2);
        let cold = serving::serving_study(&[1], horizon);

        let before = counters::snapshot();
        let warm = serving::serving_study(&[1], horizon);
        let work = counters::snapshot().delta(&before);
        assert!(
            work.no_search_work(),
            "warm serving_study performed GA/sim work: {work:?}"
        );
        assert!(work.cache_hits >= 2, "both platform designs must be served warm");
        // The DES itself is deterministic, so the rendered tables must
        // also be identical run-to-run.
        assert_eq!(cold.len(), warm.len());
        for (c, w) in cold.iter().zip(&warm) {
            assert_eq!(c.render(), w.render());
        }
    });
}

#[test]
fn stale_or_corrupt_artifacts_fall_back_to_cold_search() {
    let _g = lock();
    // Explicit (non-global) cache handle; a small GA budget keeps the
    // repeated cold searches cheap.
    let dir = scratch_dir("fallback");
    let cache = DesignCache::at(&dir);
    let model = m3vit_small();
    let platform = Platform::zcu102();
    let mut cfg = HasConfig::paper(16, 32);
    cfg.ga.population = 16;
    cfg.ga.generations = 8;

    let first = cache.get_or_compute(&model, &platform, &cfg);
    let artifact_file = || -> PathBuf {
        let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
            .expect("cache dir exists")
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .collect();
        files.sort();
        assert_eq!(files.len(), 1, "exactly one artifact expected: {files:?}");
        files.remove(0)
    };

    // Stale schema version: rewritten header reads as a miss, the
    // caller silently recomputes (no panic) and repairs the file.
    let path = artifact_file();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, text.replacen("ubimoe-design v", "ubimoe-design v999", 1)).unwrap();
    let before = counters::snapshot();
    let again = cache.get_or_compute(&model, &platform, &cfg);
    let work = counters::snapshot().delta(&before);
    assert_eq!(again.has, first.has, "recomputed result must match");
    assert!(work.cache_misses >= 1 && work.ga_true_evals > 0, "must re-search: {work:?}");

    // Key mismatch (simulated hash collision): a valid artifact for a
    // *different* key stored under this file name reads as a miss.
    let other_key = "not-the-key-you-are-looking-for";
    std::fs::write(&path, first.to_text(other_key)).unwrap();
    let before = counters::snapshot();
    let repaired = cache.get_or_compute(&model, &platform, &cfg);
    let work = counters::snapshot().delta(&before);
    assert_eq!(repaired.has, first.has);
    assert!(work.cache_misses >= 1, "collision must read as a miss: {work:?}");

    // Arbitrary garbage: still a miss, still no panic.
    std::fs::write(&path, b"\x00\xff not a design artifact \x7f").unwrap();
    let garbage = cache.get_or_compute(&model, &platform, &cfg);
    assert_eq!(garbage.has, first.has);

    // After the repairs, the file is valid again: pure hit.
    let before = counters::snapshot();
    let warm = cache.get_or_compute(&model, &platform, &cfg);
    let work = counters::snapshot().delta(&before);
    assert_eq!(warm.has, first.has);
    assert!(work.no_search_work(), "repaired artifact must serve warm: {work:?}");
    let _ = std::fs::remove_dir_all(&dir);
}
