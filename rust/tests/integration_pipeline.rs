//! Integration: the real two-engine double-buffered pipeline
//! (coordinator) over PJRT executables must produce the same numerics
//! as the sequential path and actually overlap the engines.

use ubimoe::coordinator::{run_pipeline, run_sequential, Blk2Stage, MsaStage};
use ubimoe::runtime::model::{RuntimeModel, BLK2_KINDS, MSA_KINDS};
use ubimoe::runtime::tensor::Tensor;
use ubimoe::runtime::{artifacts_available, artifacts_dir};

const CFG: &str = "m3vit-tiny";

fn skip() -> bool {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return true;
    }
    false
}

fn make_inputs(rt: &RuntimeModel, n: usize) -> Vec<Tensor> {
    (0..n)
        .map(|i| {
            let img = Tensor::random(
                vec![1, rt.cfg.in_chans, rt.cfg.img_size, rt.cfg.img_size],
                0.5,
                500 + i as u64,
            );
            rt.embed(&img).unwrap()
        })
        .collect()
}

#[test]
fn pipeline_matches_sequential_numerics() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let rt = RuntimeModel::load(&dir, CFG).unwrap();
    let inputs = make_inputs(&rt, 4);
    let depth = rt.cfg.depth;

    let (dir_a, dir_b) = (dir.clone(), dir.clone());
    let (pipe_out, report) = run_pipeline(
        depth,
        inputs.clone(),
        move || Ok(MsaStage(RuntimeModel::load_subset(&dir_a, CFG, MSA_KINDS)?)),
        move || Ok(Blk2Stage(RuntimeModel::load_subset(&dir_b, CFG, BLK2_KINDS)?)),
    )
    .unwrap();

    let msa = MsaStage(RuntimeModel::load_subset(&dir, CFG, MSA_KINDS).unwrap());
    let blk2 = Blk2Stage(RuntimeModel::load_subset(&dir, CFG, BLK2_KINDS).unwrap());
    let (seq_out, _) = run_sequential(depth, inputs, &msa, &blk2).unwrap();

    assert_eq!(pipe_out.len(), seq_out.len());
    for (i, (a, b)) in pipe_out.iter().zip(&seq_out).enumerate() {
        let diff = a.max_abs_diff(b);
        assert!(diff < 1e-5, "sample {i}: pipeline vs sequential diverge by {diff}");
    }
    assert_eq!(report.items, 4);
    // Both lanes must have executed every layer for every sample.
    let msa_spans = report.timeline.spans.iter().filter(|s| s.lane == "MSA").count();
    assert_eq!(msa_spans, 4 * depth);
}

#[test]
fn pipeline_overlaps_real_engines() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let rt = RuntimeModel::load(&dir, CFG).unwrap();
    let inputs = make_inputs(&rt, 6);
    let (dir_a, dir_b) = (dir.clone(), dir.clone());
    let (_, report) = run_pipeline(
        rt.cfg.depth,
        inputs,
        move || Ok(MsaStage(RuntimeModel::load_subset(&dir_a, CFG, MSA_KINDS)?)),
        move || Ok(Blk2Stage(RuntimeModel::load_subset(&dir_b, CFG, BLK2_KINDS)?)),
    )
    .unwrap();
    // Fig. 3's point, measured on real execution: MSA work of one
    // sample is in flight while FFN/MoE work of another runs. On a
    // single-core host the "overlap" is scheduler interleaving, so the
    // threshold is conservative.
    assert!(
        report.overlap_fraction > 0.05,
        "real-engine overlap too low: {:.3}",
        report.overlap_fraction
    );
}

#[test]
fn pipeline_logits_match_reference_model() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let rt = RuntimeModel::load(&dir, CFG).unwrap();
    let img = Tensor::random(vec![1, 3, 64, 64], 0.5, 4242);
    let want = rt.forward(&img).unwrap();

    let x0 = rt.embed(&img).unwrap();
    let (dir_a, dir_b) = (dir.clone(), dir.clone());
    let (outs, _) = run_pipeline(
        rt.cfg.depth,
        vec![x0],
        move || Ok(MsaStage(RuntimeModel::load_subset(&dir_a, CFG, MSA_KINDS)?)),
        move || Ok(Blk2Stage(RuntimeModel::load_subset(&dir_b, CFG, BLK2_KINDS)?)),
    )
    .unwrap();
    let got = rt.head(&outs[0]).unwrap();
    let diff = got.max_abs_diff(&want);
    assert!(diff < 1e-5, "pipeline+head vs forward diverge: {diff}");
}
