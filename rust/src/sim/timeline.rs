//! Execution trace + ASCII Gantt rendering — regenerates Fig. 3b (the
//! double-buffered timeline of the first MoE-ViT layers).

/// One traced span.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    pub lane: &'static str,
    pub label: String,
    pub start: f64,
    pub end: f64,
}

/// A collected execution trace (times in cycles or ms — caller's units).
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    pub spans: Vec<Span>,
    pub unit: &'static str,
}

impl Timeline {
    pub fn new(unit: &'static str) -> Self {
        Timeline { spans: Vec::new(), unit }
    }

    pub fn push(&mut self, lane: &'static str, label: impl Into<String>, start: f64, end: f64) {
        assert!(end >= start, "span ends before it starts");
        self.spans.push(Span { lane, label: label.into(), start, end });
    }

    pub fn total_end(&self) -> f64 {
        self.spans.iter().map(|s| s.end).fold(0.0, f64::max)
    }

    /// Busy time per lane (for utilization reporting).
    pub fn lane_busy(&self, lane: &str) -> f64 {
        self.spans.iter().filter(|s| s.lane == lane).map(|s| s.end - s.start).sum()
    }

    pub fn lanes(&self) -> Vec<&'static str> {
        let mut ls: Vec<&'static str> = Vec::new();
        for s in &self.spans {
            if !ls.contains(&s.lane) {
                ls.push(s.lane);
            }
        }
        ls
    }

    /// Spans on two lanes that overlap in time (the Fig. 3b point: MSA
    /// of layer i+1 overlaps MoE of layer i).
    pub fn overlap(&self, lane_a: &str, lane_b: &str) -> f64 {
        let mut total = 0.0;
        for a in self.spans.iter().filter(|s| s.lane == lane_a) {
            for b in self.spans.iter().filter(|s| s.lane == lane_b) {
                let lo = a.start.max(b.start);
                let hi = a.end.min(b.end);
                if hi > lo {
                    total += hi - lo;
                }
            }
        }
        total
    }

    /// ASCII Gantt chart, `width` characters across the full trace.
    pub fn render(&self, width: usize) -> String {
        let end = self.total_end().max(1e-9);
        let scale = width as f64 / end;
        let mut out = String::new();
        for lane in self.lanes() {
            let mut row = vec![b' '; width + 1];
            for s in self.spans.iter().filter(|s| s.lane == lane) {
                let a = (s.start * scale) as usize;
                let b = ((s.end * scale) as usize).min(width);
                let ch = s.label.bytes().next().unwrap_or(b'#');
                for slot in row.iter_mut().take(b.max(a + 1)).skip(a) {
                    *slot = ch;
                }
            }
            out.push_str(&format!("{:>10} |{}|\n", lane, String::from_utf8_lossy(&row)));
        }
        out.push_str(&format!(
            "{:>10}  0 {:-^w$} {:.2} {}\n",
            "",
            "time",
            end,
            self.unit,
            w = width.saturating_sub(10)
        ));
        out
    }

    /// CSV dump for plotting (EXPERIMENTS.md appendix).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("lane,label,start,end\n");
        for sp in &self.spans {
            s.push_str(&format!("{},{},{},{}\n", sp.lane, sp.label, sp.start, sp.end));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Timeline {
        let mut t = Timeline::new("ms");
        t.push("MSA", "A0", 0.0, 2.0);
        t.push("MoE", "M0", 2.0, 5.0);
        t.push("MSA", "A1", 2.0, 4.0);
        t
    }

    #[test]
    fn total_end_is_max() {
        assert_eq!(sample().total_end(), 5.0);
    }

    #[test]
    fn overlap_measures_double_buffering() {
        let t = sample();
        // A1 (2..4) overlaps M0 (2..5) by 2.0
        assert_eq!(t.overlap("MSA", "MoE"), 2.0);
    }

    #[test]
    fn lane_busy_sums_spans() {
        assert_eq!(sample().lane_busy("MSA"), 4.0);
        assert_eq!(sample().lane_busy("MoE"), 3.0);
    }

    #[test]
    fn render_contains_lanes_and_unit() {
        let r = sample().render(40);
        assert!(r.contains("MSA") && r.contains("MoE") && r.contains("ms"), "{r}");
    }

    #[test]
    fn csv_has_all_spans() {
        let csv = sample().to_csv();
        assert_eq!(csv.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "span ends before it starts")]
    fn rejects_negative_spans() {
        let mut t = Timeline::new("ms");
        t.push("X", "bad", 2.0, 1.0);
    }
}
