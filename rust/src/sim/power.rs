//! Power model: P = P_static + Σ dynamic(resource)·f + channel power.
//!
//! Coefficients live on [`Platform`] and are calibrated so the paper's
//! two measured design points land close (Table II: 11.50 W for the
//! ZCU102 design, 32.49 W for the U280 design) — see EXPERIMENTS.md
//! §Calibration. The model is linear in utilized resources, which is
//! the standard first-order FPGA power story (XPE does the same).

use crate::resources::{Platform, Resources};

/// Estimated board power (W) for a design using `used` resources with
/// `active_channels` memory channels busy.
pub fn design_power(platform: &Platform, used: &Resources, active_channels: usize) -> f64 {
    let f = platform.freq_mhz;
    let dynamic = (platform.dsp_mw_per_mhz * used.dsp + platform.bram_mw_per_mhz * used.bram18)
        * f
        / 1000.0;
    // LUT/FF dynamic power folded into a small coefficient of LUT count.
    let fabric = 4.0e-6 * used.lut * f / 1000.0 * 10.0;
    platform.static_w
        + dynamic
        + fabric
        + platform.chan_w * active_channels.min(platform.mem_channels) as f64
}

/// GOPS/W — the paper's cross-platform comparison metric.
pub fn efficiency_gops_per_w(gops: f64, watts: f64) -> f64 {
    gops / watts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zcu102_calibration_near_paper() {
        // Paper Table II: UbiMoE on ZCU102 draws 11.50 W with the
        // Table I design (1850 DSP, 458 BRAM36 = 916 BRAM18, 123.4K LUT).
        let p = Platform::zcu102();
        let used = Resources { dsp: 1850.0, bram18: 916.0, lut: 123_400.0, ff: 142_600.0 };
        let w = design_power(&p, &used, 1);
        assert!(
            (w - 11.50).abs() / 11.50 < 0.15,
            "ZCU102 power {w:.2} W vs paper 11.50 W (>15% off)"
        );
    }

    #[test]
    fn u280_calibration_near_paper() {
        // Paper Table II: 32.49 W with Table I design (3413 DSP,
        // 974 BRAM36 = 1948 BRAM18, 316.1K LUT).
        let p = Platform::u280();
        let used = Resources { dsp: 3413.0, bram18: 1948.0, lut: 316_100.0, ff: 385_900.0 };
        let w = design_power(&p, &used, 32);
        assert!(
            (w - 32.49).abs() / 32.49 < 0.15,
            "U280 power {w:.2} W vs paper 32.49 W (>15% off)"
        );
    }

    #[test]
    fn power_monotone_in_resources() {
        let p = Platform::zcu102();
        let small = Resources { dsp: 100.0, bram18: 50.0, lut: 2e4, ff: 3e4 };
        let big = Resources { dsp: 2000.0, bram18: 900.0, lut: 2e5, ff: 3e5 };
        assert!(design_power(&p, &big, 1) > design_power(&p, &small, 1));
    }

    #[test]
    fn idle_design_draws_static_plus_channels() {
        let p = Platform::zcu102();
        let w = design_power(&p, &Resources::default(), 0);
        assert!((w - p.static_w).abs() < 1e-9);
    }

    #[test]
    fn efficiency_math() {
        assert!((efficiency_gops_per_w(97.04, 11.50) - 8.438).abs() < 0.01);
    }
}
