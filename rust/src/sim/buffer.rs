//! Double-buffer state machine (Fig. 3a): Buf0 receives MSA outputs
//! while Buf1 feeds the MoE block; when both finish, the buffers swap.
//! Shared by the simulator (timing) and the coordinator (real
//! execution), with conflict checking so a scheduling bug cannot
//! silently corrupt a tensor.

/// Which block may touch a buffer right now.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Owner {
    /// MSA block writes its outputs here.
    MsaWrite,
    /// MoE/FFN block reads its inputs from here.
    MoeRead,
}

/// Two-buffer swap chain.
#[derive(Clone, Debug)]
pub struct DoubleBuffer {
    /// owner[i] is the current role of Buf_i.
    owners: [Owner; 2],
    swaps: u64,
    /// Outstanding accesses per buffer (guards against swap-in-use).
    active: [u32; 2],
}

impl Default for DoubleBuffer {
    fn default() -> Self {
        Self::new()
    }
}

impl DoubleBuffer {
    pub fn new() -> Self {
        // Fig. 3a: Buf0 for MSA outputs, Buf1 for MoE inputs.
        DoubleBuffer { owners: [Owner::MsaWrite, Owner::MoeRead], swaps: 0, active: [0, 0] }
    }

    /// Index of the buffer currently owned by `role`.
    pub fn index_of(&self, role: Owner) -> usize {
        if self.owners[0] == role {
            0
        } else {
            1
        }
    }

    /// Begin an access; returns the buffer index. Panics if the role's
    /// buffer is currently the *other* role's (scheduling bug).
    pub fn acquire(&mut self, role: Owner) -> usize {
        let i = self.index_of(role);
        debug_assert_eq!(self.owners[i], role);
        self.active[i] += 1;
        i
    }

    pub fn release(&mut self, idx: usize) {
        assert!(self.active[idx] > 0, "release without acquire on Buf{idx}");
        self.active[idx] -= 1;
    }

    /// Swap after both blocks finished (the Fig. 3b barrier). Errors if
    /// any access is still in flight.
    pub fn swap(&mut self) -> Result<(), String> {
        if self.active != [0, 0] {
            return Err(format!("swap while buffers in use: {:?}", self.active));
        }
        self.owners.swap(0, 1);
        self.swaps += 1;
        Ok(())
    }

    pub fn swaps(&self) -> u64 {
        self.swaps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn initial_assignment_matches_fig3a() {
        let b = DoubleBuffer::new();
        assert_eq!(b.index_of(Owner::MsaWrite), 0);
        assert_eq!(b.index_of(Owner::MoeRead), 1);
    }

    #[test]
    fn swap_flips_roles() {
        let mut b = DoubleBuffer::new();
        b.swap().unwrap();
        assert_eq!(b.index_of(Owner::MsaWrite), 1);
        assert_eq!(b.index_of(Owner::MoeRead), 0);
        b.swap().unwrap();
        assert_eq!(b.index_of(Owner::MsaWrite), 0);
        assert_eq!(b.swaps(), 2);
    }

    #[test]
    fn swap_blocked_while_in_use() {
        let mut b = DoubleBuffer::new();
        let i = b.acquire(Owner::MsaWrite);
        assert!(b.swap().is_err());
        b.release(i);
        assert!(b.swap().is_ok());
    }

    #[test]
    #[should_panic(expected = "release without acquire")]
    fn release_without_acquire_panics() {
        let mut b = DoubleBuffer::new();
        b.release(0);
    }

    #[test]
    fn roles_never_alias() {
        // Property: at any point in any acquire/release/swap sequence,
        // the two roles map to different buffers.
        check(200, |g| {
            let mut b = DoubleBuffer::new();
            let mut held: Vec<usize> = Vec::new();
            for _ in 0..g.usize(1, 30) {
                match g.usize(0, 2) {
                    0 => held.push(b.acquire(if g.bool() {
                        Owner::MsaWrite
                    } else {
                        Owner::MoeRead
                    })),
                    1 => {
                        if let Some(i) = held.pop() {
                            b.release(i);
                        }
                    }
                    _ => {
                        let _ = b.swap(); // may legitimately fail while held
                    }
                }
                if b.index_of(Owner::MsaWrite) == b.index_of(Owner::MoeRead) {
                    return prop_assert(false, "roles alias one buffer");
                }
            }
            Ok(())
        });
    }
}
