//! Whole-model accelerator simulation: composes the kernel models into
//! the Fig. 3 double-buffered execution of a full MoE-ViT, producing
//! latency / throughput / power / efficiency and the Fig. 3b timeline.
//!
//! Overlap model: within one inference the MSA→MoE chain is a strict
//! dependency, so the Fig. 3 double buffering pays off across the
//! *streams* the accelerator keeps in flight (M3ViT is a multi-task
//! model — one inference per task shares the backbone; a deployed
//! accelerator also pipelines consecutive frames). The engine simulates
//! S≥2 in-flight streams over the two hardware blocks and reports the
//! steady-state per-inference period — which is what the paper's
//! "overall latency depends on the maximum of the two components"
//! describes. `simulate_sequential` is the no-double-buffering
//! ablation (one stream, blocks strictly serialized).
//!
//! [`latency_surface`] produces the whole batch-size → service-time
//! surface (`service(B) = fill + B·period`) from a single evaluation
//! of the per-layer block costs — the fleet DES's device LUT, and the
//! per-design artifact the design cache persists
//! ([`crate::has::cache`]).

use crate::models::{ops, ModelConfig};
use crate::resources::{Platform, Resources};
use crate::sim::attention::{attn_cycles, attn_fill_cycles};
use crate::sim::linear::{task_cycles, LinearTask};
use crate::sim::memory::{share_transfer_cycles, BwAllocation, MemorySystem};
use crate::sim::moe::{ffn_block_cycles, moe_block_cycles, GateHistogram};
use crate::sim::power::design_power;
use crate::sim::timeline::Timeline;
use crate::sim::HwChoice;

/// In-flight streams the double-buffer pipeline keeps (Fig. 3: one per
/// buffer).
pub const DEFAULT_STREAMS: usize = 2;

/// Everything needed to simulate one deployment.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub model: ModelConfig,
    pub platform: Platform,
    pub hw: HwChoice,
    pub bw: BwAllocation,
    /// Per-MoE-layer routing histograms. If shorter than the number of
    /// MoE layers, the last entry (or balanced) is reused.
    pub histograms: Vec<GateHistogram>,
    /// In-flight streams (≥1). 1 ≙ no double buffering.
    pub streams: usize,
}

impl SimConfig {
    pub fn new(model: ModelConfig, platform: Platform, hw: HwChoice) -> SimConfig {
        let bw = BwAllocation::for_channels(platform.mem_channels);
        SimConfig {
            model,
            platform,
            hw,
            bw,
            histograms: Vec::new(),
            streams: DEFAULT_STREAMS,
        }
    }

    pub fn memory(&self) -> MemorySystem {
        MemorySystem::new(
            self.platform.mem_channels,
            self.platform.bw_gbs,
            self.platform.freq_mhz,
        )
    }

    fn histogram_for(&self, moe_idx: usize) -> GateHistogram {
        self.histograms
            .get(moe_idx)
            .or_else(|| self.histograms.last())
            .cloned()
            .unwrap_or_else(|| GateHistogram::balanced(&self.model))
    }
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub msa_cycles: f64,
    pub ffn_cycles: f64,
    pub moe_cycles: f64,
    /// Steady-state cycles per inference.
    pub total_cycles: f64,
    pub latency_ms: f64,
    pub gop: f64,
    pub gops: f64,
    pub power_w: f64,
    pub gops_per_w: f64,
    pub resources: Resources,
    pub timeline: Timeline,
    /// Fraction of block2-engine busy time hidden under MSA activity.
    pub overlap_fraction: f64,
}

/// MSA block latency (cycles): a fully streamed dataflow pipeline —
/// QKV generation, the fused attention kernel (Eq. 4), projection —
/// bound by its slowest stage, plus weight streaming which may also
/// bound it on starved memory.
pub fn msa_block_cycles_model(
    c: &ModelConfig,
    hw: &HwChoice,
    mem: &MemorySystem,
    msa_share: f64,
) -> f64 {
    let n = c.patches as f64;
    let f = c.dim as f64;
    let attn = attn_cycles(c.patches, c.dim, &hw.attn) + attn_fill_cycles(c.patches, &hw.attn);
    // num streaming modules of T_a×N_a lanes serve QKV (3NF²) + proj (NF²).
    let lanes = (hw.num * hw.attn.t_a * hw.attn.n_a) as f64;
    let lin = 4.0 * n * f * f / lanes;
    let wbytes = (4.0 * f * f * (hw.q_bits as f64 / 8.0)) as u64;
    let stream = share_transfer_cycles(mem, wbytes, msa_share);
    attn.max(lin).max(stream)
}

/// Non-encoder blocks (patch embed + head) on the reusable kernel.
fn non_encoder_cycles(c: &ModelConfig, sc: &SimConfig, mem: &MemorySystem) -> (f64, f64) {
    if c.img_size == 0 {
        return (0.0, 0.0);
    }
    let pin = c.in_chans * c.patch_size * c.patch_size;
    let qb = (sc.hw.q_bits as u64).div_ceil(8);
    let embed = LinearTask {
        tokens: c.patches - 1,
        f_in: pin,
        f_out: c.dim,
        weight_bytes: (pin * c.dim) as u64 * qb,
    };
    let head = LinearTask {
        tokens: 1,
        f_in: c.dim,
        f_out: c.num_classes,
        weight_bytes: (c.dim * c.num_classes) as u64 * qb,
    };
    (
        task_cycles(&embed, &sc.hw.lin, mem, sc.bw.moe_weights),
        task_cycles(&head, &sc.hw.lin, mem, sc.bw.moe_weights),
    )
}

/// Run the double-buffered simulation (Fig. 3).
pub fn simulate(sc: &SimConfig) -> SimResult {
    simulate_inner(sc, sc.streams.max(2))
}

/// Ablation: same hardware, blocks strictly sequential, one stream.
pub fn simulate_sequential(sc: &SimConfig) -> SimResult {
    simulate_inner(sc, 1)
}

/// Per-layer block costs of one deployment — the *expensive* part of a
/// simulation (every field is a kernel-model evaluation). Computed
/// once and shared across timeline walks: [`simulate_inner`] needs one
/// walk, [`latency_surface`] two — paying the model once either way.
struct BlockCosts {
    msa: f64,
    ffn: f64,
    embed: f64,
    head: f64,
    /// (cycles, is_moe) per encoder layer.
    blk2: Vec<(f64, bool)>,
    moe_seen: usize,
    moe_total: f64,
}

fn block_costs(sc: &SimConfig, mem: &MemorySystem) -> BlockCosts {
    let c = &sc.model;
    let msa = msa_block_cycles_model(c, &sc.hw, mem, sc.bw.msa);
    let ffn = ffn_block_cycles(c, &sc.hw.lin, mem, sc.bw.moe_weights);
    let (embed, head) = non_encoder_cycles(c, sc, mem);

    // Per-layer block-2 latency (dense FFN or MoE). Consecutive MoE
    // layers usually share one histogram (balanced default, or a
    // reused tail entry), so memoize the last (histogram → cycles)
    // pair — identical inputs, identical value, ~6× fewer MoE model
    // evaluations per cost build on the default path.
    let mut moe_seen = 0usize;
    let mut moe_total = 0.0;
    let mut last_moe: Option<(GateHistogram, f64)> = None;
    let blk2: Vec<(f64, bool)> = (0..c.depth)
        .map(|i| {
            if c.is_moe_layer(i) {
                let h = sc.histogram_for(moe_seen);
                moe_seen += 1;
                let hit = match &last_moe {
                    Some((prev_h, prev_cyc)) if *prev_h == h => Some(*prev_cyc),
                    _ => None,
                };
                let cyc = match hit {
                    Some(cyc) => cyc,
                    None => {
                        let cyc = moe_block_cycles(c, &h, &sc.hw.lin, mem, sc.bw.moe_weights);
                        last_moe = Some((h, cyc));
                        cyc
                    }
                };
                moe_total += cyc;
                (cyc, true)
            } else {
                (ffn, false)
            }
        })
        .collect();

    BlockCosts { msa, ffn, embed, head, blk2, moe_seen, moe_total }
}

/// One discrete-event timeline walk over the two engine resources (MSA
/// block, linear/MoE block): `streams` inferences in flight at once
/// (the double-buffer depth), `total_inferences` admitted in
/// completion order. Returns every inference's completion time (head
/// included). Every walk bumps the process work counter
/// ([`crate::util::counters`]) — the design cache's "zero cycle sims
/// on a warm run" contract is asserted against it.
fn walk(
    costs: &BlockCosts,
    streams: usize,
    total_inferences: usize,
    mut timeline: Option<&mut Timeline>,
) -> Vec<f64> {
    crate::util::counters::count_sim_walk();
    let depth = costs.blk2.len();
    let kc = 1e-3;
    let mut msa_free = 0.0f64;
    let mut blk2_free = 0.0f64;
    let mut done = vec![0.0f64; total_inferences];

    use std::collections::VecDeque;
    // (inference, layer, ready_time)
    let mut msa_q: VecDeque<(usize, usize, f64)> = VecDeque::new();
    let mut blk2_q: VecDeque<(usize, usize, f64)> = VecDeque::new();
    for s in 0..streams.min(total_inferences) {
        msa_q.push_back((s, 0, costs.embed));
    }
    let mut admitted = streams.min(total_inferences);

    while !(msa_q.is_empty() && blk2_q.is_empty()) {
        // Candidate start time on each engine.
        let msa_start = msa_q.front().map(|&(_, _, r)| r.max(msa_free));
        let blk2_start = blk2_q.front().map(|&(_, _, r)| r.max(blk2_free));
        let run_msa = match (msa_start, blk2_start) {
            (Some(a), Some(b)) => a <= b,
            (Some(_), None) => true,
            _ => false,
        };
        if run_msa {
            let (s, i, r) = msa_q.pop_front().unwrap();
            let start = r.max(msa_free);
            let end = start + costs.msa;
            msa_free = end;
            if s < 2 * streams {
                if let Some(t) = timeline.as_deref_mut() {
                    t.push("MSA", format!("{}", i % 10), start * kc, end * kc);
                }
            }
            blk2_q.push_back((s, i, end));
        } else {
            let (s, i, r) = blk2_q.pop_front().unwrap();
            let (b_cyc, is_moe) = costs.blk2[i];
            let start = r.max(blk2_free);
            let end = start + b_cyc;
            blk2_free = end;
            if s < 2 * streams {
                if let Some(t) = timeline.as_deref_mut() {
                    let lane = if is_moe { "MoE" } else { "FFN" };
                    t.push(lane, format!("{}", i % 10), start * kc, end * kc);
                }
            }
            if i + 1 < depth {
                msa_q.push_back((s, i + 1, end));
            } else {
                done[s] = end + costs.head;
                if admitted < total_inferences {
                    // next inference takes the freed buffer
                    msa_q.push_back((admitted, 0, done[s] + costs.embed));
                    admitted += 1;
                }
            }
        }
    }
    done
}

/// Steady-state per-inference period of a completed walk. Completions
/// of concurrently in-flight inferences bunch together, so measure
/// across a window that is a multiple of the stream count (same buffer
/// slot → exactly one period apart per in-flight set).
fn steady_period(done: &[f64], streams: usize) -> f64 {
    let last = done.len() - 1;
    let window = (2 * streams).min(last);
    if window > 0 {
        (done[last] - done[last - window]) / window as f64
    } else {
        done[0]
    }
}

fn simulate_inner(sc: &SimConfig, streams: usize) -> SimResult {
    let mem = sc.memory();
    let costs = block_costs(sc, &mem);
    result_from_costs(sc, &costs, streams)
}

/// Assemble a [`SimResult`] from already-evaluated block costs (one
/// timeline walk + arithmetic — no kernel-model work).
fn result_from_costs(sc: &SimConfig, costs: &BlockCosts, streams: usize) -> SimResult {
    let c = &sc.model;

    // Enough total inferences run to reach steady state.
    let total_inferences = streams.max(1) * 4;
    let mut timeline = Timeline::new("kcycles");
    let kc = 1e-3;
    let done = walk(costs, streams, total_inferences, Some(&mut timeline));
    let total = steady_period(&done, streams).max(1e-9);

    let blk2_busy: f64 = costs.blk2.iter().map(|(cyc, _)| cyc).sum::<f64>();
    let hidden = (timeline.overlap("MSA", "MoE") + timeline.overlap("MSA", "FFN")) / kc;
    let shown_blk2 = blk2_busy * (2 * streams).min(total_inferences) as f64;

    let model_ops = ops::model_ops(c, sc.hw.q_bits, sc.hw.a_bits);
    let gop = model_ops.total_gop();
    let latency_ms = sc.platform.cycles_to_ms(total);
    let gops = gop / (latency_ms / 1e3);
    let resources = sc.hw.resources(c.heads, c.patches, c.dim);
    let power_w = design_power(&sc.platform, &resources, sc.bw.total().ceil() as usize);
    let n_moe = c.num_moe_layers().max(1) as f64;

    SimResult {
        msa_cycles: costs.msa,
        ffn_cycles: costs.ffn,
        moe_cycles: if costs.moe_seen > 0 { costs.moe_total / n_moe } else { 0.0 },
        total_cycles: total,
        latency_ms,
        gop,
        gops,
        power_w,
        gops_per_w: gops / power_w,
        resources,
        timeline,
        overlap_fraction: if shown_blk2 > 0.0 { (hidden / shown_blk2).min(1.0) } else { 0.0 },
    }
}

/// The batch-latency surface of one deployment: `service(B)` for every
/// B in `1..=max_batch`, from **one pass** over the cycle model.
///
/// The fleet DES costs a batch of B images as `fill + B·period`
/// ([`crate::serve::device::DeviceModel`]): `period` is the
/// steady-state per-inference period of the double-buffered pipeline
/// (what [`simulate`] reports as `total_cycles`) and `fill` is the
/// pipeline ramp-in/out — the difference between a lone inference
/// ([`simulate_sequential`]) and the period. Building that LUT used to
/// take two independent `simulate*` calls, each re-evaluating every
/// kernel model; [`latency_surface`] evaluates the per-layer block
/// costs once and runs both timeline walks (pure queue arithmetic) on
/// the shared costs. Values are bit-identical to the per-B
/// `simulate`/`simulate_sequential` derivation — enforced by the
/// `surface_matches_per_b_simulate` property test.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencySurface {
    /// Lone-inference latency (cycles): `simulate_sequential`'s
    /// `total_cycles`, floor included.
    pub single_cycles: f64,
    /// Steady-state per-inference period (cycles): `simulate`'s
    /// `total_cycles`, floor included.
    pub period_cycles: f64,
    /// `service(B)` in cycles for B in `1..=max_batch`:
    /// `fill + B·period` with `fill = (single − period).max(0)`.
    pub service_cycles: Vec<f64>,
}

impl LatencySurface {
    /// Pipeline ramp-in/out (cycles).
    pub fn fill_cycles(&self) -> f64 {
        (self.single_cycles - self.period_cycles).max(0.0)
    }
}

/// Compute the [`LatencySurface`] for `sc` (see the type docs).
pub fn latency_surface(sc: &SimConfig, max_batch: usize) -> LatencySurface {
    let mem = sc.memory();
    let costs = block_costs(sc, &mem);
    let streams = sc.streams.max(2);
    let steady = walk(&costs, streams, streams.max(1) * 4, None);
    let period_cycles = steady_period(&steady, streams).max(1e-9);
    surface_from_costs(&costs, period_cycles, max_batch)
}

/// Finish a surface from already-known block costs and steady-state
/// period: the sequential ramp walk plus the affine table.
fn surface_from_costs(costs: &BlockCosts, period_cycles: f64, max_batch: usize) -> LatencySurface {
    let seq = walk(costs, 1, 4, None);
    let single_cycles = steady_period(&seq, 1).max(1e-9);
    let fill = (single_cycles - period_cycles).max(0.0);
    let service_cycles =
        (1..=max_batch.max(1)).map(|b| fill + b as f64 * period_cycles).collect();
    LatencySurface { single_cycles, period_cycles, service_cycles }
}

/// Full simulation result **and** latency surface from a single
/// evaluation of the per-layer block costs — what the design cache's
/// cold pipeline ([`crate::has::cache::artifact_for`]) uses, so a
/// cache miss pays the kernel models exactly once. Bit-identical to
/// calling [`simulate`] and [`latency_surface`] separately (the
/// surface's period *is* the simulation's `total_cycles`; asserted by
/// `simulate_with_surface_matches_separate_calls`).
pub fn simulate_with_surface(sc: &SimConfig, max_batch: usize) -> (SimResult, LatencySurface) {
    let mem = sc.memory();
    let costs = block_costs(sc, &mem);
    let sim = result_from_costs(sc, &costs, sc.streams.max(2));
    let surface = surface_from_costs(&costs, sim.total_cycles, max_batch);
    (sim, surface)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{m3vit_small, vit_s};
    use crate::resources::{AttnParams, LinearParams};

    fn zcu_hw() -> HwChoice {
        HwChoice {
            num: 2,
            attn: AttnParams { t_a: 8, n_a: 8 },
            lin: LinearParams { t_in: 16, t_out: 16, n_l: 2 },
            q_bits: 16,
            a_bits: 32,
        }
    }

    #[test]
    fn double_buffering_beats_sequential() {
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let dbl = simulate(&sc);
        let seq = simulate_sequential(&sc);
        assert!(
            dbl.total_cycles < 0.95 * seq.total_cycles,
            "overlap {} !< sequential {}",
            dbl.total_cycles,
            seq.total_cycles
        );
        assert!(dbl.overlap_fraction > 0.1, "{}", dbl.overlap_fraction);
    }

    #[test]
    fn steady_state_period_sandwiched() {
        // The steady-state per-inference period must sit between the
        // engine-utilization bound max(Σ L_MSA, Σ L_blk2) (perfect
        // pipelining) and the per-layer lockstep bound Σ max(L_MSA,
        // L_blk2) — the quantity Fig. 3 argues about.
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let r = simulate(&sc);
        let mem = sc.memory();
        let ffn = ffn_block_cycles(&sc.model, &sc.hw.lin, &mem, sc.bw.moe_weights);
        let moe = moe_block_cycles(
            &sc.model,
            &GateHistogram::balanced(&sc.model),
            &sc.hw.lin,
            &mem,
            sc.bw.moe_weights,
        );
        let blk2_of = |i: usize| if sc.model.is_moe_layer(i) { moe } else { ffn };
        let sum_max: f64 =
            (0..sc.model.depth).map(|i| r.msa_cycles.max(blk2_of(i))).sum();
        let sum_msa = r.msa_cycles * sc.model.depth as f64;
        let sum_blk2: f64 = (0..sc.model.depth).map(blk2_of).sum();
        let lower = sum_msa.max(sum_blk2);
        assert!(
            r.total_cycles >= 0.98 * lower,
            "period {} below engine bound {lower}",
            r.total_cycles
        );
        assert!(
            r.total_cycles <= 1.15 * sum_max,
            "period {} above lockstep bound {sum_max}",
            r.total_cycles
        );
    }

    #[test]
    fn latency_in_plausible_range_zcu102() {
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let r = simulate(&sc);
        assert!(r.latency_ms > 5.0 && r.latency_ms < 400.0, "{}", r.latency_ms);
        assert!(r.gops > 20.0, "{}", r.gops);
    }

    #[test]
    fn u280_faster_than_zcu102_same_arch_class() {
        let z = simulate(&SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw()));
        let big = HwChoice {
            num: 3,
            attn: AttnParams { t_a: 16, n_a: 16 },
            lin: LinearParams { t_in: 16, t_out: 16, n_l: 6 },
            q_bits: 16,
            a_bits: 32,
        };
        let u = simulate(&SimConfig::new(m3vit_small(), Platform::u280(), big));
        assert!(u.latency_ms < z.latency_ms, "u280 {} !< zcu102 {}", u.latency_ms, z.latency_ms);
    }

    #[test]
    fn moe_block_slower_than_ffn_on_ddr() {
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let r = simulate(&sc);
        assert!(r.moe_cycles > r.ffn_cycles, "moe {} ffn {}", r.moe_cycles, r.ffn_cycles);
    }

    #[test]
    fn timeline_shows_cross_stream_overlap() {
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let r = simulate(&sc);
        assert!(r.timeline.overlap("MSA", "MoE") > 0.0, "no MSA/MoE overlap in Fig.3b");
    }

    #[test]
    fn plain_vit_has_no_moe_lane() {
        let sc = SimConfig::new(vit_s(), Platform::zcu102(), zcu_hw());
        let r = simulate(&sc);
        assert_eq!(r.timeline.spans.iter().filter(|s| s.lane == "MoE").count(), 0);
        assert_eq!(r.moe_cycles, 0.0);
    }

    #[test]
    fn more_lanes_lower_latency() {
        let sc1 = SimConfig::new(m3vit_small(), Platform::u280(), zcu_hw());
        let mut hw2 = zcu_hw();
        hw2.lin.n_l = 8;
        hw2.attn.n_a = 16;
        let sc2 = SimConfig::new(m3vit_small(), Platform::u280(), hw2);
        assert!(simulate(&sc2).latency_ms < simulate(&sc1).latency_ms);
    }

    #[test]
    fn gops_consistent_with_latency() {
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let r = simulate(&sc);
        let expect = r.gop / (r.latency_ms / 1e3);
        assert!((r.gops - expect).abs() < 1e-9);
        assert!((r.gops_per_w - r.gops / r.power_w).abs() < 1e-9);
    }

    #[test]
    fn surface_matches_per_b_simulate() {
        // The one-pass surface must be bit-identical to the per-B
        // derivation from independent simulate/simulate_sequential
        // calls (what DeviceModel::with_hw paid before): exact float
        // equality across models, platforms and hardware points.
        use crate::util::proptest::{check, prop_assert};
        check(12, |g| {
            let model = if g.bool() { m3vit_small() } else { vit_s() };
            let platform = if g.bool() { Platform::zcu102() } else { Platform::u280() };
            let hw = HwChoice {
                num: g.usize(1, 3),
                attn: crate::resources::AttnParams {
                    t_a: *g.pick(&[4usize, 8, 16]),
                    n_a: *g.pick(&[2usize, 8, 16]),
                },
                lin: crate::resources::LinearParams {
                    t_in: *g.pick(&[8usize, 16, 32]),
                    t_out: *g.pick(&[8usize, 16]),
                    n_l: *g.pick(&[1usize, 2, 4, 8]),
                },
                q_bits: 16,
                a_bits: 32,
            };
            let ctx = format!("{hw} on {}", platform.name);
            let sc = SimConfig::new(model, platform, hw);
            let surf = latency_surface(&sc, 8);
            let period = simulate(&sc).total_cycles;
            let single = simulate_sequential(&sc).total_cycles;
            prop_assert(
                surf.period_cycles == period,
                format!("period {} vs simulate {} ({ctx})", surf.period_cycles, period),
            )?;
            prop_assert(
                surf.single_cycles == single,
                format!("single {} vs sequential {} ({ctx})", surf.single_cycles, single),
            )?;
            let fill = (single - period).max(0.0);
            prop_assert(surf.fill_cycles() == fill, format!("fill ({ctx})"))?;
            prop_assert(surf.service_cycles.len() == 8, format!("len ({ctx})"))?;
            for (i, &s) in surf.service_cycles.iter().enumerate() {
                let want = fill + (i + 1) as f64 * period;
                prop_assert(s == want, format!("service({}) {s} vs {want} ({ctx})", i + 1))?;
            }
            Ok(())
        });
    }

    #[test]
    fn simulate_with_surface_matches_separate_calls() {
        // The shared-cost combined pass must equal the two standalone
        // entry points bit-for-bit (it is what the design cache's
        // cold path persists).
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let (sim, surf) = simulate_with_surface(&sc, 8);
        let sim_ref = simulate(&sc);
        let surf_ref = latency_surface(&sc, 8);
        assert_eq!(sim.total_cycles, sim_ref.total_cycles);
        assert_eq!(sim.latency_ms, sim_ref.latency_ms);
        assert_eq!(sim.gops, sim_ref.gops);
        assert_eq!(sim.power_w, sim_ref.power_w);
        assert_eq!(surf, surf_ref);
    }

    #[test]
    fn surface_is_affine_and_monotone() {
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let s = latency_surface(&sc, 6);
        assert!(s.single_cycles >= s.period_cycles, "lone run can't beat steady state");
        for w in s.service_cycles.windows(2) {
            let step = w[1] - w[0];
            assert!((step - s.period_cycles).abs() < 1e-6, "non-affine step {step}");
        }
        assert_eq!(s.service_cycles[0], s.fill_cycles() + s.period_cycles);
    }

    #[test]
    fn more_streams_do_not_hurt_throughput() {
        let mut sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let two = simulate(&sc);
        sc.streams = 4;
        let four = simulate(&sc);
        assert!(four.total_cycles <= two.total_cycles * 1.02);
    }
}
