//! Whole-model accelerator simulation: composes the kernel models into
//! the Fig. 3 double-buffered execution of a full MoE-ViT, producing
//! latency / throughput / power / efficiency and the Fig. 3b timeline.
//!
//! Overlap model: within one inference the MSA→MoE chain is a strict
//! dependency, so the Fig. 3 double buffering pays off across the
//! *streams* the accelerator keeps in flight (M3ViT is a multi-task
//! model — one inference per task shares the backbone; a deployed
//! accelerator also pipelines consecutive frames). The engine simulates
//! S≥2 in-flight streams over the two hardware blocks and reports the
//! steady-state per-inference period — which is what the paper's
//! "overall latency depends on the maximum of the two components"
//! describes. `simulate_sequential` is the no-double-buffering
//! ablation (one stream, blocks strictly serialized).

use crate::models::{ops, ModelConfig};
use crate::resources::{Platform, Resources};
use crate::sim::attention::{attn_cycles, attn_fill_cycles};
use crate::sim::linear::{task_cycles, LinearTask};
use crate::sim::memory::{share_transfer_cycles, BwAllocation, MemorySystem};
use crate::sim::moe::{ffn_block_cycles, moe_block_cycles, GateHistogram};
use crate::sim::power::design_power;
use crate::sim::timeline::Timeline;
use crate::sim::HwChoice;

/// In-flight streams the double-buffer pipeline keeps (Fig. 3: one per
/// buffer).
pub const DEFAULT_STREAMS: usize = 2;

/// Everything needed to simulate one deployment.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub model: ModelConfig,
    pub platform: Platform,
    pub hw: HwChoice,
    pub bw: BwAllocation,
    /// Per-MoE-layer routing histograms. If shorter than the number of
    /// MoE layers, the last entry (or balanced) is reused.
    pub histograms: Vec<GateHistogram>,
    /// In-flight streams (≥1). 1 ≙ no double buffering.
    pub streams: usize,
}

impl SimConfig {
    pub fn new(model: ModelConfig, platform: Platform, hw: HwChoice) -> SimConfig {
        let bw = BwAllocation::for_channels(platform.mem_channels);
        SimConfig {
            model,
            platform,
            hw,
            bw,
            histograms: Vec::new(),
            streams: DEFAULT_STREAMS,
        }
    }

    pub fn memory(&self) -> MemorySystem {
        MemorySystem::new(
            self.platform.mem_channels,
            self.platform.bw_gbs,
            self.platform.freq_mhz,
        )
    }

    fn histogram_for(&self, moe_idx: usize) -> GateHistogram {
        self.histograms
            .get(moe_idx)
            .or_else(|| self.histograms.last())
            .cloned()
            .unwrap_or_else(|| GateHistogram::balanced(&self.model))
    }
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub msa_cycles: f64,
    pub ffn_cycles: f64,
    pub moe_cycles: f64,
    /// Steady-state cycles per inference.
    pub total_cycles: f64,
    pub latency_ms: f64,
    pub gop: f64,
    pub gops: f64,
    pub power_w: f64,
    pub gops_per_w: f64,
    pub resources: Resources,
    pub timeline: Timeline,
    /// Fraction of block2-engine busy time hidden under MSA activity.
    pub overlap_fraction: f64,
}

/// MSA block latency (cycles): a fully streamed dataflow pipeline —
/// QKV generation, the fused attention kernel (Eq. 4), projection —
/// bound by its slowest stage, plus weight streaming which may also
/// bound it on starved memory.
pub fn msa_block_cycles_model(
    c: &ModelConfig,
    hw: &HwChoice,
    mem: &MemorySystem,
    msa_share: f64,
) -> f64 {
    let n = c.patches as f64;
    let f = c.dim as f64;
    let attn = attn_cycles(c.patches, c.dim, &hw.attn) + attn_fill_cycles(c.patches, &hw.attn);
    // num streaming modules of T_a×N_a lanes serve QKV (3NF²) + proj (NF²).
    let lanes = (hw.num * hw.attn.t_a * hw.attn.n_a) as f64;
    let lin = 4.0 * n * f * f / lanes;
    let wbytes = (4.0 * f * f * (hw.q_bits as f64 / 8.0)) as u64;
    let stream = share_transfer_cycles(mem, wbytes, msa_share);
    attn.max(lin).max(stream)
}

/// Non-encoder blocks (patch embed + head) on the reusable kernel.
fn non_encoder_cycles(c: &ModelConfig, sc: &SimConfig, mem: &MemorySystem) -> (f64, f64) {
    if c.img_size == 0 {
        return (0.0, 0.0);
    }
    let pin = c.in_chans * c.patch_size * c.patch_size;
    let qb = (sc.hw.q_bits as u64).div_ceil(8);
    let embed = LinearTask {
        tokens: c.patches - 1,
        f_in: pin,
        f_out: c.dim,
        weight_bytes: (pin * c.dim) as u64 * qb,
    };
    let head = LinearTask {
        tokens: 1,
        f_in: c.dim,
        f_out: c.num_classes,
        weight_bytes: (c.dim * c.num_classes) as u64 * qb,
    };
    (
        task_cycles(&embed, &sc.hw.lin, mem, sc.bw.moe_weights),
        task_cycles(&head, &sc.hw.lin, mem, sc.bw.moe_weights),
    )
}

/// Run the double-buffered simulation (Fig. 3).
pub fn simulate(sc: &SimConfig) -> SimResult {
    simulate_inner(sc, sc.streams.max(2))
}

/// Ablation: same hardware, blocks strictly sequential, one stream.
pub fn simulate_sequential(sc: &SimConfig) -> SimResult {
    simulate_inner(sc, 1)
}

fn simulate_inner(sc: &SimConfig, streams: usize) -> SimResult {
    let c = &sc.model;
    let mem = sc.memory();
    let msa_c = msa_block_cycles_model(c, &sc.hw, &mem, sc.bw.msa);
    let ffn_c = ffn_block_cycles(c, &sc.hw.lin, &mem, sc.bw.moe_weights);
    let (embed_c, head_c) = non_encoder_cycles(c, sc, &mem);

    // Per-layer block-2 latency (dense FFN or MoE). Consecutive MoE
    // layers usually share one histogram (balanced default, or a
    // reused tail entry), so memoize the last (histogram → cycles)
    // pair — identical inputs, identical value, ~6× fewer MoE model
    // evaluations per simulate() call on the default path.
    let mut moe_seen = 0usize;
    let mut moe_total = 0.0;
    let mut last_moe: Option<(GateHistogram, f64)> = None;
    let blk2: Vec<(f64, bool)> = (0..c.depth)
        .map(|i| {
            if c.is_moe_layer(i) {
                let h = sc.histogram_for(moe_seen);
                moe_seen += 1;
                let hit = match &last_moe {
                    Some((prev_h, prev_cyc)) if *prev_h == h => Some(*prev_cyc),
                    _ => None,
                };
                let cyc = match hit {
                    Some(cyc) => cyc,
                    None => {
                        let cyc = moe_block_cycles(c, &h, &sc.hw.lin, &mem, sc.bw.moe_weights);
                        last_moe = Some((h, cyc));
                        cyc
                    }
                };
                moe_total += cyc;
                (cyc, true)
            } else {
                (ffn_c, false)
            }
        })
        .collect();

    // Discrete-event simulation over the two engine resources (MSA
    // block, linear/MoE block). `streams` inferences are in flight at
    // once (the double-buffer depth); enough total inferences run to
    // reach steady state.
    let total_inferences = streams.max(1) * 4;
    let mut timeline = Timeline::new("kcycles");
    let kc = 1e-3;
    let mut msa_free = 0.0f64;
    let mut blk2_free = 0.0f64;
    let mut done = vec![0.0f64; total_inferences];

    use std::collections::VecDeque;
    // (inference, layer, ready_time)
    let mut msa_q: VecDeque<(usize, usize, f64)> = VecDeque::new();
    let mut blk2_q: VecDeque<(usize, usize, f64)> = VecDeque::new();
    for s in 0..streams.min(total_inferences) {
        msa_q.push_back((s, 0, embed_c));
    }
    let mut admitted = streams.min(total_inferences);

    while !(msa_q.is_empty() && blk2_q.is_empty()) {
        // Candidate start time on each engine.
        let msa_start = msa_q.front().map(|&(_, _, r)| r.max(msa_free));
        let blk2_start = blk2_q.front().map(|&(_, _, r)| r.max(blk2_free));
        let run_msa = match (msa_start, blk2_start) {
            (Some(a), Some(b)) => a <= b,
            (Some(_), None) => true,
            _ => false,
        };
        if run_msa {
            let (s, i, r) = msa_q.pop_front().unwrap();
            let start = r.max(msa_free);
            let end = start + msa_c;
            msa_free = end;
            if s < 2 * streams {
                timeline.push("MSA", format!("{}", i % 10), start * kc, end * kc);
            }
            blk2_q.push_back((s, i, end));
        } else {
            let (s, i, r) = blk2_q.pop_front().unwrap();
            let (b_cyc, is_moe) = blk2[i];
            let start = r.max(blk2_free);
            let end = start + b_cyc;
            blk2_free = end;
            if s < 2 * streams {
                let lane = if is_moe { "MoE" } else { "FFN" };
                timeline.push(lane, format!("{}", i % 10), start * kc, end * kc);
            }
            if i + 1 < c.depth {
                msa_q.push_back((s, i + 1, end));
            } else {
                done[s] = end + head_c;
                if admitted < total_inferences {
                    // next inference takes the freed buffer
                    msa_q.push_back((admitted, 0, done[s] + embed_c));
                    admitted += 1;
                }
            }
        }
    }

    // Steady-state per-inference period. Completions of concurrently
    // in-flight inferences bunch together, so measure across a window
    // that is a multiple of the stream count (same buffer slot →
    // exactly one period apart per in-flight set).
    let last = total_inferences - 1;
    let window = (2 * streams).min(last);
    let period = if window > 0 {
        (done[last] - done[last - window]) / window as f64
    } else {
        done[0]
    };
    let total = period.max(1e-9);

    let blk2_busy: f64 = blk2.iter().map(|(cyc, _)| cyc).sum::<f64>();
    let hidden = (timeline.overlap("MSA", "MoE") + timeline.overlap("MSA", "FFN")) / kc;
    let shown_blk2 = blk2_busy * (2 * streams).min(total_inferences) as f64;

    let model_ops = ops::model_ops(c, sc.hw.q_bits, sc.hw.a_bits);
    let gop = model_ops.total_gop();
    let latency_ms = sc.platform.cycles_to_ms(total);
    let gops = gop / (latency_ms / 1e3);
    let resources = sc.hw.resources(c.heads, c.patches, c.dim);
    let power_w = design_power(&sc.platform, &resources, sc.bw.total().ceil() as usize);
    let n_moe = c.num_moe_layers().max(1) as f64;

    SimResult {
        msa_cycles: msa_c,
        ffn_cycles: ffn_c,
        moe_cycles: if moe_seen > 0 { moe_total / n_moe } else { 0.0 },
        total_cycles: total,
        latency_ms,
        gop,
        gops,
        power_w,
        gops_per_w: gops / power_w,
        resources,
        timeline,
        overlap_fraction: if shown_blk2 > 0.0 { (hidden / shown_blk2).min(1.0) } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{m3vit_small, vit_s};
    use crate::resources::{AttnParams, LinearParams};

    fn zcu_hw() -> HwChoice {
        HwChoice {
            num: 2,
            attn: AttnParams { t_a: 8, n_a: 8 },
            lin: LinearParams { t_in: 16, t_out: 16, n_l: 2 },
            q_bits: 16,
            a_bits: 32,
        }
    }

    #[test]
    fn double_buffering_beats_sequential() {
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let dbl = simulate(&sc);
        let seq = simulate_sequential(&sc);
        assert!(
            dbl.total_cycles < 0.95 * seq.total_cycles,
            "overlap {} !< sequential {}",
            dbl.total_cycles,
            seq.total_cycles
        );
        assert!(dbl.overlap_fraction > 0.1, "{}", dbl.overlap_fraction);
    }

    #[test]
    fn steady_state_period_sandwiched() {
        // The steady-state per-inference period must sit between the
        // engine-utilization bound max(Σ L_MSA, Σ L_blk2) (perfect
        // pipelining) and the per-layer lockstep bound Σ max(L_MSA,
        // L_blk2) — the quantity Fig. 3 argues about.
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let r = simulate(&sc);
        let mem = sc.memory();
        let ffn = ffn_block_cycles(&sc.model, &sc.hw.lin, &mem, sc.bw.moe_weights);
        let moe = moe_block_cycles(
            &sc.model,
            &GateHistogram::balanced(&sc.model),
            &sc.hw.lin,
            &mem,
            sc.bw.moe_weights,
        );
        let blk2_of = |i: usize| if sc.model.is_moe_layer(i) { moe } else { ffn };
        let sum_max: f64 =
            (0..sc.model.depth).map(|i| r.msa_cycles.max(blk2_of(i))).sum();
        let sum_msa = r.msa_cycles * sc.model.depth as f64;
        let sum_blk2: f64 = (0..sc.model.depth).map(blk2_of).sum();
        let lower = sum_msa.max(sum_blk2);
        assert!(
            r.total_cycles >= 0.98 * lower,
            "period {} below engine bound {lower}",
            r.total_cycles
        );
        assert!(
            r.total_cycles <= 1.15 * sum_max,
            "period {} above lockstep bound {sum_max}",
            r.total_cycles
        );
    }

    #[test]
    fn latency_in_plausible_range_zcu102() {
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let r = simulate(&sc);
        assert!(r.latency_ms > 5.0 && r.latency_ms < 400.0, "{}", r.latency_ms);
        assert!(r.gops > 20.0, "{}", r.gops);
    }

    #[test]
    fn u280_faster_than_zcu102_same_arch_class() {
        let z = simulate(&SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw()));
        let big = HwChoice {
            num: 3,
            attn: AttnParams { t_a: 16, n_a: 16 },
            lin: LinearParams { t_in: 16, t_out: 16, n_l: 6 },
            q_bits: 16,
            a_bits: 32,
        };
        let u = simulate(&SimConfig::new(m3vit_small(), Platform::u280(), big));
        assert!(u.latency_ms < z.latency_ms, "u280 {} !< zcu102 {}", u.latency_ms, z.latency_ms);
    }

    #[test]
    fn moe_block_slower_than_ffn_on_ddr() {
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let r = simulate(&sc);
        assert!(r.moe_cycles > r.ffn_cycles, "moe {} ffn {}", r.moe_cycles, r.ffn_cycles);
    }

    #[test]
    fn timeline_shows_cross_stream_overlap() {
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let r = simulate(&sc);
        assert!(r.timeline.overlap("MSA", "MoE") > 0.0, "no MSA/MoE overlap in Fig.3b");
    }

    #[test]
    fn plain_vit_has_no_moe_lane() {
        let sc = SimConfig::new(vit_s(), Platform::zcu102(), zcu_hw());
        let r = simulate(&sc);
        assert_eq!(r.timeline.spans.iter().filter(|s| s.lane == "MoE").count(), 0);
        assert_eq!(r.moe_cycles, 0.0);
    }

    #[test]
    fn more_lanes_lower_latency() {
        let sc1 = SimConfig::new(m3vit_small(), Platform::u280(), zcu_hw());
        let mut hw2 = zcu_hw();
        hw2.lin.n_l = 8;
        hw2.attn.n_a = 16;
        let sc2 = SimConfig::new(m3vit_small(), Platform::u280(), hw2);
        assert!(simulate(&sc2).latency_ms < simulate(&sc1).latency_ms);
    }

    #[test]
    fn gops_consistent_with_latency() {
        let sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let r = simulate(&sc);
        let expect = r.gop / (r.latency_ms / 1e3);
        assert!((r.gops - expect).abs() < 1e-9);
        assert!((r.gops_per_w - r.gops / r.power_w).abs() < 1e-9);
    }

    #[test]
    fn more_streams_do_not_hurt_throughput() {
        let mut sc = SimConfig::new(m3vit_small(), Platform::zcu102(), zcu_hw());
        let two = simulate(&sc);
        sc.streams = 4;
        let four = simulate(&sc);
        assert!(four.total_cycles <= two.total_cycles * 1.02);
    }
}
