//! Streaming attention kernel model (§III-B, Eq. 4) and the Fig. 4
//! memory-traffic comparison (naive single-q vs patch-reordered).

use crate::resources::AttnParams;

/// Eq. 4: L_attn = N²·F / (T_a·N_a) cycles.
///
/// Both softmax halves (max pipeline and exp/sum pipeline) are
/// co-scheduled with the QK dot in the fused kernel, so the block is
/// bound by this single expression — "both attention parts achieve the
/// same latency".
pub fn attn_cycles(n_patches: usize, f_dim: usize, p: &AttnParams) -> f64 {
    let n = n_patches as f64;
    let f = f_dim as f64;
    n * n * f / ((p.t_a * p.n_a) as f64)
}

/// Pipeline fill/drain overhead of the fused streaming kernel: the
/// depth of the QK→max→exp→·V→÷ chain, a few tens of cycles per tile
/// row — negligible against Eq. 4 but modeled so short sequences don't
/// get a free lunch.
pub fn attn_fill_cycles(n_patches: usize, p: &AttnParams) -> f64 {
    let rows = (n_patches as f64 / p.n_a as f64).ceil();
    40.0 + 8.0 * rows
}

/// Off-chip K/V traffic (bytes) of the **naive single-q** dataflow of
/// Fig. 4a: every PE reloads the K patches for each q it processes, so
/// K is fetched once per (query, key) pair.
pub fn naive_kv_traffic_bytes(n_patches: usize, f_dim: usize, a_bits: u32) -> u64 {
    let n = n_patches as u64;
    let f = f_dim as u64;
    let b = (a_bits as u64).div_ceil(8);
    // K reloaded N times (once per query row) + V the same + Q once.
    2 * n * n * f * b + n * f * b
}

/// Off-chip K/V traffic after the paper's patch reorder (Fig. 4b): Q is
/// pinned to PEs (loaded once), K/V are broadcast once per *group* of
/// N_a queries instead of once per query.
pub fn reordered_kv_traffic_bytes(
    n_patches: usize,
    f_dim: usize,
    a_bits: u32,
    n_a: usize,
) -> u64 {
    let n = n_patches as u64;
    let f = f_dim as u64;
    let b = (a_bits as u64).div_ceil(8);
    let groups = (n_patches as u64).div_ceil(n_a as u64);
    2 * groups * n * f * b + n * f * b
}

/// Per-cycle K-broadcast bandwidth pressure (bytes/cycle) of each
/// dataflow — what Fig. 4 is really about: the naive form needs N_a
/// distinct K streams, the reordered form one shared stream.
pub fn kv_streams(n_a: usize, reordered: bool) -> usize {
    if reordered {
        1
    } else {
        n_a
    }
}

/// On-chip score storage (elements) — the fused kernel never
/// materializes the N×N score matrix; the two-pass safe softmax needs
/// a full row of scores per in-flight query.
pub fn score_buffer_elems(n_patches: usize, n_a: usize, fused: bool) -> usize {
    if fused {
        // running (m, l, acc) registers only: O(1) per PE
        3 * n_a
    } else {
        n_patches * n_a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn eq4_exact() {
        let p = AttnParams { t_a: 8, n_a: 4 };
        // 197² · 384 / 32
        let want = 197.0f64 * 197.0 * 384.0 / 32.0;
        assert_eq!(attn_cycles(197, 384, &p), want);
    }

    #[test]
    fn doubling_pes_halves_latency() {
        let a = AttnParams { t_a: 8, n_a: 4 };
        let b = AttnParams { t_a: 8, n_a: 8 };
        assert!((attn_cycles(197, 384, &a) / attn_cycles(197, 384, &b) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn reorder_reduces_traffic_by_na() {
        // With N divisible by N_a the reduction on the K/V term is
        // exactly N_a.
        let naive = naive_kv_traffic_bytes(192, 384, 32);
        let reord = reordered_kv_traffic_bytes(192, 384, 32, 8);
        let q_term = 192u64 * 384 * 4;
        let naive_kv = naive - q_term;
        let reord_kv = reord - q_term;
        assert_eq!(naive_kv, 8 * reord_kv);
    }

    #[test]
    fn fused_softmax_needs_no_score_buffer() {
        assert!(score_buffer_elems(197, 8, true) < score_buffer_elems(197, 8, false) / 50);
    }

    #[test]
    fn single_broadcast_stream_after_reorder() {
        assert_eq!(kv_streams(16, true), 1);
        assert_eq!(kv_streams(16, false), 16);
    }

    #[test]
    fn prop_reordered_never_worse() {
        check(200, |g| {
            let n = g.usize(2, 512);
            let f = g.usize(8, 1024);
            let n_a = g.usize(1, 64);
            let naive = naive_kv_traffic_bytes(n, f, 32);
            let reord = reordered_kv_traffic_bytes(n, f, 32, n_a);
            prop_assert(
                reord <= naive,
                format!("reordered worse: n={n} f={f} n_a={n_a} {reord} > {naive}"),
            )
        });
    }

    #[test]
    fn prop_latency_positive_and_monotone_in_n() {
        check(100, |g| {
            let p = AttnParams { t_a: g.usize(1, 64), n_a: g.usize(1, 64) };
            let n = g.usize(2, 256);
            let f = g.usize(8, 512);
            let l1 = attn_cycles(n, f, &p);
            let l2 = attn_cycles(n + 1, f, &p);
            prop_assert(l1 > 0.0 && l2 > l1, format!("n={n} f={f} {l1} {l2}"))
        });
    }
}
