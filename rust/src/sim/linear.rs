//! Reusable linear kernel model (§III-C): N_L weight-sharing CUs behind
//! a round-robin router, weight tiles streamed from off-chip and
//! broadcast to all CUs.

use crate::resources::LinearParams;
use crate::sim::memory::{share_transfer_cycles, MemorySystem};

/// One dense linear task: `tokens` rows through a (f_in × f_out) matrix.
#[derive(Clone, Copy, Debug)]
pub struct LinearTask {
    pub tokens: usize,
    pub f_in: usize,
    pub f_out: usize,
    /// Weight bytes that must be streamed for this task (0 if resident).
    pub weight_bytes: u64,
}

impl LinearTask {
    pub fn macs(&self) -> u64 {
        (self.tokens * self.f_in * self.f_out) as u64
    }
}

/// Tile count of one (f_in × f_out) matrix on a (T_in × T_out) grid —
/// the quantity both the compute and the fill terms share.
#[inline]
pub fn tile_count(f_in: usize, f_out: usize, p: &LinearParams) -> f64 {
    (f_in as f64 / p.t_in as f64).ceil() * (f_out as f64 / p.t_out as f64).ceil()
}

/// The compute model with the tile count already in hand (hot loops
/// hoist it): the router hands tokens to CUs round-robin, so the
/// busiest CU owns ceil(tokens/N_L); each token needs one cycle per
/// tile. This is THE formula — every caller (including the hoisted
/// MoE expert loop) goes through here so the model can't diverge.
#[inline]
pub fn compute_cycles_with_tiles(tokens: usize, n_l: usize, tiles: f64) -> f64 {
    if tokens == 0 {
        return 0.0;
    }
    (tokens as f64 / n_l as f64).ceil() * tiles
}

/// Compute cycles of one task (tile count derived from its shape).
#[inline]
pub fn compute_cycles(task: &LinearTask, p: &LinearParams) -> f64 {
    compute_cycles_with_tiles(task.tokens, p.n_l, tile_count(task.f_in, task.f_out, p))
}

/// Router dispatch overhead: reading the next N_L unused patch indices
/// and steering the vectors — a couple of cycles per token.
#[inline]
pub fn router_cycles(tokens: usize) -> f64 {
    2.0 * tokens as f64
}

/// Weight streaming cycles for the task over the allocated share.
#[inline]
pub fn stream_cycles(task: &LinearTask, mem: &MemorySystem, share_channels: f64) -> f64 {
    share_transfer_cycles(mem, task.weight_bytes, share_channels)
}

/// Latency of one task on the reusable kernel with double-buffered
/// weight tiles: compute and the *next* tile's stream overlap, so the
/// task is bound by the slower of the two plus the first-tile fill.
/// (GA-fitness hot path: the tile ceils are computed once, not per
/// term as in the seed.)
pub fn task_cycles(
    task: &LinearTask,
    p: &LinearParams,
    mem: &MemorySystem,
    share_channels: f64,
) -> f64 {
    let tiles = tile_count(task.f_in, task.f_out, p);
    let compute =
        compute_cycles_with_tiles(task.tokens, p.n_l, tiles).max(router_cycles(task.tokens));
    let stream = stream_cycles(task, mem, share_channels);
    let first_tile = stream / tiles.max(1.0); // fill: first tile can't overlap
    compute.max(stream) + first_tile
}

/// Utilization of the CU array while running `task` (1.0 = every lane
/// busy every cycle) — the §III-C argument for the router: static
/// assignment would idle CUs when expert token counts are unbalanced.
pub fn cu_utilization(task: &LinearTask, p: &LinearParams) -> f64 {
    if task.tokens == 0 {
        return 0.0;
    }
    let ideal = task.macs() as f64 / p.macs_per_cycle();
    ideal / compute_cycles(task, p).max(1e-9)
        * (task.f_in as f64 / ((task.f_in as f64 / p.t_in as f64).ceil() * p.t_in as f64))
        .min(1.0)
}

/// Latency of the same work on N_L *statically partitioned* kernels
/// (the strawman §III-C argues against): tokens pre-split into N_L
/// fixed groups; a skewed split leaves kernels idle. `split` gives the
/// per-kernel token counts (must sum to tokens).
pub fn static_partition_cycles(
    split: &[usize],
    f_in: usize,
    f_out: usize,
    p: &LinearParams,
) -> f64 {
    let tiles =
        (f_in as f64 / p.t_in as f64).ceil() * (f_out as f64 / p.t_out as f64).ceil();
    split
        .iter()
        .map(|&t| t as f64 * tiles)
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    fn p() -> LinearParams {
        LinearParams { t_in: 8, t_out: 8, n_l: 4 }
    }

    fn mem() -> MemorySystem {
        MemorySystem::new(1, 19.2, 300.0)
    }

    #[test]
    fn compute_cycles_exact() {
        let t = LinearTask { tokens: 16, f_in: 64, f_out: 64, weight_bytes: 0 };
        // 16/4 = 4 tokens per CU; (64/8)·(64/8) = 64 tiles
        assert_eq!(compute_cycles(&t, &p()), 4.0 * 64.0);
    }

    #[test]
    fn zero_tokens_zero_cycles() {
        let t = LinearTask { tokens: 0, f_in: 64, f_out: 64, weight_bytes: 100 };
        assert_eq!(compute_cycles(&t, &p()), 0.0);
    }

    #[test]
    fn round_robin_balances_within_one() {
        // 17 tokens on 4 CUs: busiest CU gets 5 → ceil(17/4)
        let t = LinearTask { tokens: 17, f_in: 8, f_out: 8, weight_bytes: 0 };
        assert_eq!(compute_cycles(&t, &p()), 5.0);
    }

    #[test]
    fn task_bound_by_stream_when_memory_poor() {
        // Big weights, few tokens: the stream dominates.
        let t = LinearTask { tokens: 4, f_in: 384, f_out: 1536, weight_bytes: 1_179_648 };
        let c = compute_cycles(&t, &p());
        let total = task_cycles(&t, &p(), &mem(), 0.6);
        assert!(total > 3.0 * c, "compute {c}, total {total}");
    }

    #[test]
    fn task_bound_by_compute_when_memory_rich() {
        let hbm = MemorySystem::new(32, 460.0, 200.0);
        let t = LinearTask { tokens: 197, f_in: 384, f_out: 1536, weight_bytes: 1_179_648 };
        let small = LinearParams { t_in: 4, t_out: 4, n_l: 1 };
        let total = task_cycles(&t, &small, &hbm, 20.0);
        let c = compute_cycles(&t, &small);
        assert!(total < 1.2 * c, "compute {c}, total {total}");
    }

    #[test]
    fn router_beats_static_partition_on_skew() {
        // All 64 tokens landed on one static kernel (worst-case gate
        // skew); the router spreads them ceil(64/4)=16 per CU.
        let pp = p();
        let t = LinearTask { tokens: 64, f_in: 64, f_out: 64, weight_bytes: 0 };
        let routed = compute_cycles(&t, &pp);
        let skewed = static_partition_cycles(&[64, 0, 0, 0], 64, 64, &pp);
        assert_eq!(routed * 4.0, skewed);
    }

    #[test]
    fn utilization_at_most_one() {
        let t = LinearTask { tokens: 64, f_in: 64, f_out: 64, weight_bytes: 0 };
        let u = cu_utilization(&t, &p());
        assert!(u > 0.9 && u <= 1.0, "{u}");
    }

    #[test]
    fn prop_task_cycles_monotone_in_tokens() {
        check(100, |g| {
            let pp = LinearParams {
                t_in: *g.pick(&[4usize, 8, 16]),
                t_out: *g.pick(&[4usize, 8, 16]),
                n_l: g.usize(1, 8),
            };
            let tok = g.usize(1, 200);
            let f_in = g.usize(8, 512);
            let f_out = g.usize(8, 512);
            let t1 = LinearTask { tokens: tok, f_in, f_out, weight_bytes: 1000 };
            let t2 = LinearTask { tokens: tok + 8, ..t1 };
            let m = mem();
            prop_assert(
                task_cycles(&t2, &pp, &m, 0.5) >= task_cycles(&t1, &pp, &m, 0.5),
                format!("tokens {tok}"),
            )
        });
    }

    #[test]
    fn prop_router_never_slower_than_any_static_split() {
        check(150, |g| {
            let n_l = g.usize(2, 8);
            let pp = LinearParams { t_in: 8, t_out: 8, n_l };
            let tokens = g.usize(1, 120);
            // random static split of `tokens` over n_l kernels
            let mut split = vec![0usize; n_l];
            for _ in 0..tokens {
                let i = g.usize(0, n_l - 1);
                split[i] += 1;
            }
            let t = LinearTask { tokens, f_in: 32, f_out: 32, weight_bytes: 0 };
            let routed = compute_cycles(&t, &pp);
            let stat = static_partition_cycles(&split, 32, 32, &pp);
            prop_assert(routed <= stat + 1e-9, format!("{routed} > {stat} ({split:?})"))
        });
    }
}
