//! SLR floorplan model (Fig. 5 / §III-A): on multi-die FPGAs, blocks
//! are assigned to SLRs to minimize die crossings and keep the
//! memory-hungry MoE block next to the memory subsystem (AutoBridge-
//! style placement: HBM sits on SLR0 of the U280).

use crate::resources::{Platform, Resources};

/// A placeable block and its resource demand.
#[derive(Clone, Debug)]
pub struct Block {
    pub name: String,
    pub demand: Resources,
    /// Bytes/s of off-chip traffic this block generates (drives the
    /// prefer-memory-SLR rule).
    pub mem_traffic: f64,
}

/// The result of floorplanning.
#[derive(Clone, Debug)]
pub struct Floorplan {
    /// slr_of[i] = SLR index of block i.
    pub slr_of: Vec<usize>,
    /// Per-SLR aggregated usage.
    pub slr_used: Vec<Resources>,
    /// Number of dataflow edges that cross dies.
    pub crossings: usize,
}

/// Greedy placement: sort blocks by memory traffic (heaviest first);
/// heaviest goes to the memory SLR; subsequent blocks go to the SLR
/// with the most remaining capacity among those adjacent to their
/// dataflow predecessor (blocks are chained in the given order:
/// embed → MSA → MoE → head).
pub fn place(platform: &Platform, blocks: &[Block]) -> Result<Floorplan, String> {
    let slrs = platform.slrs.max(1);
    let per_slr = platform.budget().scale(1.0 / slrs as f64);
    let mut used = vec![Resources::default(); slrs];
    let mut slr_of = vec![usize::MAX; blocks.len()];

    // Highest-traffic block is pinned to the memory SLR.
    if !blocks.is_empty() {
        let hot = blocks
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.mem_traffic.total_cmp(&b.1.mem_traffic))
            .map(|(i, _)| i)
            .unwrap();
        let m = platform.mem_slr;
        if !blocks[hot].demand.fits(&per_slr) {
            return Err(format!("block {} does not fit one SLR", blocks[hot].name));
        }
        slr_of[hot] = m;
        used[m] = used[m].add(&blocks[hot].demand);
    }

    for (i, b) in blocks.iter().enumerate() {
        if slr_of[i] != usize::MAX {
            continue;
        }
        // Candidate SLRs ordered by: adjacency to the previous block in
        // the chain, then remaining DSP capacity.
        let prev_slr = if i > 0 && slr_of[i - 1] != usize::MAX {
            Some(slr_of[i - 1])
        } else {
            None
        };
        let mut candidates: Vec<usize> = (0..slrs).collect();
        candidates.sort_by(|&x, &y| {
            let adj = |s: usize| {
                prev_slr.map_or(0, |p| (s as i64 - p as i64).unsigned_abs() as usize)
            };
            let rem = |s: usize| per_slr.dsp - used[s].dsp - b.demand.dsp;
            adj(x).cmp(&adj(y)).then(rem(y).total_cmp(&rem(x)))
        });
        let mut placed = false;
        for &s in &candidates {
            if used[s].add(&b.demand).fits(&per_slr) {
                slr_of[i] = s;
                used[s] = used[s].add(&b.demand);
                placed = true;
                break;
            }
        }
        if !placed {
            return Err(format!("no SLR can host block {}", b.name));
        }
    }

    // Count crossings along the dataflow chain.
    let crossings = slr_of
        .windows(2)
        .filter(|w| w[0] != w[1])
        .map(|w| (w[0] as i64 - w[1] as i64).unsigned_abs() as usize)
        .sum();

    Ok(Floorplan { slr_of, slr_used: used, crossings })
}

/// ASCII rendering of the floorplan (the Fig. 5-style report).
pub fn render(platform: &Platform, blocks: &[Block], plan: &Floorplan) -> String {
    let slrs = platform.slrs.max(1);
    let mut out = String::new();
    out.push_str(&format!("Floorplan on {} ({} SLR)\n", platform.name, slrs));
    for s in (0..slrs).rev() {
        let members: Vec<&str> = blocks
            .iter()
            .enumerate()
            .filter(|(i, _)| plan.slr_of[*i] == s)
            .map(|(_, b)| b.name.as_str())
            .collect();
        let tag = if s == platform.mem_slr { " [MEM]" } else { "" };
        out.push_str(&format!(
            "  SLR{s}{tag}: {:<40} DSP {:>6.0} BRAM18 {:>6.0}\n",
            members.join(", "),
            plan.slr_used[s].dsp,
            plan.slr_used[s].bram18
        ));
    }
    out.push_str(&format!("  die crossings on dataflow: {}\n", plan.crossings));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blk(name: &str, dsp: f64, traffic: f64) -> Block {
        Block {
            name: name.into(),
            demand: Resources { dsp, bram18: dsp / 4.0, lut: dsp * 30.0, ff: dsp * 40.0 },
            mem_traffic: traffic,
        }
    }

    #[test]
    fn moe_lands_on_memory_slr() {
        let u = Platform::u280();
        let blocks = vec![
            blk("embed", 100.0, 1e8),
            blk("msa", 900.0, 2e8),
            blk("moe", 1100.0, 5e9), // dominant weight streamer
            blk("head", 50.0, 1e7),
        ];
        let plan = place(&u, &blocks).unwrap();
        assert_eq!(plan.slr_of[2], u.mem_slr, "MoE must sit on the HBM SLR");
    }

    #[test]
    fn single_die_never_crosses() {
        let z = Platform::zcu102();
        let blocks =
            vec![blk("msa", 800.0, 1e8), blk("moe", 900.0, 2e9), blk("head", 20.0, 1e6)];
        let plan = place(&z, &blocks).unwrap();
        assert_eq!(plan.crossings, 0);
    }

    #[test]
    fn capacity_respected_per_slr() {
        let u = Platform::u280();
        let per_slr = u.budget().scale(1.0 / u.slrs as f64);
        let blocks = vec![
            blk("a", per_slr.dsp * 0.8, 1e9),
            blk("b", per_slr.dsp * 0.8, 1e8),
            blk("c", per_slr.dsp * 0.8, 1e7),
        ];
        let plan = place(&u, &blocks).unwrap();
        for s in 0..u.slrs {
            assert!(plan.slr_used[s].dsp <= per_slr.dsp + 1e-9);
        }
    }

    #[test]
    fn oversized_block_rejected() {
        let u = Platform::u280();
        let blocks = vec![blk("huge", 1e6, 1e9)];
        assert!(place(&u, &blocks).is_err());
    }

    #[test]
    fn render_mentions_mem_slr() {
        let u = Platform::u280();
        let blocks = vec![blk("moe", 500.0, 1e9), blk("msa", 500.0, 1e8)];
        let plan = place(&u, &blocks).unwrap();
        let r = render(&u, &blocks, &plan);
        assert!(r.contains("[MEM]"), "{r}");
        assert!(r.contains("SLR2"), "{r}");
    }
}
