//! Expert-weight cache model (extension of §III-C's "due to weight
//! sharing, our approach can reduce off-chip memory access pressure at
//! runtime, making it more favorable for deploying larger-scale
//! models").
//!
//! On-chip BRAM left over after the kernels can pin a few experts'
//! weights; a cached expert skips its DDR/HBM stream entirely. Because
//! gate distributions are temporally correlated across layers/frames,
//! even a small cache cuts the dominant MoE traffic. This module
//! models an LRU (or static most-frequent) cache over expert ids and
//! the resulting stream savings; `benches/ablations.rs` sweeps it.

use crate::models::ModelConfig;
use crate::sim::moe::GateHistogram;

/// Replacement policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Least-recently-used over expert activations.
    Lru,
    /// Statically pin the most-frequent experts of a profile.
    StaticTopK,
}

/// An expert-weight cache with `slots` expert-sized entries.
#[derive(Clone, Debug)]
pub struct ExpertCache {
    pub slots: usize,
    pub policy: Policy,
    /// Resident expert ids, most-recent first (LRU order).
    resident: Vec<usize>,
    pub hits: u64,
    pub misses: u64,
}

impl ExpertCache {
    pub fn new(slots: usize, policy: Policy) -> ExpertCache {
        ExpertCache { slots, policy, resident: Vec::new(), hits: 0, misses: 0 }
    }

    /// Statically warm the cache from a profile histogram.
    pub fn warm_from_profile(&mut self, hist: &GateHistogram) {
        let mut order: Vec<usize> = (0..hist.tokens_per_expert.len()).collect();
        order.sort_by_key(|&e| std::cmp::Reverse(hist.tokens_per_expert[e]));
        self.resident = order.into_iter().take(self.slots).collect();
    }

    /// Access expert `e`'s weights; returns true on hit (no stream).
    pub fn access(&mut self, e: usize) -> bool {
        if self.slots == 0 {
            self.misses += 1;
            return false;
        }
        if let Some(pos) = self.resident.iter().position(|&r| r == e) {
            self.hits += 1;
            if self.policy == Policy::Lru {
                let id = self.resident.remove(pos);
                self.resident.insert(0, id);
            }
            true
        } else {
            self.misses += 1;
            if self.policy == Policy::Lru {
                self.resident.insert(0, e);
                self.resident.truncate(self.slots);
            }
            false
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// BRAM18 cost of the cache: `slots` experts × 2·F·D weights at
    /// q bits, in 18Kb blocks (banked like the kernel's weight tiles).
    pub fn bram18_cost(&self, c: &ModelConfig, q_bits: u32) -> f64 {
        let bits = (2 * c.dim * c.expert_dim()) as f64 * q_bits as f64;
        let bram_bits = 18.0 * 1024.0;
        (bits / bram_bits).ceil() * self.slots as f64
    }
}

/// Weight bytes streamed for one MoE block given the cache state
/// (experts visited in id order — the expert-by-expert schedule).
pub fn streamed_bytes_with_cache(
    c: &ModelConfig,
    cache: &mut ExpertCache,
    q_bits: u32,
) -> u64 {
    let per_expert = (2 * c.dim * c.expert_dim()) as u64 * (q_bits as u64).div_ceil(8);
    let mut bytes = 0;
    for e in 0..c.num_experts {
        if !cache.access(e) {
            bytes += per_expert;
        }
    }
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::m3vit_small;

    #[test]
    fn lru_hits_on_repeat_access() {
        let mut c = ExpertCache::new(2, Policy::Lru);
        assert!(!c.access(0));
        assert!(!c.access(1));
        assert!(c.access(0));
        assert!(c.access(1));
        // third expert evicts LRU (0 was touched before 1… order: 1,0)
        assert!(!c.access(2)); // evicts 0
        assert!(c.access(1));
        assert!(!c.access(0));
        assert!(c.hit_rate() > 0.3);
    }

    #[test]
    fn zero_slots_never_hit() {
        let mut c = ExpertCache::new(0, Policy::Lru);
        for e in 0..10 {
            assert!(!c.access(e));
        }
        assert_eq!(c.hit_rate(), 0.0);
    }

    #[test]
    fn static_topk_pins_hot_experts() {
        let model = m3vit_small();
        let hist = GateHistogram::skewed(&model, 2.0, 1);
        let mut c = ExpertCache::new(4, Policy::StaticTopK);
        c.warm_from_profile(&hist);
        // The 4 hottest experts must hit.
        let mut order: Vec<usize> = (0..model.num_experts).collect();
        order.sort_by_key(|&e| std::cmp::Reverse(hist.tokens_per_expert[e]));
        for &e in order.iter().take(4) {
            assert!(c.access(e), "hot expert {e} missed");
        }
        for &e in order.iter().skip(4) {
            assert!(!c.access(e), "cold expert {e} hit statically");
        }
    }

    #[test]
    fn full_cache_eliminates_all_traffic() {
        let model = m3vit_small();
        let mut c = ExpertCache::new(model.num_experts, Policy::Lru);
        // first block streams everything…
        let first = streamed_bytes_with_cache(&model, &mut c, 16);
        assert!(first > 0);
        // …second block streams nothing.
        let second = streamed_bytes_with_cache(&model, &mut c, 16);
        assert_eq!(second, 0);
    }

    #[test]
    fn bram_cost_scales_with_slots() {
        let model = m3vit_small();
        let c2 = ExpertCache::new(2, Policy::Lru);
        let c4 = ExpertCache::new(4, Policy::Lru);
        assert_eq!(c4.bram18_cost(&model, 16), 2.0 * c2.bram18_cost(&model, 16));
        // One expert of m3vit-small = 2·384·1536·16 bits ≈ 1024 BRAM18:
        // clearly too big to cache many — the model shows the trade.
        assert!(c2.bram18_cost(&model, 16) > 1000.0);
    }

    #[test]
    fn tiny_model_experts_are_cacheable() {
        let tiny = crate::models::m3vit_tiny();
        let c = ExpertCache::new(2, Policy::Lru);
        // 2·192·768·16 bits / 18Kb ≈ 256 per expert
        assert!(c.bram18_cost(&tiny, 16) < 600.0);
    }
}
