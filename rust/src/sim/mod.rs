//! Cycle-level accelerator simulator.
//!
//! Implements the paper's analytical performance model (§IV-A, Eq. 4)
//! extended to a full event model: per-kernel cycle counts, off-chip
//! weight/activation streaming over DDR/HBM channels, the Fig. 3
//! double-buffered MSA/MoE overlap, SLR placement, and power. The HAS
//! search (has/), every baseline (baselines/) and all paper-table
//! benches run on top of this.

pub mod attention;
pub mod buffer;
pub mod cache;
pub mod engine;
pub mod linear;
pub mod memory;
pub mod moe;
pub mod placement;
pub mod power;
pub mod timeline;

use crate::resources::{AttnParams, LinearParams};

/// A complete hardware configuration — the paper's search vector
/// F_c = [num, T_a, N_a, T_in, T_out, N_L] plus bit-widths.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HwChoice {
    /// Number of streaming linear modules serving the MSA block's
    /// QKV-generation and projection stages.
    pub num: usize,
    pub attn: AttnParams,
    pub lin: LinearParams,
    /// Weight bit-width q (16 for the paper's main designs).
    pub q_bits: u32,
    /// Activation bit-width (32 for Table I/II, 16 for Table III).
    pub a_bits: u32,
}

impl HwChoice {
    pub fn resources(
        &self,
        heads: usize,
        n_patches: usize,
        f_dim: usize,
    ) -> crate::resources::Resources {
        crate::resources::design_resources(
            &self.attn,
            &self.lin,
            self.num,
            self.q_bits,
            self.a_bits,
            heads,
            n_patches,
            f_dim,
        )
    }

    /// A deliberately small-but-valid configuration (tests, lower
    /// bounds for search).
    pub fn minimal(q_bits: u32, a_bits: u32) -> HwChoice {
        HwChoice {
            num: 1,
            attn: AttnParams { t_a: 2, n_a: 1 },
            lin: LinearParams { t_in: 2, t_out: 2, n_l: 1 },
            q_bits,
            a_bits,
        }
    }
}

impl std::fmt::Display for HwChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "F_c=[num={}, T_a={}, N_a={}, T_in={}, T_out={}, N_L={}] W{}A{}",
            self.num,
            self.attn.t_a,
            self.attn.n_a,
            self.lin.t_in,
            self.lin.t_out,
            self.lin.n_l,
            self.q_bits,
            self.a_bits
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_roundtrips_fields() {
        let c = HwChoice::minimal(16, 32);
        let s = format!("{c}");
        assert!(s.contains("num=1") && s.contains("W16A32"), "{s}");
    }

    #[test]
    fn resources_nonzero() {
        let c = HwChoice::minimal(16, 32);
        let r = c.resources(6, 197, 384);
        assert!(r.dsp > 0.0 && r.bram18 > 0.0);
    }
}
