//! MoE block model: gate + expert-by-expert execution (M3ViT order) on
//! the reusable linear kernel, with per-expert weight streaming
//! double-buffered against compute.

use crate::models::ModelConfig;
use crate::resources::LinearParams;
use crate::sim::linear::{task_cycles, LinearTask};
use crate::sim::memory::MemorySystem;

/// Per-expert token counts for one MoE block invocation. Produced
/// either synthetically ([`GateHistogram::balanced`] /
/// [`GateHistogram::skewed`]) or from the real gate decisions the Rust
/// runtime observes via the gate_probe artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct GateHistogram {
    pub tokens_per_expert: Vec<usize>,
}

impl GateHistogram {
    /// Perfectly balanced routing: k·N assignments spread over E.
    pub fn balanced(c: &ModelConfig) -> GateHistogram {
        let total = c.top_k * c.patches;
        let e = c.num_experts;
        let mut t = vec![total / e; e];
        for slot in t.iter_mut().take(total % e) {
            *slot += 1;
        }
        GateHistogram { tokens_per_expert: t }
    }

    /// Skewed routing with a Zipf-ish tail — the stress case the
    /// round-robin router exists for.
    pub fn skewed(c: &ModelConfig, alpha: f64, seed: u64) -> GateHistogram {
        let e = c.num_experts;
        let total = c.top_k * c.patches;
        let mut rng = crate::util::rng::Rng::new(seed);
        let weights: Vec<f64> = (1..=e).map(|r| 1.0 / (r as f64).powf(alpha)).collect();
        let sum: f64 = weights.iter().sum();
        let mut t: Vec<usize> =
            weights.iter().map(|w| (w / sum * total as f64) as usize).collect();
        let mut assigned: usize = t.iter().sum();
        while assigned < total {
            let i = rng.below(e);
            t[i] += 1;
            assigned += 1;
        }
        GateHistogram { tokens_per_expert: t }
    }

    pub fn total_assignments(&self) -> usize {
        self.tokens_per_expert.iter().sum()
    }
}

/// Cycles to stream one expert's two weight matrices (F×D and D×F at
/// the q=16 default) over a `share_channels` slice of the memory
/// system. In the expert-by-expert schedule every expert's stream
/// hides behind the previous expert's compute **except the leading
/// one** — this exposed leading stream is exactly what a device skips
/// when the batch's dominant expert is still resident from the
/// previous batch (`serve::device` derives its residency discount from
/// this value; the fill/2 heuristic remains only as the fallback for
/// synthetic `from_latencies` devices).
pub fn expert_stream_cycles(
    c: &ModelConfig,
    mem: &MemorySystem,
    share_channels: f64,
) -> f64 {
    let f = c.dim;
    let d = c.expert_dim();
    let qb = (16u64).div_ceil(8); // weights streamed at q=16 by default
    let expert_weight_bytes = (2 * f * d) as u64 * qb;
    let t = LinearTask { tokens: 0, f_in: f, f_out: d, weight_bytes: expert_weight_bytes };
    crate::sim::linear::stream_cycles(&t, mem, share_channels)
}

/// Latency (cycles) of one MoE block: gate, then for each expert e —
/// stream its two weight matrices while computing the previous expert
/// (double buffering), process its routed tokens through FFN layers 1
/// and 2.
pub fn moe_block_cycles(
    c: &ModelConfig,
    hist: &GateHistogram,
    p: &LinearParams,
    mem: &MemorySystem,
    share_channels: f64,
) -> f64 {
    assert_eq!(hist.tokens_per_expert.len(), c.num_experts);
    let f = c.dim;
    let d = c.expert_dim();
    let wb = (c.dim * c.num_experts) as u64; // gate weights (elements)
    let qb = (16u64).div_ceil(8); // weights streamed at q=16 by default

    // Gate: one linear over all tokens (weights usually resident, they
    // are tiny — stream cost still charged).
    let gate = LinearTask {
        tokens: c.patches,
        f_in: f,
        f_out: c.num_experts,
        weight_bytes: wb * qb,
    };
    let mut cycles = task_cycles(&gate, p, mem, share_channels);

    // Expert-by-expert: per-expert latency is max(compute, stream of
    // the NEXT expert's weights); the first expert's stream is exposed.
    // Every expert streams the same two (F×D, D×F) matrices over the
    // same share, and the FFN tile counts do not depend on the routed
    // token count — both are loop-invariant, so hoist them (the seed
    // recomputed the stream E+1 times and the tile ceils 4·E times;
    // this loop is the GA-fitness hot path).
    let expert_stream = expert_stream_cycles(c, mem, share_channels);
    cycles += expert_stream;
    let tiles_l1 = crate::sim::linear::tile_count(f, d, p);
    let tiles_l2 = crate::sim::linear::tile_count(d, f, p);
    for &tok in &hist.tokens_per_expert {
        let compute = crate::sim::linear::compute_cycles_with_tiles(tok, p.n_l, tiles_l1)
            + crate::sim::linear::compute_cycles_with_tiles(tok, p.n_l, tiles_l2)
            + crate::sim::linear::router_cycles(tok);
        // compute of expert e overlaps stream of expert e+1
        cycles += compute.max(expert_stream);
    }
    cycles
}

/// Dense FFN block (non-MoE layers) on the same kernel.
pub fn ffn_block_cycles(
    c: &ModelConfig,
    p: &LinearParams,
    mem: &MemorySystem,
    share_channels: f64,
) -> f64 {
    let f = c.dim;
    let h = c.mlp_ratio * c.dim;
    let qb = 2u64;
    let l1 = LinearTask { tokens: c.patches, f_in: f, f_out: h, weight_bytes: (f * h) as u64 * qb };
    let l2 = LinearTask { tokens: c.patches, f_in: h, f_out: f, weight_bytes: (f * h) as u64 * qb };
    task_cycles(&l1, p, mem, share_channels) + task_cycles(&l2, p, mem, share_channels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::m3vit_small;
    use crate::util::proptest::{check, prop_assert};

    fn setup() -> (ModelConfig, LinearParams, MemorySystem) {
        (
            m3vit_small(),
            LinearParams { t_in: 16, t_out: 16, n_l: 2 },
            MemorySystem::new(1, 19.2, 300.0),
        )
    }

    #[test]
    fn balanced_histogram_conserves_assignments() {
        let c = m3vit_small();
        let h = GateHistogram::balanced(&c);
        assert_eq!(h.total_assignments(), c.top_k * c.patches);
        let max = *h.tokens_per_expert.iter().max().unwrap();
        let min = *h.tokens_per_expert.iter().min().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn skewed_histogram_conserves_assignments() {
        let c = m3vit_small();
        let h = GateHistogram::skewed(&c, 1.2, 42);
        assert_eq!(h.total_assignments(), c.top_k * c.patches);
        assert!(h.tokens_per_expert[0] > h.tokens_per_expert[c.num_experts - 1]);
    }

    #[test]
    fn moe_block_streams_all_experts() {
        // On single-channel DDR the block must be stream-bound: its
        // latency exceeds pure compute by a wide margin.
        let (c, p, mem) = setup();
        let h = GateHistogram::balanced(&c);
        let cycles = moe_block_cycles(&c, &h, &p, &mem, 0.6);
        // all-expert weight stream at ~31.5 B/cycle share:
        let stream_bytes = (c.num_experts * 2 * c.dim * c.expert_dim() * 2) as f64;
        let min_stream = stream_bytes / (52.5 * 0.6);
        assert!(cycles > 0.9 * min_stream, "cycles {cycles} < stream bound {min_stream}");
    }

    #[test]
    fn hbm_makes_moe_compute_bound() {
        // Use a wide kernel so compute is cheap: on DDR the expert
        // stream then dominates; on HBM it vanishes.
        let c = m3vit_small();
        let p = LinearParams { t_in: 32, t_out: 32, n_l: 8 };
        let hbm = MemorySystem::new(32, 460.0, 200.0);
        let h = GateHistogram::balanced(&c);
        let ddr = MemorySystem::new(1, 19.2, 300.0);
        let fast = moe_block_cycles(&c, &h, &p, &hbm, 20.0);
        let slow = moe_block_cycles(&c, &h, &p, &ddr, 0.6);
        assert!(fast < slow / 2.0, "hbm {fast} vs ddr {slow}");
    }

    #[test]
    fn skew_does_not_change_total_compute_much() {
        // The router rebalances *within* an expert's token set; skew
        // across experts costs only ceil() effects per expert, so the
        // difference between balanced and mildly skewed should be small
        // when compute-bound.
        let c = m3vit_small();
        let p = LinearParams { t_in: 16, t_out: 16, n_l: 4 };
        let hbm = MemorySystem::new(32, 460.0, 200.0);
        let bal = moe_block_cycles(&c, &GateHistogram::balanced(&c), &p, &hbm, 20.0);
        let skew = moe_block_cycles(&c, &GateHistogram::skewed(&c, 0.8, 7), &p, &hbm, 20.0);
        assert!((skew - bal).abs() / bal < 0.10, "bal {bal} skew {skew}");
    }

    #[test]
    fn expert_stream_is_the_exposed_leading_stream() {
        // The residency-discount source: streaming one expert's two
        // weight matrices. Positive on DDR, vanishing on HBM, and
        // never larger than a whole MoE block that contains it.
        let (c, p, mem) = setup();
        let s = expert_stream_cycles(&c, &mem, 0.6);
        assert!(s > 0.0);
        let hbm = MemorySystem::new(32, 460.0, 200.0);
        assert!(expert_stream_cycles(&c, &hbm, 20.0) < s);
        let h = GateHistogram::balanced(&c);
        assert!(s < moe_block_cycles(&c, &h, &p, &mem, 0.6));
    }

    #[test]
    fn ffn_block_positive_and_scales() {
        let (c, p, mem) = setup();
        let base = ffn_block_cycles(&c, &p, &mem, 0.6);
        let wide = LinearParams { t_in: 32, t_out: 32, n_l: 2 };
        let faster = ffn_block_cycles(&c, &wide, &mem, 0.6);
        assert!(base > 0.0 && faster <= base);
    }

    #[test]
    fn prop_moe_cycles_monotone_in_expert_count_of_tokens() {
        check(40, |g| {
            let c = m3vit_small();
            let p = LinearParams { t_in: 16, t_out: 16, n_l: g.usize(1, 4) };
            let mem = MemorySystem::new(32, 460.0, 200.0);
            let mut t1 = vec![0usize; c.num_experts];
            for slot in t1.iter_mut() {
                *slot = g.usize(0, 40);
            }
            let mut t2 = t1.clone();
            let i = g.usize(0, c.num_experts - 1);
            t2[i] += g.usize(1, 30);
            let h1 = GateHistogram { tokens_per_expert: t1 };
            let h2 = GateHistogram { tokens_per_expert: t2 };
            // NOTE: histograms here need not sum to k·N — the model
            // takes whatever the gate produced.
            let c1 = moe_partial(&c, &h1, &p, &mem);
            let c2 = moe_partial(&c, &h2, &p, &mem);
            prop_assert(c2 >= c1, format!("{c2} < {c1}"))
        });

        fn moe_partial(
            c: &ModelConfig,
            h: &GateHistogram,
            p: &LinearParams,
            mem: &MemorySystem,
        ) -> f64 {
            moe_block_cycles(c, h, p, mem, 20.0)
        }
    }
}
