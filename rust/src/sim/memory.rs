//! Off-chip memory model: DDR (ZCU102/U250) and HBM (U280) channels.
//!
//! The paper allocates bandwidth "dynamically during the hardware
//! generation process" and, on U280, stripes expert weights across HBM
//! channels attached to SLR0 (§III-A). We model a channel set with an
//! efficiency factor and let consumers reserve a share.

/// One memory subsystem (all channels of one kind).
#[derive(Clone, Debug)]
pub struct MemorySystem {
    /// Number of independent channels.
    pub channels: usize,
    /// Peak bytes/s per channel.
    pub chan_bytes_per_sec: f64,
    /// Sustained fraction of peak (row misses, refresh, AXI overhead).
    pub efficiency: f64,
    /// Accelerator clock (to convert to bytes/cycle).
    pub freq_hz: f64,
}

impl MemorySystem {
    pub fn new(channels: usize, total_gbs: f64, freq_mhz: f64) -> Self {
        MemorySystem {
            channels,
            chan_bytes_per_sec: total_gbs * 1e9 / channels as f64,
            efficiency: 0.82,
            freq_hz: freq_mhz * 1e6,
        }
    }

    /// Sustained bytes/cycle delivered by `n_chan` channels.
    pub fn bytes_per_cycle(&self, n_chan: usize) -> f64 {
        let n = n_chan.min(self.channels) as f64;
        n * self.chan_bytes_per_sec * self.efficiency / self.freq_hz
    }

    /// Cycles to transfer `bytes` over `n_chan` channels, including a
    /// fixed per-burst setup cost.
    pub fn transfer_cycles(&self, bytes: u64, n_chan: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        const BURST_SETUP: f64 = 30.0;
        BURST_SETUP + bytes as f64 / self.bytes_per_cycle(n_chan)
    }
}

/// A static bandwidth plan: how many channels each consumer owns.
/// (On single-channel DDR these are time-shares of the one channel —
/// modeled as fractional channels.)
#[derive(Clone, Copy, Debug)]
pub struct BwAllocation {
    /// Channels streaming expert/FFN weights into the MoE block.
    pub moe_weights: f64,
    /// Channels feeding MSA weights + activations.
    pub msa: f64,
    /// Channels for host activation traffic (Fig. 3a Buf0/Buf1).
    pub activations: f64,
}

impl BwAllocation {
    /// The paper's U280 placement: most channels to the expert
    /// streamer, the rest split between MSA and host buffers.
    pub fn for_channels(channels: usize) -> BwAllocation {
        if channels >= 8 {
            let c = channels as f64;
            BwAllocation { moe_weights: c * 0.625, msa: c * 0.25, activations: c * 0.125 }
        } else {
            // Single/few-channel DDR: time-multiplexed shares. Expert
            // streaming is the critical consumer (III-A), so it owns
            // three quarters of the channel.
            let c = channels as f64;
            BwAllocation { moe_weights: c * 0.75, msa: c * 0.15, activations: c * 0.10 }
        }
    }

    pub fn total(&self) -> f64 {
        self.moe_weights + self.msa + self.activations
    }
}

/// Cycles to move `bytes` given a fractional channel share.
pub fn share_transfer_cycles(mem: &MemorySystem, bytes: u64, share_channels: f64) -> f64 {
    if bytes == 0 {
        return 0.0;
    }
    const BURST_SETUP: f64 = 30.0;
    let bpc = mem.bytes_per_cycle(mem.channels) * (share_channels / mem.channels as f64);
    BURST_SETUP + bytes as f64 / bpc.max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    fn hbm() -> MemorySystem {
        MemorySystem::new(32, 460.0, 200.0)
    }

    fn ddr() -> MemorySystem {
        MemorySystem::new(1, 19.2, 300.0)
    }

    #[test]
    fn bytes_per_cycle_scales_with_channels() {
        let m = hbm();
        let one = m.bytes_per_cycle(1);
        let all = m.bytes_per_cycle(32);
        assert!((all / one - 32.0).abs() < 1e-9);
    }

    #[test]
    fn channel_count_clamped() {
        let m = ddr();
        assert_eq!(m.bytes_per_cycle(1), m.bytes_per_cycle(99));
    }

    #[test]
    fn ddr_sustained_rate_sane() {
        // 19.2 GB/s × 0.82 at 300 MHz ≈ 52.5 B/cycle
        let m = ddr();
        let bpc = m.bytes_per_cycle(1);
        assert!((bpc - 52.48).abs() < 0.1, "{bpc}");
    }

    #[test]
    fn transfer_includes_setup() {
        let m = ddr();
        assert_eq!(m.transfer_cycles(0, 1), 0.0);
        assert!(m.transfer_cycles(1, 1) > 30.0);
    }

    #[test]
    fn allocation_conserves_channels() {
        for ch in [1, 2, 4, 8, 32] {
            let a = BwAllocation::for_channels(ch);
            assert!(a.total() <= ch as f64 + 1e-9, "{ch}: {}", a.total());
            assert!(a.moe_weights > 0.0 && a.msa > 0.0 && a.activations > 0.0);
        }
    }

    #[test]
    fn moe_gets_majority_share() {
        // §III-A: the expert streamer sits next to the memory and gets
        // the lion's share — it is the bandwidth-critical block.
        for ch in [1, 4, 32] {
            let a = BwAllocation::for_channels(ch);
            assert!(a.moe_weights > a.msa && a.moe_weights > a.activations);
        }
    }

    #[test]
    fn prop_transfer_monotone_in_bytes() {
        check(100, |g| {
            let m = hbm();
            let b1 = g.u64() % 1_000_000;
            let extra = g.u64() % 1_000_000;
            let c = g.usize(1, 32);
            let t1 = m.transfer_cycles(b1, c);
            let t2 = m.transfer_cycles(b1 + extra, c);
            prop_assert(t2 >= t1, format!("{b1}+{extra} on {c}ch: {t2} < {t1}"))
        });
    }
}
