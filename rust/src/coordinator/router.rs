//! Round-robin CU router (§III-C): distributes the patch indices
//! routed to the active expert across N_L compute units, in order,
//! so every CU carries the same load regardless of how the gate
//! skewed the tokens. Only the router touches activations; weights are
//! broadcast — both properties are checked by tests/proptests here and
//! exercised against real gate output in the integration tests.

/// Assignment of one expert's token list onto CUs.
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    /// per_cu[c] = patch indices handled by CU c, in arrival order.
    pub per_cu: Vec<Vec<usize>>,
}

impl Assignment {
    pub fn loads(&self) -> Vec<usize> {
        self.per_cu.iter().map(|v| v.len()).collect()
    }

    pub fn max_load(&self) -> usize {
        self.loads().into_iter().max().unwrap_or(0)
    }

    pub fn min_load(&self) -> usize {
        self.loads().into_iter().min().unwrap_or(0)
    }

    pub fn total(&self) -> usize {
        self.per_cu.iter().map(|v| v.len()).sum()
    }
}

/// The round-robin router: reads the first N_L unused patch indices
/// and cyclically hands them to the CUs.
pub fn route_round_robin(patch_indices: &[usize], n_cu: usize) -> Assignment {
    assert!(n_cu > 0);
    let mut per_cu = vec![Vec::new(); n_cu];
    for (i, &p) in patch_indices.iter().enumerate() {
        per_cu[i % n_cu].push(p);
    }
    Assignment { per_cu }
}

/// Static pre-partitioned assignment (the strawman §III-C rejects):
/// patch indices are split by *patch id range*, so a skewed gate can
/// leave CUs idle. Provided for the ablation bench.
pub fn route_static(patch_indices: &[usize], n_cu: usize, n_patches: usize) -> Assignment {
    assert!(n_cu > 0);
    let mut per_cu = vec![Vec::new(); n_cu];
    let span = n_patches.div_ceil(n_cu);
    for &p in patch_indices {
        per_cu[(p / span.max(1)).min(n_cu - 1)].push(p);
    }
    Assignment { per_cu }
}

/// Token lists per expert from flat gate indices (B·N·k assignment
/// stream): expert_tokens[e] = positions routed to expert e, in order.
pub fn expert_token_lists(gate_idx: &[i32], num_experts: usize, top_k: usize) -> Vec<Vec<usize>> {
    let mut lists = vec![Vec::new(); num_experts];
    for (slot, &e) in gate_idx.iter().enumerate() {
        let token = slot / top_k;
        if (e as usize) < num_experts {
            lists[e as usize].push(token);
        }
    }
    lists
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn round_robin_balances_within_one() {
        let idx: Vec<usize> = (0..17).collect();
        let a = route_round_robin(&idx, 4);
        assert_eq!(a.total(), 17);
        assert!(a.max_load() - a.min_load() <= 1, "{:?}", a.loads());
    }

    #[test]
    fn round_robin_preserves_order_per_cu() {
        let idx = vec![9, 3, 7, 1, 8, 2];
        let a = route_round_robin(&idx, 2);
        assert_eq!(a.per_cu[0], vec![9, 7, 8]);
        assert_eq!(a.per_cu[1], vec![3, 1, 2]);
    }

    #[test]
    fn static_partition_can_starve() {
        // All tokens in the low patch range → CU0 takes everything.
        let idx: Vec<usize> = (0..10).collect();
        let a = route_static(&idx, 4, 64);
        assert_eq!(a.per_cu[0].len(), 10);
        assert_eq!(a.per_cu[1].len(), 0);
    }

    #[test]
    fn expert_token_lists_from_gate() {
        // 3 tokens, top-2: token0→(0,1), token1→(1,2), token2→(0,2)
        let gi = vec![0, 1, 1, 2, 0, 2];
        let lists = expert_token_lists(&gi, 4, 2);
        assert_eq!(lists[0], vec![0, 2]);
        assert_eq!(lists[1], vec![0, 1]);
        assert_eq!(lists[2], vec![1, 2]);
        assert!(lists[3].is_empty());
    }

    #[test]
    fn prop_router_conserves_and_balances() {
        check(300, |g| {
            let n = g.usize(0, 400);
            let n_cu = g.usize(1, 16);
            let idx = g.vec_usize(n, 0, 1000);
            let a = route_round_robin(&idx, n_cu);
            // conservation: nothing lost, nothing duplicated
            let mut flat: Vec<usize> = a.per_cu.iter().flatten().copied().collect();
            let mut orig = idx.clone();
            flat.sort_unstable();
            orig.sort_unstable();
            prop_assert(flat == orig, "token set changed")?;
            // balance: |max - min| ≤ 1
            prop_assert(
                a.max_load() - a.min_load() <= 1,
                format!("unbalanced {:?}", a.loads()),
            )
        });
    }

    #[test]
    fn prop_router_max_load_is_ceiling() {
        check(200, |g| {
            let n = g.usize(1, 500);
            let n_cu = g.usize(1, 12);
            let idx = g.vec_usize(n, 0, 10);
            let a = route_round_robin(&idx, n_cu);
            prop_assert(a.max_load() == n.div_ceil(n_cu), format!("{n} on {n_cu}"))
        });
    }

    #[test]
    fn prop_gate_lists_conserve_assignments() {
        check(200, |g| {
            let tokens = g.usize(1, 100);
            let e = g.usize(1, 16);
            let k = g.usize(1, e.min(4));
            let mut gi = Vec::with_capacity(tokens * k);
            for _ in 0..tokens * k {
                gi.push(g.usize(0, e - 1) as i32);
            }
            let lists = expert_token_lists(&gi, e, k);
            let total: usize = lists.iter().map(|l| l.len()).sum();
            prop_assert(total == tokens * k, format!("{total} != {}", tokens * k))
        });
    }
}
