//! L3 coordinator: the host-side orchestration the paper assigns to
//! the CPU (Fig. 3a) — double-buffered block pipeline, round-robin CU
//! router, expert-by-expert scheduler, request batcher, metrics.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod router;
pub mod scheduler;

pub use pipeline::{run_pipeline, run_sequential, Blk2Stage, MsaStage, PipelineReport, StageEngine};
