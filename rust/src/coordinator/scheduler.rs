//! Expert-by-expert schedule (M3ViT's computation order, §II): given
//! gate decisions, produce the ordered per-expert work items the MoE
//! block executes — load expert e's weights once, process every token
//! routed to it. Feeds both the simulator (measured histograms) and
//! the reporting layer.

use crate::coordinator::router::{expert_token_lists, route_round_robin, Assignment};

/// One expert's scheduled work.
#[derive(Clone, Debug)]
pub struct ExpertWork {
    pub expert: usize,
    pub tokens: Vec<usize>,
    pub cu_assignment: Assignment,
}

/// The full schedule for one MoE block invocation.
#[derive(Clone, Debug)]
pub struct MoeSchedule {
    pub items: Vec<ExpertWork>,
    pub num_experts: usize,
    pub top_k: usize,
}

impl MoeSchedule {
    /// Build from flat gate indices (shape B·N·k flattened).
    pub fn from_gate(gate_idx: &[i32], num_experts: usize, top_k: usize, n_cu: usize) -> Self {
        let lists = expert_token_lists(gate_idx, num_experts, top_k);
        let items = lists
            .into_iter()
            .enumerate()
            .map(|(expert, tokens)| {
                let cu_assignment = route_round_robin(&tokens, n_cu);
                ExpertWork { expert, tokens, cu_assignment }
            })
            .collect();
        MoeSchedule { items, num_experts, top_k }
    }

    /// Token histogram (for the simulator).
    pub fn histogram(&self) -> Vec<usize> {
        self.items.iter().map(|w| w.tokens.len()).collect()
    }

    /// Total token-expert assignments.
    pub fn total_assignments(&self) -> usize {
        self.items.iter().map(|w| w.tokens.len()).sum()
    }

    /// Number of experts that received zero tokens (idle weight loads —
    /// could be skipped by a "skip empty experts" optimization; the
    /// ablation bench measures its value).
    pub fn idle_experts(&self) -> usize {
        self.items.iter().filter(|w| w.tokens.is_empty()).count()
    }

    /// Load-imbalance factor across experts: max/mean token count.
    pub fn imbalance(&self) -> f64 {
        let h = self.histogram();
        let max = *h.iter().max().unwrap_or(&0) as f64;
        let mean = self.total_assignments() as f64 / self.num_experts.max(1) as f64;
        if mean > 0.0 {
            max / mean
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn schedule_covers_every_assignment() {
        // 4 tokens, top-2 over 4 experts.
        let gi = vec![0, 1, 2, 3, 0, 2, 1, 3];
        let s = MoeSchedule::from_gate(&gi, 4, 2, 2);
        assert_eq!(s.total_assignments(), 8);
        assert_eq!(s.histogram(), vec![2, 2, 2, 2]);
        assert_eq!(s.idle_experts(), 0);
        assert!((s.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skewed_gate_detected() {
        let gi = vec![0, 0, 0, 0, 0, 0]; // everything to expert 0
        let s = MoeSchedule::from_gate(&gi, 4, 1, 2);
        assert_eq!(s.histogram(), vec![6, 0, 0, 0]);
        assert_eq!(s.idle_experts(), 3);
        assert!(s.imbalance() > 3.9);
    }

    #[test]
    fn cu_assignments_balanced_per_expert() {
        let gi: Vec<i32> = (0..64).map(|i| i % 4).collect();
        let s = MoeSchedule::from_gate(&gi, 4, 2, 3);
        for w in &s.items {
            assert!(w.cu_assignment.max_load() - w.cu_assignment.min_load() <= 1);
        }
    }

    #[test]
    fn prop_schedule_conserves_tokens() {
        check(150, |g| {
            let tokens = g.usize(1, 80);
            let e = g.usize(1, 12);
            let k = g.usize(1, 3.min(e));
            let gi: Vec<i32> =
                (0..tokens * k).map(|_| g.usize(0, e - 1) as i32).collect();
            let s = MoeSchedule::from_gate(&gi, e, k, g.usize(1, 8));
            prop_assert(
                s.total_assignments() == tokens * k,
                format!("{} != {}", s.total_assignments(), tokens * k),
            )?;
            // each expert's CU assignment is internally consistent
            for w in &s.items {
                prop_assert(
                    w.cu_assignment.total() == w.tokens.len(),
                    "cu assignment lost tokens",
                )?;
            }
            Ok(())
        });
    }
}
