//! Dynamic batcher: groups incoming requests into the batch sizes the
//! AOT artifacts were compiled for (PJRT executables are fixed-shape),
//! padding the tail batch when the timeout expires.
//!
//! Time is injected via [`Clock`] rather than read from
//! `std::time::Instant`: the runtime path uses the wall clock
//! (default), while the fleet-serving DES (serve/) and the tests drive
//! a [`crate::util::clock::VirtualClock`] — batch-formation decisions
//! are then exact functions of simulated time, with no sleeps or flaky
//! `Instant` arithmetic anywhere.

use std::collections::VecDeque;
use std::time::Duration;

use crate::util::clock::{Clock, WallClock};

/// One queued inference request. `enqueued` is the batcher clock's
/// `now()` at push time (Duration since the clock's epoch).
#[derive(Clone, Debug)]
pub struct Request<T> {
    pub id: u64,
    pub payload: T,
    pub enqueued: Duration,
}

/// A formed batch: the chosen executable batch size, the member
/// requests, and how many trailing slots are padding.
#[derive(Clone, Debug)]
pub struct Batch<T> {
    pub batch_size: usize,
    pub requests: Vec<Request<T>>,
    pub padding: usize,
}

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Batch sizes with compiled executables, ascending (e.g. [1, 4]).
    pub sizes: Vec<usize>,
    /// Max time the oldest request may wait before a padded flush.
    pub max_wait: Duration,
}

/// The queue + policy. (`Clock + Send` keeps `Batcher<T: Send>: Send`,
/// as the runtime's worker-thread idiom expects.)
pub struct Batcher<T> {
    cfg: BatcherConfig,
    /// FIFO backlog. A deque, not a Vec: taking a batch from the
    /// front must not shift the whole backlog (the serving DES runs
    /// deep-overload sweeps where the backlog reaches thousands).
    queue: VecDeque<Request<T>>,
    next_id: u64,
    clock: Box<dyn Clock + Send>,
}

impl<T> Batcher<T> {
    /// Wall-clock batcher (the runtime serving path).
    pub fn new(cfg: BatcherConfig) -> Self {
        Self::with_clock(cfg, Box::new(WallClock::new()))
    }

    /// Batcher on an injected clock (virtual for the DES and tests).
    pub fn with_clock(cfg: BatcherConfig, clock: Box<dyn Clock + Send>) -> Self {
        assert!(!cfg.sizes.is_empty());
        let mut cfg = cfg;
        cfg.sizes.sort_unstable();
        Batcher { cfg, queue: VecDeque::new(), next_id: 0, clock }
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }

    pub fn push(&mut self, payload: T) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let enqueued = self.clock.now();
        self.queue.push_back(Request { id, payload, enqueued });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Enqueue time of the oldest waiting request — the DES schedules
    /// its padded-flush wakeup at `oldest_enqueued() + max_wait`.
    pub fn oldest_enqueued(&self) -> Option<Duration> {
        self.queue.front().map(|r| r.enqueued)
    }

    /// Form the next batch at the clock's current time, if policy
    /// allows:
    /// * if the queue can fill the largest size → emit immediately;
    /// * else if the oldest request exceeded max_wait → emit the best
    ///   (largest-covering) size with padding;
    /// * else wait (None).
    pub fn next_batch(&mut self) -> Option<Batch<T>> {
        self.next_batch_at(self.clock.now())
    }

    /// Same decision at an explicit time (callers that manage time
    /// themselves; `now` must be ≥ every enqueue time).
    pub fn next_batch_at(&mut self, now: Duration) -> Option<Batch<T>> {
        if self.queue.is_empty() {
            return None;
        }
        let biggest = *self.cfg.sizes.last().unwrap();
        if self.queue.len() >= biggest {
            return Some(self.take(biggest, biggest));
        }
        let oldest_wait = now.saturating_sub(self.queue[0].enqueued);
        if oldest_wait >= self.cfg.max_wait {
            let n = self.queue.len();
            // Smallest compiled size that covers all pending requests,
            // or the largest size if even that doesn't cover them.
            let size = *self
                .cfg
                .sizes
                .iter()
                .find(|&&s| s >= n)
                .unwrap_or(&biggest);
            let take_n = n.min(size);
            return Some(self.take(take_n, size));
        }
        None
    }

    /// Flush everything (shutdown), possibly into multiple batches.
    pub fn drain(&mut self) -> Vec<Batch<T>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let n = self.queue.len();
            let biggest = *self.cfg.sizes.last().unwrap();
            let size = *self.cfg.sizes.iter().find(|&&s| s >= n).unwrap_or(&biggest);
            let take_n = n.min(size);
            out.push(self.take(take_n, size));
        }
        out
    }

    /// Evict the raw FIFO backlog without forming batches — failover:
    /// when a device fails, its queued requests are re-dispatched
    /// elsewhere (with their original enqueue stamps), not executed as
    /// padded batches on a dead device like [`Batcher::drain`] would.
    pub fn take_pending(&mut self) -> Vec<Request<T>> {
        self.queue.drain(..).collect()
    }

    fn take(&mut self, n: usize, batch_size: usize) -> Batch<T> {
        let requests: Vec<Request<T>> = self.queue.drain(..n).collect();
        Batch { batch_size, padding: batch_size - requests.len(), requests }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn cfg() -> BatcherConfig {
        BatcherConfig { sizes: vec![1, 4], max_wait: Duration::from_millis(10) }
    }

    /// Batcher on a virtual clock the test controls — no real waiting.
    fn virt() -> (Batcher<i32>, VirtualClock) {
        let clock = VirtualClock::new();
        (Batcher::with_clock(cfg(), Box::new(clock.clone())), clock)
    }

    #[test]
    fn full_batch_emitted_immediately() {
        let (mut b, _clock) = virt();
        for i in 0..5 {
            b.push(i);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.batch_size, 4);
        assert_eq!(batch.padding, 0);
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn partial_batch_waits_for_timeout() {
        let (mut b, clock) = virt();
        b.push(0);
        b.push(1);
        assert!(b.next_batch().is_none(), "should wait");
        // One tick before the deadline: still waiting.
        clock.advance_to(Duration::from_millis(10) - Duration::from_nanos(1));
        assert!(b.next_batch().is_none(), "deadline is inclusive, not early");
        clock.advance_to(Duration::from_millis(10));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.batch_size, 4);
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.padding, 2);
    }

    #[test]
    fn single_request_times_out_to_b1() {
        let (mut b, clock) = virt();
        b.push(42);
        clock.advance_by(Duration::from_millis(20));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.batch_size, 1);
        assert_eq!(batch.padding, 0);
    }

    #[test]
    fn timeout_measured_from_oldest_request() {
        let (mut b, clock) = virt();
        b.push(0);
        clock.advance_by(Duration::from_millis(8));
        b.push(1); // young request must not reset the deadline
        clock.advance_by(Duration::from_millis(2));
        let batch = b.next_batch().expect("oldest hit max_wait");
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.oldest_enqueued(), None);
    }

    #[test]
    fn oldest_enqueued_tracks_queue_head() {
        let (mut b, clock) = virt();
        assert_eq!(b.oldest_enqueued(), None);
        clock.advance_to(Duration::from_millis(3));
        b.push(0);
        clock.advance_to(Duration::from_millis(9));
        b.push(1);
        assert_eq!(b.oldest_enqueued(), Some(Duration::from_millis(3)));
    }

    #[test]
    fn drain_covers_everything() {
        let (mut b, _clock) = virt();
        for i in 0..7 {
            b.push(i);
        }
        let batches = b.drain();
        let total: usize = batches.iter().map(|x| x.requests.len()).sum();
        assert_eq!(total, 7);
        assert_eq!(b.pending(), 0);
        // ids preserved in order
        let ids: Vec<u64> =
            batches.iter().flat_map(|x| x.requests.iter().map(|r| r.id)).collect();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn take_pending_evicts_fifo_without_batching() {
        let (mut b, clock) = virt();
        clock.advance_to(Duration::from_millis(2));
        b.push(10);
        clock.advance_to(Duration::from_millis(5));
        b.push(11);
        let evicted = b.take_pending();
        assert_eq!(b.pending(), 0);
        assert_eq!(
            evicted.iter().map(|r| r.payload).collect::<Vec<_>>(),
            vec![10, 11],
            "FIFO order preserved"
        );
        // Original enqueue stamps survive the eviction (failover
        // re-dispatch keeps true arrival-side wait accounting).
        assert_eq!(evicted[0].enqueued, Duration::from_millis(2));
        assert_eq!(evicted[1].enqueued, Duration::from_millis(5));
        assert!(b.take_pending().is_empty());
    }

    #[test]
    fn ids_monotone() {
        let (mut b, _clock) = virt();
        let a = b.push(0);
        let c = b.push(1);
        assert!(c > a);
    }

    #[test]
    fn batcher_stays_send() {
        // The runtime moves batchers into worker threads; the clock
        // indirection must not cost the auto-trait.
        fn assert_send<T: Send>(_: &T) {}
        assert_send(&Batcher::<u32>::new(cfg()));
        let (b, _clock) = virt();
        assert_send(&b);
    }

    #[test]
    fn wall_clock_default_still_works() {
        // The runtime path: no injected clock, queue-fill semantics
        // identical (no timeout dependence exercised here).
        let mut b = Batcher::new(cfg());
        for i in 0..4 {
            b.push(i);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.batch_size, 4);
        assert_eq!(batch.padding, 0);
    }
}
