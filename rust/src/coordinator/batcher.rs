//! Dynamic batcher: groups incoming requests into the batch sizes the
//! AOT artifacts were compiled for (PJRT executables are fixed-shape),
//! padding the tail batch when the timeout expires.

use std::time::{Duration, Instant};

/// One queued inference request.
#[derive(Clone, Debug)]
pub struct Request<T> {
    pub id: u64,
    pub payload: T,
    pub enqueued: Instant,
}

/// A formed batch: the chosen executable batch size, the member
/// requests, and how many trailing slots are padding.
#[derive(Clone, Debug)]
pub struct Batch<T> {
    pub batch_size: usize,
    pub requests: Vec<Request<T>>,
    pub padding: usize,
}

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Batch sizes with compiled executables, ascending (e.g. [1, 4]).
    pub sizes: Vec<usize>,
    /// Max time the oldest request may wait before a padded flush.
    pub max_wait: Duration,
}

/// The queue + policy.
pub struct Batcher<T> {
    cfg: BatcherConfig,
    queue: Vec<Request<T>>,
    next_id: u64,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(!cfg.sizes.is_empty());
        let mut cfg = cfg;
        cfg.sizes.sort_unstable();
        Batcher { cfg, queue: Vec::new(), next_id: 0 }
    }

    pub fn push(&mut self, payload: T) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push(Request { id, payload, enqueued: Instant::now() });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Form the next batch, if policy allows:
    /// * if the queue can fill the largest size → emit immediately;
    /// * else if the oldest request exceeded max_wait → emit the best
    ///   (largest-covering) size with padding;
    /// * else wait (None).
    pub fn next_batch(&mut self, now: Instant) -> Option<Batch<T>> {
        if self.queue.is_empty() {
            return None;
        }
        let biggest = *self.cfg.sizes.last().unwrap();
        if self.queue.len() >= biggest {
            return Some(self.take(biggest, biggest));
        }
        let oldest_wait = now.duration_since(self.queue[0].enqueued);
        if oldest_wait >= self.cfg.max_wait {
            let n = self.queue.len();
            // Smallest compiled size that covers all pending requests,
            // or the largest size if even that doesn't cover them.
            let size = *self
                .cfg
                .sizes
                .iter()
                .find(|&&s| s >= n)
                .unwrap_or(&biggest);
            let take_n = n.min(size);
            return Some(self.take(take_n, size));
        }
        None
    }

    /// Flush everything (shutdown), possibly into multiple batches.
    pub fn drain(&mut self) -> Vec<Batch<T>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let n = self.queue.len();
            let biggest = *self.cfg.sizes.last().unwrap();
            let size = *self.cfg.sizes.iter().find(|&&s| s >= n).unwrap_or(&biggest);
            let take_n = n.min(size);
            out.push(self.take(take_n, size));
        }
        out
    }

    fn take(&mut self, n: usize, batch_size: usize) -> Batch<T> {
        let requests: Vec<Request<T>> = self.queue.drain(..n).collect();
        Batch { batch_size, padding: batch_size - requests.len(), requests }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BatcherConfig {
        BatcherConfig { sizes: vec![1, 4], max_wait: Duration::from_millis(10) }
    }

    #[test]
    fn full_batch_emitted_immediately() {
        let mut b = Batcher::new(cfg());
        for i in 0..5 {
            b.push(i);
        }
        let batch = b.next_batch(Instant::now()).unwrap();
        assert_eq!(batch.batch_size, 4);
        assert_eq!(batch.padding, 0);
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn partial_batch_waits_for_timeout() {
        let mut b = Batcher::new(cfg());
        b.push(0);
        b.push(1);
        assert!(b.next_batch(Instant::now()).is_none(), "should wait");
        let later = Instant::now() + Duration::from_millis(20);
        let batch = b.next_batch(later).unwrap();
        assert_eq!(batch.batch_size, 4);
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.padding, 2);
    }

    #[test]
    fn single_request_times_out_to_b1() {
        let mut b = Batcher::new(cfg());
        b.push(42);
        let later = Instant::now() + Duration::from_millis(20);
        let batch = b.next_batch(later).unwrap();
        assert_eq!(batch.batch_size, 1);
        assert_eq!(batch.padding, 0);
    }

    #[test]
    fn drain_covers_everything() {
        let mut b = Batcher::new(cfg());
        for i in 0..7 {
            b.push(i);
        }
        let batches = b.drain();
        let total: usize = batches.iter().map(|x| x.requests.len()).sum();
        assert_eq!(total, 7);
        assert_eq!(b.pending(), 0);
        // ids preserved in order
        let ids: Vec<u64> =
            batches.iter().flat_map(|x| x.requests.iter().map(|r| r.id)).collect();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn ids_monotone() {
        let mut b = Batcher::new(cfg());
        let a = b.push(0);
        let c = b.push(1);
        assert!(c > a);
    }
}
