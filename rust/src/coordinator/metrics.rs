//! Request/stage latency metrics for the coordinator: counters,
//! percentiles, per-lane busy time (the runtime analog of the
//! simulator's timeline).

use std::time::Duration;

/// A latency recorder with percentile queries.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean(&self) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        let sum: u64 = self.samples_us.iter().sum();
        Duration::from_micros(sum / self.samples_us.len() as u64)
    }

    /// p in [0,100].
    pub fn percentile(&self, p: f64) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        Duration::from_micros(v[idx.min(v.len() - 1)])
    }

    pub fn p50(&self) -> Duration {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> Duration {
        self.percentile(99.0)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.samples_us.iter().copied().max().unwrap_or(0))
    }
}

/// Coordinator-level metrics for one run.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorMetrics {
    pub request_latency: LatencyStats,
    pub msa_stage: LatencyStats,
    pub ffn_stage: LatencyStats,
    pub requests_done: u64,
    pub batches_run: u64,
    pub padded_slots: u64,
    pub buffer_swaps: u64,
}

impl CoordinatorMetrics {
    pub fn throughput_rps(&self, wall: Duration) -> f64 {
        self.requests_done as f64 / wall.as_secs_f64().max(1e-12)
    }

    /// Fraction of executed batch slots that were padding (batching
    /// efficiency — lower is better).
    pub fn padding_fraction(&self, slots: u64) -> f64 {
        if slots == 0 {
            0.0
        } else {
            self.padded_slots as f64 / slots as f64
        }
    }

    pub fn summary(&self, wall: Duration) -> String {
        format!(
            "requests={} batches={} swaps={} wall={:?} throughput={:.2} req/s \
             latency p50={:?} p99={:?} (msa p50 {:?}, ffn/moe p50 {:?})",
            self.requests_done,
            self.batches_run,
            self.buffer_swaps,
            wall,
            self.throughput_rps(wall),
            self.request_latency.p50(),
            self.request_latency.p99(),
            self.msa_stage.p50(),
            self.ffn_stage.p50(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::default();
        for ms in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 100] {
            s.record(Duration::from_millis(ms));
        }
        assert!(s.p50() <= s.p99());
        assert_eq!(s.max(), Duration::from_millis(100));
        assert_eq!(s.count(), 10);
        assert!(s.mean() >= Duration::from_millis(10));
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStats::default();
        assert_eq!(s.p50(), Duration::ZERO);
        assert_eq!(s.mean(), Duration::ZERO);
    }

    #[test]
    fn throughput_math() {
        let m = CoordinatorMetrics { requests_done: 100, ..Default::default() };
        assert!((m.throughput_rps(Duration::from_secs(2)) - 50.0).abs() < 1e-9);
        assert_eq!(m.padding_fraction(0), 0.0);
    }
}
