//! Request/stage latency metrics for the coordinator: counters,
//! percentiles, per-lane busy time (the runtime analog of the
//! simulator's timeline). The fleet-serving DES (serve/) aggregates
//! per-device recorders with [`LatencyStats::merge`], so fleet-wide
//! percentiles are computed over the exact union of recorded samples,
//! never approximated from per-device percentiles.
//!
//! Since the DES was rebuilt for tens-of-millions-of-request horizons,
//! [`LatencyStats`] is a **streaming log-bucketed histogram**
//! (HDR-style): O(1) record, O(1) memory in the sample count, exact
//! bucket-wise `merge`. The PR-2 store-all-samples recorder is
//! retained as the test-path reference (the `exact` module below,
//! compiled only under test — the same pattern as the HAS naive
//! evaluator) and a proptest pins histogram percentiles to within one
//! bucket of the exact nearest-rank answer.

use std::time::Duration;

/// Sub-bucket resolution of the streaming histogram: `2^SUB_BITS`
/// buckets per power of two, so a bucket spanning `[lo, hi]` has
/// `hi - lo < lo / 128` — better than 1% relative resolution.
const SUB_BITS: u32 = 7;
const SUB: usize = 1 << SUB_BITS;

/// Bucket index of a microsecond value. Values below `SUB` get exact
/// width-1 buckets; above, each power of two splits into `SUB` equal
/// buckets. Monotone in `v_us`, so cumulative bucket counts walk the
/// sample set in sorted order (up to intra-bucket ties).
#[inline]
fn bucket_index(v_us: u64) -> usize {
    if v_us < SUB as u64 {
        v_us as usize
    } else {
        let msb = 63 - v_us.leading_zeros(); // >= SUB_BITS
        let shift = msb - SUB_BITS;
        ((shift as usize) << SUB_BITS) + (v_us >> shift) as usize
    }
}

/// Inclusive `[lo, hi]` microsecond range of bucket `i` (the inverse
/// of [`bucket_index`]). Width 1 below `2·SUB`, `< lo/128` above.
#[inline]
fn bucket_bounds(i: usize) -> (u64, u64) {
    if i < SUB {
        return (i as u64, i as u64);
    }
    let shift = (i >> SUB_BITS) as u32 - 1;
    let lo = ((SUB + (i & (SUB - 1))) as u64) << shift;
    (lo, lo + (1u64 << shift) - 1)
}

/// A streaming latency recorder with percentile queries.
///
/// `percentile` keeps the **nearest-rank** convention: the p-th
/// percentile of n samples is the k-th smallest with
/// `k = ⌈p/100 · n⌉` (clamped to [1, n]). The histogram returns the
/// upper bound of the bucket holding that k-th sample, clamped to the
/// exactly-tracked `[min, max]` — so the result is exact for k = 1 and
/// k = n (hence for n ≤ 2 at every p, which tiny-count tests rely on),
/// exact below 256 µs, and within `1/128` (< 1%) relative error of the
/// exact nearest-rank sample everywhere else. `mean`, `count`, `min`
/// and `max` are exact; `merge` is an exact bucket-count union.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LatencyStats {
    /// bucket_index → sample count, grown lazily to the highest bucket
    /// seen. The last entry is always nonzero, so runs recording the
    /// same value multiset compare equal.
    buckets: Vec<u64>,
    count: u64,
    /// Exact Σ samples in µs (u128: immune to overflow at any horizon).
    sum_us: u128,
    min_us: u64,
    max_us: u64,
}

impl Default for LatencyStats {
    fn default() -> Self {
        LatencyStats { buckets: Vec::new(), count: 0, sum_us: 0, min_us: u64::MAX, max_us: 0 }
    }
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        let v = d.as_micros() as u64;
        let i = bucket_index(v);
        if i >= self.buckets.len() {
            self.buckets.resize(i + 1, 0);
        }
        self.buckets[i] += 1;
        self.count += 1;
        self.sum_us += v as u128;
        self.min_us = self.min_us.min(v);
        self.max_us = self.max_us.max(v);
    }

    pub fn count(&self) -> usize {
        self.count as usize
    }

    /// Absorb another recorder (fleet-wide aggregation over per-device
    /// stats): bucket counts add element-wise, so the merge is exactly
    /// what recording every sample into one recorder would produce.
    pub fn merge(&mut self, other: &LatencyStats) {
        if other.buckets.len() > self.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Exact mean (the sum is tracked outside the buckets).
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros((self.sum_us / self.count as u128) as u64)
    }

    /// Nearest-rank percentile, p in [0,100] (see type docs for the
    /// resolution contract). Empty recorder → `Duration::ZERO`.
    pub fn percentile(&self, p: f64) -> Duration {
        self.percentiles(&[p])[0]
    }

    /// Several percentiles (one bucket walk each; the walk is over
    /// O(log(max)·128) buckets, not over samples).
    pub fn percentiles(&self, ps: &[f64]) -> Vec<Duration> {
        if self.count == 0 {
            return vec![Duration::ZERO; ps.len()];
        }
        ps.iter()
            .map(|&p| {
                let rank = ((p / 100.0) * self.count as f64).ceil() as u64;
                Duration::from_micros(self.value_at_rank(rank.clamp(1, self.count)))
            })
            .collect()
    }

    /// Value reported for the k-th smallest sample, 1 ≤ k ≤ count.
    fn value_at_rank(&self, k: u64) -> u64 {
        if k <= 1 {
            return self.min_us;
        }
        if k >= self.count {
            return self.max_us;
        }
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= k {
                // The true k-th sample lives in bucket i (cumulative
                // counts are sorted order); report its upper bound,
                // clamped into the exactly-known value range.
                return bucket_bounds(i).1.clamp(self.min_us, self.max_us);
            }
        }
        self.max_us
    }

    /// Fraction of samples ≤ `bound` (SLO attainment). Counted at
    /// bucket resolution: every sample sharing `bound`'s bucket counts
    /// as within bound (≤ 1/128 relative slack on the cut point, and
    /// exact whenever `bound` is a bucket boundary — in particular
    /// below 256 µs). Empty → 1.0 (an idle service violates no SLO).
    pub fn fraction_leq(&self, bound: Duration) -> f64 {
        if self.count == 0 {
            return 1.0;
        }
        let cut = bucket_index(bound.as_micros() as u64);
        let ok: u64 = self.buckets.iter().take(cut + 1).sum();
        ok as f64 / self.count as f64
    }

    pub fn p50(&self) -> Duration {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> Duration {
        self.percentile(99.0)
    }

    pub fn p999(&self) -> Duration {
        self.percentile(99.9)
    }

    /// Exact maximum recorded sample.
    pub fn max(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            Duration::from_micros(self.max_us)
        }
    }

    /// Single-line text encoding for the fleet-report disk cache
    /// (`has/cache.rs`): `count|sum_us|min_us|max_us|i:c|i:c|...` with
    /// one sparse `index:count` pair per nonzero bucket, ascending.
    /// Only nonzero buckets are written and the highest index comes
    /// last, so [`Self::from_wire`] rebuilds the exact `buckets` vector
    /// (trailing entry nonzero — the invariant behind derived `Eq`) and
    /// the round-trip is bit-identical, including the empty-recorder
    /// sentinel `min_us = u64::MAX, max_us = 0`.
    pub fn to_wire(&self) -> String {
        let mut out =
            format!("{}|{}|{}|{}", self.count, self.sum_us, self.min_us, self.max_us);
        for (i, &c) in self.buckets.iter().enumerate() {
            if c != 0 {
                out.push_str(&format!("|{i}:{c}"));
            }
        }
        out
    }

    /// Strict inverse of [`Self::to_wire`]. `None` on any malformed
    /// input: wrong field count, non-numeric fields, zero or
    /// out-of-order bucket counts, or bucket counts that do not sum to
    /// `count` — corruption must read as a cache miss, never as a
    /// plausible-but-wrong histogram.
    pub fn from_wire(s: &str) -> Option<LatencyStats> {
        let mut parts = s.split('|');
        let count: u64 = parts.next()?.parse().ok()?;
        let sum_us: u128 = parts.next()?.parse().ok()?;
        let min_us: u64 = parts.next()?.parse().ok()?;
        let max_us: u64 = parts.next()?.parse().ok()?;
        let mut buckets: Vec<u64> = Vec::new();
        let mut total: u64 = 0;
        let mut last_index: Option<usize> = None;
        for pair in parts {
            let (i_s, c_s) = pair.split_once(':')?;
            let i: usize = i_s.parse().ok()?;
            let c: u64 = c_s.parse().ok()?;
            if c == 0 || last_index.is_some_and(|last| i <= last) {
                return None;
            }
            last_index = Some(i);
            if i >= buckets.len() {
                buckets.resize(i + 1, 0);
            }
            buckets[i] = c;
            total = total.checked_add(c)?;
        }
        if total != count {
            return None;
        }
        if count == 0 && !(min_us == u64::MAX && max_us == 0 && sum_us == 0) {
            return None;
        }
        Some(LatencyStats { buckets, count, sum_us, min_us, max_us })
    }
}

/// The PR-2 store-all-samples recorder, retained verbatim behind the
/// test path as the reference the streaming histogram is
/// equivalence-tested against (the same pattern as the retained naive
/// HAS evaluator in `has/mod.rs`). Not compiled into release builds.
#[cfg(test)]
pub(crate) mod exact {
    use std::time::Duration;

    /// Exact nearest-rank recorder: keeps every sample.
    #[derive(Clone, Debug, Default)]
    pub struct ExactLatencyStats {
        samples_us: Vec<u64>,
    }

    impl ExactLatencyStats {
        pub fn record(&mut self, d: Duration) {
            self.samples_us.push(d.as_micros() as u64);
        }

        pub fn percentile(&self, p: f64) -> Duration {
            if self.samples_us.is_empty() {
                return Duration::ZERO;
            }
            let mut v = self.samples_us.clone();
            v.sort_unstable();
            let n = v.len();
            let rank = ((p / 100.0) * n as f64).ceil() as usize;
            Duration::from_micros(v[rank.clamp(1, n) - 1])
        }

        pub fn mean(&self) -> Duration {
            if self.samples_us.is_empty() {
                return Duration::ZERO;
            }
            let sum: u64 = self.samples_us.iter().sum();
            Duration::from_micros(sum / self.samples_us.len() as u64)
        }

        pub fn fraction_leq(&self, bound: Duration) -> f64 {
            if self.samples_us.is_empty() {
                return 1.0;
            }
            let b = bound.as_micros() as u64;
            let ok = self.samples_us.iter().filter(|&&s| s <= b).count();
            ok as f64 / self.samples_us.len() as f64
        }

        pub fn max(&self) -> Duration {
            Duration::from_micros(self.samples_us.iter().copied().max().unwrap_or(0))
        }
    }
}

/// Coordinator-level metrics for one run.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorMetrics {
    pub request_latency: LatencyStats,
    pub msa_stage: LatencyStats,
    pub ffn_stage: LatencyStats,
    pub requests_done: u64,
    pub batches_run: u64,
    pub padded_slots: u64,
    pub buffer_swaps: u64,
}

impl CoordinatorMetrics {
    pub fn throughput_rps(&self, wall: Duration) -> f64 {
        self.requests_done as f64 / wall.as_secs_f64().max(1e-12)
    }

    /// Fraction of executed batch slots that were padding (batching
    /// efficiency — lower is better).
    pub fn padding_fraction(&self, slots: u64) -> f64 {
        if slots == 0 {
            0.0
        } else {
            self.padded_slots as f64 / slots as f64
        }
    }

    pub fn summary(&self, wall: Duration) -> String {
        format!(
            "requests={} batches={} swaps={} wall={:?} throughput={:.2} req/s \
             latency p50={:?} p99={:?} (msa p50 {:?}, ffn/moe p50 {:?})",
            self.requests_done,
            self.batches_run,
            self.buffer_swaps,
            wall,
            self.throughput_rps(wall),
            self.request_latency.p50(),
            self.request_latency.p99(),
            self.msa_stage.p50(),
            self.ffn_stage.p50(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::exact::ExactLatencyStats;
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    /// Histogram resolution contract: got is the exact value, or above
    /// it by at most one bucket (< 1/128 relative).
    fn within_bin(got: Duration, exact: Duration) -> bool {
        let (g, e) = (got.as_micros() as u64, exact.as_micros() as u64);
        g >= e && g - e <= e / SUB as u64
    }

    #[test]
    fn bucket_roundtrip_and_resolution() {
        for v in [0u64, 1, 17, 127, 128, 255, 256, 999, 5000, 123_456, 7_654_321, 1 << 40] {
            let i = bucket_index(v);
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && v <= hi, "v={v} not in bucket [{lo},{hi}]");
            assert!(hi - lo <= lo.max(1) / SUB as u64, "bucket too wide at v={v}");
            // Monotone across the boundary.
            assert!(bucket_index(v + 1) >= i);
        }
    }

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::default();
        for ms in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 100] {
            s.record(Duration::from_millis(ms));
        }
        assert!(s.p50() <= s.p99());
        assert_eq!(s.max(), Duration::from_millis(100));
        assert_eq!(s.count(), 10);
        assert!(s.mean() >= Duration::from_millis(10));
        // Nearest-rank on n=10: p50 → 5th smallest (within one bucket),
        // p0/p100 → exact min/max.
        assert!(within_bin(s.p50(), Duration::from_millis(5)), "p50={:?}", s.p50());
        assert_eq!(s.percentile(100.0), Duration::from_millis(100));
        assert_eq!(s.percentile(0.0), Duration::from_millis(1));
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStats::default();
        assert_eq!(s.p50(), Duration::ZERO);
        assert_eq!(s.mean(), Duration::ZERO);
        assert_eq!(s.max(), Duration::ZERO);
        assert_eq!(s.fraction_leq(Duration::ZERO), 1.0);
    }

    #[test]
    fn nearest_rank_tiny_counts() {
        // n = 1: every percentile is the sample (rank-1 and rank-n are
        // tracked exactly, so tiny counts lose nothing to bucketing).
        let mut one = LatencyStats::default();
        one.record(Duration::from_millis(7));
        for p in [0.0, 1.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(one.percentile(p), Duration::from_millis(7), "p={p}");
        }
        // n = 2: p ≤ 50 → smaller sample, p > 50 → larger.
        let mut two = LatencyStats::default();
        two.record(Duration::from_millis(10));
        two.record(Duration::from_millis(20));
        assert_eq!(two.p50(), Duration::from_millis(10));
        assert_eq!(two.percentile(50.1), Duration::from_millis(20));
        assert_eq!(two.p99(), Duration::from_millis(20));
    }

    #[test]
    fn merge_is_exact_union() {
        let mut a = LatencyStats::default();
        let mut b = LatencyStats::default();
        let mut all = LatencyStats::default();
        for (i, ms) in [5u64, 1, 9, 2, 8, 3, 7, 4, 6, 100].iter().enumerate() {
            let d = Duration::from_millis(*ms);
            if i % 2 == 0 {
                a.record(d);
            } else {
                b.record(d);
            }
            all.record(d);
        }
        a.merge(&b);
        assert_eq!(a, all, "merge must equal recording the union directly");
        for p in [0.0, 25.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            assert_eq!(a.percentile(p), all.percentile(p), "p={p}");
        }
        assert_eq!(a.mean(), all.mean());
    }

    #[test]
    fn fraction_leq_counts_inclusive() {
        let mut s = LatencyStats::default();
        for ms in [1u64, 2, 3, 4] {
            s.record(Duration::from_millis(ms));
        }
        assert!((s.fraction_leq(Duration::from_millis(2)) - 0.5).abs() < 1e-12);
        assert!((s.fraction_leq(Duration::from_millis(4)) - 1.0).abs() < 1e-12);
        assert!((s.fraction_leq(Duration::ZERO) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_batch_matches_single_queries() {
        let mut s = LatencyStats::default();
        for ms in [4u64, 2, 9, 1] {
            s.record(Duration::from_millis(ms));
        }
        let batch = s.percentiles(&[0.0, 50.0, 99.0]);
        assert_eq!(batch, vec![s.percentile(0.0), s.p50(), s.p99()]);
    }

    #[test]
    fn record_is_flat_memory() {
        // The whole point of the histogram: bucket storage is bounded
        // by the value range, not the sample count.
        let mut s = LatencyStats::default();
        for i in 0..200_000u64 {
            s.record(Duration::from_micros(500 + (i % 977)));
        }
        assert_eq!(s.count(), 200_000);
        assert!(s.buckets.len() < 2048, "buckets grew with samples: {}", s.buckets.len());
    }

    #[test]
    fn prop_histogram_percentiles_within_bin_of_exact() {
        // The acceptance proptest: on random sample sets spanning six
        // orders of magnitude, every histogram percentile lands in the
        // same bucket as the exact nearest-rank sample (never below it,
        // never more than one 1/128-wide bucket above), and the
        // moments tracked exactly agree exactly.
        check(120, |g| {
            let n = g.usize(1, 400);
            let mut h = LatencyStats::default();
            let mut e = ExactLatencyStats::default();
            for _ in 0..n {
                let v = match g.usize(0, 3) {
                    0 => g.usize(0, 255),
                    1 => g.usize(0, 100_000),
                    2 => g.usize(0, 50_000_000),
                    _ => g.usize(0, 1 << 40),
                } as u64;
                let d = Duration::from_micros(v);
                h.record(d);
                e.record(d);
            }
            let ps = [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0, g.f64(0.0, 100.0)];
            for p in ps {
                let hv = h.percentile(p);
                let ev = e.percentile(p);
                prop_assert(
                    within_bin(hv, ev),
                    format!("p={p}: histogram {hv:?} vs exact {ev:?} (n={n})"),
                )?;
            }
            prop_assert(h.mean() == e.mean(), "mean must be exact")?;
            prop_assert(h.max() == e.max(), "max must be exact")?;
            let b = Duration::from_micros(g.usize(0, 200_000) as u64);
            prop_assert(
                h.fraction_leq(b) >= e.fraction_leq(b) - 1e-12,
                "fraction_leq may only round the cut upward",
            )
        });
    }

    #[test]
    fn wire_roundtrip_is_bit_identical() {
        let mut s = LatencyStats::default();
        for us in [3u64, 3, 999, 100_000, 7_654_321] {
            s.record(Duration::from_micros(us));
        }
        let back = LatencyStats::from_wire(&s.to_wire()).expect("wire parse");
        assert_eq!(back, s, "derived Eq: buckets, count, sum, min, max all equal");
        // Empty recorder: the u64::MAX/0 sentinel must survive.
        let empty = LatencyStats::default();
        assert_eq!(empty.to_wire(), format!("0|0|{}|0", u64::MAX));
        assert_eq!(LatencyStats::from_wire(&empty.to_wire()), Some(empty));
    }

    #[test]
    fn wire_rejects_corruption() {
        let mut s = LatencyStats::default();
        s.record(Duration::from_micros(42));
        let good = s.to_wire();
        assert!(LatencyStats::from_wire(&good).is_some());
        for bad in [
            "",
            "1|2|3",                       // too few fields
            "x|0|0|0",                     // non-numeric
            "1|42|42|42|42:0",             // zero bucket count
            "1|42|42|42|9:1|5:1",          // out-of-order buckets
            "2|42|42|42|42:1",             // Σ buckets != count
            "0|0|5|0",                     // empty count with non-sentinel min
        ] {
            assert_eq!(LatencyStats::from_wire(bad), None, "must reject {bad:?}");
        }
        // Flipping the stored count must read as corruption, not data.
        let tampered = good.replacen("1|", "2|", 1);
        assert_eq!(LatencyStats::from_wire(&tampered), None);
    }

    #[test]
    fn prop_wire_roundtrip_random_histograms() {
        check(80, |g| {
            let n = g.usize(0, 300);
            let mut s = LatencyStats::default();
            for _ in 0..n {
                s.record(Duration::from_micros(g.usize(0, 50_000_000) as u64));
            }
            let back = LatencyStats::from_wire(&s.to_wire());
            prop_assert(back.as_ref() == Some(&s), "wire round-trip must be exact")
        });
    }

    #[test]
    fn throughput_math() {
        let m = CoordinatorMetrics { requests_done: 100, ..Default::default() };
        assert!((m.throughput_rps(Duration::from_secs(2)) - 50.0).abs() < 1e-9);
        assert_eq!(m.padding_fraction(0), 0.0);
    }
}
