//! Request/stage latency metrics for the coordinator: counters,
//! percentiles, per-lane busy time (the runtime analog of the
//! simulator's timeline). The fleet-serving DES (serve/) aggregates
//! per-device recorders with [`LatencyStats::merge`], so fleet-wide
//! percentiles are computed over the exact union of samples, never
//! approximated from per-device percentiles.

use std::time::Duration;

/// A latency recorder with percentile queries.
///
/// `percentile` uses the **nearest-rank** convention: the p-th
/// percentile of n samples is the k-th smallest with
/// `k = ⌈p/100 · n⌉` (clamped to [1, n]) — always an *observed*
/// sample, never an interpolated value. Consequences for tiny sample
/// counts, relied on by tests: with n = 1 every percentile is that
/// one sample; with n = 2, p ≤ 50 returns the smaller and p > 50 the
/// larger; p = 0 returns the minimum, p = 100 the maximum.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    /// Absorb another recorder's samples (fleet-wide aggregation over
    /// per-device stats: merged percentiles are exact, identical to
    /// recording every sample into one stats object).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }

    pub fn mean(&self) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        let sum: u64 = self.samples_us.iter().sum();
        Duration::from_micros(sum / self.samples_us.len() as u64)
    }

    /// Nearest-rank percentile, p in [0,100] (see type docs). Empty
    /// recorder → `Duration::ZERO`.
    pub fn percentile(&self, p: f64) -> Duration {
        self.percentiles(&[p])[0]
    }

    /// Several percentiles with a single sort of the sample set.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<Duration> {
        if self.samples_us.is_empty() {
            return vec![Duration::ZERO; ps.len()];
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let n = v.len();
        ps.iter()
            .map(|&p| {
                let rank = ((p / 100.0) * n as f64).ceil() as usize;
                Duration::from_micros(v[rank.clamp(1, n) - 1])
            })
            .collect()
    }

    /// Fraction of samples ≤ `bound` (SLO attainment). Empty → 1.0
    /// (an idle service violates no SLO).
    pub fn fraction_leq(&self, bound: Duration) -> f64 {
        if self.samples_us.is_empty() {
            return 1.0;
        }
        let b = bound.as_micros() as u64;
        let ok = self.samples_us.iter().filter(|&&s| s <= b).count();
        ok as f64 / self.samples_us.len() as f64
    }

    pub fn p50(&self) -> Duration {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> Duration {
        self.percentile(99.0)
    }

    pub fn p999(&self) -> Duration {
        self.percentile(99.9)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.samples_us.iter().copied().max().unwrap_or(0))
    }
}

/// Coordinator-level metrics for one run.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorMetrics {
    pub request_latency: LatencyStats,
    pub msa_stage: LatencyStats,
    pub ffn_stage: LatencyStats,
    pub requests_done: u64,
    pub batches_run: u64,
    pub padded_slots: u64,
    pub buffer_swaps: u64,
}

impl CoordinatorMetrics {
    pub fn throughput_rps(&self, wall: Duration) -> f64 {
        self.requests_done as f64 / wall.as_secs_f64().max(1e-12)
    }

    /// Fraction of executed batch slots that were padding (batching
    /// efficiency — lower is better).
    pub fn padding_fraction(&self, slots: u64) -> f64 {
        if slots == 0 {
            0.0
        } else {
            self.padded_slots as f64 / slots as f64
        }
    }

    pub fn summary(&self, wall: Duration) -> String {
        format!(
            "requests={} batches={} swaps={} wall={:?} throughput={:.2} req/s \
             latency p50={:?} p99={:?} (msa p50 {:?}, ffn/moe p50 {:?})",
            self.requests_done,
            self.batches_run,
            self.buffer_swaps,
            wall,
            self.throughput_rps(wall),
            self.request_latency.p50(),
            self.request_latency.p99(),
            self.msa_stage.p50(),
            self.ffn_stage.p50(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::default();
        for ms in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 100] {
            s.record(Duration::from_millis(ms));
        }
        assert!(s.p50() <= s.p99());
        assert_eq!(s.max(), Duration::from_millis(100));
        assert_eq!(s.count(), 10);
        assert!(s.mean() >= Duration::from_millis(10));
        // Nearest-rank on n=10: p50 → 5th smallest, p100 → max.
        assert_eq!(s.p50(), Duration::from_millis(5));
        assert_eq!(s.percentile(100.0), Duration::from_millis(100));
        assert_eq!(s.percentile(0.0), Duration::from_millis(1));
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStats::default();
        assert_eq!(s.p50(), Duration::ZERO);
        assert_eq!(s.mean(), Duration::ZERO);
        assert_eq!(s.fraction_leq(Duration::ZERO), 1.0);
    }

    #[test]
    fn nearest_rank_tiny_counts() {
        // n = 1: every percentile is the sample.
        let mut one = LatencyStats::default();
        one.record(Duration::from_millis(7));
        for p in [0.0, 1.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(one.percentile(p), Duration::from_millis(7), "p={p}");
        }
        // n = 2: p ≤ 50 → smaller sample, p > 50 → larger.
        let mut two = LatencyStats::default();
        two.record(Duration::from_millis(10));
        two.record(Duration::from_millis(20));
        assert_eq!(two.p50(), Duration::from_millis(10));
        assert_eq!(two.percentile(50.1), Duration::from_millis(20));
        assert_eq!(two.p99(), Duration::from_millis(20));
    }

    #[test]
    fn merge_is_exact_union() {
        let mut a = LatencyStats::default();
        let mut b = LatencyStats::default();
        let mut all = LatencyStats::default();
        for (i, ms) in [5u64, 1, 9, 2, 8, 3, 7, 4, 6, 100].iter().enumerate() {
            let d = Duration::from_millis(*ms);
            if i % 2 == 0 {
                a.record(d);
            } else {
                b.record(d);
            }
            all.record(d);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for p in [0.0, 25.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            assert_eq!(a.percentile(p), all.percentile(p), "p={p}");
        }
        assert_eq!(a.mean(), all.mean());
    }

    #[test]
    fn fraction_leq_counts_inclusive() {
        let mut s = LatencyStats::default();
        for ms in [1u64, 2, 3, 4] {
            s.record(Duration::from_millis(ms));
        }
        assert!((s.fraction_leq(Duration::from_millis(2)) - 0.5).abs() < 1e-12);
        assert!((s.fraction_leq(Duration::from_millis(4)) - 1.0).abs() < 1e-12);
        assert!((s.fraction_leq(Duration::ZERO) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_batch_matches_single_queries() {
        let mut s = LatencyStats::default();
        for ms in [4u64, 2, 9, 1] {
            s.record(Duration::from_millis(ms));
        }
        let batch = s.percentiles(&[0.0, 50.0, 99.0]);
        assert_eq!(batch, vec![s.percentile(0.0), s.p50(), s.p99()]);
    }

    #[test]
    fn throughput_math() {
        let m = CoordinatorMetrics { requests_done: 100, ..Default::default() };
        assert!((m.throughput_rps(Duration::from_secs(2)) - 50.0).abs() < 1e-9);
        assert_eq!(m.padding_fraction(0), 0.0);
    }
}
