//! The Fig. 3 double-buffered block pipeline, for real execution.
//!
//! Two engine threads — the MSA block and the FFN/MoE block — run
//! concurrently, exactly like the two hardware blocks: while the MSA
//! engine processes sample s at layer l, the FFN/MoE engine processes
//! another sample. Buffer hand-off between the engines is the swap of
//! Fig. 3a; with ≥2 samples in flight both engines stay busy and the
//! measured wall time approaches Σ max(L_MSA, L_blk2) — the property
//! the simulator assumes and the e2e example verifies.
//!
//! Because the `xla` crate's client is not `Send`, each engine thread
//! *constructs its own engine* (own PJRT client, own compiled blocks,
//! own device weights) from a `Send` factory closure — which mirrors
//! the hardware, where each block is its own fabric region.

use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::runtime::tensor::Tensor;
use crate::sim::timeline::Timeline;

/// One pipeline stage: runs its block for a given layer.
pub trait StageEngine {
    fn run(&self, layer: usize, x: &Tensor) -> Result<Tensor>;
}

/// MSA view over a RuntimeModel.
pub struct MsaStage(pub crate::runtime::model::RuntimeModel);

impl StageEngine for MsaStage {
    fn run(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        self.0.msa(layer, x)
    }
}

/// FFN/MoE view over a RuntimeModel.
pub struct Blk2Stage(pub crate::runtime::model::RuntimeModel);

impl StageEngine for Blk2Stage {
    fn run(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        self.0.ffn_or_moe(layer, x)
    }
}

/// Measured pipeline statistics.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    /// Compute window: first block start → last block end. Excludes
    /// engine construction (PJRT compilation inside each thread),
    /// which `total_with_setup` includes.
    pub wall: Duration,
    pub total_with_setup: Duration,
    pub msa_busy: Duration,
    pub blk2_busy: Duration,
    /// Busy-time overlap fraction: how much of the two engines' work
    /// ran concurrently (0 = fully serialized, →1 = fully overlapped).
    pub overlap_fraction: f64,
    pub timeline: Timeline,
    pub items: usize,
}

struct Item {
    id: usize,
    layer: usize,
    tensor: Tensor,
}

enum Msg {
    Work(Item),
    Stop,
}

type Span = (&'static str, String, Duration, Duration);

/// Run `inputs` (post-embed token tensors) through `depth` encoder
/// layers on the two-engine pipeline. Engines are built inside their
/// threads by the factories. Returns outputs in input order plus the
/// measured report.
pub fn run_pipeline<FA, FB, A, B>(
    depth: usize,
    inputs: Vec<Tensor>,
    make_msa: FA,
    make_blk2: FB,
) -> Result<(Vec<Tensor>, PipelineReport)>
where
    FA: FnOnce() -> Result<A> + Send,
    FB: FnOnce() -> Result<B> + Send,
    A: StageEngine,
    B: StageEngine,
{
    let n = inputs.len();
    let t0 = Instant::now();

    let (msa_tx, msa_rx) = mpsc::channel::<Msg>();
    let (blk2_tx, blk2_rx) = mpsc::channel::<Msg>();
    let (done_tx, done_rx) = mpsc::channel::<Result<Item>>();
    let (span_tx, span_rx) = mpsc::channel::<Span>();

    let mut outputs: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
    let mut timeline = Timeline::new("ms");
    let mut msa_busy = Duration::ZERO;
    let mut blk2_busy = Duration::ZERO;

    std::thread::scope(|s| -> Result<()> {
        // --- MSA engine thread.
        let blk2_tx_a = blk2_tx.clone();
        let done_tx_a = done_tx.clone();
        let span_tx_a = span_tx.clone();
        s.spawn(move || {
            let engine = match make_msa() {
                Ok(e) => e,
                Err(e) => {
                    let _ = done_tx_a.send(Err(e.context("constructing MSA engine")));
                    return;
                }
            };
            while let Ok(Msg::Work(item)) = msa_rx.recv() {
                let st = t0.elapsed();
                let out = engine.run(item.layer, &item.tensor);
                let en = t0.elapsed();
                let _ = span_tx_a.send(("MSA", format!("{}", item.layer % 10), st, en));
                match out {
                    Ok(tensor) => {
                        let _ = blk2_tx_a
                            .send(Msg::Work(Item { id: item.id, layer: item.layer, tensor }));
                    }
                    Err(e) => {
                        let _ = done_tx_a.send(Err(e));
                    }
                }
            }
        });

        // --- FFN/MoE engine thread.
        let msa_tx_b = msa_tx.clone();
        let done_tx_b = done_tx.clone();
        let span_tx_b = span_tx;
        s.spawn(move || {
            let engine = match make_blk2() {
                Ok(e) => e,
                Err(e) => {
                    let _ = done_tx_b.send(Err(e.context("constructing FFN/MoE engine")));
                    return;
                }
            };
            while let Ok(Msg::Work(item)) = blk2_rx.recv() {
                let st = t0.elapsed();
                let out = engine.run(item.layer, &item.tensor);
                let en = t0.elapsed();
                let _ = span_tx_b.send(("FFN/MoE", format!("{}", item.layer % 10), st, en));
                match out {
                    Ok(tensor) => {
                        let next = Item { id: item.id, layer: item.layer + 1, tensor };
                        if next.layer < depth {
                            let _ = msa_tx_b.send(Msg::Work(next));
                        } else {
                            let _ = done_tx_b.send(Ok(next));
                        }
                    }
                    Err(e) => {
                        let _ = done_tx_b.send(Err(e));
                    }
                }
            }
        });
        drop(done_tx);

        // Inject all samples at layer 0. A closed queue means the MSA
        // engine died during construction — the error arrives on
        // done_rx below, so don't error here.
        for (id, tensor) in inputs.into_iter().enumerate() {
            if msa_tx.send(Msg::Work(Item { id, layer: 0, tensor })).is_err() {
                break;
            }
        }

        // Collect outputs (or the first error).
        let mut result: Result<()> = Ok(());
        let mut received = 0usize;
        while received < n {
            match done_rx.recv() {
                Ok(Ok(item)) => {
                    outputs[item.id] = Some(item.tensor);
                    received += 1;
                }
                Ok(Err(e)) => {
                    result = Err(e);
                    break;
                }
                Err(_) => {
                    result = Err(anyhow!("pipeline workers exited early"));
                    break;
                }
            }
        }

        // Shut both engines down (each thread exits on Stop or on a
        // closed channel).
        let _ = msa_tx.send(Msg::Stop);
        let _ = blk2_tx.send(Msg::Stop);
        drop(msa_tx);
        drop(blk2_tx);

        // Gather spans (channel closes when both threads exit).
        while let Ok((lane, label, st, en)) = span_rx.recv() {
            timeline.push(lane, label, st.as_secs_f64() * 1e3, en.as_secs_f64() * 1e3);
            if lane == "MSA" {
                msa_busy += en - st;
            } else {
                blk2_busy += en - st;
            }
        }
        result
    })?;

    let total_with_setup = t0.elapsed();
    // Compute window: from the first block start to the last block end
    // (excludes per-thread engine construction / PJRT compilation).
    let first_start = timeline
        .spans
        .iter()
        .map(|s| s.start)
        .fold(f64::INFINITY, f64::min);
    let wall = if first_start.is_finite() {
        Duration::from_secs_f64(((timeline.total_end() - first_start) / 1e3).max(0.0))
    } else {
        total_with_setup
    };
    let concurrent = timeline.overlap("MSA", "FFN/MoE");
    let denom = msa_busy.as_secs_f64().min(blk2_busy.as_secs_f64()) * 1e3;
    let overlap_fraction = if denom > 0.0 { (concurrent / denom).min(1.0) } else { 0.0 };

    let out: Result<Vec<Tensor>> = outputs
        .into_iter()
        .enumerate()
        .map(|(i, o)| o.ok_or_else(|| anyhow!("missing output {i}")))
        .collect();
    Ok((
        out?,
        PipelineReport {
            wall,
            total_with_setup,
            msa_busy,
            blk2_busy,
            overlap_fraction,
            timeline,
            items: n,
        },
    ))
}

/// Strictly sequential execution on a single engine pair (the no-
/// double-buffering ablation — Fig. 3's counterfactual).
pub fn run_sequential<A: StageEngine, B: StageEngine>(
    depth: usize,
    inputs: Vec<Tensor>,
    msa: &A,
    blk2: &B,
) -> Result<(Vec<Tensor>, Duration)> {
    let t0 = Instant::now();
    let mut out = Vec::with_capacity(inputs.len());
    for x in inputs {
        let mut t = x;
        for layer in 0..depth {
            t = msa.run(layer, &t)?;
            t = blk2.run(layer, &t)?;
        }
        out.push(t);
    }
    Ok((out, t0.elapsed()))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mock stage: adds `delta` and sleeps a configured time.
    struct Mock {
        delta: f32,
        ms: u64,
    }

    impl StageEngine for Mock {
        fn run(&self, _l: usize, x: &Tensor) -> Result<Tensor> {
            std::thread::sleep(Duration::from_millis(self.ms));
            Ok(Tensor::new(x.dims.clone(), x.data.iter().map(|v| v + self.delta).collect()))
        }
    }

    #[test]
    fn pipeline_computes_correctly_and_in_order() {
        let inputs: Vec<Tensor> =
            (0..4).map(|i| Tensor::new(vec![1], vec![i as f32 * 10.0])).collect();
        let (out, report) = run_pipeline(
            3,
            inputs,
            || Ok(Mock { delta: 1.0, ms: 1 }),
            || Ok(Mock { delta: 1.0, ms: 1 }),
        )
        .unwrap();
        assert_eq!(out.len(), 4);
        for (i, t) in out.iter().enumerate() {
            // 3 layers × 2 stages × (+1.0)
            assert_eq!(t.data[0], i as f32 * 10.0 + 6.0);
        }
        assert_eq!(report.items, 4);
        assert_eq!(report.timeline.spans.iter().filter(|s| s.lane == "MSA").count(), 12);
    }

    #[test]
    fn pipeline_overlaps_with_multiple_samples() {
        let inputs: Vec<Tensor> = (0..4).map(|_| Tensor::zeros(vec![4])).collect();
        let (_, report) = run_pipeline(
            4,
            inputs.clone(),
            || Ok(Mock { delta: 1.0, ms: 4 }),
            || Ok(Mock { delta: 1.0, ms: 4 }),
        )
        .unwrap();
        let a = Mock { delta: 1.0, ms: 4 };
        let b = Mock { delta: 1.0, ms: 4 };
        let (_, seq_wall) = run_sequential(4, inputs, &a, &b).unwrap();
        assert!(report.overlap_fraction > 0.3, "overlap {}", report.overlap_fraction);
        assert!(
            report.wall < seq_wall,
            "pipeline {:?} !< sequential {:?}",
            report.wall,
            seq_wall
        );
    }

    #[test]
    fn single_sample_has_no_overlap_but_completes() {
        let (out, report) = run_pipeline(
            2,
            vec![Tensor::zeros(vec![2])],
            || Ok(Mock { delta: 1.0, ms: 1 }),
            || Ok(Mock { delta: 1.0, ms: 1 }),
        )
        .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].data, vec![4.0, 4.0]);
        assert!(report.overlap_fraction < 0.2);
    }

    struct Failing;
    impl StageEngine for Failing {
        fn run(&self, _: usize, _: &Tensor) -> Result<Tensor> {
            anyhow::bail!("msa exploded")
        }
    }

    #[test]
    fn engine_failure_propagates() {
        let err = run_pipeline(
            1,
            vec![Tensor::zeros(vec![1])],
            || Ok(Failing),
            || Ok(Mock { delta: 1.0, ms: 0 }),
        );
        assert!(err.is_err());
        assert!(format!("{:?}", err.err().unwrap()).contains("msa exploded"));
    }

    #[test]
    fn factory_failure_propagates() {
        let err = run_pipeline(
            1,
            vec![Tensor::zeros(vec![1])],
            || -> Result<Mock> { anyhow::bail!("no bitstream") },
            || Ok(Mock { delta: 1.0, ms: 0 }),
        );
        assert!(err.is_err());
        assert!(format!("{:?}", err.err().unwrap()).contains("no bitstream"));
    }

    #[test]
    fn results_keep_input_order_under_unequal_stage_times() {
        let inputs: Vec<Tensor> =
            (0..6).map(|i| Tensor::new(vec![1], vec![i as f32])).collect();
        let (out, _) = run_pipeline(
            2,
            inputs,
            || Ok(Mock { delta: 0.5, ms: 2 }),
            || Ok(Mock { delta: 0.25, ms: 5 }),
        )
        .unwrap();
        for (i, t) in out.iter().enumerate() {
            assert!((t.data[0] - (i as f32 + 1.5)).abs() < 1e-6);
        }
    }
}
