//! Deployment configuration files: a small sectioned `key = value`
//! format (the vendored crate set has no serde/toml) so deployments
//! are reproducible artifacts rather than CLI incantations.
//!
//! ```text
//! # ubimoe deployment
//! [deploy]
//! model    = m3vit-small
//! platform = u280
//! q_bits   = 16
//! a_bits   = 32
//!
//! [ga]
//! population  = 48
//! generations = 60
//! seed        = 12648430
//!
//! [override]          # optional: skip HAS, force a configuration
//! num = 2
//! t_a = 16
//! n_a = 8
//! t_in = 16
//! t_out = 16
//! n_l = 4
//! ```

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

use crate::has::ga::GaParams;
use crate::models::{by_name, ModelConfig};
use crate::resources::{AttnParams, LinearParams, Platform};
use crate::sim::HwChoice;

/// Parsed sectioned key-value file.
#[derive(Clone, Debug, Default)]
pub struct Ini {
    sections: HashMap<String, HashMap<String, String>>,
}

impl Ini {
    pub fn parse(text: &str) -> Result<Ini> {
        let mut sections: HashMap<String, HashMap<String, String>> = HashMap::new();
        let mut current = String::from("");
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                current = name.trim().to_string();
                sections.entry(current.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                sections
                    .entry(current.clone())
                    .or_default()
                    .insert(k.trim().to_string(), v.trim().to_string());
            } else {
                bail!("line {}: expected `key = value` or `[section]`", lineno + 1);
            }
        }
        Ok(Ini { sections })
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, section: &str, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("[{section}] {key} = {v:?}: {e}")),
        }
    }

    pub fn has_section(&self, section: &str) -> bool {
        self.sections.contains_key(section)
    }
}

/// A fully resolved deployment spec.
#[derive(Clone, Debug)]
pub struct DeploymentSpec {
    pub model: ModelConfig,
    pub platform: Platform,
    pub q_bits: u32,
    pub a_bits: u32,
    pub ga: GaParams,
    /// If set, skip HAS and use this configuration directly.
    pub hw_override: Option<HwChoice>,
}

impl DeploymentSpec {
    pub fn from_ini(ini: &Ini) -> Result<DeploymentSpec> {
        let model_name = ini.get("deploy", "model").unwrap_or("m3vit-small");
        let model =
            by_name(model_name).with_context(|| format!("unknown model {model_name}"))?;
        let plat_name = ini.get("deploy", "platform").unwrap_or("zcu102");
        let mut platform = Platform::by_name(plat_name)
            .with_context(|| format!("unknown platform {plat_name}"))?;
        let q_bits: u32 = ini.get_parsed("deploy", "q_bits")?.unwrap_or(16);
        let a_bits: u32 = ini.get_parsed("deploy", "a_bits")?.unwrap_or(32);
        if let Some(f) = ini.get_parsed::<f64>("deploy", "freq_mhz")? {
            platform.freq_mhz = f;
        }

        let mut ga = GaParams::default();
        if let Some(v) = ini.get_parsed("ga", "population")? {
            ga.population = v;
        }
        if let Some(v) = ini.get_parsed("ga", "generations")? {
            ga.generations = v;
        }
        if let Some(v) = ini.get_parsed("ga", "seed")? {
            ga.seed = v;
        }

        let hw_override = if ini.has_section("override") {
            let need = |k: &str| -> Result<usize> {
                ini.get_parsed("override", k)?
                    .with_context(|| format!("[override] requires `{k}`"))
            };
            Some(HwChoice {
                num: need("num")?,
                attn: AttnParams { t_a: need("t_a")?, n_a: need("n_a")? },
                lin: LinearParams {
                    t_in: need("t_in")?,
                    t_out: need("t_out")?,
                    n_l: need("n_l")?,
                },
                q_bits,
                a_bits,
            })
        } else {
            None
        };

        Ok(DeploymentSpec { model, platform, q_bits, a_bits, ga, hw_override })
    }

    pub fn load(path: &Path) -> Result<DeploymentSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_ini(&Ini::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "
# comment
[deploy]
model    = m3vit-small
platform = u280
q_bits   = 16
a_bits   = 16
freq_mhz = 250

[ga]
population  = 24
generations = 10
seed        = 7
";

    #[test]
    fn parses_sections_and_comments() {
        let ini = Ini::parse(SAMPLE).unwrap();
        assert_eq!(ini.get("deploy", "model"), Some("m3vit-small"));
        assert_eq!(ini.get("ga", "seed"), Some("7"));
        assert_eq!(ini.get("missing", "x"), None);
    }

    #[test]
    fn builds_spec_with_freq_override() {
        let spec = DeploymentSpec::from_ini(&Ini::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(spec.model.name, "m3vit-small");
        assert_eq!(spec.platform.freq_mhz, 250.0);
        assert_eq!(spec.a_bits, 16);
        assert_eq!(spec.ga.population, 24);
        assert!(spec.hw_override.is_none());
    }

    #[test]
    fn hw_override_requires_all_fields() {
        let bad = "[override]\nnum = 2\n";
        let err = DeploymentSpec::from_ini(&Ini::parse(bad).unwrap());
        assert!(err.is_err());
        let good = "[override]\nnum=2\nt_a=16\nn_a=8\nt_in=16\nt_out=16\nn_l=4\n";
        let spec = DeploymentSpec::from_ini(&Ini::parse(good).unwrap()).unwrap();
        let hw = spec.hw_override.unwrap();
        assert_eq!(hw.attn.t_a, 16);
        assert_eq!(hw.lin.n_l, 4);
    }

    #[test]
    fn defaults_when_sections_missing() {
        let spec = DeploymentSpec::from_ini(&Ini::parse("").unwrap()).unwrap();
        assert_eq!(spec.model.name, "m3vit-small");
        assert_eq!(spec.platform.name, "ZCU102");
        assert_eq!(spec.q_bits, 16);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Ini::parse("not a kv line").is_err());
        assert!(Ini::parse("[deploy]\nmodel m3vit").is_err());
    }

    #[test]
    fn rejects_unknown_names() {
        assert!(DeploymentSpec::from_ini(
            &Ini::parse("[deploy]\nmodel = nope\n").unwrap()
        )
        .is_err());
        assert!(DeploymentSpec::from_ini(
            &Ini::parse("[deploy]\nplatform = nope\n").unwrap()
        )
        .is_err());
    }
}
