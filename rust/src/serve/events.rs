//! The discrete-event core: a time-ordered event queue on virtual
//! time.
//!
//! Virtual time is integral nanoseconds since simulation start, so
//! event ordering is exact integer comparison — no float ties, no
//! platform-dependent rounding. Events at the same instant pop in
//! insertion order (a monotone sequence number breaks ties), which is
//! what makes the whole simulation a deterministic function of
//! (config, seed).
//!
//! Built lean for tens-of-millions-of-request horizons:
//!
//! * entries are 24 bytes (ns timestamp + u32 seq + compact kind) —
//!   pinned by a size regression test below;
//! * the DES streams arrivals from its sorted schedule via
//!   [`EventQueue::next_at`] instead of preloading them, so the heap
//!   holds only O(devices) deadline/completion entries;
//! * superseded flush deadlines carry a generation tag and are
//!   *cancelled* (skipped on pop) rather than accumulating as no-op
//!   wakeups — the heap stays bounded under sustained partial-batch
//!   load (regression-tested in `serve/mod.rs`).

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Duration;

/// What happens at an event's firing time. Payload indices are `u32`
/// to keep entries small; request/device/generation counts stay far
/// below 2^32 even at tens of millions of requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Request `req` (index into the arrival schedule) enters the
    /// fleet. The DES streams arrivals outside the heap; this variant
    /// serves tests and ad-hoc schedules.
    Arrival { req: u32 },
    /// A device's oldest queued request may have hit the batcher's
    /// max_wait — re-run batch formation. `gen` is the device's
    /// deadline generation at scheduling time: a pop whose `gen` no
    /// longer matches the device's live deadline was superseded and is
    /// skipped (cancellation).
    FlushDeadline { device: u32, gen: u32 },
    /// The batch in flight on `device` finishes service. `gen` is the
    /// device's batch generation at start time: a pop whose `gen` no
    /// longer matches the in-flight batch belongs to a batch lost to a
    /// device failure and is skipped (cancellation, same mechanism as
    /// flush deadlines).
    BatchDone { device: u32, gen: u32 },
    /// Fault injection: `device` fails now. Its queued and in-flight
    /// requests fail over to the rest of the fleet; the slot stays
    /// down until the matching [`EventKind::DeviceRepair`].
    DeviceFail { device: u32 },
    /// Fault injection: `device` comes back from repair and rejoins
    /// the dispatchable fleet; requests parked at fleet level during a
    /// full outage re-enter dispatch now.
    DeviceRepair { device: u32 },
    /// Per-attempt client deadline for request `req` expired. Stale if
    /// the request settled or already moved past `attempt` (each
    /// retry bumps the attempt counter, cancelling older timers).
    AttemptTimeout { req: u32, attempt: u32 },
    /// Backoff elapsed: re-dispatch request `req` (its next attempt).
    RetryDispatch { req: u32 },
    /// Hedge delay elapsed: if `req` is still unsettled, dispatch a
    /// duplicate copy to a second device (first completion wins).
    HedgeDispatch { req: u32 },
    /// A closed-loop user's think time expired: user `user` issues its
    /// next request now (or retires if the arrival horizon has
    /// passed). Only scheduled by [`crate::serve::Workload::ClosedLoop`]
    /// runs; the heap holds at most one per user.
    UserThink { user: u32 },
    /// Periodic autoscaling-controller wakeup: evaluate the window
    /// signal and scale the fleet. At most one is live at a time; none
    /// are scheduled past the arrival horizon.
    ScaleTick,
    /// Periodic observability sampler wakeup
    /// ([`crate::obs::sampler`]): read the windowed gauges and emit
    /// one time-series row per device plus a fleet row. At most one is
    /// live at a time; only scheduled when a sampler is attached, and
    /// the DES compensates its event/peak-event counters so the
    /// `FleetReport` is bit-identical with or without it (proptested).
    SampleTick,
    /// A tripped circuit breaker's cooldown elapsed: if `gen` still
    /// matches the breaker's live probe generation, the breaker
    /// half-opens and `device` rejoins dispatch for a probe period
    /// ([`crate::serve::overload::Breaker`]). Stale generations are
    /// skipped — the same cancellation idiom as flush deadlines.
    BreakerProbe { device: u32, gen: u32 },
    /// Periodic brownout-controller wakeup
    /// ([`crate::serve::overload::BrownoutController`]): evaluate the
    /// windowed SLO signal (rejects count as misses) and flip the
    /// fleet between full-precision and degraded service tables. At
    /// most one is live at a time; none are scheduled past the
    /// arrival horizon.
    BrownoutTick,
    /// Periodic expert-rebalancing wakeup
    /// ([`crate::serve::shard::plan_moves`]): read the window's
    /// per-expert routed counts, re-home/grow/trim replicas, reset the
    /// window. At most one is live at a time; none are scheduled past
    /// the arrival horizon.
    RebalanceTick,
}

/// One scheduled event (24 bytes; see the size regression test).
#[derive(Clone, Copy, Debug)]
pub struct Event {
    at_ns: u64,
    /// Insertion-order tie-breaker (unique per queue).
    seq: u32,
    pub kind: EventKind,
}

impl Event {
    /// Firing time (virtual time since simulation start).
    pub fn at(&self) -> Duration {
        Duration::from_nanos(self.at_ns)
    }
}

// Min-heap ordering on (at_ns, seq): BinaryHeap is a max-heap, so the
// comparison is reversed. `seq` is unique, so equality can only occur
// for an event compared against itself — Eq/Ord stay consistent.
impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.at_ns == other.at_ns && self.seq == other.seq
    }
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        other.at_ns.cmp(&self.at_ns).then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Time-ordered event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u32,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn push(&mut self, at: Duration, kind: EventKind) {
        let seq = self.next_seq;
        self.next_seq = self.next_seq.checked_add(1).expect("event sequence overflow (u32)");
        self.heap.push(Event { at_ns: at.as_nanos() as u64, seq, kind });
    }

    /// Earliest event; ties pop in insertion order.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    /// Firing time of the earliest event without popping it — the DES
    /// merges the heap with its sorted arrival stream on this.
    pub fn next_at(&self) -> Option<Duration> {
        self.heap.peek().map(Event::at)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn entries_stay_lean() {
        // The scale contract: one heap entry is 24 bytes. Growing it
        // (e.g. widening payloads back to usize) is a deliberate
        // decision, not an accident.
        assert!(std::mem::size_of::<Event>() <= 24, "{}", std::mem::size_of::<Event>());
        // The kind itself must fit next to the u64 timestamp + u32
        // seq: tag + two u32 payload words. New variants (overload
        // PR: BreakerProbe, BrownoutTick) must respect this.
        assert!(
            std::mem::size_of::<EventKind>() <= 12,
            "{}",
            std::mem::size_of::<EventKind>()
        );
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(ms(5), EventKind::BatchDone { device: 0, gen: 0 });
        q.push(ms(1), EventKind::Arrival { req: 0 });
        q.push(ms(3), EventKind::FlushDeadline { device: 1, gen: 0 });
        assert_eq!(q.next_at(), Some(ms(1)));
        let order: Vec<Duration> = std::iter::from_fn(|| q.pop()).map(|e| e.at()).collect();
        assert_eq!(order, vec![ms(1), ms(3), ms(5)]);
        assert_eq!(q.next_at(), None);
    }

    #[test]
    fn ties_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        for req in 0..10u32 {
            q.push(ms(7), EventKind::Arrival { req });
        }
        let order: Vec<EventKind> = std::iter::from_fn(|| q.pop()).map(|e| e.kind).collect();
        let want: Vec<EventKind> = (0..10u32).map(|req| EventKind::Arrival { req }).collect();
        assert_eq!(order, want);
    }

    #[test]
    fn same_instant_storm_pops_in_insertion_order() {
        // Adversarial tie storm: 10k events at one instant, mixed
        // kinds, interleaved with earlier/later events. Insertion
        // order must survive heap sifting exactly.
        let mut q = EventQueue::new();
        q.push(ms(9), EventKind::BatchDone { device: 99, gen: 7 });
        let mut want = Vec::with_capacity(10_000);
        for i in 0..10_000u32 {
            let kind = match i % 3 {
                0 => EventKind::Arrival { req: i },
                1 => EventKind::FlushDeadline { device: i, gen: i },
                _ => EventKind::BatchDone { device: i, gen: i },
            };
            q.push(ms(7), kind);
            want.push(kind);
        }
        q.push(ms(1), EventKind::Arrival { req: 424_242 });
        assert_eq!(q.len(), 10_002);
        assert_eq!(q.pop().unwrap().kind, EventKind::Arrival { req: 424_242 });
        let storm: Vec<EventKind> = (0..10_000).map(|_| q.pop().unwrap().kind).collect();
        assert_eq!(storm, want, "tie storm must pop in insertion order");
        assert_eq!(q.pop().unwrap().kind, EventKind::BatchDone { device: 99, gen: 7 });
        assert!(q.is_empty());
    }

    #[test]
    fn nanosecond_timestamps_roundtrip_exactly() {
        let mut q = EventQueue::new();
        let t = Duration::new(3, 123_456_789);
        q.push(t, EventKind::Arrival { req: 0 });
        assert_eq!(q.next_at(), Some(t));
        assert_eq!(q.pop().unwrap().at(), t);
    }

    #[test]
    fn len_and_empty() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(ms(1), EventKind::Arrival { req: 0 });
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
