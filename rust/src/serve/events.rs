//! The discrete-event core: a time-ordered event queue on virtual
//! time.
//!
//! Virtual time is a [`Duration`] since simulation start (integral
//! nanoseconds), so event ordering is exact integer comparison — no
//! float ties, no platform-dependent rounding. Events at the same
//! instant pop in insertion order (a monotone sequence number breaks
//! ties), which is what makes the whole simulation a deterministic
//! function of (config, seed).

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Duration;

/// What happens at an event's firing time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Request `req` (index into the arrival schedule) enters the
    /// fleet and is dispatched to a device queue.
    Arrival { req: usize },
    /// A device's oldest queued request may have hit the batcher's
    /// max_wait — re-run batch formation (idempotent wakeup; stale
    /// deadlines are harmless no-ops).
    FlushDeadline { device: usize },
    /// The batch in flight on `device` finishes service.
    BatchDone { device: usize },
}

/// One scheduled event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub at: Duration,
    /// Insertion-order tie-breaker (unique per queue).
    pub seq: u64,
    pub kind: EventKind,
}

// Min-heap ordering on (at, seq): BinaryHeap is a max-heap, so the
// comparison is reversed. `seq` is unique, so equality can only occur
// for an event compared against itself — Eq/Ord stay consistent.
impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Time-ordered event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn push(&mut self, at: Duration, kind: EventKind) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { at, seq, kind });
    }

    /// Earliest event; ties pop in insertion order.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(ms(5), EventKind::BatchDone { device: 0 });
        q.push(ms(1), EventKind::Arrival { req: 0 });
        q.push(ms(3), EventKind::FlushDeadline { device: 1 });
        let order: Vec<Duration> = std::iter::from_fn(|| q.pop()).map(|e| e.at).collect();
        assert_eq!(order, vec![ms(1), ms(3), ms(5)]);
    }

    #[test]
    fn ties_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        for req in 0..10 {
            q.push(ms(7), EventKind::Arrival { req });
        }
        let order: Vec<EventKind> = std::iter::from_fn(|| q.pop()).map(|e| e.kind).collect();
        let want: Vec<EventKind> = (0..10).map(|req| EventKind::Arrival { req }).collect();
        assert_eq!(order, want);
    }

    #[test]
    fn len_and_empty() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(ms(1), EventKind::Arrival { req: 0 });
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
