//! A fleet device: one FPGA accelerator running an HAS-chosen UbiMoE
//! configuration, costed by the cycle-level simulator.
//!
//! The DES never re-runs the cycle model inside the event loop — a
//! [`DeviceModel`] precomputes a batch-size → service-time table once:
//!
//! * `period` — steady-state cycles per inference from the Fig. 3
//!   double-buffered pipeline ([`crate::sim::engine::simulate`]), i.e.
//!   the marginal cost of one more image in a batch;
//! * `fill` — pipeline ramp-in/out, the difference between a lone
//!   inference ([`crate::sim::engine::simulate_sequential`]) and the
//!   steady-state period.
//!
//! A batch of B images then costs `fill + B·period`: batch-1 equals
//! the paper's single-image latency, large batches amortize the fill
//! and approach the steady-state throughput the paper reports. Service
//! time depends on the *executable* batch size, padding included —
//! padded slots burn real cycles, which is why the padding fraction is
//! a first-class fleet metric.
//!
//! The table is built from the engine's one-pass
//! [`crate::sim::engine::latency_surface`] (block costs evaluated
//! once), and [`DeviceModel::from_search`] goes through the persistent
//! design cache ([`crate::has::cache`]) — a warm process builds fleet
//! devices with zero GA evaluations and zero cycle-sim walks.

use std::time::Duration;

use crate::coordinator::batcher::{Batch, Batcher, BatcherConfig};
use crate::has::{cache, HasConfig};
use crate::models::ModelConfig;
use crate::resources::Platform;
use crate::serve::metrics::DeviceMetrics;
use crate::sim::engine::{latency_surface, LatencySurface, SimConfig};
use crate::sim::moe::expert_stream_cycles;
use crate::sim::HwChoice;
use crate::util::clock::VirtualClock;

/// Fallback residency-discount divisor: `fill / RESIDENCY_FILL_DIV`
/// for devices built from raw (fill, period) latencies.
///
/// Rationale (the ROADMAP "expert-weight cache affinity" item): in the
/// Fig. 3 double-buffered pipeline every expert's weight stream hides
/// behind the previous expert's compute *except the leading one*
/// (`sim/moe.rs` exposes exactly the first expert's stream), and that
/// exposed stream is part of the ramp-in `fill` (= sequential −
/// steady-state latency). When a batch's dominant expert was also the
/// previous batch's dominant expert on the same device, its weights
/// are still resident in on-chip buffers and the exposed leading
/// stream is skipped. Cycle-model-backed devices (`with_hw`,
/// `from_search`) now derive the discount from the *actual* exposed
/// stream — [`expert_stream_cycles`], stored in the design-cache
/// artifact — clamped to the fill; synthetic
/// [`DeviceModel::from_latencies`] devices have no weight-stream model
/// and keep the historical half-the-fill heuristic. Either way service stays
/// positive because service(B) = fill + B·period > fill ≥ discount,
/// and fill = 0 devices get no discount, so affinity-blind tests are
/// unchanged.
pub const RESIDENCY_FILL_DIV: u32 = 2;

/// Immutable per-device cost model.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceModel {
    pub name: String,
    /// Compiled executable batch sizes, ascending.
    pub batch_sizes: Vec<usize>,
    /// service[i] = service time of a batch of batch_sizes[i].
    service: Vec<Duration>,
    /// Pipeline ramp-in/out (service(B) = fill + B·period).
    fill: Duration,
    /// Steady-state per-image period.
    period: Duration,
    /// Service-time discount when the batch's dominant expert is
    /// already resident (see [`RESIDENCY_FILL_DIV`]).
    residency_discount: Duration,
}

impl DeviceModel {
    /// Cost model for a pinned hardware configuration (tests, pinned
    /// deployments; no search cost). One [`latency_surface`] pass —
    /// the per-layer block costs are evaluated once for both the
    /// steady-state period and the ramp-in.
    pub fn with_hw(
        model: &ModelConfig,
        platform: &Platform,
        hw: HwChoice,
        batch_sizes: &[usize],
    ) -> DeviceModel {
        let sc = SimConfig::new(model.clone(), platform.clone(), hw);
        let max_b = batch_sizes.iter().copied().max().unwrap_or(1);
        let surface = latency_surface(&sc, max_b);
        let stream = (model.num_experts > 0)
            .then(|| expert_stream_cycles(model, &sc.memory(), sc.bw.moe_weights));
        Self::from_surface(
            format!("{}/{}", platform.name, model.name),
            platform,
            &surface,
            stream,
            batch_sizes,
        )
    }

    /// Run the 2-stage HAS for (model, platform) and build the cost
    /// model for the chosen design (the production constructor; one
    /// search per fleet, shared by every device replica). Uses the
    /// same timing rule and GA budget as `report::deploy`, so serving
    /// curves cost devices exactly as Tables I–III do — and goes
    /// through the same persistent design cache: on a warm process the
    /// device is rebuilt from the stored artifact (surface + expert
    /// weight-stream) with zero search or simulation work,
    /// bit-identical to the cold build (proptested in
    /// `rust/tests/design_cache.rs`).
    pub fn from_search(
        model: &ModelConfig,
        platform: &Platform,
        q_bits: u32,
        a_bits: u32,
        batch_sizes: &[usize],
    ) -> DeviceModel {
        let platform = platform.clone().with_bitwidth_timing(a_bits);
        let cfg = HasConfig::deployment(q_bits, a_bits);
        let art = cache::cached_design(model, &platform, &cfg);
        let stream = (model.num_experts > 0).then_some(art.expert_stream_cycles);
        Self::from_surface(
            format!("{}/{}", platform.name, model.name),
            &platform,
            &art.surface,
            stream,
            batch_sizes,
        )
    }

    /// Build the service LUT from a cycle-model batch-latency surface
    /// — the shared constructor behind [`DeviceModel::with_hw`] (fresh
    /// surface) and [`DeviceModel::from_search`] (cached artifact
    /// surface), which is what makes cold and warm devices identical
    /// by construction. When `stream_cycles` is given (MoE models) the
    /// residency discount is the exposed leading expert weight-stream
    /// — the thing residency actually skips — clamped to the fill (a
    /// batch cannot recover more ramp-in than it pays).
    pub fn from_surface(
        name: String,
        platform: &Platform,
        surface: &LatencySurface,
        stream_cycles: Option<f64>,
        batch_sizes: &[usize],
    ) -> DeviceModel {
        let period_ms = platform.cycles_to_ms(surface.period_cycles);
        let single_ms = platform.cycles_to_ms(surface.single_cycles);
        let fill_ms = (single_ms - period_ms).max(0.0);
        let mut dm = Self::from_latencies(
            name,
            Duration::from_secs_f64(fill_ms * 1e-3),
            Duration::from_secs_f64(period_ms * 1e-3),
            batch_sizes,
        );
        if let Some(stream) = stream_cycles {
            let stream_ms = platform.cycles_to_ms(stream);
            dm.residency_discount = Duration::from_secs_f64(stream_ms * 1e-3).min(dm.fill);
        }
        dm
    }

    /// Direct (fill, period) table — synthetic devices for unit and
    /// property tests that should not pay for the cycle model. With no
    /// weight-stream model available, the residency discount falls
    /// back to the fill/[`RESIDENCY_FILL_DIV`] heuristic.
    pub fn from_latencies(
        name: String,
        fill: Duration,
        period: Duration,
        batch_sizes: &[usize],
    ) -> DeviceModel {
        assert!(!batch_sizes.is_empty(), "need at least one executable batch size");
        assert!(period > Duration::ZERO, "period must be positive");
        let mut sizes = batch_sizes.to_vec();
        sizes.sort_unstable();
        sizes.dedup();
        let service = sizes.iter().map(|&b| fill + period * b as u32).collect();
        DeviceModel {
            name,
            batch_sizes: sizes,
            service,
            fill,
            period,
            residency_discount: fill / RESIDENCY_FILL_DIV,
        }
    }

    /// Service time of one executed batch of compiled size
    /// `batch_size` (padding occupies slots, so only the executable
    /// size matters).
    pub fn service_time(&self, batch_size: usize) -> Duration {
        let i = self
            .batch_sizes
            .iter()
            .position(|&b| b == batch_size)
            .unwrap_or_else(|| panic!("no compiled executable for batch size {batch_size}"));
        self.service[i]
    }

    /// Service time of a batch whose dominant expert may be resident
    /// from the device's previous batch: the full table entry, minus
    /// the weight-stream discount when `dominant_resident`
    /// (see [`RESIDENCY_FILL_DIV`]). The DES uses this so the
    /// expert-affinity dispatch policy's cache locality actually shows
    /// up in the latency–throughput curves.
    pub fn service_time_with_residency(
        &self,
        batch_size: usize,
        dominant_resident: bool,
    ) -> Duration {
        let full = self.service_time(batch_size);
        if dominant_resident {
            full - self.residency_discount
        } else {
            full
        }
    }

    /// The residency discount this device applies (weight-stream
    /// derived for cycle-model devices, fill-derived fallback).
    pub fn residency_discount(&self) -> Duration {
        self.residency_discount
    }

    /// Pipeline ramp-in/out of the service model.
    pub fn fill(&self) -> Duration {
        self.fill
    }

    /// Steady-state per-image period of the service model.
    pub fn period(&self) -> Duration {
        self.period
    }

    /// `(fill_ns, period_ns)`: the affine service-LUT coefficients the
    /// shortest-expected-delay dispatcher keys its tournament tree
    /// with. A request joining a backlog of `l` resident requests is
    /// expected to complete after `fill + (l+1)·period` — the service
    /// LUT evaluated at "backlog plus me", extended affinely past the
    /// largest compiled batch.
    pub fn expected_delay_weights(&self) -> (u64, u64) {
        (self.fill.as_nanos() as u64, self.period.as_nanos() as u64)
    }

    /// Latency of a lone request on an idle device (smallest batch).
    pub fn unloaded_latency(&self) -> Duration {
        self.service[0]
    }

    /// Best sustainable request rate: max over compiled sizes of
    /// B / service(B) — reached when full largest batches stream
    /// back-to-back.
    pub fn peak_rps(&self) -> f64 {
        self.batch_sizes
            .iter()
            .zip(&self.service)
            .map(|(&b, s)| b as f64 / s.as_secs_f64())
            .fold(0.0, f64::max)
    }

    /// The same device re-costed at a reduced bit-width — the brownout
    /// controller's degraded service table
    /// ([`crate::serve::overload::BrownoutConfig`]). UbiMoE's
    /// compute-bound blocks scale near-linearly with operand width
    /// (the Table I 8-bit vs 16-bit points), so a `num/den` width
    /// ratio scales both LUT coefficients: fill and period shrink by
    /// `num/den`, service(B) = fill + B·period follows, and the
    /// residency discount shrinks with the weight stream it models
    /// (clamped to the new fill). The batch-size menu is *identical*
    /// by construction — a brownout swap must never invalidate formed
    /// batches or the batcher's compiled sizes.
    pub fn degraded(&self, num: u32, den: u32) -> DeviceModel {
        assert!(num >= 1 && num <= den, "degraded scale must be a fraction <= 1");
        let mut dm = Self::from_latencies(
            format!("{}~{num}/{den}w", self.name),
            self.fill * num / den,
            self.period * num / den,
            &self.batch_sizes,
        );
        dm.residency_discount = (self.residency_discount * num / den).min(dm.fill);
        dm
    }
}

/// A request in service: the executed batch, its start time and the
/// batch generation its completion event carries. A device failure
/// drops the in-flight record; the orphaned
/// [`crate::serve::events::EventKind::BatchDone`] then reads as stale
/// by generation mismatch and is skipped — the lost batch never
/// completes.
#[derive(Clone, Debug)]
pub struct InFlight {
    pub started: Duration,
    pub batch: Batch<usize>,
    pub gen: u32,
}

/// Mutable DES state of one device.
pub struct DeviceState {
    /// Per-device dynamic batcher on the shared virtual clock —
    /// request indices queue here until a batch forms.
    pub batcher: Batcher<usize>,
    pub in_flight: Option<InFlight>,
    pub metrics: DeviceMetrics,
    /// The live flush deadline, if any: (firing time, generation).
    /// A FlushDeadline event whose generation no longer matches was
    /// superseded and is skipped on pop (cancellation) — the heap
    /// never accumulates stale wakeups.
    pub(crate) deadline: Option<(Duration, u32)>,
    /// Generation stamped onto the next scheduled deadline.
    pub(crate) next_deadline_gen: u32,
    /// Generation stamped onto the next started batch (see
    /// [`InFlight::gen`]). Monotone per slot across retools so a
    /// BatchDone orphaned by a failure can never collide with a later
    /// batch's generation.
    pub(crate) next_batch_gen: u32,
    /// Dominant expert of the most recently started batch — its
    /// weights are resident for the next batch's residency discount.
    pub(crate) resident_expert: Option<u32>,
    /// Expert set hosted by this device when expert sharding is active
    /// (indexed by expert id; empty = sharding off, the device serves
    /// the whole model). Hosted experts' weights are pinned on-device,
    /// so they are *always* resident for the residency discount — the
    /// upgrade from the single dominant-expert hint to per-device
    /// expert sets.
    pub(crate) hosted: Vec<bool>,
}

impl DeviceState {
    pub fn new(model: &DeviceModel, max_wait: Duration, clock: VirtualClock) -> DeviceState {
        let cfg = BatcherConfig { sizes: model.batch_sizes.clone(), max_wait };
        DeviceState {
            batcher: Batcher::with_clock(cfg, Box::new(clock)),
            in_flight: None,
            metrics: DeviceMetrics::default(),
            deadline: None,
            next_deadline_gen: 0,
            next_batch_gen: 0,
            resident_expert: None,
            hosted: Vec::new(),
        }
    }

    /// Start hosting `expert` (sizes the set lazily so shard-free runs
    /// never allocate it).
    pub(crate) fn host(&mut self, expert: u32, num_experts: usize) {
        if self.hosted.is_empty() {
            self.hosted = vec![false; num_experts];
        }
        self.hosted[expert as usize] = true;
    }

    /// Stop hosting `expert` (new routing only; queued work drains).
    pub(crate) fn unhost(&mut self, expert: u32) {
        if let Some(h) = self.hosted.get_mut(expert as usize) {
            *h = false;
        }
    }

    /// Whether this device hosts `expert` (false when sharding is off).
    pub(crate) fn hosts(&self, expert: u32) -> bool {
        self.hosted.get(expert as usize).copied().unwrap_or(false)
    }

    /// Whether a batch dominated by `expert` gets the residency
    /// discount: either the previous batch left it resident, or the
    /// shard placement pins its weights here permanently.
    pub(crate) fn is_resident(&self, expert: u32) -> bool {
        self.resident_expert == Some(expert) || self.hosts(expert)
    }

    /// Re-template a retired slot for autoscaler reuse: a fresh
    /// batcher compiled for the *new* model's batch sizes (the slot
    /// drained before retiring, so the queue is empty) and fresh
    /// residency state. Metrics are kept — per-slot counters span
    /// activations — and so is the flush-deadline generation counter,
    /// which keeps any still-in-heap deadline event from the previous
    /// activation cancelled instead of colliding with a restarted
    /// generation 0.
    pub(crate) fn retool(&mut self, model: &DeviceModel, max_wait: Duration, clock: VirtualClock) {
        debug_assert!(
            self.in_flight.is_none() && self.batcher.pending() == 0,
            "retooling a slot that has not drained"
        );
        let cfg = BatcherConfig { sizes: model.batch_sizes.clone(), max_wait };
        self.batcher = Batcher::with_clock(cfg, Box::new(clock));
        self.resident_expert = None;
        // Sharding and autoscaling are mutually exclusive (typed
        // config error), but a retooled slot must never carry a stale
        // expert set regardless.
        self.hosted.clear();
        // An empty queue has no live deadline; dropping the record
        // guarantees any still-in-heap event from the previous
        // activation reads as superseded.
        self.deadline = None;
    }

    /// Requests on this device: queued + riding the in-flight batch
    /// (the join-shortest-queue load signal).
    pub fn load(&self) -> usize {
        self.batcher.pending()
            + self.in_flight.as_ref().map_or(0, |f| f.batch.requests.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::m3vit_small;
    use crate::resources::{AttnParams, LinearParams};

    fn hw() -> HwChoice {
        HwChoice {
            num: 2,
            attn: AttnParams { t_a: 8, n_a: 8 },
            lin: LinearParams { t_in: 16, t_out: 16, n_l: 2 },
            q_bits: 16,
            a_bits: 32,
        }
    }

    #[test]
    fn service_table_affine_in_batch_size() {
        let d = DeviceModel::from_latencies(
            "syn".into(),
            Duration::from_millis(3),
            Duration::from_millis(10),
            &[1, 4, 8],
        );
        assert_eq!(d.service_time(1), Duration::from_millis(13));
        assert_eq!(d.service_time(4), Duration::from_millis(43));
        assert_eq!(d.service_time(8), Duration::from_millis(83));
        assert_eq!(d.unloaded_latency(), Duration::from_millis(13));
    }

    #[test]
    fn batching_raises_peak_throughput() {
        let d = DeviceModel::from_latencies(
            "syn".into(),
            Duration::from_millis(5),
            Duration::from_millis(10),
            &[1, 8],
        );
        // 8/85ms > 1/15ms: the fill amortizes.
        let b1 = 1.0 / d.service_time(1).as_secs_f64();
        assert!(d.peak_rps() > b1, "{} !> {b1}", d.peak_rps());
    }

    #[test]
    fn residency_discount_recovers_half_the_fill() {
        let d = DeviceModel::from_latencies(
            "syn".into(),
            Duration::from_millis(6),
            Duration::from_millis(10),
            &[1, 4],
        );
        assert_eq!(d.residency_discount(), Duration::from_millis(3));
        assert_eq!(d.service_time_with_residency(4, false), d.service_time(4));
        assert_eq!(
            d.service_time_with_residency(4, true),
            d.service_time(4) - Duration::from_millis(3)
        );
        assert!(d.service_time_with_residency(1, true) > Duration::ZERO);
        // No fill → no discount: synthetic throughput-only devices are
        // unchanged by residency.
        let flat = DeviceModel::from_latencies(
            "flat".into(),
            Duration::ZERO,
            Duration::from_millis(10),
            &[1, 4],
        );
        assert_eq!(flat.service_time_with_residency(4, true), flat.service_time(4));
    }

    #[test]
    fn degraded_scales_the_lut_and_keeps_the_batch_menu() {
        let d = DeviceModel::from_latencies(
            "syn".into(),
            Duration::from_millis(5),
            Duration::from_millis(10),
            &[1, 4, 8],
        );
        let deg = d.degraded(3, 5);
        assert_eq!(deg.batch_sizes, d.batch_sizes, "swap-compatible menu");
        assert_eq!(deg.fill(), Duration::from_millis(3));
        assert_eq!(deg.period(), Duration::from_millis(6));
        assert_eq!(deg.service_time(8), Duration::from_millis(3 + 48));
        // Faster table ⇒ strictly more capacity (the brownout point).
        assert!(deg.peak_rps() > d.peak_rps());
        // The discount scales with the stream it models and stays
        // clamped to the new fill.
        assert_eq!(deg.residency_discount(), d.residency_discount() * 3 / 5);
        assert!(deg.residency_discount() <= deg.fill());
        // Identity scale is a rename, nothing else.
        let same = d.degraded(1, 1);
        assert_eq!(same.service_time(4), d.service_time(4));
    }

    #[test]
    #[should_panic(expected = "no compiled executable")]
    fn unknown_batch_size_rejected() {
        let d = DeviceModel::from_latencies(
            "syn".into(),
            Duration::ZERO,
            Duration::from_millis(1),
            &[1, 4],
        );
        let _ = d.service_time(3);
    }

    #[test]
    fn sim_backed_model_matches_engine_latencies() {
        use crate::sim::engine::simulate_sequential;
        let model = m3vit_small();
        let p = Platform::zcu102();
        let d = DeviceModel::with_hw(&model, &p, hw(), &[1, 4]);
        let sc = SimConfig::new(model, p.clone(), hw());
        let single_ms = p.cycles_to_ms(simulate_sequential(&sc).total_cycles);
        // Batch-1 service is the paper's single-image latency.
        let b1_ms = d.service_time(1).as_secs_f64() * 1e3;
        assert!((b1_ms - single_ms).abs() < 1e-6, "{b1_ms} vs {single_ms}");
        // Larger batches amortize the fill: cheaper per image.
        let per4 = d.service_time(4).as_secs_f64() / 4.0;
        assert!(per4 < d.service_time(1).as_secs_f64());
    }

    #[test]
    fn sim_backed_discount_is_the_expert_weight_stream() {
        // ROADMAP depth item: cycle-model devices derive the residency
        // discount from the exposed leading expert weight-stream of
        // sim/moe.rs, not the fill/2 heuristic.
        let model = m3vit_small();
        let p = Platform::zcu102();
        let d = DeviceModel::with_hw(&model, &p, hw(), &[1, 4]);
        let sc = SimConfig::new(model.clone(), p.clone(), hw());
        let stream_ms = p.cycles_to_ms(expert_stream_cycles(&model, &sc.memory(), sc.bw.moe_weights));
        let want = Duration::from_secs_f64(stream_ms * 1e-3).min(d.fill());
        assert_eq!(d.residency_discount(), want);
        assert!(d.residency_discount() > Duration::ZERO, "DDR stream must be exposed");
        // Clamped: a batch can never go faster than fill-free service.
        assert!(d.residency_discount() <= d.fill());
        // Non-MoE models have no expert stream to skip.
        let plain = DeviceModel::with_hw(&crate::models::vit_s(), &p, hw(), &[1, 4]);
        assert_eq!(plain.residency_discount(), plain.fill() / RESIDENCY_FILL_DIV);
    }

    #[test]
    fn expected_delay_weights_expose_the_affine_lut() {
        let d = DeviceModel::from_latencies(
            "syn".into(),
            Duration::from_millis(3),
            Duration::from_millis(10),
            &[1, 4],
        );
        let (fill_ns, period_ns) = d.expected_delay_weights();
        assert_eq!(fill_ns, 3_000_000);
        assert_eq!(period_ns, 10_000_000);
        assert_eq!(d.fill(), Duration::from_millis(3));
        assert_eq!(d.period(), Duration::from_millis(10));
        // fill + (0+1)·period == service(1).
        assert_eq!(fill_ns + period_ns, d.service_time(1).as_nanos() as u64);
    }

    #[test]
    fn device_state_load_counts_queue_and_flight() {
        let d = DeviceModel::from_latencies(
            "syn".into(),
            Duration::ZERO,
            Duration::from_millis(1),
            &[1, 4],
        );
        let clock = VirtualClock::new();
        let mut st = DeviceState::new(&d, Duration::from_millis(5), clock.clone());
        st.batcher.push(0);
        st.batcher.push(1);
        assert_eq!(st.load(), 2);
        let batch = st.batcher.next_batch_at(Duration::from_millis(10)).unwrap();
        st.in_flight = Some(InFlight { started: clock_now(&clock), batch, gen: 0 });
        assert_eq!(st.load(), 2);
    }

    fn clock_now(c: &VirtualClock) -> Duration {
        use crate::util::clock::Clock;
        c.now()
    }

    #[test]
    fn hosted_expert_sets_extend_residency() {
        let d = DeviceModel::from_latencies(
            "syn".into(),
            Duration::ZERO,
            Duration::from_millis(1),
            &[1],
        );
        let mut st = DeviceState::new(&d, Duration::from_millis(5), VirtualClock::new());
        // Sharding off: empty set, nothing hosted, residency is the
        // dominant-expert hint alone.
        assert!(!st.hosts(0));
        assert!(!st.is_resident(3));
        st.resident_expert = Some(3);
        assert!(st.is_resident(3));
        assert!(!st.is_resident(2));
        // Hosting pins residency regardless of the last batch.
        st.host(2, 4);
        assert!(st.hosts(2));
        assert!(st.is_resident(2));
        st.unhost(2);
        assert!(!st.hosts(2));
        assert!(!st.is_resident(2));
        // Out-of-range queries are false, not panics.
        assert!(!st.hosts(99));
    }
}
