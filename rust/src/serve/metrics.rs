//! Serving metrics: per-device recorders and their exact fleet-wide
//! aggregation.
//!
//! Latency is split the way queueing theory wants it: **queue wait**
//! (arrival → batch start, which includes time spent waiting for the
//! batcher to form a batch), **service** (batch start → batch done),
//! and **end-to-end** (arrival → done; always wait + service, a DES
//! invariant the proptests check). Aggregation merges the streaming
//! histograms bucket-wise ([`LatencyStats::merge`], an exact union at
//! bucket resolution), so fleet percentiles are computed over the
//! union of recorded samples — never the average of per-device
//! percentiles, which is not a percentile of anything.

use std::time::Duration;

use crate::coordinator::metrics::LatencyStats;
use crate::serve::autoscale::AutoscaleSummary;
use crate::serve::faults::FaultSummary;
use crate::serve::overload::OverloadSummary;
use crate::serve::shard::ShardSummary;

/// The single guard point for count-over-window rate math: every
/// req/s and event/s figure in serve/ divides here. Zero-duration
/// windows are a config error upstream (`simulate_fleet` rejects a
/// zero horizon outright); the clamp only covers degenerate empty
/// runs (e.g. a workload that admitted nothing, leaving makespan
/// zero), which report 0 instead of NaN/Inf.
pub fn rate_per_sec(count: u64, window: Duration) -> f64 {
    count as f64 / window.as_secs_f64().max(1e-12)
}

/// One device's counters for a run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DeviceMetrics {
    /// Arrival → batch start.
    pub queue_wait: LatencyStats,
    /// Batch start → batch completion (the batch the request rode in).
    pub service: LatencyStats,
    /// Arrival → completion.
    pub e2e: LatencyStats,
    pub completed: u64,
    pub batches: u64,
    /// Executed batch slots (Σ batch_size over executed batches).
    pub slots: u64,
    /// Executed slots that were padding.
    pub padded_slots: u64,
    /// Total time the device spent serving batches.
    pub busy: Duration,
}

impl DeviceMetrics {
    /// Absorb another device's counters (exact: latency sample sets
    /// are unioned).
    pub fn merge_from(&mut self, other: &DeviceMetrics) {
        self.queue_wait.merge(&other.queue_wait);
        self.service.merge(&other.service);
        self.e2e.merge(&other.e2e);
        self.completed += other.completed;
        self.batches += other.batches;
        self.slots += other.slots;
        self.padded_slots += other.padded_slots;
        self.busy += other.busy;
    }

    /// Fraction of executed slots that carried no request.
    pub fn padding_fraction(&self) -> f64 {
        if self.slots == 0 {
            0.0
        } else {
            self.padded_slots as f64 / self.slots as f64
        }
    }

    /// Busy time over the observation window.
    pub fn utilization(&self, window: Duration) -> f64 {
        if window.is_zero() {
            0.0
        } else {
            self.busy.as_secs_f64() / window.as_secs_f64()
        }
    }
}

/// Result of one fleet simulation.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetReport {
    pub per_device: Vec<DeviceMetrics>,
    /// Exact aggregation of `per_device`.
    pub fleet: DeviceMetrics,
    /// Requests *offered* by the workload (every one settles before
    /// the simulation ends — `completed + dropped + rejected ==
    /// admitted`, conservation asserted by the DES). Named for the
    /// pre-overload era when nothing was rejected at the edge; with
    /// admission control active, `admitted - rejected` requests
    /// actually entered dispatch.
    pub admitted: u64,
    /// Mean offered load over the arrival horizon.
    pub offered_rps: f64,
    /// Arrival horizon of the workload.
    pub horizon: Duration,
    /// Last completion time — ≥ horizon when the run drains a backlog.
    pub makespan: Duration,
    /// Events the DES processed (arrivals + flush wakeups + batch
    /// completions + user-think wakeups + controller ticks) — the
    /// numerator of the events/s throughput figure (EXPERIMENTS.md
    /// §DES-throughput).
    pub events: u64,
    /// Largest event-heap length observed. With streamed arrivals and
    /// deadline cancellation this stays O(devices + in-flight +
    /// closed-loop users), independent of the request count
    /// (regression-tested).
    pub peak_events: u64,
    /// Integrated fleet availability in seconds: Σ over device
    /// activations of (retirement − spawn), devices still up at the
    /// end closing at max(makespan, horizon). For a static fleet this
    /// is exactly `devices × max(makespan, horizon)`; it is the cost
    /// side of the autoscaling study (attainment bought per
    /// device-second).
    pub device_seconds: f64,
    /// Controller trajectory — `Some` iff the run was autoscaled.
    pub autoscale: Option<AutoscaleSummary>,
    /// Requests that exhausted their attempt budget and were dropped.
    /// Always 0 without fault injection (no deadline ⇒ no drops).
    pub dropped: u64,
    /// Fault-machinery counters — `Some` iff fault injection was
    /// active (a non-inert [`crate::serve::FaultConfig`]).
    pub faults: Option<FaultSummary>,
    /// Requests rejected at the admission edge (priority-aware
    /// shedding). Always 0 without overload protection.
    pub rejected: u64,
    /// Overload-machinery counters (per-class splits, breaker and
    /// brownout activity) — `Some` iff overload protection or shadow
    /// classification was active (a non-inert
    /// [`crate::serve::OverloadConfig`]).
    pub overload: Option<OverloadSummary>,
    /// Expert-sharding counters (routing, capacity reroutes and
    /// expert-drops, no-replica drops, transfers, rebalancer moves) —
    /// `Some` iff sharding was active (a non-inert
    /// [`crate::serve::ShardConfig`]). No-replica drops are included
    /// in [`FleetReport::dropped`].
    pub shard: Option<ShardSummary>,
}

impl FleetReport {
    /// Sustained completion rate over the whole run (drain included,
    /// so past saturation this converges to fleet capacity while
    /// `offered_rps` keeps growing).
    pub fn achieved_rps(&self) -> f64 {
        rate_per_sec(self.fleet.completed, self.makespan)
    }

    /// Fraction of requests whose end-to-end latency met `slo`.
    pub fn slo_attainment(&self, slo: Duration) -> f64 {
        self.fleet.e2e.fraction_leq(slo)
    }

    /// Goodput over offered: completed / admitted. 1.0 for an empty
    /// run (nothing offered, nothing failed) and for every fault-free
    /// unprotected run (conservation: no drops without a deadline, no
    /// rejects without admission control — both count against it).
    pub fn goodput_fraction(&self) -> f64 {
        if self.admitted == 0 {
            1.0
        } else {
            self.fleet.completed as f64 / self.admitted as f64
        }
    }

    /// SLO attainment measured over every *admitted* request, not just
    /// the completed ones: a dropped request is an SLO miss, so this
    /// is `slo_attainment × goodput_fraction`. The honest number for
    /// chaos runs — dropping slow requests must not flatter the SLO.
    pub fn slo_attainment_admitted(&self, slo: Duration) -> f64 {
        self.slo_attainment(slo) * self.goodput_fraction()
    }

    /// Mean per-device utilization over the makespan.
    pub fn mean_utilization(&self) -> f64 {
        if self.per_device.is_empty() {
            return 0.0;
        }
        let sum: f64 = self.per_device.iter().map(|d| d.utilization(self.makespan)).sum();
        sum / self.per_device.len() as f64
    }

    pub fn summary(&self) -> String {
        let [p50, p99, p999] = match self.fleet.e2e.percentiles(&[50.0, 99.0, 99.9])[..] {
            [a, b, c] => [a, b, c],
            _ => unreachable!(),
        };
        format!(
            "devices={} offered={:.1} req/s achieved={:.1} req/s \
             e2e p50={:?} p99={:?} p999={:?} util={:.0}% padding={:.1}% \
             batches={} makespan={:?} device-seconds={:.1}",
            self.per_device.len(),
            self.offered_rps,
            self.achieved_rps(),
            p50,
            p99,
            p999,
            100.0 * self.mean_utilization(),
            100.0 * self.fleet.padding_fraction(),
            self.fleet.batches,
            self.makespan,
            self.device_seconds,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dm(lat_ms: &[u64], busy_ms: u64) -> DeviceMetrics {
        let mut m = DeviceMetrics {
            completed: lat_ms.len() as u64,
            batches: 1,
            slots: lat_ms.len() as u64 + 1,
            padded_slots: 1,
            busy: Duration::from_millis(busy_ms),
            ..Default::default()
        };
        for &ms in lat_ms {
            m.e2e.record(Duration::from_millis(ms));
        }
        m
    }

    #[test]
    fn merge_sums_counters_and_unions_samples() {
        let a = dm(&[1, 3], 10);
        let b = dm(&[2, 100], 30);
        let mut f = DeviceMetrics::default();
        f.merge_from(&a);
        f.merge_from(&b);
        assert_eq!(f.completed, 4);
        assert_eq!(f.slots, 6);
        assert_eq!(f.busy, Duration::from_millis(40));
        assert_eq!(f.e2e.percentile(100.0), Duration::from_millis(100));
        assert_eq!(f.e2e.percentile(0.0), Duration::from_millis(1));
    }

    #[test]
    fn utilization_and_padding() {
        let m = dm(&[1, 2, 3], 500);
        assert!((m.utilization(Duration::from_secs(1)) - 0.5).abs() < 1e-12);
        assert!((m.padding_fraction() - 0.25).abs() < 1e-12);
        assert_eq!(DeviceMetrics::default().padding_fraction(), 0.0);
    }

    #[test]
    fn report_rates_and_slo() {
        let fleet = dm(&[10, 20, 30, 40], 0);
        let report = FleetReport {
            per_device: vec![fleet.clone()],
            fleet,
            admitted: 4,
            offered_rps: 2.0,
            horizon: Duration::from_secs(2),
            makespan: Duration::from_secs(2),
            events: 9,
            peak_events: 3,
            device_seconds: 2.0,
            autoscale: None,
            dropped: 0,
            faults: None,
            rejected: 0,
            overload: None,
            shard: None,
        };
        assert!((report.achieved_rps() - 2.0).abs() < 1e-9);
        assert!((report.slo_attainment(Duration::from_millis(20)) - 0.5).abs() < 1e-12);
        assert!(report.summary().contains("achieved=2.0 req/s"));
        // Fault-free: goodput is total, admitted-basis SLO == SLO.
        assert_eq!(report.goodput_fraction(), 1.0);
        assert_eq!(
            report.slo_attainment_admitted(Duration::from_millis(20)),
            report.slo_attainment(Duration::from_millis(20))
        );
    }

    #[test]
    fn goodput_discounts_drops() {
        let fleet = dm(&[10, 20, 30], 0); // 3 completed of 4 admitted
        let report = FleetReport {
            per_device: vec![fleet.clone()],
            fleet,
            admitted: 4,
            offered_rps: 2.0,
            horizon: Duration::from_secs(2),
            makespan: Duration::from_secs(2),
            events: 9,
            peak_events: 3,
            device_seconds: 2.0,
            autoscale: None,
            dropped: 1,
            faults: Some(FaultSummary { dropped: 1, ..Default::default() }),
            rejected: 0,
            overload: None,
            shard: None,
        };
        assert!((report.goodput_fraction() - 0.75).abs() < 1e-12);
        // All 3 completions met 30 ms, but the drop counts against
        // the admitted basis.
        let slo = Duration::from_millis(30);
        assert_eq!(report.slo_attainment(slo), 1.0);
        assert!((report.slo_attainment_admitted(slo) - 0.75).abs() < 1e-12);
        // Empty run: vacuous success, not NaN.
        let empty = FleetReport {
            per_device: vec![],
            fleet: DeviceMetrics::default(),
            admitted: 0,
            offered_rps: 0.0,
            horizon: Duration::from_secs(1),
            makespan: Duration::ZERO,
            events: 0,
            peak_events: 0,
            device_seconds: 0.0,
            autoscale: None,
            dropped: 0,
            faults: None,
            rejected: 0,
            overload: None,
            shard: None,
        };
        assert_eq!(empty.goodput_fraction(), 1.0);
    }

    #[test]
    fn rate_helper_guards_degenerate_windows() {
        assert!((rate_per_sec(10, Duration::from_secs(2)) - 5.0).abs() < 1e-12);
        // Degenerate empty-run window: finite (≈0 count dominates),
        // never NaN/Inf.
        assert!(rate_per_sec(0, Duration::ZERO).is_finite());
        assert_eq!(rate_per_sec(0, Duration::ZERO), 0.0);
    }
}
