//! Fleet-serving study: a deterministic discrete-event simulator that
//! drives traffic through a fleet of UbiMoE accelerators.
//!
//! The paper evaluates one accelerator at single-image latency and
//! steady-state throughput (Tables I–III). A production deployment
//! faces different questions: given live traffic, dynamic batching
//! onto fixed-shape executables, and a **fleet** of devices behind a
//! dispatcher — what latency distribution does a given load see, where
//! is the knee of the latency–throughput curve, how many users can the
//! fleet carry at an SLO, and how many devices does that take? This
//! module answers all of them on top of the existing stack:
//!
//! * each [`device::DeviceModel`] wraps an HAS-chosen configuration
//!   ([`crate::has`]) costed by the cycle-level simulator
//!   ([`crate::sim::engine`]) into a batch-size → service-time table,
//!   with a dominant-expert **residency discount** so the
//!   expert-affinity policy's weight-cache locality shows up in
//!   service times ([`device::RESIDENCY_FILL_DIV`]);
//! * batch formation reuses the coordinator's dynamic batcher
//!   ([`crate::coordinator::batcher`]) verbatim, running on the DES's
//!   **virtual clock** (the [`crate::util::clock::Clock`] trait);
//! * dispatch generalizes the §III-C round-robin CU router to fleet
//!   scope ([`dispatch`]): round-robin, capacity-weighted round-robin,
//!   join-shortest-queue, a MoE-expert-affinity policy, and
//!   heterogeneity-aware shortest-expected-delay (the tournament tree
//!   re-keyed from queue length to expected-completion ns — the
//!   ROADMAP mixed-fleet item, studied in
//!   [`crate::report::serving::mixed_fleet_table`]);
//! * workloads ([`workload`]) are seeded **open-loop** generators
//!   (Poisson / bursty-MMPP / replayable trace) *or* a **closed-loop**
//!   user model ([`Workload::ClosedLoop`]): N users cycling request →
//!   completion → exponential think time → next request, driven live
//!   off `UserThink` events on the same heap — the "max users at SLO"
//!   question ([`crate::report::serving::max_users_at_slo`]);
//! * an optional **autoscaling controller** ([`autoscale`], attached
//!   via [`ServeConfig::autoscale`]) resizes the fleet mid-run against
//!   an SLO-attainment window signal: proactive instant scale-up,
//!   patient drain-before-remove scale-down, device-seconds accounted
//!   per activation ([`FleetReport::device_seconds`]);
//! * optional **fault injection** ([`faults`], attached via
//!   [`ServeConfig::faults`]): scripted and seeded-stochastic device
//!   outages with failover re-dispatch (lost batches cancelled by
//!   generation, wasted service charged), per-attempt deadlines with
//!   capped-exponential-backoff retries and a drop budget, hedged
//!   duplicates, and SEU-style batch corruption — the graceful-
//!   degradation story behind [`crate::report::serving::chaos_study`];
//! * optional **overload protection** ([`overload`], attached via
//!   [`ServeConfig::overload`]): priority classes assigned at the
//!   arrival edge, per-class token-bucket + queue-depth admission
//!   control shedding the lowest class first (conservation extends to
//!   `completed + dropped + rejected == offered`), per-device circuit
//!   breakers tripping on the fault machinery's timeout streaks, and
//!   a hysteresis brownout controller that swaps devices onto
//!   lower-bit-width service tables under sustained SLO pressure —
//!   the demand-side graceful-degradation story behind
//!   [`crate::report::serving::overload_study`];
//! * metrics ([`metrics`]) record per-device and fleet-wide queueing +
//!   service latency (p50/p99/p999), throughput, utilization, padding
//!   fraction and SLO attainment;
//! * optional **observability** ([`crate::obs`], attached via
//!   [`simulate_fleet_observed`]): every consequential event emits a
//!   typed, virtual-ns-stamped trace record, and a heap-scheduled
//!   sampler ([`ServeConfig::sampler`]) reads windowed per-device +
//!   fleet gauges into a CSV time series. Observation is zero-cost
//!   when off and never perturbs the run: the `FleetReport` is
//!   bit-identical with tracing/sampling on or off (the sampler's
//!   heap events are compensated out of the event counters;
//!   proptested), and a fixed (config, seed) yields byte-identical
//!   trace files.
//!
//! **Scale.** The hot path is built for tens-of-millions-of-request
//! horizons (`benches/serve_scale.rs` drives ≥1M requests through a
//! 16-device fleet; CI records the events/s row in BENCH_serve.json):
//!
//! * **Streaming metrics.** Latency recorders are log-bucketed
//!   streaming histograms — O(1) record, memory bounded by the value
//!   range, exact bucket-wise `merge`. Resolution contract
//!   ([`crate::coordinator::metrics::LatencyStats`]): percentiles are
//!   exact at rank 1 and rank n (so min/max/tiny-n queries lose
//!   nothing), exact below 256 µs, and otherwise land within one
//!   1/128-wide (< 1%) bucket **above** the exact nearest-rank
//!   sample; `count`, `mean` and `max` are exact. The PR-2
//!   store-all-samples recorder is retained on the test path and a
//!   proptest pins the histogram to it.
//! * **Indexed dispatch.** Device loads live in a tournament tree
//!   ([`dispatch::LoadTracker`]) updated on dispatch/completion, so
//!   an arrival costs O(log fleet), not an O(fleet) rescan; tie-breaks
//!   (lowest index) are proptested identical to the scan. Scale
//!   events resize the tree (O(fleet), rare) without touching the
//!   per-arrival cost.
//! * **Lean, bounded event heap.** Arrivals stream from the sorted
//!   schedule instead of being preloaded; superseded flush deadlines
//!   are cancelled by generation instead of accumulating as no-op
//!   wakeups. The heap holds O(devices + in-flight + closed-loop
//!   users) 24-byte entries regardless of the request count
//!   (regression-tested).
//!
//! Everything runs on virtual time with seeded RNG: a fixed
//! (config, seed) pair produces a bit-identical [`FleetReport`] —
//! open-loop, closed-loop and autoscaled alike — enforced by tests
//! here and proptests in `tests/serve_properties.rs`.

pub mod autoscale;
pub mod device;
pub mod dispatch;
pub mod events;
pub mod faults;
pub mod metrics;
pub mod overload;
pub mod shard;
pub mod workload;

use std::time::Duration;

use crate::coordinator::batcher::Batch;
use crate::coordinator::metrics::LatencyStats;
use crate::obs::sampler::{ppm, SampleRow, SamplerConfig};
use crate::obs::trace::{DispatchWhy, TraceRecord, TraceSink};
use crate::obs::Observer;
use crate::util::clock::VirtualClock;
use crate::util::rng::{Rng, SplitMix64};
use autoscale::{AutoscaleConfig, AutoscaleSummary, Controller, WindowSignal};
use device::{DeviceModel, DeviceState, InFlight};
use dispatch::{DispatchPolicy, Dispatcher, LoadTracker};
use events::{EventKind, EventQueue};
use overload::{Breaker, BrownoutController, BrownoutSignal, RejectReason, TokenBucket};
use shard::{MoveKind, Popularity};
use workload::NUM_CLASSES;
pub use faults::{FaultConfig, FaultPlan, FaultSpan, FaultSummary};
pub use metrics::{DeviceMetrics, FleetReport};
pub use overload::{
    AdmissionConfig, BreakerConfig, BrownoutConfig, OverloadConfig, OverloadSummary,
};
pub use shard::{
    CapacityConfig, DriftConfig, PlacementMove, RebalanceConfig, ShardConfig, ShardSummary,
};
pub use workload::{ClassMix, Priority, Workload, WorkloadError};

/// One fleet-serving experiment.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// The initial fleet (homogeneous replicas or a mixed fleet); the
    /// autoscaling controller, when attached, grows and shrinks from
    /// here.
    pub devices: Vec<DeviceModel>,
    pub workload: Workload,
    pub dispatch: DispatchPolicy,
    /// Batcher flush timeout on every device.
    pub max_wait: Duration,
    /// Arrival horizon: open-loop schedules cover `[0, horizon)` and
    /// closed-loop users issue requests only before it; the run then
    /// drains every admitted request. Must be positive — a zero
    /// horizon makes offered load undefined and is rejected by
    /// [`simulate_fleet`].
    pub horizon: Duration,
    /// Seeds the workload, the expert-hint stream and the closed-loop
    /// think-time streams.
    pub seed: u64,
    /// Experts in the served model (dominant-expert hints are drawn
    /// uniformly from 0..num_experts). 0 means no experts to be
    /// affine to: hints are disabled, the residency discount never
    /// applies, and an ExpertAffinity dispatch falls back to
    /// join-shortest-queue (otherwise every zero hint would pin one
    /// home device).
    pub num_experts: usize,
    /// SLO-driven autoscaling ([`autoscale`]); `None` = static fleet.
    pub autoscale: Option<AutoscaleConfig>,
    /// Fault injection and graceful degradation ([`faults`]). `None`
    /// — or a config with every knob inert
    /// ([`FaultConfig::is_inert`]) — runs the perfect-world baseline,
    /// bit-identical to a config without the field (proptested).
    pub faults: Option<FaultConfig>,
    /// Time-series sampling cadence ([`crate::obs::sampler`]); only
    /// takes effect when [`simulate_fleet_observed`] is handed a
    /// series collector, and never changes the `FleetReport` either
    /// way (proptested).
    pub sampler: Option<SamplerConfig>,
    /// Overload protection ([`overload`]): per-class admission
    /// control, priority-aware shedding, circuit breakers and
    /// brownout degradation. `None` — or a config with every knob
    /// inert ([`OverloadConfig::is_inert`]) — runs unprotected,
    /// bit-identical to a config without the field (proptested).
    pub overload: Option<OverloadConfig>,
    /// Expert sharding ([`shard`]): each device hosts an expert *set*,
    /// a seeded top-k router assigns experts from a skewed (optionally
    /// drifting) popularity, dispatch is constrained to devices hosting
    /// the serving expert, per-expert capacity windows reroute or
    /// expert-drop overflow, and an optional controller replicates hot
    /// experts and rebalances placement. `None` — or a config with
    /// every knob inert ([`ShardConfig::is_inert`]) — runs unsharded,
    /// bit-identical to a config without the field (proptested).
    pub shard: Option<ShardConfig>,
}

impl ServeConfig {
    /// A homogeneous fleet of `n` replicas of `device` with sensible
    /// defaults: max_wait is half the unloaded batch-1 latency (so
    /// batching never adds more than ~50% of a service time to an
    /// idle-fleet request).
    pub fn uniform(device: DeviceModel, n: usize, workload: Workload) -> ServeConfig {
        assert!(n > 0);
        let max_wait = device.unloaded_latency() / 2;
        ServeConfig {
            devices: vec![device; n],
            workload,
            dispatch: DispatchPolicy::JoinShortestQueue,
            max_wait,
            horizon: Duration::from_secs(10),
            seed: 0xF1EE7,
            num_experts: 16,
            autoscale: None,
            faults: None,
            sampler: None,
            overload: None,
            shard: None,
        }
    }

    /// A heterogeneous fleet (e.g. a ZCU102 edge tier next to a U280
    /// core tier), same defaults as [`ServeConfig::uniform`] except
    /// max_wait is half the *fastest* device's unloaded batch-1
    /// latency, so batching never dominates an idle-fleet request on
    /// any tier.
    pub fn mixed(devices: Vec<DeviceModel>, workload: Workload) -> ServeConfig {
        assert!(!devices.is_empty());
        let max_wait = devices.iter().map(|d| d.unloaded_latency()).min().unwrap() / 2;
        ServeConfig {
            devices,
            workload,
            dispatch: DispatchPolicy::JoinShortestQueue,
            max_wait,
            horizon: Duration::from_secs(10),
            seed: 0xF1EE7,
            num_experts: 16,
            autoscale: None,
            faults: None,
            sampler: None,
            overload: None,
            shard: None,
        }
    }

    /// Fleet peak throughput of the *initial* fleet: Σ per-device peak
    /// (the normalization for offered-load sweeps).
    pub fn fleet_peak_rps(&self) -> f64 {
        self.devices.iter().map(|d| d.peak_rps()).sum()
    }

    /// Canonical single-line key of everything the DES *reads*: the
    /// identity under which [`crate::has::cache`] memoizes whole
    /// [`FleetReport`]s. Two configs with equal keys produce
    /// bit-identical reports (the determinism contract), so a disk hit
    /// may stand in for the event loop.
    ///
    /// Encoding rules match `has/cache.rs::design_key`: floats appear
    /// as 16-hex-digit IEEE-754 bit patterns (representation equality,
    /// never formatting), durations as integer nanoseconds, fields
    /// `;`-separated in a fixed order. Devices are keyed by their
    /// service-table inputs `(fill, period, residency_discount,
    /// batch_sizes)` — complete because `service(B) = fill + B·period`
    /// by construction, and `residency_discount` is included because
    /// the surface/degraded paths override it independently. Device
    /// *names* are display-only and excluded.
    ///
    /// `sampler` is deliberately excluded: observation never perturbs
    /// the report (bit-identity with the sampler on/off is proptested
    /// in `tests/serve_properties.rs`), so sampled and unsampled runs
    /// share one cache entry. A `Some` config with every knob inert
    /// keys differently from `None` — harmless (one extra cache entry;
    /// both store the identical report).
    pub fn canonical_key(&self) -> String {
        use std::fmt::Write as _;
        fn fbits(v: f64) -> String {
            format!("{:016x}", v.to_bits())
        }
        fn dev_key(out: &mut String, d: &DeviceModel) {
            let _ = write!(
                out,
                "{}/{}/{}/",
                d.fill().as_nanos(),
                d.period().as_nanos(),
                d.residency_discount().as_nanos()
            );
            for (i, b) in d.batch_sizes.iter().enumerate() {
                if i > 0 {
                    out.push('.');
                }
                let _ = write!(out, "{b}");
            }
        }
        fn opt_f(v: Option<f64>) -> String {
            v.map_or_else(|| "-".into(), fbits)
        }
        fn opt_u<T: std::fmt::Display>(v: Option<T>) -> String {
            v.map_or_else(|| "-".into(), |x| x.to_string())
        }

        let mut k = String::from("serve");
        k.push_str(";dev=");
        for (i, d) in self.devices.iter().enumerate() {
            if i > 0 {
                k.push('+');
            }
            dev_key(&mut k, d);
        }
        k.push_str(";wl=");
        match &self.workload {
            Workload::Poisson { rate_rps } => {
                let _ = write!(k, "poisson:{}", fbits(*rate_rps));
            }
            Workload::Mmpp2 { rate_low_rps, rate_high_rps, dwell_low, dwell_high } => {
                let _ = write!(
                    k,
                    "mmpp2:{}:{}:{}:{}",
                    fbits(*rate_low_rps),
                    fbits(*rate_high_rps),
                    dwell_low.as_nanos(),
                    dwell_high.as_nanos()
                );
            }
            Workload::Trace { arrivals } => {
                k.push_str("trace:");
                for (i, a) in arrivals.iter().enumerate() {
                    if i > 0 {
                        k.push('.');
                    }
                    let _ = write!(k, "{}", a.as_nanos());
                }
            }
            Workload::ClosedLoop { users, think_time } => {
                let _ = write!(k, "closed:{}:{}", users, think_time.as_nanos());
            }
        }
        let _ = write!(
            k,
            ";dp={};wait={};hz={};seed={};ex={}",
            self.dispatch.name(),
            self.max_wait.as_nanos(),
            self.horizon.as_nanos(),
            self.seed,
            self.num_experts
        );
        k.push_str(";as=");
        match &self.autoscale {
            None => k.push_str("none"),
            Some(a) => {
                dev_key(&mut k, &a.template);
                let _ = write!(
                    k,
                    ":{}:{}:{}:{}:{}:{}:{}",
                    a.window.as_nanos(),
                    a.slo.as_nanos(),
                    fbits(a.target_attainment),
                    a.min_devices,
                    a.max_devices,
                    fbits(a.rho_target),
                    a.scale_down_patience
                );
            }
        }
        k.push_str(";ft=");
        match &self.faults {
            None => k.push_str("none"),
            Some(f) => {
                for (i, s) in f.plan.spans().iter().enumerate() {
                    if i > 0 {
                        k.push('.');
                    }
                    let _ =
                        write!(k, "{}@{}-{}", s.device, s.from.as_nanos(), s.to.as_nanos());
                }
                let _ = write!(
                    k,
                    ":{}:{}:{}:{}:{}:{}:{}:{}",
                    opt_u(f.mtbf.map(|d| d.as_nanos())),
                    f.mttr.as_nanos(),
                    fbits(f.seu_per_batch),
                    opt_u(f.deadline.map(|d| d.as_nanos())),
                    f.max_attempts,
                    f.backoff_base.as_nanos(),
                    f.backoff_cap.as_nanos(),
                    opt_u(f.hedge_delay.map(|d| d.as_nanos()))
                );
            }
        }
        k.push_str(";ov=");
        match &self.overload {
            None => k.push_str("none"),
            Some(o) => {
                let _ = write!(
                    k,
                    "{}:{}:{}:{}",
                    fbits(o.mix.interactive),
                    fbits(o.mix.batch),
                    fbits(o.mix.background),
                    u8::from(o.shadow)
                );
                match &o.admission {
                    None => k.push_str(":adm-none"),
                    Some(a) => {
                        let _ = write!(
                            k,
                            ":adm:{}:{}:{}:{}",
                            a.rate_caps.map(opt_f).join("."),
                            fbits(a.burst),
                            a.queue_limits.map(opt_u).join("."),
                            a.attempt_budget.map(opt_u).join(".")
                        );
                    }
                }
                match &o.breaker {
                    None => k.push_str(":brk-none"),
                    Some(b) => {
                        let _ =
                            write!(k, ":brk:{}:{}", b.trip_after, b.cooldown.as_nanos());
                    }
                }
                match &o.brownout {
                    None => k.push_str(":bro-none"),
                    Some(b) => {
                        let _ = write!(
                            k,
                            ":bro:{}:{}:{}:{}:{}:{}:{}:",
                            b.window.as_nanos(),
                            b.slo.as_nanos(),
                            fbits(b.enter_attainment),
                            fbits(b.exit_attainment),
                            b.enter_patience,
                            b.exit_patience,
                            fbits(b.accuracy_cost_per_request)
                        );
                        for (i, d) in b.degraded.iter().enumerate() {
                            if i > 0 {
                                k.push('+');
                            }
                            dev_key(&mut k, d);
                        }
                    }
                }
            }
        }
        k.push_str(";sh=");
        match &self.shard {
            None => k.push_str("none"),
            Some(s) => {
                let _ = write!(
                    k,
                    "{}:{}:{}:{}:{}:{}:{}:{}:{}",
                    s.top_k,
                    fbits(s.zipf_s),
                    s.replication,
                    s.hot_experts,
                    s.drift
                        .as_ref()
                        .map_or_else(|| "-".into(), |d| format!(
                            "{}/{}",
                            d.every.as_nanos(),
                            d.shift
                        )),
                    s.capacity
                        .as_ref()
                        .map_or_else(|| "-".into(), |c| format!(
                            "{}/{}",
                            c.window.as_nanos(),
                            c.cap_tokens
                        )),
                    s.rebalance
                        .as_ref()
                        .map_or_else(|| "-".into(), |r| r.every.as_nanos().to_string()),
                    s.transfer_cost.as_nanos(),
                    fbits(s.expert_drop_cost)
                );
            }
        }
        k
    }

    /// Cross-field configuration checks, surfaced as typed errors at
    /// construction time instead of mid-run asserts. [`simulate_fleet`]
    /// calls this first and panics with the error's `Display` message;
    /// callers composing configs programmatically can check it
    /// themselves and recover. Inert `overload`/`shard` values are
    /// skipped — they are contractually identical to `None`.
    pub fn validate(&self) -> Result<(), ServeConfigError> {
        if let Some(o) = self.overload.as_ref().filter(|o| !o.is_inert()) {
            if o.brownout.is_some() && self.autoscale.is_some() {
                return Err(ServeConfigError::BrownoutWithAutoscale);
            }
        }
        if let Some(s) = self.shard.as_ref().filter(|s| !s.is_inert()) {
            if self.autoscale.is_some() {
                return Err(ServeConfigError::ShardWithAutoscale);
            }
            if self.num_experts == 0 {
                return Err(ServeConfigError::ShardWithoutExperts);
            }
            if !(1..=self.num_experts).contains(&s.top_k) {
                return Err(ServeConfigError::ShardTopKBounds {
                    top_k: s.top_k,
                    num_experts: self.num_experts,
                });
            }
            if !(1..=self.devices.len()).contains(&s.replication) {
                return Err(ServeConfigError::ShardReplicationBounds {
                    replication: s.replication,
                    devices: self.devices.len(),
                });
            }
            if matches!(&s.capacity, Some(c) if c.window.is_zero()) {
                return Err(ServeConfigError::ShardZeroWindow("capacity window"));
            }
            if matches!(&s.rebalance, Some(r) if r.every.is_zero()) {
                return Err(ServeConfigError::ShardZeroWindow("rebalance period"));
            }
            if matches!(&s.drift, Some(d) if d.every.is_zero()) {
                return Err(ServeConfigError::ShardZeroWindow("drift phase"));
            }
        }
        Ok(())
    }
}

/// Cross-field [`ServeConfig`] mistakes caught by
/// [`ServeConfig::validate`] before the event loop starts — a typed
/// value instead of a mid-run assert, so sweep harnesses can skip
/// invalid corners gracefully.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeConfigError {
    /// Brownout and autoscaling are both fleet-reshaping controllers;
    /// only one may run.
    BrownoutWithAutoscale,
    /// Expert sharding pins placement to the initial fleet; the
    /// autoscaler invalidates it by resizing.
    ShardWithAutoscale,
    /// Sharding routes over experts, so `num_experts == 0` leaves the
    /// router with nothing to draw.
    ShardWithoutExperts,
    /// `ShardConfig::top_k` must be in `1..=num_experts`.
    ShardTopKBounds { top_k: usize, num_experts: usize },
    /// `ShardConfig::replication` must be in `1..=devices`.
    ShardReplicationBounds { replication: usize, devices: usize },
    /// A shard window/period knob (named in the payload) is zero.
    ShardZeroWindow(&'static str),
    /// The fleet planner (`report::plan`) was handed zero platform
    /// templates — the composition genome would be empty.
    PlanEmptyTemplates,
    /// The planner's scenario grid has zero points — fitness would
    /// aggregate over nothing.
    PlanEmptyScenarioGrid,
    /// A planner autoscale-preset constant (named in the payload) is
    /// out of bounds: `rho_target`/`target_attainment` in `(0, 1]`,
    /// `min_devices ≥ 1`, `min ≤ max`, positive window, patience ≥ 1.
    PlanAutoscaleBounds(&'static str),
}

impl std::fmt::Display for ServeConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeConfigError::BrownoutWithAutoscale => write!(
                f,
                "brownout and autoscaling both reshape the fleet mid-run; \
                 run one controller at a time"
            ),
            ServeConfigError::ShardWithAutoscale => write!(
                f,
                "expert sharding and autoscaling both reshape the fleet mid-run; \
                 run one controller at a time"
            ),
            ServeConfigError::ShardWithoutExperts => {
                write!(f, "expert sharding needs num_experts > 0 to route over")
            }
            ServeConfigError::ShardTopKBounds { top_k, num_experts } => {
                write!(f, "shard top_k {top_k} outside 1..={num_experts}")
            }
            ServeConfigError::ShardReplicationBounds { replication, devices } => {
                write!(f, "shard replication {replication} outside 1..={devices}")
            }
            ServeConfigError::ShardZeroWindow(which) => {
                write!(f, "shard {which} must be positive")
            }
            ServeConfigError::PlanEmptyTemplates => {
                write!(f, "fleet planner needs at least one platform template")
            }
            ServeConfigError::PlanEmptyScenarioGrid => {
                write!(f, "fleet planner needs at least one scenario-grid point")
            }
            ServeConfigError::PlanAutoscaleBounds(which) => {
                write!(f, "plan autoscale preset: {which} out of bounds")
            }
        }
    }
}

impl std::error::Error for ServeConfigError {}

/// Expert-hint context threaded through batch starts: per-request
/// dominant-expert hints (owned here so closed-loop runs can grow the
/// vector as users issue requests), the enable flag, and a reusable
/// scratch buffer for the per-batch mode computation — the hot loop
/// never allocates for it.
struct HintCtx {
    hints: Vec<u32>,
    enabled: bool,
    /// (expert, count) accumulator reused across batches.
    scratch: Vec<(u32, u32)>,
}

/// Dominant expert of a formed batch: the most frequent member hint,
/// smallest expert id on ties (deterministic). One O(B) counting pass
/// over the members (distinct hints ≤ B), not a rescan per member.
///
/// Batch payloads are `(request << 1) | hedge_bit` — the request index
/// is recovered with a shift (fault-free runs always carry bit 0 = 0).
fn dominant_expert(batch: &Batch<usize>, hints: &[u32], scratch: &mut Vec<(u32, u32)>) -> u32 {
    scratch.clear();
    for r in &batch.requests {
        let h = hints[r.payload >> 1];
        match scratch.iter_mut().find(|(e, _)| *e == h) {
            Some((_, c)) => *c += 1,
            None => scratch.push((h, 1)),
        }
    }
    let mut best_count = 0u32;
    let mut best_hint = u32::MAX;
    for &(e, c) in scratch.iter() {
        if c > best_count || (c == best_count && e < best_hint) {
            best_count = c;
            best_hint = e;
        }
    }
    best_hint
}

/// The trace hookup threaded through the event loop: `None` when
/// tracing is off, in which case [`emit`]'s record-constructing
/// closure never runs — observation is zero-cost when off.
type Tr<'a, 'b> = &'a mut Option<&'b mut dyn TraceSink>;

/// Emit a trace record at virtual time `at`, constructing it lazily.
#[inline]
fn emit(tr: Tr<'_, '_>, at: Duration, f: impl FnOnce() -> TraceRecord) {
    if let Some(sink) = tr {
        sink.record(at.as_nanos() as u64, f());
    }
}

fn try_start(
    st: &mut DeviceState,
    model: &DeviceModel,
    q: &mut EventQueue,
    now: Duration,
    idx: usize,
    hc: &mut HintCtx,
    tr: Tr<'_, '_>,
) {
    if st.in_flight.is_some() {
        return;
    }
    if let Some(batch) = st.batcher.next_batch() {
        let service = if hc.enabled {
            let dom = dominant_expert(&batch, &hc.hints, &mut hc.scratch);
            // Hosted (shard-pinned) experts are always resident; the
            // single-slot cache covers the unsharded dominant expert.
            let resident = st.is_resident(dom);
            st.resident_expert = Some(dom);
            model.service_time_with_residency(batch.batch_size, resident)
        } else {
            model.service_time(batch.batch_size)
        };
        // Generation-stamped completion: a device failure takes the
        // in-flight slot, so the orphaned BatchDone pops with a stale
        // generation and is skipped (the lost batch never completes).
        let gen = st.next_batch_gen;
        st.next_batch_gen = st.next_batch_gen.wrapping_add(1);
        q.push(now + service, EventKind::BatchDone { device: idx as u32, gen });
        emit(tr, now, || TraceRecord::BatchOpen {
            device: idx as u64,
            size: batch.batch_size as u64,
            padding: batch.padding as u64,
            service_ns: service.as_nanos() as u64,
            reqs: batch.requests.iter().map(|r| (r.payload >> 1) as u64).collect(),
        });
        st.in_flight = Some(InFlight { started: now, batch, gen });
    } else if let Some(oldest) = st.batcher.oldest_enqueued() {
        // Partial batch waiting: wake up when its oldest member hits
        // max_wait. If that deadline is already scheduled, the live
        // event covers it; otherwise schedule a fresh generation —
        // any previously live event with an older generation is
        // thereby cancelled (skipped on pop), so the heap never
        // accumulates superseded deadlines.
        let deadline = (oldest + st.batcher.config().max_wait).max(now);
        let already = matches!(st.deadline, Some((d, _)) if d == deadline);
        if !already {
            let gen = st.next_deadline_gen;
            st.next_deadline_gen = st.next_deadline_gen.wrapping_add(1);
            q.push(deadline, EventKind::FlushDeadline { device: idx as u32, gen });
            st.deadline = Some((deadline, gen));
        }
    }
}

/// Exponential think-time draw (mean `mean`; zero mean means the user
/// re-fires instantly — the saturating closed-loop regime).
fn think_gap(rng: &mut Rng, mean: Duration) -> Duration {
    if mean.is_zero() {
        Duration::ZERO
    } else {
        Duration::from_secs_f64(-(1.0 - rng.f64()).ln() * mean.as_secs_f64())
    }
}

/// Lifecycle of a fleet slot under autoscaling. Static runs keep every
/// slot `Serving` for the whole simulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Slot {
    /// In the dispatch set, serving traffic.
    Serving,
    /// Removed from the dispatch set, finishing its queued and
    /// in-flight work (drain-before-remove).
    Draining,
    /// Drained and gone; the slot may be reused by a later scale-up.
    Retired,
    /// Down hard (fault injection): out of the dispatch set, queue and
    /// in-flight work already failed over, waiting for its repair
    /// event to return it to `Serving`.
    Failed,
}

/// One device activation: slot `slot` was available from `from` until
/// `to` (open = still up when the run ended). The device-seconds sum
/// is over these spans.
#[derive(Clone, Debug)]
struct ActiveSpan {
    slot: usize,
    from: Duration,
    to: Option<Duration>,
}

fn close_span(spans: &mut [ActiveSpan], slot: usize, now: Duration) {
    let span = spans
        .iter_mut()
        .rev()
        .find(|s| s.slot == slot && s.to.is_none())
        .expect("retiring a slot with no open activation span");
    span.to = Some(now);
}

/// Windowed controller bookkeeping of an autoscaled run.
struct ScaleState {
    ctl: Controller,
    /// End-to-end latencies completed in the current window.
    window_e2e: LatencyStats,
    /// Requests admitted in the current window.
    window_arrivals: u64,
    summary: AutoscaleSummary,
}

/// Windowed gauge accumulators of an observed run — allocated only
/// when a [`SamplerConfig`] *and* a series collector are both present
/// ([`simulate_fleet_observed`]); the unobserved hot path carries
/// none of it.
struct SamplerState {
    every: Duration,
    slo: Option<Duration>,
    /// Whether a SampleTick is currently in the heap (the peak-events
    /// compensation subtracts it so the report stays bit-identical).
    scheduled: bool,
    /// Ticks fired so far (the events-counter compensation).
    ticks: u64,
    /// End-to-end latencies completed in the current window.
    window_e2e: LatencyStats,
    window_done_fleet: u64,
    window_done_dev: Vec<u64>,
    /// Busy credit (accumulated busy + elapsed in-flight service) per
    /// device at the previous tick — windowed utilization is the
    /// delta, continuous across completions, failures and SEU reruns.
    prev_busy: Vec<Duration>,
}

/// Live fault-machinery state, allocated only when [`ServeConfig::faults`]
/// has an active knob — the perfect-world hot path carries none of it
/// (and stays bit-identical to a `faults: None` run, proptested).
struct ChaosState {
    fc: FaultConfig,
    /// Attempt number of each request's newest dispatch (1-based);
    /// an [`EventKind::AttemptTimeout`] carrying an older number was
    /// superseded by a retry and is skipped.
    attempts: Vec<u32>,
    /// Whether the request's hedge duplicate was already sent.
    hedged: Vec<bool>,
    /// Device of the newest primary dispatch (`u32::MAX` = parked at
    /// fleet level) — the hedge copy avoids it.
    primary_dev: Vec<u32>,
    /// Payload copies parked at fleet level during a total outage,
    /// flushed on the next repair (or scale-up) in arrival order.
    pending: Vec<usize>,
    /// Dedicated SEU stream: corruption draws never perturb the
    /// workload / hint / user streams.
    seu_rng: Rng,
    summary: FaultSummary,
}

/// Live brownout bookkeeping: the pure hysteresis controller plus the
/// current window's evidence and the stashed full-precision tables.
struct BrownoutWindows {
    ctl: BrownoutController,
    window_completions: u64,
    window_met: u64,
    window_rejects: u64,
    /// Full-precision service tables, restored on brownout exit.
    full: Vec<DeviceModel>,
}

/// Live overload-protection state, allocated only when
/// [`ServeConfig::overload`] has an active knob — the unprotected hot
/// path carries none of it (and stays bit-identical to an
/// `overload: None` run, proptested). The class stream lives here, so
/// inert configs never even draw it.
struct OverloadState {
    oc: OverloadConfig,
    /// Priority-class index of each request
    /// ([`workload::Priority::index`]), assigned at the arrival edge.
    class: Vec<u8>,
    /// Dedicated class-assignment stream: classification draws never
    /// perturb the workload / hint / user / fault streams.
    class_rng: Rng,
    /// Per-class token buckets (`None` = uncapped).
    buckets: [Option<TokenBucket>; NUM_CLASSES],
    /// Per-device circuit breakers, grown with the fleet.
    breakers: Vec<Breaker>,
    brownout: Option<BrownoutWindows>,
    summary: OverloadSummary,
}

/// Classify one arrival and run the admission edge. Exactly one class
/// draw per offered request — shadow mode and full enforcement consume
/// the class stream identically, so per-class accounting is comparable
/// across study rows sharing a seed. Returns the class index and the
/// rejection reason, if any (`None` = admitted). The caller settles
/// rejected requests and emits the `reject` trace record.
fn admission_edge(
    ov: &mut OverloadState,
    now: Duration,
    loads: &LoadTracker,
    n_dev: usize,
) -> (usize, Option<RejectReason>) {
    let c = ov.oc.mix.draw(&mut ov.class_rng).index();
    ov.class.push(c as u8);
    ov.summary.offered_by_class[c] += 1;
    let mut verdict = None;
    if !ov.oc.shadow {
        if let Some(ac) = &ov.oc.admission {
            // Resident-count limit first (state-free), then the token
            // bucket — a queue-rejected request never burns a token.
            if let Some(limit) = ac.queue_limits[c] {
                let resident: usize = (0..n_dev).map(|i| loads.get(i)).sum();
                if resident >= limit {
                    verdict = Some(RejectReason::QueueLimit);
                }
            }
            if verdict.is_none() {
                if let Some(tb) = &mut ov.buckets[c] {
                    if !tb.admit(now.as_nanos() as u64) {
                        verdict = Some(RejectReason::RateCap);
                    }
                }
            }
        }
    }
    match verdict {
        Some(why) => {
            ov.summary.rejected += 1;
            ov.summary.rejected_by_class[c] += 1;
            match why {
                RejectReason::RateCap => ov.summary.rejected_rate += 1,
                RejectReason::QueueLimit => ov.summary.rejected_queue += 1,
            }
            // Rejects count as SLO misses in the brownout window —
            // shedding must not mask the pressure it relieves.
            if let Some(bw) = &mut ov.brownout {
                bw.window_rejects += 1;
            }
        }
        None => ov.summary.admitted_by_class[c] += 1,
    }
    (c, verdict)
}

/// Live expert-sharding state, allocated only when
/// [`ServeConfig::shard`] has an active knob — the unsharded hot path
/// carries none of it (and stays bit-identical to a `shard: None` run,
/// proptested). The router stream lives here, so inert configs never
/// even draw it.
struct ShardState {
    sc: ShardConfig,
    pop: Popularity,
    /// Dedicated router stream: expert draws never perturb the
    /// workload / hint / user / fault / class streams.
    rng: Rng,
    /// Current placement: expert → hosting devices (kept in sync with
    /// each [`DeviceState::hosted`] set).
    replicas: Vec<Vec<u32>>,
    /// Per-request serving expert after capacity resolution
    /// (`u32::MAX` = expert-dropped: served degraded, any device).
    expert: Vec<u32>,
    /// Per-request primary (drawn) expert.
    primary: Vec<u32>,
    /// Per-request secondary experts, flattened with stride
    /// `top_k − 1` (drawn order — capacity reroute preference).
    secondaries: Vec<u32>,
    /// Per-request interconnect charge (ns), set when the primary copy
    /// is dispatched and added to the winning completion's e2e.
    xfer_ns: Vec<u64>,
    /// Per-request non-local expert-fetch count behind `xfer_ns`.
    remote: Vec<u32>,
    /// Per-expert capacity window: (window index, admitted count),
    /// reset lazily when the window index moves on.
    cap_window: Vec<(u64, u64)>,
    /// Per-expert routed counts since the last rebalance tick — the
    /// planner's demand signal.
    window_counts: Vec<u64>,
    /// Scratch: devices masked out around a shard-constrained pick.
    masked: Vec<usize>,
    /// Copies that found no live replica of their serving expert,
    /// settled as drops at the end of the event iteration
    /// (payload, time).
    undeliverable: Vec<(usize, Duration)>,
    summary: ShardSummary,
}

/// Draw one request's expert assignment: a primary plus `top_k − 1`
/// distinct secondaries from the popularity distribution at the
/// current drift phase. Every arrival is routed — admitted or not —
/// with a fixed number of RNG draws (collisions advance ranks
/// deterministically instead of redrawing), so `routed` equals the
/// offered count and the stream stays aligned across configs sharing
/// a seed.
fn route_arrival(sh: &mut ShardState, now: Duration) {
    let phase = sh.pop.phase(now.as_nanos() as u64);
    let e_cnt = sh.pop.num_experts();
    let u = sh.rng.f64();
    let primary = sh.pop.expert_of_rank(sh.pop.draw_rank(u), phase);
    let base = sh.secondaries.len();
    for _ in 1..sh.sc.top_k {
        let u = sh.rng.f64();
        let mut rank = sh.pop.draw_rank(u);
        loop {
            let cand = sh.pop.expert_of_rank(rank, phase);
            if cand != primary && !sh.secondaries[base..].contains(&cand) {
                sh.secondaries.push(cand);
                break;
            }
            rank = (rank + 1) % e_cnt;
        }
    }
    sh.primary.push(primary);
    sh.expert.push(primary);
    sh.xfer_ns.push(0);
    sh.remote.push(0);
    sh.summary.routed += 1;
    sh.window_counts[primary as usize] += 1;
}

/// Capacity resolution for an *admitted* request: the primary expert
/// takes a token from its window if one is left; otherwise the
/// secondaries are tried in drawn order (reroute); all over budget ⇒
/// expert-drop (`u32::MAX`) — the request is served degraded with the
/// accuracy-proxy cost charged at completion. Overwrites the request's
/// dominant-expert hint so affinity dispatch and the residency
/// discount track the shard assignment.
fn resolve_capacity(
    sh: &mut ShardState,
    now: Duration,
    req: usize,
    hints: &mut [u32],
    tr: Tr<'_, '_>,
) {
    let primary = sh.primary[req];
    let k = sh.sc.top_k;
    let cap = sh.sc.capacity.as_ref().map(|c| (c.window.as_nanos() as u64, c.cap_tokens));
    let effective = match cap {
        None => primary,
        Some((win_ns, cap_tokens)) => {
            let win = now.as_nanos() as u64 / win_ns;
            let mut chosen = u32::MAX;
            for slot in 0..k {
                let e = if slot == 0 {
                    primary
                } else {
                    sh.secondaries[req * (k - 1) + slot - 1]
                };
                let w = &mut sh.cap_window[e as usize];
                if w.0 != win {
                    *w = (win, 0);
                }
                if w.1 < cap_tokens {
                    w.1 += 1;
                    chosen = e;
                    if slot > 0 {
                        sh.summary.rerouted += 1;
                    }
                    break;
                }
            }
            if chosen == u32::MAX {
                sh.summary.expert_drops += 1;
            }
            chosen
        }
    };
    sh.expert[req] = effective;
    hints[req] = if effective == u32::MAX { primary } else { effective };
    let eff_i = if effective == u32::MAX { -1 } else { effective as i64 };
    let rerouted = effective != u32::MAX && effective != primary;
    emit(tr, now, || TraceRecord::Route {
        req: req as u64,
        expert: eff_i,
        primary: primary as u64,
        rerouted,
    });
}

/// Dispatch one request copy — payload `(request << 1) | hedge_bit` —
/// to the policy's pick, or park it at fleet level when no device is
/// active (total outage; only reachable with fault injection). Hedge
/// copies pass `exclude` to avoid their primary device when at least
/// one other device is active. With sharding, the pick is constrained
/// to active devices hosting the copy's serving expert; an empty
/// candidate set queues the copy as undeliverable (settled as a
/// no-replica drop at the end of the event iteration). Returns the
/// chosen device, if any.
#[allow(clippy::too_many_arguments)]
fn dispatch_copy(
    payload: usize,
    now: Duration,
    dispatcher: &mut Dispatcher,
    loads: &mut LoadTracker,
    devices: &mut [DeviceState],
    models: &[DeviceModel],
    q: &mut EventQueue,
    hc: &mut HintCtx,
    chaos: &mut Option<ChaosState>,
    shard: &mut Option<ShardState>,
    exclude: Option<usize>,
    tr: Tr<'_, '_>,
    why: DispatchWhy,
) -> Option<usize> {
    let req = payload >> 1;
    let hint = hc.hints[req] as usize;
    // Shard constraint: deactivate every active device that does not
    // host the copy's serving expert around the pick (the same masking
    // idiom as the hedge exclude below; expert-dropped copies carry no
    // constraint). An empty candidate set is the no-replica outcome.
    let mut shard_masked = false;
    if let Some(sh) = shard.as_mut() {
        let eff = sh.expert[req];
        if eff != u32::MAX {
            sh.masked.clear();
            for d in 0..devices.len() {
                if loads.is_active(d) && !devices[d].hosts(eff) {
                    loads.deactivate(d);
                    sh.masked.push(d);
                }
            }
            shard_masked = true;
            if loads.active_count() == 0 {
                for &d in sh.masked.iter() {
                    loads.activate(d);
                }
                sh.undeliverable.push((payload, now));
                emit(tr, now, || TraceRecord::NoReplica {
                    req: req as u64,
                    expert: eff as u64,
                });
                return None;
            }
        }
    }
    // Hedge exclude, evaluated against the shard-constrained set: a
    // hedge copy avoids its primary device only when another candidate
    // exists.
    let masked = exclude.filter(|&x| loads.is_active(x) && loads.active_count() > 1);
    if let Some(x) = masked {
        loads.deactivate(x);
    }
    let picked = dispatcher.try_pick_indexed(loads, hint);
    if let Some(x) = masked {
        loads.activate(x);
    }
    if shard_masked {
        let sh = shard.as_mut().expect("shard mask without shard state");
        for &d in sh.masked.iter() {
            loads.activate(d);
        }
    }
    match picked {
        Some(d) => {
            loads.add(d, 1);
            devices[d].batcher.push(payload);
            emit(tr, now, || TraceRecord::Dispatch {
                req: req as u64,
                hedge: payload & 1 == 1,
                why,
                device: d as i64,
                load: loads.get(d) as u64,
            });
            // Interconnect charge, (re)computed for the primary copy:
            // each routed expert the landing device does not host is
            // one weight fetch over the interconnect, added to the
            // winning completion's e2e.
            if payload & 1 == 0 {
                if let Some(sh) = shard.as_mut() {
                    let eff = sh.expert[req];
                    if eff != u32::MAX {
                        let k = sh.sc.top_k;
                        let mut remote = 0u32;
                        if sh.primary[req] != eff && !devices[d].hosts(sh.primary[req]) {
                            remote += 1;
                        }
                        for s in 0..k - 1 {
                            let e = sh.secondaries[req * (k - 1) + s];
                            if e != eff && !devices[d].hosts(e) {
                                remote += 1;
                            }
                        }
                        sh.remote[req] = remote;
                        let xns =
                            remote as u64 * sh.sc.transfer_cost.as_nanos() as u64;
                        sh.xfer_ns[req] = xns;
                        if remote > 0 {
                            emit(tr, now, || TraceRecord::Xfer {
                                req: req as u64,
                                device: d as u64,
                                remote: remote as u64,
                                xfer_ns: xns,
                            });
                        }
                    }
                }
            }
            try_start(&mut devices[d], &models[d], q, now, d, hc, tr);
            if payload & 1 == 0 {
                if let Some(ch) = chaos.as_mut() {
                    ch.primary_dev[req] = d as u32;
                }
            }
            Some(d)
        }
        None => {
            let ch = chaos
                .as_mut()
                .expect("dispatch over a fleet with no active device");
            ch.pending.push(payload);
            if payload & 1 == 0 {
                ch.primary_dev[req] = u32::MAX;
            }
            emit(tr, now, || TraceRecord::Dispatch {
                req: req as u64,
                hedge: payload & 1 == 1,
                why,
                device: -1,
                load: 0,
            });
            None
        }
    }
}

/// Run the fleet simulation to completion (horizon + drain). Every
/// admitted request settles exactly once — completed, or (only with a
/// deadline configured) dropped after its attempt budget — asserted,
/// and checked again by the conservation proptests (across autoscale
/// and fault events too).
pub fn simulate_fleet(cfg: &ServeConfig) -> FleetReport {
    simulate_fleet_observed(cfg, Observer::none())
}

/// [`simulate_fleet`] with an observation hookup: every consequential
/// event goes to `obs.trace` (when present) as a typed
/// [`TraceRecord`], and [`ServeConfig::sampler`] drives windowed
/// gauges into `obs.series` (when present). Observation never feeds
/// back into the simulation: the returned report is bit-identical to
/// the unobserved run (proptested in `tests/serve_properties.rs`).
pub fn simulate_fleet_observed(cfg: &ServeConfig, obs: Observer<'_>) -> FleetReport {
    let Observer { mut trace, mut series } = obs;
    assert!(!cfg.devices.is_empty(), "empty fleet");
    assert!(
        !cfg.horizon.is_zero(),
        "zero-horizon ServeConfig: offered load is undefined (horizon must be positive)"
    );
    if let Err(e) = cfg.validate() {
        panic!("invalid ServeConfig: {e}");
    }
    let (closed, users, think_time) = match cfg.workload {
        Workload::ClosedLoop { users, think_time } => {
            assert!(users > 0, "closed-loop workload needs at least one user");
            (true, users, think_time)
        }
        _ => (false, 0, Duration::ZERO),
    };

    // Request-indexed state. Open loop: the precomputed schedule is
    // streamed below AND doubles as the arrival-time lookup; closed
    // loop: grown live as users issue requests.
    let mut arrival_times: Vec<Duration> = if closed {
        Vec::new()
    } else {
        cfg.workload
            .arrivals(cfg.horizon, cfg.seed)
            .expect("open-loop workloads always have a precomputable schedule")
    };
    // A request is *settled* once its fate is sealed: completed, or
    // dropped at the attempt budget. Late zombie copies (hedge losers,
    // post-drop retries) find the flag set and are discarded.
    let mut settled = vec![false; arrival_times.len()];

    // Dominant-expert hint per request (a gate-profile proxy; the
    // runtime would take this from the previous frame's routing).
    // Open-loop hints come from a dedicated stream; closed-loop hints
    // are drawn from the issuing user's stream at issuance time.
    let mut hint_rng = Rng::new(cfg.seed ^ 0xA551_6E0E);
    let mut hint_ctx = HintCtx {
        hints: arrival_times
            .iter()
            .map(|_| if cfg.num_experts > 0 { hint_rng.below(cfg.num_experts) as u32 } else { 0 })
            .collect(),
        enabled: cfg.num_experts > 0,
        scratch: Vec::new(),
    };

    // Closed-loop users: independent per-user RNG streams (think times
    // + hints), seeded off the config seed, so user u's k-th draw does
    // not depend on how the fleet interleaved other users.
    let mut user_rng: Vec<Rng> = if closed {
        let mut sm = SplitMix64::new(cfg.seed ^ 0xC105_ED10);
        (0..users).map(|_| Rng::new(sm.next_u64())).collect()
    } else {
        Vec::new()
    };
    // Issuing user of each closed-loop request.
    let mut req_user: Vec<u32> = Vec::new();

    let clock = VirtualClock::new();
    // Owned (not borrowed from cfg): the autoscaling controller grows
    // the fleet mid-run.
    let mut models: Vec<DeviceModel> = cfg.devices.clone();
    let mut devices: Vec<DeviceState> =
        models.iter().map(|m| DeviceState::new(m, cfg.max_wait, clock.clone())).collect();
    let mut slots: Vec<Slot> = vec![Slot::Serving; models.len()];
    let mut spans: Vec<ActiveSpan> = (0..models.len())
        .map(|slot| ActiveSpan { slot, from: Duration::ZERO, to: None })
        .collect();

    // No experts ⇒ no affinity to exploit: fall back to JSQ rather
    // than pinning every request's zero hint to device 0.
    let policy = if cfg.num_experts == 0 && cfg.dispatch == DispatchPolicy::ExpertAffinity {
        DispatchPolicy::JoinShortestQueue
    } else {
        cfg.dispatch
    };
    let mut dispatcher = if policy == DispatchPolicy::WeightedRoundRobin {
        let periods: Vec<Duration> = models.iter().map(|m| m.period()).collect();
        Dispatcher::weighted_by_period(&periods)
    } else {
        Dispatcher::new(policy)
    };
    let mut q = EventQueue::new();
    // Incremental load signal: +1 on dispatch, −occupancy on batch
    // completion (a batch start moves requests queue → flight, net 0).
    // Shortest-expected-delay re-keys the same tournament tree from
    // queue length to expected-completion ns derived from each
    // device's own service LUT — mixed-fleet dispatch stays O(log n)
    // per arrival while becoming capacity-aware.
    let sed = policy == DispatchPolicy::ShortestExpectedDelay;
    let mut loads = if sed {
        LoadTracker::with_expected_delay(
            models.iter().map(|d| d.expected_delay_weights()).collect(),
        )
    } else {
        LoadTracker::new(devices.len())
    };

    // Autoscaling: seed the first controller tick (none if the window
    // does not fit inside the horizon — the run is then effectively
    // static).
    let mut scale: Option<ScaleState> = cfg.autoscale.clone().map(|ac| {
        assert!(
            (ac.min_devices..=ac.max_devices).contains(&cfg.devices.len()),
            "initial fleet size outside the autoscale [min, max] bounds"
        );
        let n0 = cfg.devices.len();
        ScaleState {
            ctl: Controller::new(ac),
            window_e2e: LatencyStats::default(),
            window_arrivals: 0,
            summary: AutoscaleSummary {
                peak_active: n0,
                min_active: n0,
                final_active: n0,
                ..Default::default()
            },
        }
    });
    if let Some(sc) = &scale {
        let first = sc.ctl.config().window;
        if first < cfg.horizon {
            q.push(first, EventKind::ScaleTick);
        }
    }

    // Fault injection: normalize the effective outage plan (scripted
    // ∪ seeded-stochastic MTBF/MTTR), validate it against the initial
    // fleet, and schedule every fail/repair pair up front. An inert
    // config is discarded entirely, so the run is event-for-event
    // identical to `faults: None`.
    let fc = cfg.faults.as_ref().filter(|f| !f.is_inert());
    let plan: FaultPlan = match fc {
        None => FaultPlan::empty(),
        Some(f) => {
            assert!(f.max_attempts >= 1, "attempt budget must allow the first attempt");
            assert!(
                (0.0..1.0).contains(&f.seu_per_batch),
                "SEU probability must be in [0, 1), got {}",
                f.seu_per_batch
            );
            let mut plan = f.plan.clone();
            if let Some(mtbf) = f.mtbf {
                plan = plan.merged(&FaultPlan::stochastic(
                    cfg.devices.len(),
                    mtbf,
                    f.mttr,
                    cfg.horizon,
                    cfg.seed ^ 0xFA11_5EED,
                ));
            }
            if let Some(d) = plan.max_device() {
                assert!(
                    d < cfg.devices.len(),
                    "fault plan targets device {d} beyond the initial fleet of {}",
                    cfg.devices.len()
                );
            }
            plan
        }
    };
    let mut chaos: Option<ChaosState> = fc.map(|f| ChaosState {
        fc: f.clone(),
        attempts: Vec::with_capacity(arrival_times.len()),
        hedged: Vec::with_capacity(arrival_times.len()),
        primary_dev: Vec::with_capacity(arrival_times.len()),
        pending: Vec::new(),
        seu_rng: Rng::new(cfg.seed ^ 0x5E00_0BAD),
        summary: FaultSummary::default(),
    });
    if !plan.is_empty() {
        // Chronological push order keeps the heap's tie-break sequence
        // a pure function of the plan.
        let mut sched: Vec<FaultSpan> = plan.spans().to_vec();
        sched.sort_by_key(|s| (s.from, s.device));
        for s in &sched {
            q.push(s.from, EventKind::DeviceFail { device: s.device as u32 });
            q.push(s.to, EventKind::DeviceRepair { device: s.device as u32 });
        }
    }

    // Overload protection ([`overload`]): classification + admission
    // at the arrival edge, per-device circuit breakers, brownout
    // degradation. An inert config is discarded entirely — the run is
    // draw-for-draw identical to `overload: None` (proptested),
    // including the class stream, which only inert-free runs create.
    let mut overload: Option<OverloadState> = cfg
        .overload
        .as_ref()
        .filter(|o| !o.is_inert())
        .map(|o| {
            if o.shadow {
                assert!(
                    o.admission.is_none() && o.breaker.is_none() && o.brownout.is_none(),
                    "shadow mode is observation-only: drop the enforcement knobs"
                );
            }
            let mut buckets: [Option<TokenBucket>; NUM_CLASSES] = [None, None, None];
            if let Some(ac) = &o.admission {
                assert!(ac.burst >= 1.0, "admission burst must hold at least one token");
                for (c, cap) in ac.rate_caps.iter().enumerate() {
                    buckets[c] = cap.map(|r| TokenBucket::new(r, ac.burst));
                }
                for b in ac.attempt_budget.iter().flatten() {
                    assert!(*b >= 1, "attempt budgets must allow the first attempt");
                }
            }
            if let Some(bc) = &o.breaker {
                bc.validate();
                assert!(
                    cfg.faults.as_ref().is_some_and(|f| f.deadline.is_some()),
                    "circuit breakers feed on attempt timeouts: \
                     configure FaultConfig::deadline"
                );
            }
            if let Some(bc) = &o.brownout {
                // Brownout + autoscale was rejected by cfg.validate()
                // above (ServeConfigError::BrownoutWithAutoscale).
                bc.validate(&cfg.devices);
            }
            OverloadState {
                class: Vec::with_capacity(arrival_times.len()),
                class_rng: Rng::new(cfg.seed ^ 0xC1A5_55E5),
                buckets,
                breakers: vec![Breaker::new(); cfg.devices.len()],
                brownout: o.brownout.as_ref().map(|_| BrownoutWindows {
                    ctl: BrownoutController::new(),
                    window_completions: 0,
                    window_met: 0,
                    window_rejects: 0,
                    full: cfg.devices.clone(),
                }),
                summary: OverloadSummary::default(),
                oc: o.clone(),
            }
        });
    if let Some(ov) = &overload {
        if let Some(bc) = &ov.oc.brownout {
            // Same cadence contract as ScaleTick: no ticks past the
            // horizon (the drain has nothing left to protect).
            if bc.window < cfg.horizon {
                q.push(bc.window, EventKind::BrownoutTick);
            }
        }
    }

    // Expert sharding ([`shard`]): seeded top-k router, deterministic
    // initial placement synced into each device's hosted set, and the
    // rebalancing controller's first tick. An inert config is
    // discarded entirely — the run is draw-for-draw identical to
    // `shard: None` (proptested), including the router stream, which
    // only inert-free runs create. Bounds were checked by
    // cfg.validate() above.
    let mut shard: Option<ShardState> = cfg
        .shard
        .as_ref()
        .filter(|s| !s.is_inert())
        .map(|s| ShardState {
            pop: Popularity::new(cfg.num_experts, s.zipf_s, s.drift.as_ref()),
            rng: Rng::new(cfg.seed ^ 0x5AA4_D0E5),
            replicas: shard::initial_placement(
                cfg.num_experts,
                cfg.devices.len(),
                s.replication,
                s.hot_experts,
            ),
            expert: Vec::with_capacity(arrival_times.len()),
            primary: Vec::with_capacity(arrival_times.len()),
            secondaries: Vec::new(),
            xfer_ns: Vec::with_capacity(arrival_times.len()),
            remote: Vec::with_capacity(arrival_times.len()),
            cap_window: vec![(0, 0); cfg.num_experts],
            window_counts: vec![0; cfg.num_experts],
            masked: Vec::new(),
            undeliverable: Vec::new(),
            summary: ShardSummary::default(),
            sc: s.clone(),
        });
    if let Some(sh) = &shard {
        for (e, hs) in sh.replicas.iter().enumerate() {
            for &d in hs {
                devices[d as usize].host(e as u32, cfg.num_experts);
            }
        }
        if let Some(rb) = &sh.sc.rebalance {
            if rb.every < cfg.horizon {
                q.push(rb.every, EventKind::RebalanceTick);
            }
        }
    }

    // Closed-loop: every user thinks once, then issues its first
    // request (zero think time ⇒ everyone fires at t = 0).
    for u in 0..users {
        let gap = think_gap(&mut user_rng[u], think_time);
        q.push(gap, EventKind::UserThink { user: u as u32 });
    }

    // Observability. The trace opens with a self-describing meta
    // record; the sampler (active only when both the config knob and
    // a collector are present) schedules its first tick *after* every
    // other initial push, so the relative insertion order — and hence
    // tie-breaking — of all non-sampler events is exactly the
    // unobserved run's.
    emit(&mut trace, Duration::ZERO, || TraceRecord::Meta {
        devices: cfg.devices.len() as u64,
        horizon_ns: cfg.horizon.as_nanos() as u64,
        seed: cfg.seed,
        policy: policy.name(),
        experts: cfg.num_experts as u64,
        max_wait_ns: cfg.max_wait.as_nanos() as u64,
    });
    let mut sampler: Option<SamplerState> = match (&cfg.sampler, &series) {
        (Some(sc), Some(_)) => {
            assert!(!sc.every.is_zero(), "sampler cadence must be positive");
            Some(SamplerState {
                every: sc.every,
                slo: sc.slo,
                scheduled: true,
                ticks: 0,
                window_e2e: LatencyStats::default(),
                window_done_fleet: 0,
                window_done_dev: vec![0; models.len()],
                prev_busy: vec![Duration::ZERO; models.len()],
            })
        }
        _ => None,
    };
    if let Some(sp) = &sampler {
        q.push(sp.every, EventKind::SampleTick);
    }

    let mut next_arrival = 0usize;
    // Settled requests so far — the sampler's keep-ticking signal
    // (cheap enough to track unconditionally; not part of the report).
    let mut settled_count: u64 = 0;
    let mut makespan = Duration::ZERO;
    let mut events: u64 = 0;
    let mut peak_events: u64 = 0;

    loop {
        // Merge the sorted open-loop arrival stream with the heap;
        // arrivals win ties (they carried the lowest sequence numbers
        // when they were preloaded, and still must fire first at equal
        // times). Closed-loop arrivals live *in* the heap as UserThink
        // events, so the stream head is empty there.
        let stream_head =
            if closed { None } else { arrival_times.get(next_arrival).copied() };
        let take_arrival = match (stream_head, q.next_at()) {
            (Some(t), Some(h)) => t <= h,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => break,
        };
        if take_arrival {
            let req = next_arrival;
            let at = arrival_times[req];
            next_arrival += 1;
            clock.advance_to(at);
            debug_assert!(
                devices.iter().enumerate().all(|(i, d)| loads.get(i) == d.load()),
                "load tracker drifted from device state"
            );
            if let Some(sc) = &mut scale {
                sc.window_arrivals += 1;
            }
            if let Some(ch) = &mut chaos {
                ch.attempts.push(1);
                ch.hedged.push(false);
                ch.primary_dev.push(u32::MAX);
            }
            // Route *every* arrival before the admission edge: the
            // router draw, `routed` count and window tally happen even
            // for requests the edge rejects, so `routed == admitted`
            // and the RNG stream is independent of overload verdicts.
            if let Some(sh) = &mut shard {
                route_arrival(sh, at);
            }
            emit(&mut trace, at, || TraceRecord::Arrival {
                req: req as u64,
                hint: hint_ctx.hints[req] as u64,
            });
            // Admission edge: a rejected request settles immediately
            // (the `rejected` leg of conservation) and never touches
            // the dispatch path, the deadline watcher or the hedge
            // timer.
            let rejected = match &mut overload {
                Some(ov) => {
                    let (class, verdict) = admission_edge(ov, at, &loads, devices.len());
                    if let Some(why) = verdict {
                        settled[req] = true;
                        settled_count += 1;
                        emit(&mut trace, at, || TraceRecord::Reject {
                            req: req as u64,
                            class: class as u64,
                            why: why.label(),
                        });
                    }
                    verdict.is_some()
                }
                None => false,
            };
            if !rejected {
                // Capacity resolution only for admitted requests: an
                // expert's window tokens are spent on work that will
                // actually dispatch.
                if let Some(sh) = &mut shard {
                    resolve_capacity(sh, at, req, &mut hint_ctx.hints, &mut trace);
                }
                dispatch_copy(
                    req << 1,
                    at,
                    &mut dispatcher,
                    &mut loads,
                    &mut devices,
                    &models,
                    &mut q,
                    &mut hint_ctx,
                    &mut chaos,
                    &mut shard,
                    None,
                    &mut trace,
                    DispatchWhy::Arrive,
                );
                if let Some(ch) = &chaos {
                    if let Some(dl) = ch.fc.deadline {
                        q.push(
                            at + dl,
                            EventKind::AttemptTimeout { req: req as u32, attempt: 1 },
                        );
                    }
                    if let Some(hd) = ch.fc.hedge_delay {
                        q.push(at + hd, EventKind::HedgeDispatch { req: req as u32 });
                    }
                }
            }
        } else {
            let ev = q.pop().expect("heap event vanished between peek and pop");
            let now = ev.at();
            clock.advance_to(now);
            match ev.kind {
                EventKind::Arrival { .. } => {
                    unreachable!("arrivals stream outside the heap")
                }
                EventKind::UserThink { user } => {
                    // A user's think time expired. Issue the next
                    // request if the horizon is still open; otherwise
                    // the user retires.
                    if now < cfg.horizon {
                        let req = arrival_times.len();
                        arrival_times.push(now);
                        let u = user as usize;
                        let h = if cfg.num_experts > 0 {
                            user_rng[u].below(cfg.num_experts) as u32
                        } else {
                            0
                        };
                        hint_ctx.hints.push(h);
                        req_user.push(user);
                        settled.push(false);
                        if let Some(sc) = &mut scale {
                            sc.window_arrivals += 1;
                        }
                        if let Some(ch) = &mut chaos {
                            ch.attempts.push(1);
                            ch.hedged.push(false);
                            ch.primary_dev.push(u32::MAX);
                        }
                        // Same contract as the open-loop site: route
                        // before the admission edge so routed ==
                        // admitted holds for closed loops too.
                        if let Some(sh) = &mut shard {
                            route_arrival(sh, now);
                        }
                        emit(&mut trace, now, || TraceRecord::Arrival {
                            req: req as u64,
                            hint: h as u64,
                        });
                        // Admission edge, closed-loop flavour: a
                        // rejected user's request settles here and the
                        // user goes back to thinking — rejection is
                        // fast feedback, not a hang.
                        let rejected = match &mut overload {
                            Some(ov) => {
                                let (class, verdict) =
                                    admission_edge(ov, now, &loads, devices.len());
                                if let Some(why) = verdict {
                                    settled[req] = true;
                                    settled_count += 1;
                                    emit(&mut trace, now, || TraceRecord::Reject {
                                        req: req as u64,
                                        class: class as u64,
                                        why: why.label(),
                                    });
                                }
                                verdict.is_some()
                            }
                            None => false,
                        };
                        if rejected {
                            let u = user as usize;
                            let gap = think_gap(&mut user_rng[u], think_time);
                            q.push(now + gap, EventKind::UserThink { user });
                        } else {
                            if let Some(sh) = &mut shard {
                                resolve_capacity(
                                    sh,
                                    now,
                                    req,
                                    &mut hint_ctx.hints,
                                    &mut trace,
                                );
                            }
                            dispatch_copy(
                                req << 1,
                                now,
                                &mut dispatcher,
                                &mut loads,
                                &mut devices,
                                &models,
                                &mut q,
                                &mut hint_ctx,
                                &mut chaos,
                                &mut shard,
                                None,
                                &mut trace,
                                DispatchWhy::Arrive,
                            );
                            if let Some(ch) = &chaos {
                                if let Some(dl) = ch.fc.deadline {
                                    q.push(
                                        now + dl,
                                        EventKind::AttemptTimeout {
                                            req: req as u32,
                                            attempt: 1,
                                        },
                                    );
                                }
                                if let Some(hd) = ch.fc.hedge_delay {
                                    q.push(
                                        now + hd,
                                        EventKind::HedgeDispatch { req: req as u32 },
                                    );
                                }
                            }
                        }
                    }
                }
                EventKind::FlushDeadline { device, gen } => {
                    let device = device as usize;
                    // Generation mismatch ⇒ this deadline was
                    // superseded: cancelled, skip.
                    if devices[device].deadline.map(|(_, g)| g) == Some(gen) {
                        devices[device].deadline = None;
                        emit(&mut trace, now, || TraceRecord::Flush { device: device as u64 });
                        try_start(
                            &mut devices[device],
                            &models[device],
                            &mut q,
                            now,
                            device,
                            &mut hint_ctx,
                            &mut trace,
                        );
                    }
                }
                EventKind::BatchDone { device, gen } => {
                    let device = device as usize;
                    let live =
                        devices[device].in_flight.as_ref().map(|f| f.gen) == Some(gen);
                    // SEU draw for every live completion when the knob
                    // is on — one stream read per batch, so the event
                    // interleaving cannot perturb the sequence.
                    let corrupted = live
                        && match &mut chaos {
                            Some(ch) if ch.fc.seu_per_batch > 0.0 => {
                                ch.seu_rng.chance(ch.fc.seu_per_batch)
                            }
                            _ => false,
                        };
                    if !live {
                        // The batch was lost to a device failure; its
                        // completion pops with a cancelled generation.
                        debug_assert!(
                            chaos.is_some(),
                            "stale BatchDone without fault injection"
                        );
                    } else if corrupted {
                        // SEU: the batch burned its cycles but the
                        // result is garbage — charge the work and
                        // re-execute in place (the dominant expert is
                        // resident now, so the rerun takes the hit-path
                        // service time when hints are enabled).
                        let st = &mut devices[device];
                        let inf = st.in_flight.as_mut().expect("live batch vanished");
                        st.metrics.batches += 1;
                        st.metrics.slots += inf.batch.batch_size as u64;
                        st.metrics.padded_slots += inf.batch.padding as u64;
                        st.metrics.busy += now - inf.started;
                        let service = if hint_ctx.enabled {
                            models[device].service_time_with_residency(inf.batch.batch_size, true)
                        } else {
                            models[device].service_time(inf.batch.batch_size)
                        };
                        inf.started = now;
                        q.push(
                            now + service,
                            EventKind::BatchDone { device: device as u32, gen },
                        );
                        emit(&mut trace, now, || TraceRecord::SeuRerun {
                            device: device as u64,
                            service_ns: service.as_nanos() as u64,
                        });
                        chaos
                            .as_mut()
                            .expect("SEU rerun requires fault injection")
                            .summary
                            .seu_reruns += 1;
                    } else {
                        let st = &mut devices[device];
                        let inf =
                            st.in_flight.take().expect("BatchDone without a batch in flight");
                        makespan = makespan.max(now);
                        st.metrics.batches += 1;
                        st.metrics.slots += inf.batch.batch_size as u64;
                        st.metrics.padded_slots += inf.batch.padding as u64;
                        st.metrics.busy += now - inf.started;
                        loads.sub(device, inf.batch.requests.len());
                        // The done-list carries only the copies that
                        // will actually settle here, so a span's
                        // completion is attributable to exactly one
                        // batch (zombies excluded).
                        emit(&mut trace, now, || TraceRecord::BatchDone {
                            device: device as u64,
                            size: inf.batch.batch_size as u64,
                            padding: inf.batch.padding as u64,
                            service_ns: (now - inf.started).as_nanos() as u64,
                            done: inf
                                .batch
                                .requests
                                .iter()
                                .filter(|r| !settled[r.payload >> 1])
                                .map(|r| (r.payload >> 1) as u64)
                                .collect(),
                        });
                        for r in &inf.batch.requests {
                            let req = r.payload >> 1;
                            if settled[req] {
                                // Zombie copy: the request already won
                                // elsewhere (retry/hedge) or was
                                // dropped. Real cycles, no credit.
                                assert!(
                                    chaos.is_some(),
                                    "request {req} completed twice without fault injection"
                                );
                                continue;
                            }
                            settled[req] = true;
                            settled_count += 1;
                            st.metrics.completed += 1;
                            // enqueued == arrival on the first
                            // dispatch; later for failover / retry /
                            // hedge copies (requeue time).
                            debug_assert!(r.enqueued >= arrival_times[req]);
                            let mut e2e = now - arrival_times[req];
                            // Interconnect transfers for non-local
                            // experts are charged once, at the winning
                            // completion (the dispatch that placed this
                            // copy recorded them; losers charge
                            // nothing).
                            if let Some(sh) = &mut shard {
                                e2e += Duration::from_nanos(sh.xfer_ns[req]);
                                sh.summary.transfers += sh.remote[req] as u64;
                                sh.summary.transfer_ns += sh.xfer_ns[req];
                                if sh.expert[req] == u32::MAX {
                                    sh.summary.degraded_completions += 1;
                                }
                            }
                            st.metrics.queue_wait.record(inf.started - r.enqueued);
                            st.metrics.service.record(now - inf.started);
                            st.metrics.e2e.record(e2e);
                            if let Some(sc) = &mut scale {
                                sc.window_e2e.record(e2e);
                            }
                            if let Some(ov) = &mut overload {
                                let c = ov.class[req] as usize;
                                ov.summary.completed_by_class[c] += 1;
                                ov.summary.e2e_by_class[c].record(e2e);
                                if let Some(bw) = &mut ov.brownout {
                                    bw.window_completions += 1;
                                    let slo = ov
                                        .oc
                                        .brownout
                                        .as_ref()
                                        .expect("brownout windows without a config")
                                        .slo;
                                    if e2e <= slo {
                                        bw.window_met += 1;
                                    }
                                    if bw.ctl.degraded() {
                                        ov.summary.degraded_completions += 1;
                                    }
                                }
                            }
                            if let Some(sp) = &mut sampler {
                                sp.window_e2e.record(e2e);
                                sp.window_done_fleet += 1;
                                if device >= sp.window_done_dev.len() {
                                    sp.window_done_dev.resize(device + 1, 0);
                                }
                                sp.window_done_dev[device] += 1;
                            }
                            emit(&mut trace, now, || TraceRecord::Done {
                                req: req as u64,
                                device: device as u64,
                                e2e_ns: e2e.as_nanos() as u64,
                                queue_ns: (inf.started - r.enqueued).as_nanos() as u64,
                                service_ns: (now - inf.started).as_nanos() as u64,
                                hedge: r.payload & 1 == 1,
                            });
                            if r.payload & 1 == 1 {
                                chaos
                                    .as_mut()
                                    .expect("hedged copy requires fault injection")
                                    .summary
                                    .hedge_wins += 1;
                            }
                            if closed {
                                // The issuing user starts thinking; its
                                // next request arrives after the draw
                                // (or it retires at the horizon check
                                // above).
                                let u = req_user[req] as usize;
                                let gap = think_gap(&mut user_rng[u], think_time);
                                q.push(now + gap, EventKind::UserThink { user: req_user[req] });
                            }
                        }
                        // A completed batch is success evidence for
                        // the device's breaker: it resets the timeout
                        // streak, and a half-open probe period ends
                        // (close) on its first completion.
                        if let Some(ov) = &mut overload {
                            if ov.oc.breaker.is_some()
                                && !ov.oc.shadow
                                && device < ov.breakers.len()
                                && ov.breakers[device].on_success()
                            {
                                ov.summary.breaker_closes += 1;
                                emit(&mut trace, now, || TraceRecord::BreakerClose {
                                    device: device as u64,
                                });
                            }
                        }
                        try_start(
                            &mut devices[device],
                            &models[device],
                            &mut q,
                            now,
                            device,
                            &mut hint_ctx,
                            &mut trace,
                        );
                        // Drain-before-remove: a draining device
                        // retires the moment it runs dry.
                        if slots[device] == Slot::Draining
                            && devices[device].in_flight.is_none()
                            && devices[device].batcher.pending() == 0
                        {
                            slots[device] = Slot::Retired;
                            close_span(&mut spans, device, now);
                            emit(&mut trace, now, || TraceRecord::Retire {
                                slot: device as u64,
                            });
                        }
                    }
                }
                EventKind::DeviceFail { device } => {
                    let d = device as usize;
                    // Spawned replicas (index ≥ initial fleet) never
                    // appear in a validated plan; a Retired slot has
                    // nothing to lose and stays retired (its scheduled
                    // span still counts as downtime in the summary).
                    if matches!(slots[d], Slot::Serving | Slot::Draining) {
                        slots[d] = Slot::Failed;
                        loads.deactivate(d);
                        // A hard failure supersedes the breaker: reset
                        // it (invalidating any in-flight probe) so the
                        // repaired device comes back unmasked.
                        if let Some(ov) = &mut overload {
                            if d < ov.breakers.len() {
                                ov.breakers[d].reset();
                            }
                        }
                        let st = &mut devices[d];
                        // A live flush deadline dies with the queue,
                        // and on-chip expert weights do not survive
                        // the repair reconfiguration.
                        st.deadline = None;
                        st.resident_expert = None;
                        let mut orphans: Vec<usize> = Vec::new();
                        let mut lost_batch = false;
                        if let Some(inf) = st.in_flight.take() {
                            // The batch in service is lost mid-flight:
                            // its BatchDone is cancelled by generation
                            // and the burned cycles are charged as
                            // wasted service.
                            st.metrics.busy += now - inf.started;
                            let ch = chaos
                                .as_mut()
                                .expect("DeviceFail requires fault injection");
                            ch.summary.lost_batches += 1;
                            ch.summary.wasted_service += now - inf.started;
                            orphans.extend(inf.batch.requests.iter().map(|r| r.payload));
                            lost_batch = true;
                        }
                        orphans.extend(
                            st.batcher.take_pending().into_iter().map(|r| r.payload),
                        );
                        loads.set(d, 0);
                        let live =
                            orphans.iter().filter(|&&p| !settled[p >> 1]).count() as u64;
                        let ch =
                            chaos.as_mut().expect("DeviceFail requires fault injection");
                        ch.summary.device_failures += 1;
                        ch.summary.failovers += live;
                        emit(&mut trace, now, || TraceRecord::DeviceFail {
                            device: d as u64,
                            lost_batch,
                            orphans: live,
                        });
                        // Failover: every still-live copy re-enters
                        // dispatch; settled zombies are discarded.
                        for p in orphans {
                            if settled[p >> 1] {
                                continue;
                            }
                            dispatch_copy(
                                p,
                                now,
                                &mut dispatcher,
                                &mut loads,
                                &mut devices,
                                &models,
                                &mut q,
                                &mut hint_ctx,
                                &mut chaos,
                                &mut shard,
                                None,
                                &mut trace,
                                DispatchWhy::Failover,
                            );
                        }
                    }
                }
                EventKind::DeviceRepair { device } => {
                    let d = device as usize;
                    if slots[d] == Slot::Failed {
                        // Back to serving — a failed Draining slot
                        // also returns here; the controller re-drains
                        // any surplus at its next tick.
                        slots[d] = Slot::Serving;
                        loads.activate(d);
                        // The total-outage parking lot drains through
                        // the normal dispatch path now that capacity
                        // is back.
                        let parked = std::mem::take(
                            &mut chaos
                                .as_mut()
                                .expect("DeviceRepair requires fault injection")
                                .pending,
                        );
                        emit(&mut trace, now, || TraceRecord::DeviceRepair {
                            device: d as u64,
                            parked: parked.iter().filter(|&&p| !settled[p >> 1]).count()
                                as u64,
                        });
                        for p in parked {
                            if settled[p >> 1] {
                                continue;
                            }
                            dispatch_copy(
                                p,
                                now,
                                &mut dispatcher,
                                &mut loads,
                                &mut devices,
                                &models,
                                &mut q,
                                &mut hint_ctx,
                                &mut chaos,
                                &mut shard,
                                None,
                                &mut trace,
                                DispatchWhy::Parked,
                            );
                        }
                    }
                }
                EventKind::AttemptTimeout { req, attempt } => {
                    let req = req as usize;
                    let ch =
                        chaos.as_mut().expect("AttemptTimeout requires fault injection");
                    // Stale if the request settled or a newer attempt
                    // superseded this watcher.
                    if !settled[req] && ch.attempts[req] == attempt {
                        emit(&mut trace, now, || TraceRecord::AttemptTimeout {
                            req: req as u64,
                            attempt: attempt as u64,
                        });
                        // A live timeout is failure evidence for the
                        // primary device's breaker. Tripping masks the
                        // device out of dispatch (its queued work
                        // continues) and schedules a half-open probe —
                        // never on the last active device: masking it
                        // would park the whole fleet on demand, which
                        // is the outage the breaker exists to avoid.
                        if let Some(ov) = &mut overload {
                            if let Some(bc) = &ov.oc.breaker {
                                let pd = ch.primary_dev[req];
                                if !ov.oc.shadow && pd != u32::MAX {
                                    let d = pd as usize;
                                    if slots[d] == Slot::Serving
                                        && loads.is_active(d)
                                        && loads.active_count() > 1
                                    {
                                        if d >= ov.breakers.len() {
                                            ov.breakers.resize_with(d + 1, Breaker::new);
                                        }
                                        let streak = ov.breakers[d].streak() + 1;
                                        if ov.breakers[d].on_failure(bc.trip_after) {
                                            ov.summary.breaker_trips += 1;
                                            loads.deactivate(d);
                                            q.push(
                                                now + bc.cooldown,
                                                EventKind::BreakerProbe {
                                                    device: pd,
                                                    gen: ov.breakers[d].gen(),
                                                },
                                            );
                                            emit(&mut trace, now, || {
                                                TraceRecord::BreakerTrip {
                                                    device: d as u64,
                                                    streak: streak as u64,
                                                }
                                            });
                                        }
                                    }
                                }
                            }
                        }
                        // Per-class retry budgets shed low-priority
                        // retries first: class c gets
                        // min(max_attempts, attempt_budget[c]).
                        let budget = match &overload {
                            Some(ov) if !ov.oc.shadow => ov
                                .oc
                                .admission
                                .as_ref()
                                .and_then(|a| a.attempt_budget[ov.class[req] as usize])
                                .map_or(ch.fc.max_attempts, |b| b.min(ch.fc.max_attempts)),
                            _ => ch.fc.max_attempts,
                        };
                        if attempt >= budget {
                            // Budget exhausted: drop — counted, never
                            // silently lost. Late copies still in some
                            // queue become zombies.
                            settled[req] = true;
                            settled_count += 1;
                            ch.summary.dropped += 1;
                            emit(&mut trace, now, || TraceRecord::Drop {
                                req: req as u64,
                                attempts: attempt as u64,
                            });
                            if closed {
                                // The user's request failed; they
                                // think, then try something else.
                                let u = req_user[req] as usize;
                                let gap = think_gap(&mut user_rng[u], think_time);
                                q.push(
                                    now + gap,
                                    EventKind::UserThink { user: req_user[req] },
                                );
                            }
                        } else {
                            // Capped exponential backoff before the
                            // next attempt.
                            let shift = (attempt - 1).min(32);
                            let backoff_ns = (ch.fc.backoff_base.as_nanos() as u64)
                                .saturating_mul(1u64 << shift)
                                .min(ch.fc.backoff_cap.as_nanos() as u64);
                            emit(&mut trace, now, || TraceRecord::Retry {
                                req: req as u64,
                                attempt: attempt as u64,
                                backoff_ns,
                            });
                            q.push(
                                now + Duration::from_nanos(backoff_ns),
                                EventKind::RetryDispatch { req: req as u32 },
                            );
                        }
                    }
                }
                EventKind::RetryDispatch { req } => {
                    let req = req as usize;
                    if !settled[req] {
                        let (deadline, attempt) = {
                            let ch = chaos
                                .as_mut()
                                .expect("RetryDispatch requires fault injection");
                            ch.attempts[req] += 1;
                            ch.summary.retries += 1;
                            (ch.fc.deadline, ch.attempts[req])
                        };
                        dispatch_copy(
                            req << 1,
                            now,
                            &mut dispatcher,
                            &mut loads,
                            &mut devices,
                            &models,
                            &mut q,
                            &mut hint_ctx,
                            &mut chaos,
                            &mut shard,
                            None,
                            &mut trace,
                            DispatchWhy::Retry,
                        );
                        if let Some(dl) = deadline {
                            q.push(
                                now + dl,
                                EventKind::AttemptTimeout { req: req as u32, attempt },
                            );
                        }
                    }
                }
                EventKind::HedgeDispatch { req } => {
                    let req = req as usize;
                    let (proceed, exclude) = {
                        let ch = chaos
                            .as_mut()
                            .expect("HedgeDispatch requires fault injection");
                        if settled[req] || ch.hedged[req] {
                            (false, None)
                        } else {
                            ch.hedged[req] = true;
                            ch.summary.hedges += 1;
                            let p = ch.primary_dev[req];
                            (true, (p != u32::MAX).then_some(p as usize))
                        }
                    };
                    if proceed {
                        // Duplicate to a different device than the
                        // primary (when one exists); first completion
                        // wins, the loser settles as a zombie.
                        dispatch_copy(
                            (req << 1) | 1,
                            now,
                            &mut dispatcher,
                            &mut loads,
                            &mut devices,
                            &models,
                            &mut q,
                            &mut hint_ctx,
                            &mut chaos,
                            &mut shard,
                            exclude,
                            &mut trace,
                            DispatchWhy::Hedge,
                        );
                    }
                }
                EventKind::ScaleTick => {
                    let sc = scale.as_mut().expect("ScaleTick without an autoscale config");
                    let window = sc.ctl.config().window;
                    let slo = sc.ctl.config().slo;
                    sc.summary.ticks += 1;
                    let backlog: usize = (0..devices.len()).map(|i| loads.get(i)).sum();
                    let active_n = slots.iter().filter(|s| **s == Slot::Serving).count();
                    let signal = WindowSignal {
                        arrivals: sc.window_arrivals,
                        attainment: sc.window_e2e.fraction_leq(slo),
                        backlog,
                        active: active_n,
                    };
                    let desired = sc.ctl.desired(&signal);
                    let calm = sc.ctl.calm_streak();
                    emit(&mut trace, now, || TraceRecord::ScaleTick {
                        arrivals: signal.arrivals,
                        attain_ppm: (signal.attainment * 1e6).round() as u64,
                        backlog: signal.backlog as u64,
                        active: signal.active as u64,
                        desired: desired as u64,
                        calm: calm as u64,
                    });
                    let mut active_now = active_n;
                    // Scale-up (instant): cancel a drain first (the
                    // device is still warm), then reuse a retired
                    // slot, then grow the fleet. Lowest slot index
                    // first — deterministic.
                    while active_now < desired {
                        if let Some(slot) = slots.iter().position(|s| *s == Slot::Draining)
                        {
                            slots[slot] = Slot::Serving;
                            loads.activate(slot);
                            // Slot reuse invalidates breaker history
                            // (and any in-flight probe): the returning
                            // replica starts with a clean record.
                            if let Some(ov) = &mut overload {
                                if slot < ov.breakers.len() {
                                    ov.breakers[slot].reset();
                                }
                            }
                            emit(&mut trace, now, || TraceRecord::ScaleUp {
                                slot: slot as u64,
                                mode: "undrain",
                            });
                        } else {
                            let template = sc.ctl.config().template.clone();
                            if let Some(slot) =
                                slots.iter().position(|s| *s == Slot::Retired)
                            {
                                // Retool, don't just relabel: a mixed
                                // initial fleet's retired slot may have
                                // a different compiled batch-size set
                                // than the template.
                                devices[slot].retool(&template, cfg.max_wait, clock.clone());
                                if sed {
                                    loads.set_weight(
                                        slot,
                                        template.expected_delay_weights(),
                                    );
                                }
                                dispatcher.set_period(slot, template.period());
                                models[slot] = template;
                                slots[slot] = Slot::Serving;
                                loads.activate(slot);
                                if let Some(ov) = &mut overload {
                                    if slot < ov.breakers.len() {
                                        ov.breakers[slot].reset();
                                    }
                                }
                                spans.push(ActiveSpan { slot, from: now, to: None });
                                emit(&mut trace, now, || TraceRecord::ScaleUp {
                                    slot: slot as u64,
                                    mode: "retool",
                                });
                            } else {
                                let slot = devices.len();
                                devices.push(DeviceState::new(
                                    &template,
                                    cfg.max_wait,
                                    clock.clone(),
                                ));
                                loads.push_device(
                                    sed.then(|| template.expected_delay_weights()),
                                );
                                dispatcher.push_period(template.period());
                                models.push(template);
                                slots.push(Slot::Serving);
                                if let Some(ov) = &mut overload {
                                    ov.breakers.resize_with(slots.len(), Breaker::new);
                                }
                                spans.push(ActiveSpan { slot, from: now, to: None });
                                emit(&mut trace, now, || TraceRecord::ScaleUp {
                                    slot: slot as u64,
                                    mode: "spawn",
                                });
                            }
                        }
                        sc.summary.scale_ups += 1;
                        active_now += 1;
                    }
                    // Scale-down: drain the device the dispatcher
                    // likes best (least backed up — shortest drain),
                    // idle devices retiring immediately.
                    while active_now > desired {
                        let mut victim = loads.argmin();
                        if slots[victim] != Slot::Serving {
                            // Key-saturation corner: an inactive
                            // u64::MAX leaf can win an argmin tie
                            // against a saturated active key. Fall
                            // back to the first serving slot.
                            victim = slots
                                .iter()
                                .position(|s| *s == Slot::Serving)
                                .expect("scale-down below one serving slot");
                        }
                        slots[victim] = Slot::Draining;
                        loads.deactivate(victim);
                        sc.summary.scale_downs += 1;
                        active_now -= 1;
                        emit(&mut trace, now, || TraceRecord::ScaleDown {
                            slot: victim as u64,
                        });
                        if devices[victim].in_flight.is_none()
                            && devices[victim].batcher.pending() == 0
                        {
                            slots[victim] = Slot::Retired;
                            close_span(&mut spans, victim, now);
                            emit(&mut trace, now, || TraceRecord::Retire {
                                slot: victim as u64,
                            });
                        }
                    }
                    sc.summary.peak_active = sc.summary.peak_active.max(active_now);
                    sc.summary.min_active = sc.summary.min_active.min(active_now);
                    // Capacity may have just returned via scale-up
                    // during a total outage: drain the fleet-level
                    // parking lot through normal dispatch.
                    if loads.active_count() > 0
                        && matches!(&chaos, Some(ch) if !ch.pending.is_empty())
                    {
                        let parked = std::mem::take(
                            &mut chaos.as_mut().expect("checked above").pending,
                        );
                        for p in parked {
                            if settled[p >> 1] {
                                continue;
                            }
                            dispatch_copy(
                                p,
                                now,
                                &mut dispatcher,
                                &mut loads,
                                &mut devices,
                                &models,
                                &mut q,
                                &mut hint_ctx,
                                &mut chaos,
                                &mut shard,
                                None,
                                &mut trace,
                                DispatchWhy::Parked,
                            );
                        }
                    }
                    // New window; no ticks past the horizon (there are
                    // no further arrivals to react to — the fleet just
                    // drains).
                    sc.window_e2e = LatencyStats::default();
                    sc.window_arrivals = 0;
                    let next = now + window;
                    if next < cfg.horizon {
                        q.push(next, EventKind::ScaleTick);
                    }
                }
                EventKind::SampleTick => {
                    let sp = sampler.as_mut().expect("SampleTick without a sampler");
                    sp.ticks += 1;
                    sp.scheduled = false;
                    // Scale-up may have grown the fleet since the last
                    // tick — new slots start with zero credit.
                    if sp.window_done_dev.len() < devices.len() {
                        sp.window_done_dev.resize(devices.len(), 0);
                    }
                    if sp.prev_busy.len() < devices.len() {
                        sp.prev_busy.resize(devices.len(), Duration::ZERO);
                    }
                    let every_ns = sp.every.as_nanos();
                    let t_ns = now.as_nanos() as u64;
                    let sr = series
                        .as_mut()
                        .expect("SampleTick without a series collector");
                    let mut fleet_queue = 0u64;
                    let mut fleet_flight = 0u64;
                    let mut fleet_wbusy = Duration::ZERO;
                    let mut fleet_backlog = 0u64;
                    let mut serving = 0u64;
                    for d in 0..devices.len() {
                        let st = &devices[d];
                        let queue = st.batcher.pending() as u64;
                        let in_flight = st
                            .in_flight
                            .as_ref()
                            .map_or(0, |f| f.batch.requests.len())
                            as u64;
                        // Busy credit: accumulated busy plus the
                        // elapsed part of any in-flight service —
                        // monotone and continuous across completions,
                        // failures and SEU reruns, so the windowed
                        // delta is exact utilization.
                        let credit = st.metrics.busy
                            + st.in_flight
                                .as_ref()
                                .map_or(Duration::ZERO, |f| now - f.started);
                        let wbusy = credit.saturating_sub(sp.prev_busy[d]);
                        sp.prev_busy[d] = credit;
                        let active = slots[d] == Slot::Serving;
                        let backlog = loads.get(d) as u64;
                        fleet_queue += queue;
                        fleet_flight += in_flight;
                        fleet_wbusy += wbusy;
                        fleet_backlog += backlog;
                        serving += active as u64;
                        sr.push(SampleRow {
                            t_ns,
                            device: d as i64,
                            queue,
                            in_flight,
                            busy_ppm: ppm(wbusy.as_nanos(), every_ns),
                            completed: sp.window_done_dev[d],
                            backlog,
                            active: active as u64,
                            p99_ns: 0,
                            attain_ppm: 0,
                        });
                    }
                    let window_empty = sp.window_e2e.count() == 0;
                    sr.push(SampleRow {
                        t_ns,
                        device: -1,
                        queue: fleet_queue,
                        in_flight: fleet_flight,
                        busy_ppm: ppm(
                            fleet_wbusy.as_nanos(),
                            every_ns * u128::from(serving.max(1)),
                        ),
                        completed: sp.window_done_fleet,
                        backlog: fleet_backlog,
                        active: serving,
                        p99_ns: if window_empty {
                            0
                        } else {
                            sp.window_e2e.p99().as_nanos() as u64
                        },
                        attain_ppm: match sp.slo {
                            Some(slo) if !window_empty => {
                                (sp.window_e2e.fraction_leq(slo) * 1e6).round() as u64
                            }
                            _ => 1_000_000,
                        },
                    });
                    sp.window_e2e = LatencyStats::default();
                    sp.window_done_fleet = 0;
                    sp.window_done_dev.iter_mut().for_each(|c| *c = 0);
                    // Keep ticking while arrivals can still be
                    // admitted or any admitted request is unsettled
                    // (post-horizon drain stays visible); both clear ⇒
                    // the sampler stops and the run can terminate.
                    if now < cfg.horizon || settled_count < settled.len() as u64 {
                        q.push(now + sp.every, EventKind::SampleTick);
                        sp.scheduled = true;
                    }
                }
                EventKind::BreakerProbe { device, gen } => {
                    let d = device as usize;
                    let ov = overload
                        .as_mut()
                        .expect("BreakerProbe without overload protection");
                    // Stale generations (breaker already closed or
                    // reset) and non-serving slots (failed / drained
                    // under the open breaker) are skipped; the
                    // breaker half-opens only when the device can
                    // actually take probe traffic.
                    if slots[d] == Slot::Serving && ov.breakers[d].on_probe(gen) {
                        loads.activate(d);
                        emit(&mut trace, now, || TraceRecord::BreakerProbe {
                            device: d as u64,
                        });
                    }
                }
                EventKind::BrownoutTick => {
                    let ov = overload
                        .as_mut()
                        .expect("BrownoutTick without overload protection");
                    let bc = ov
                        .oc
                        .brownout
                        .as_ref()
                        .expect("BrownoutTick without a brownout config");
                    let bw = ov.brownout.as_mut().expect("brownout config without windows");
                    // Duty-cycle accounting first: the elapsed window
                    // was spent in the *pre-transition* mode.
                    if bw.ctl.degraded() {
                        ov.summary.brownout_windows += 1;
                    }
                    let sig = BrownoutSignal {
                        completions: bw.window_completions,
                        met: bw.window_met,
                        rejects: bw.window_rejects,
                    };
                    let attain_ppm = (sig.attainment() * 1e6).round() as u64;
                    match bw.ctl.observe(bc, &sig) {
                        Some(true) => {
                            // Enter brownout: swap every device onto
                            // its degraded (lower-bit-width) service
                            // table. Identical batch-size menus
                            // (validated) keep formed batches and the
                            // batcher untouched; in-flight batches
                            // finish at the speed they started at.
                            ov.summary.brownout_enters += 1;
                            emit(&mut trace, now, || TraceRecord::BrownoutEnter {
                                attain_ppm,
                            });
                            for (d, deg) in bc.degraded.iter().enumerate() {
                                models[d] = deg.clone();
                                if sed {
                                    loads.set_weight(d, models[d].expected_delay_weights());
                                }
                                dispatcher.set_period(d, models[d].period());
                            }
                        }
                        Some(false) => {
                            // Exit: restore the stashed full-precision
                            // tables (same swap discipline).
                            emit(&mut trace, now, || TraceRecord::BrownoutExit {
                                attain_ppm,
                            });
                            for (d, full) in bw.full.iter().enumerate() {
                                models[d] = full.clone();
                                if sed {
                                    loads.set_weight(d, models[d].expected_delay_weights());
                                }
                                dispatcher.set_period(d, models[d].period());
                            }
                        }
                        None => {}
                    }
                    bw.window_completions = 0;
                    bw.window_met = 0;
                    bw.window_rejects = 0;
                    let next = now + bc.window;
                    if next < cfg.horizon {
                        q.push(next, EventKind::BrownoutTick);
                    }
                }
                EventKind::RebalanceTick => {
                    // Replication/placement controller: read the
                    // window's per-expert routed counts, re-home
                    // replicas stranded on dead devices, grow hot
                    // experts, trim cold surplus. Moves are
                    // drain-before-move by construction — dropping a
                    // replica only stops *future* routing; work already
                    // queued on the device completes where it sits.
                    let sh = shard.as_mut().expect("RebalanceTick without sharding");
                    let rb = sh
                        .sc
                        .rebalance
                        .clone()
                        .expect("RebalanceTick without a rebalance config");
                    let alive: Vec<bool> =
                        (0..devices.len()).map(|d| loads.is_active(d)).collect();
                    let moves = shard::plan_moves(
                        &sh.window_counts,
                        &sh.replicas,
                        &alive,
                        sh.sc.replication,
                        sh.sc.hot_experts,
                    );
                    if !moves.is_empty() {
                        sh.summary.rebalances += 1;
                    }
                    for m in &moves {
                        let (e, d) = (m.expert, m.device);
                        match m.kind {
                            MoveKind::Add => {
                                devices[d].host(e, cfg.num_experts);
                                sh.replicas[e as usize].push(d as u32);
                                sh.summary.replica_adds += 1;
                                emit(&mut trace, now, || TraceRecord::ReplicaAdd {
                                    expert: e as u64,
                                    device: d as u64,
                                });
                            }
                            MoveKind::Drop => {
                                devices[d].unhost(e);
                                sh.replicas[e as usize].retain(|&x| x != d as u32);
                                sh.summary.replica_drops += 1;
                                emit(&mut trace, now, || TraceRecord::ReplicaDrop {
                                    expert: e as u64,
                                    device: d as u64,
                                });
                            }
                        }
                    }
                    for c in sh.window_counts.iter_mut() {
                        *c = 0;
                    }
                    let next = now + rb.every;
                    if next < cfg.horizon {
                        q.push(next, EventKind::RebalanceTick);
                    }
                }
            }
        }
        // Undeliverable copies: dispatch found no live replica of the
        // request's effective expert anywhere in the fleet. Hedge
        // copies die silently (the primary is still in play); a
        // primary copy settles as a counted drop — the `no_replica`
        // leg of conservation — and a closed-loop user goes back to
        // thinking rather than hanging forever.
        if let Some(sh) = &mut shard {
            if !sh.undeliverable.is_empty() {
                let undeliv = std::mem::take(&mut sh.undeliverable);
                for (p, at) in undeliv {
                    let req = p >> 1;
                    if p & 1 == 1 || settled[req] {
                        continue;
                    }
                    settled[req] = true;
                    settled_count += 1;
                    sh.summary.no_replica_drops += 1;
                    let attempts =
                        chaos.as_ref().map_or(1, |ch| ch.attempts[req]) as u64;
                    emit(&mut trace, at, || TraceRecord::Drop {
                        req: req as u64,
                        attempts,
                    });
                    if closed {
                        let u = req_user[req] as usize;
                        let gap = think_gap(&mut user_rng[u], think_time);
                        q.push(at + gap, EventKind::UserThink { user: req_user[req] });
                    }
                }
            }
        }
        events += 1;
        peak_events = peak_events.max(
            (q.len() as u64).saturating_sub(
                sampler.as_ref().map_or(0, |s| u64::from(s.scheduled)),
            ),
        );
    }

    assert!(
        settled.iter().all(|&c| c),
        "DES terminated with unsettled requests (batcher stall)"
    );

    let admitted = arrival_times.len() as u64;
    let offered_rps = metrics::rate_per_sec(admitted, cfg.horizon);
    // Devices still up close their span at the end of the run: the
    // later of last completion and the arrival horizon (an idle tail
    // still had the fleet provisioned).
    let end = makespan.max(cfg.horizon);
    let device_seconds: f64 = spans
        .iter()
        .map(|s| (s.to.unwrap_or(end).saturating_sub(s.from)).as_secs_f64())
        .sum();
    let autoscale_summary = scale.map(|mut sc| {
        sc.summary.final_active = slots.iter().filter(|s| **s == Slot::Serving).count();
        sc.summary
    });
    // Drops come from two places: fault-injection budgets (chaos) and
    // no-replica undeliverables (sharding). Both settled their
    // requests in-loop; the totals are additive by construction.
    let dropped = chaos.as_ref().map_or(0, |ch| ch.summary.dropped)
        + shard.as_ref().map_or(0, |sh| sh.summary.no_replica_drops);
    let rejected = overload.as_ref().map_or(0, |ov| ov.summary.rejected);
    let overload_summary = overload.map(|mut ov| {
        // The accuracy proxy is a pure function of the degraded
        // completion count (one multiply at the end, so summation
        // order can never perturb the bit-determinism contract).
        if let Some(bc) = &ov.oc.brownout {
            ov.summary.accuracy_cost =
                ov.summary.degraded_completions as f64 * bc.accuracy_cost_per_request;
        }
        ov.summary
    });
    let shard_summary = shard.map(|mut sh| {
        // Same discipline as the brownout proxy: accuracy cost is one
        // multiply over the final degraded count, never a running sum.
        sh.summary.accuracy_cost =
            sh.summary.degraded_completions as f64 * sh.sc.expert_drop_cost;
        sh.summary
    });
    let faults_summary = chaos.map(|mut ch| {
        // Per-slot scheduled downtime over the observation window —
        // availability is derived from the normalized plan, so it is
        // exact, not sampled.
        ch.summary.downtime = (0..devices.len()).map(|i| plan.downtime(i, end)).collect();
        ch.summary
    });

    let per_device: Vec<DeviceMetrics> = devices.into_iter().map(|d| d.metrics).collect();
    let mut fleet = DeviceMetrics::default();
    for d in &per_device {
        fleet.merge_from(d);
    }
    // Conservation across failures, retries, hedges, drops and
    // admission rejections: every offered request settled exactly one
    // way — `completed + dropped + rejected == offered` (the overload
    // PR's extension of the PR 6 law; `rejected` is 0 without it).
    assert_eq!(
        fleet.completed + dropped + rejected,
        admitted,
        "conservation violated: completed + dropped + rejected != offered"
    );
    if let Some(os) = &overload_summary {
        debug_assert_eq!(
            os.offered_by_class.iter().sum::<u64>(),
            admitted,
            "per-class offered counts must partition the arrival count"
        );
    }
    // Sharded conservation: every routed token is completed (possibly
    // degraded via expert-drop), rerouted-then-completed, dropped
    // (chaos or no-replica) or rejected at the admission edge —
    // nothing routes and then vanishes.
    if let Some(ss) = &shard_summary {
        assert_eq!(
            ss.routed, admitted,
            "router must draw for every arrival, admitted or not"
        );
        assert!(
            ss.degraded_completions <= fleet.completed,
            "degraded completions are a subset of completions"
        );
        assert_eq!(
            (fleet.completed - ss.degraded_completions)
                + ss.degraded_completions
                + dropped
                + rejected,
            ss.routed,
            "sharded conservation violated: completed + degraded + dropped + rejected != routed"
        );
    }
    // Events-counter compensation: SampleTicks are observation, not
    // simulation — subtract them so the report is bit-identical with
    // the sampler off (the peak-events side was compensated in-loop).
    let events = events - sampler.as_ref().map_or(0, |s| s.ticks);
    // Work-counter registration: one DES run of `events` events. Lives
    // on the process-global registry (never in the report), so the
    // fleet-report memo contract — warm reruns perform zero DES event
    // loops — is assertable from counter deltas alone.
    crate::obs::registry::count_des_run(events);
    // Overload totals ride a dedicated record just before the frozen
    // Summary line, so pre-overload trace consumers keep working.
    if let Some(os) = &overload_summary {
        emit(&mut trace, end, || TraceRecord::OverloadSummary {
            rejected: os.rejected,
            rejected_rate: os.rejected_rate,
            rejected_queue: os.rejected_queue,
            breaker_trips: os.breaker_trips,
            breaker_closes: os.breaker_closes,
            brownout_enters: os.brownout_enters,
            degraded_completions: os.degraded_completions,
        });
    }
    // Shard totals ride their own record between OverloadSummary and
    // the frozen Summary line — same back-compat discipline.
    if let Some(ss) = &shard_summary {
        emit(&mut trace, end, || TraceRecord::ShardSummary {
            routed: ss.routed,
            rerouted: ss.rerouted,
            expert_drops: ss.expert_drops,
            no_replica: ss.no_replica_drops,
            transfers: ss.transfers,
            replica_adds: ss.replica_adds,
            replica_drops: ss.replica_drops,
        });
    }
    emit(&mut trace, end, || TraceRecord::Summary {
        admitted,
        completed: fleet.completed,
        dropped,
        makespan_ns: makespan.as_nanos() as u64,
    });
    FleetReport {
        per_device,
        fleet,
        admitted,
        offered_rps,
        horizon: cfg.horizon,
        makespan,
        events,
        peak_events,
        device_seconds,
        autoscale: autoscale_summary,
        dropped,
        faults: faults_summary,
        rejected,
        overload: overload_summary,
        shard: shard_summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::Platform;

    fn synthetic() -> DeviceModel {
        DeviceModel::from_latencies(
            "syn".into(),
            Duration::from_millis(4),
            Duration::from_millis(10),
            &[1, 2, 4, 8],
        )
    }

    fn poisson_cfg(n_dev: usize, util: f64) -> ServeConfig {
        let dev = synthetic();
        let rate = util * dev.peak_rps() * n_dev as f64;
        ServeConfig::uniform(dev, n_dev, Workload::Poisson { rate_rps: rate })
    }

    #[test]
    fn canonical_key_covers_what_the_des_reads() {
        let base = poisson_cfg(2, 0.5);
        let k = base.canonical_key();
        // Deterministic and self-equal.
        assert_eq!(k, base.clone().canonical_key());
        assert!(k.starts_with("serve;dev="), "key is namespaced: {k}");
        // Every DES-read field perturbs the key.
        let mut c = base.clone();
        c.seed ^= 1;
        assert_ne!(c.canonical_key(), k, "seed must key");
        let mut c = base.clone();
        c.num_experts += 1;
        assert_ne!(c.canonical_key(), k, "num_experts must key");
        let mut c = base.clone();
        c.horizon += Duration::from_millis(1);
        assert_ne!(c.canonical_key(), k, "horizon must key");
        let mut c = base.clone();
        c.dispatch = DispatchPolicy::RoundRobin;
        assert_ne!(c.canonical_key(), k, "dispatch must key");
        let mut c = base.clone();
        c.max_wait += Duration::from_micros(1);
        assert_ne!(c.canonical_key(), k, "max_wait must key");
        let mut c = base.clone();
        c.devices.pop();
        assert_ne!(c.canonical_key(), k, "fleet size must key");
        let mut c = base.clone();
        c.workload = Workload::Poisson { rate_rps: 1.0 };
        assert_ne!(c.canonical_key(), k, "workload must key");
        // Float fields key by bit pattern, not formatting: -0.0 != 0.0.
        let mut a = base.clone();
        let mut b = base.clone();
        a.workload = Workload::Poisson { rate_rps: 0.0 };
        b.workload = Workload::Poisson { rate_rps: -0.0 };
        assert_ne!(a.canonical_key(), b.canonical_key());
        // The sampler is observation, not simulation: excluded.
        let mut c = base.clone();
        c.sampler = Some(SamplerConfig::for_horizon(c.horizon, 16));
        assert_eq!(c.canonical_key(), k, "sampler must not key");
        // Optional subsystems key once attached.
        let mut c = base.clone();
        c.faults = Some(FaultConfig {
            plan: FaultPlan::new(vec![FaultSpan::new(
                0,
                Duration::from_millis(10),
                Duration::from_millis(20),
            )]),
            ..FaultConfig::default()
        });
        assert_ne!(c.canonical_key(), k, "faults must key");
    }

    #[test]
    fn conserves_every_request() {
        let r = simulate_fleet(&poisson_cfg(3, 0.7));
        assert_eq!(r.fleet.completed, r.admitted);
        assert_eq!(r.fleet.e2e.count() as u64, r.admitted);
        let per: u64 = r.per_device.iter().map(|d| d.completed).sum();
        assert_eq!(per, r.admitted);
        assert!(r.makespan >= r.horizon / 2);
        assert!(r.events >= r.admitted, "every arrival is an event");
    }

    #[test]
    fn fixed_seed_is_bit_identical() {
        let cfg = poisson_cfg(4, 0.8);
        let a = simulate_fleet(&cfg);
        let b = simulate_fleet(&cfg);
        assert_eq!(a, b, "same seed/config must give identical fleet metrics");
        let mut cfg2 = cfg.clone();
        cfg2.seed ^= 1;
        let c = simulate_fleet(&cfg2);
        assert_ne!(a, c, "different seed should perturb the run");
    }

    #[test]
    #[should_panic(expected = "zero-horizon")]
    fn zero_horizon_config_rejected() {
        let mut cfg = poisson_cfg(1, 0.5);
        cfg.horizon = Duration::ZERO;
        let _ = simulate_fleet(&cfg);
    }

    #[test]
    fn heap_stays_bounded_under_sustained_partial_batches() {
        // Regression for stale-deadline accumulation AND arrival
        // preloading: a coarse batch-8-only executable under a load
        // that almost never fills it forces a deadline flush per
        // batch for the whole horizon. The heap must stay
        // O(devices + in-flight), independent of the admitted count.
        let dev = DeviceModel::from_latencies(
            "partial".into(),
            Duration::ZERO,
            Duration::from_millis(2),
            &[8],
        );
        let mut cfg = ServeConfig::uniform(dev, 4, Workload::Poisson { rate_rps: 400.0 });
        cfg.horizon = Duration::from_secs(20);
        let r = simulate_fleet(&cfg);
        assert!(r.admitted > 5_000, "need sustained load, got {}", r.admitted);
        assert_eq!(r.fleet.completed, r.admitted);
        assert!(
            r.peak_events <= 6 * 4 + 8,
            "heap grew with request count: peak {} for {} admitted",
            r.peak_events,
            r.admitted
        );
    }

    #[test]
    fn residency_separates_affinity_from_jsq() {
        // The ROADMAP cache-affinity item, observable end to end:
        // with 4 experts homed on 4 devices, expert-affinity dispatch
        // repeats each device's dominant expert batch after batch, so
        // the residency discount keeps recovering fill time — total
        // busy time (Σ service) must come out strictly below JSQ,
        // which scatters experts across devices.
        let dev = DeviceModel::from_latencies(
            "aff".into(),
            Duration::from_millis(8),
            Duration::from_millis(2),
            &[1, 2, 4, 8],
        );
        let rate = 0.8 * dev.peak_rps() * 4.0;
        let mut aff = ServeConfig::uniform(dev, 4, Workload::Poisson { rate_rps: rate });
        aff.dispatch = DispatchPolicy::ExpertAffinity;
        aff.num_experts = 4;
        let mut jsq = aff.clone();
        jsq.dispatch = DispatchPolicy::JoinShortestQueue;
        let a = simulate_fleet(&aff);
        let j = simulate_fleet(&jsq);
        assert_eq!(a.fleet.completed, j.fleet.completed);
        assert!(
            a.fleet.busy < j.fleet.busy,
            "affinity busy {:?} !< jsq busy {:?} — residency discount not separating",
            a.fleet.busy,
            j.fleet.busy
        );
        assert_ne!(a, j, "policies must produce distinct reports");
    }

    #[test]
    fn sed_is_tie_identical_to_jsq_on_homogeneous_fleet() {
        // On identical replicas the expected-delay key is strictly
        // monotone in load with the same coefficients everywhere, so
        // shortest-expected-delay makes exactly join-shortest-queue's
        // choices (ties included) — the whole report must come out
        // bit-identical.
        let mut jsq = poisson_cfg(4, 0.9);
        jsq.dispatch = DispatchPolicy::JoinShortestQueue;
        let mut sed = jsq.clone();
        sed.dispatch = DispatchPolicy::ShortestExpectedDelay;
        assert_eq!(
            simulate_fleet(&jsq),
            simulate_fleet(&sed),
            "homogeneous SED must degenerate to JSQ exactly"
        );
    }

    #[test]
    fn sed_cuts_the_mixed_fleet_tail_below_jsq() {
        // A 2-edge + 2-core mixed fleet with a 10x per-image speed
        // gap. JSQ compares queue *lengths*, so it keeps feeding the
        // slow edge tier whenever its count dips below the core
        // tier's; every request it parks there pays ~85 ms of service
        // against ~9 ms on a core device, which is exactly what the
        // p99 measures. SED's expected-delay key routes to the edge
        // tier only when the core backlog genuinely costs more.
        let edge = DeviceModel::from_latencies(
            "edge".into(),
            Duration::from_millis(5),
            Duration::from_millis(10),
            &[1, 2, 4, 8],
        );
        let core = DeviceModel::from_latencies(
            "core".into(),
            Duration::from_millis(1),
            Duration::from_millis(1),
            &[1, 2, 4, 8],
        );
        let peak = 2.0 * edge.peak_rps() + 2.0 * core.peak_rps();
        let mk = |policy| {
            let mut cfg = ServeConfig::mixed(
                vec![edge.clone(), edge.clone(), core.clone(), core.clone()],
                Workload::Poisson { rate_rps: 0.7 * peak },
            );
            cfg.dispatch = policy;
            cfg.horizon = Duration::from_secs(20);
            cfg
        };
        let s = simulate_fleet(&mk(DispatchPolicy::ShortestExpectedDelay));
        let j = simulate_fleet(&mk(DispatchPolicy::JoinShortestQueue));
        assert_eq!(s.fleet.completed, j.fleet.completed, "same offered traffic");
        assert!(
            s.fleet.e2e.p99() < j.fleet.e2e.p99(),
            "SED p99 {:?} !< JSQ p99 {:?} on the mixed fleet",
            s.fleet.e2e.p99(),
            j.fleet.e2e.p99()
        );
    }

    #[test]
    fn subcritical_load_is_served_at_offered_rate() {
        let r = simulate_fleet(&poisson_cfg(2, 0.4));
        let ratio = r.achieved_rps() / r.offered_rps;
        assert!((0.9..=1.01).contains(&ratio), "achieved/offered = {ratio}");
        // Light load: e2e stays on the scale of a few batch services
        // (service(8) = 84 ms for the synthetic device), far from the
        // seconds-scale waits of the overload tests.
        let bound = Duration::from_millis(3 * 84);
        assert!(r.fleet.e2e.p99() < bound, "p99 {:?}", r.fleet.e2e.p99());
    }

    #[test]
    fn throughput_scales_with_fleet_size() {
        // Offered load = 8x one device's peak: saturates a lone
        // device AND a 4-device fleet, so the sustained completion
        // rate must scale ~4x with the fleet.
        let one = simulate_fleet(&poisson_cfg(1, 8.0));
        let mut big = poisson_cfg(1, 8.0); // same offered load…
        big.devices = vec![synthetic(); 4]; // …4x the fleet
        let four = simulate_fleet(&big);
        let speedup = four.achieved_rps() / one.achieved_rps();
        assert!(speedup > 3.0, "fleet scaling {speedup}");
    }

    #[test]
    fn overload_queues_grow_and_tail_explodes() {
        let calm = simulate_fleet(&poisson_cfg(2, 0.4));
        let hot = simulate_fleet(&poisson_cfg(2, 1.3));
        assert!(hot.makespan > hot.horizon, "overload must drain past the horizon");
        assert!(
            hot.fleet.e2e.p99() > 3 * calm.fleet.e2e.p99(),
            "p99 {:?} !>> {:?}",
            hot.fleet.e2e.p99(),
            calm.fleet.e2e.p99()
        );
    }

    #[test]
    fn padding_appears_when_executables_are_coarse() {
        // Only a batch-4 executable: a trickle of lone requests must
        // pad 3 of every 4 slots.
        let dev = DeviceModel::from_latencies(
            "coarse".into(),
            Duration::ZERO,
            Duration::from_millis(5),
            &[4],
        );
        let mut cfg = ServeConfig::uniform(dev, 1, Workload::Poisson { rate_rps: 3.0 });
        cfg.horizon = Duration::from_secs(20);
        let r = simulate_fleet(&cfg);
        assert!(r.fleet.padding_fraction() > 0.3, "{}", r.fleet.padding_fraction());
        // And with a batch-1 executable available, padding vanishes
        // at the same load.
        let fine = DeviceModel::from_latencies(
            "fine".into(),
            Duration::ZERO,
            Duration::from_millis(5),
            &[1, 4],
        );
        let mut cfg2 = ServeConfig::uniform(fine, 1, Workload::Poisson { rate_rps: 3.0 });
        cfg2.horizon = Duration::from_secs(20);
        let r2 = simulate_fleet(&cfg2);
        assert!(r2.fleet.padding_fraction() < r.fleet.padding_fraction());
    }

    #[test]
    fn bursty_traffic_has_worse_tail_than_poisson_at_same_mean() {
        let dev = synthetic();
        let mean = 0.75 * dev.peak_rps();
        let mut poisson =
            ServeConfig::uniform(dev.clone(), 1, Workload::Poisson { rate_rps: mean });
        poisson.horizon = Duration::from_secs(30);
        let mut bursty = ServeConfig::uniform(
            dev,
            1,
            Workload::Mmpp2 {
                rate_low_rps: 0.3 * mean,
                rate_high_rps: 1.7 * mean,
                dwell_low: Duration::from_secs(2),
                dwell_high: Duration::from_secs(2),
            },
        );
        bursty.horizon = Duration::from_secs(30);
        let p = simulate_fleet(&poisson);
        let b = simulate_fleet(&bursty);
        assert!(
            b.fleet.e2e.p99() > p.fleet.e2e.p99(),
            "bursty p99 {:?} !> poisson p99 {:?}",
            b.fleet.e2e.p99(),
            p.fleet.e2e.p99()
        );
    }

    #[test]
    fn affinity_without_experts_falls_back_to_jsq() {
        let mut aff = poisson_cfg(3, 0.9);
        aff.dispatch = DispatchPolicy::ExpertAffinity;
        aff.num_experts = 0;
        let mut jsq = aff.clone();
        jsq.dispatch = DispatchPolicy::JoinShortestQueue;
        assert_eq!(
            simulate_fleet(&aff),
            simulate_fleet(&jsq),
            "0 experts: affinity must degrade to JSQ, not pin device 0"
        );
    }

    #[test]
    fn trace_replay_reproduces_the_poisson_run() {
        let dev = synthetic();
        let rate = 0.6 * dev.peak_rps();
        let mut cfg = ServeConfig::uniform(dev, 2, Workload::Poisson { rate_rps: rate });
        cfg.horizon = Duration::from_secs(5);
        let live = simulate_fleet(&cfg);
        let mut replay = cfg.clone();
        replay.workload = cfg.workload.to_trace(cfg.horizon, cfg.seed).unwrap();
        let replayed = simulate_fleet(&replay);
        assert_eq!(live, replayed, "captured trace must replay bit-identically");
    }

    #[test]
    fn static_device_seconds_are_fleet_size_times_run_length() {
        let calm = simulate_fleet(&poisson_cfg(3, 0.4));
        let want = 3.0 * calm.makespan.max(calm.horizon).as_secs_f64();
        assert!(
            (calm.device_seconds - want).abs() < 1e-9,
            "static device-seconds {} != {want}",
            calm.device_seconds
        );
        assert!(calm.autoscale.is_none(), "static run carries no controller summary");
        // Overload: the drain extends availability past the horizon.
        let hot = simulate_fleet(&poisson_cfg(2, 1.3));
        let want_hot = 2.0 * hot.makespan.as_secs_f64();
        assert!((hot.device_seconds - want_hot).abs() < 1e-9);
    }

    // ---- closed loop -------------------------------------------------

    fn closed_cfg(n_dev: usize, users: usize, think: Duration) -> ServeConfig {
        ServeConfig::uniform(
            synthetic(),
            n_dev,
            Workload::ClosedLoop { users, think_time: think },
        )
    }

    #[test]
    fn closed_loop_fixed_users_and_seed_bit_identical() {
        // The satellite contract: fixed (users, seed) ⇒ bit-identical
        // FleetReport, and either knob perturbs the run.
        let cfg = closed_cfg(2, 24, Duration::from_millis(50));
        let a = simulate_fleet(&cfg);
        let b = simulate_fleet(&cfg);
        assert_eq!(a, b, "closed loop must be deterministic");
        let mut reseeded = cfg.clone();
        reseeded.seed ^= 1;
        assert_ne!(a, simulate_fleet(&reseeded), "seed must matter");
        let mut more_users = cfg.clone();
        more_users.workload =
            Workload::ClosedLoop { users: 25, think_time: Duration::from_millis(50) };
        assert_ne!(a, simulate_fleet(&more_users), "user count must matter");
    }

    #[test]
    fn closed_loop_conserves_and_completes_every_request() {
        let r = simulate_fleet(&closed_cfg(2, 16, Duration::from_millis(20)));
        assert!(r.admitted > 0, "users must issue requests");
        assert_eq!(r.fleet.completed, r.admitted);
        assert_eq!(r.fleet.e2e.count() as u64, r.admitted);
    }

    #[test]
    fn zero_think_time_users_saturate_like_the_open_loop_knee() {
        // think_time = 0: each user re-fires the instant its previous
        // request completes, so the fleet holds `users` requests in
        // flight forever. With enough users to keep every device's
        // largest batch full, the sustained rate must match what the
        // open-loop model achieves past its knee (both are the fleet's
        // capacity plateau).
        let closed = simulate_fleet(&closed_cfg(4, 64, Duration::ZERO));
        let open = simulate_fleet(&poisson_cfg(4, 1.3));
        let ratio = closed.achieved_rps() / open.achieved_rps();
        assert!(
            (0.85..=1.1).contains(&ratio),
            "closed-loop saturation {} vs open-loop plateau {} (ratio {ratio})",
            closed.achieved_rps(),
            open.achieved_rps()
        );
        // And the fleet is genuinely saturated: utilization ~ 1.
        assert!(closed.mean_utilization() > 0.9, "{}", closed.mean_utilization());
    }

    #[test]
    fn think_time_throttles_closed_loop_load() {
        // Little's law: users / (think + service) arrivals per second.
        // Longer thinking ⇒ fewer requests from the same user pool.
        let brisk = simulate_fleet(&closed_cfg(2, 16, Duration::from_millis(20)));
        let lazy = simulate_fleet(&closed_cfg(2, 16, Duration::from_millis(500)));
        assert!(
            lazy.admitted < brisk.admitted / 2,
            "500 ms thinkers admitted {} !<< 20 ms thinkers {}",
            lazy.admitted,
            brisk.admitted
        );
    }

    // ---- autoscaling -------------------------------------------------

    /// A deterministic calm → burst → calm trace (evenly spaced
    /// arrivals, no RNG): calm at `calm_rps` on [0, t1) and [t2, t3),
    /// burst at `burst_rps` on [t1, t2).
    fn phased_trace(calm_rps: f64, burst_rps: f64, t1: f64, t2: f64, t3: f64) -> Workload {
        let mut arrivals = Vec::new();
        let mut push_phase = |from: f64, to: f64, rate: f64| {
            let gap = 1.0 / rate;
            let mut t = from + gap;
            while t < to {
                arrivals.push(Duration::from_secs_f64(t));
                t += gap;
            }
        };
        push_phase(0.0, t1, calm_rps);
        push_phase(t1, t2, burst_rps);
        push_phase(t2, t3, calm_rps);
        arrivals.sort_unstable();
        Workload::Trace { arrivals }
    }

    fn autoscaled_cfg() -> ServeConfig {
        let dev = synthetic(); // peak = 8 / 84 ms ≈ 95 req/s
        let peak = dev.peak_rps();
        let slo = dev.service_time(8) * 3; // 252 ms e2e budget
        let mut cfg = ServeConfig::uniform(
            dev.clone(),
            1,
            phased_trace(0.3 * peak, 2.4 * peak, 10.0, 20.0, 30.0),
        );
        cfg.horizon = Duration::from_secs(30);
        cfg.autoscale = Some(AutoscaleConfig::for_device(dev, slo));
        cfg
    }

    #[test]
    fn autoscaler_rides_the_burst_up_and_back_down() {
        let r = simulate_fleet(&autoscaled_cfg());
        assert_eq!(r.fleet.completed, r.admitted, "conservation across scale events");
        let s = r.autoscale.as_ref().expect("autoscaled run must carry a summary");
        assert!(s.ticks > 10, "controller must have run: {s:?}");
        assert!(s.scale_ups >= 2, "burst must grow the fleet: {s:?}");
        assert!(s.scale_downs >= 2, "calm must shrink it again: {s:?}");
        assert!(s.peak_active >= 3, "burst demand ≈ 2.4 devices at ρ=0.7: {s:?}");
        assert!(s.min_active == 1, "calm demand fits one device: {s:?}");
        assert!(s.final_active <= 2, "fleet must come back down: {s:?}");
        // The economic point: availability tracked demand, so the run
        // cost strictly less than keeping the peak fleet up throughout.
        let end = r.makespan.max(r.horizon).as_secs_f64();
        assert!(
            r.device_seconds < s.peak_active as f64 * end,
            "device-seconds {} !< peak-static {}",
            r.device_seconds,
            s.peak_active as f64 * end
        );
        assert!(
            r.device_seconds > end - 1e-9,
            "at least the always-on floor device: {} vs {end}",
            r.device_seconds
        );
    }

    #[test]
    fn autoscaled_run_is_bit_identical_per_seed() {
        let cfg = autoscaled_cfg();
        assert_eq!(
            simulate_fleet(&cfg),
            simulate_fleet(&cfg),
            "controller decisions are pure functions of DES state"
        );
    }

    #[test]
    fn autoscaler_holds_the_floor_on_calm_traffic() {
        // Evenly spaced arrivals (no burst phase), so every window
        // sees the same calm count — the controller must never leave
        // the floor.
        let dev = synthetic();
        let slo = dev.service_time(8) * 3;
        let calm = 0.3 * dev.peak_rps();
        let mut cfg =
            ServeConfig::uniform(dev.clone(), 1, phased_trace(calm, calm, 5.0, 5.0, 20.0));
        cfg.horizon = Duration::from_secs(20);
        cfg.autoscale = Some(AutoscaleConfig::for_device(dev, slo));
        let r = simulate_fleet(&cfg);
        let s = r.autoscale.as_ref().unwrap();
        assert_eq!(s.peak_active, 1, "calm traffic must not scale up: {s:?}");
        assert_eq!(r.per_device.len(), 1, "no replicas ever spawned");
    }

    #[test]
    fn autoscaler_retools_reused_slots_from_mixed_initial_fleets() {
        // Regression: a retired slot from a mixed initial fleet may
        // carry a different compiled batch-size set than the scale-up
        // template. Reuse must rebuild the batcher for the template
        // (DeviceState::retool) — with the stale batcher, the deep
        // burst queue below forms a batch-16 the template has no
        // executable for, and service_time panics.
        let wide = DeviceModel::from_latencies(
            "wide".into(),
            Duration::from_millis(4),
            Duration::from_millis(10),
            &[1, 2, 4, 8, 16],
        );
        let narrow = synthetic(); // sizes [1, 2, 4, 8]
        let peak = narrow.peak_rps();
        let slo = narrow.service_time(8) * 3;
        // Near-idle calm (inter-arrival ≫ service, so at the drain
        // tick both devices sit at load 0 and the least-loaded tie
        // breaks to slot 0 — the wide device retires), then a hard
        // burst that reuses the retired slot and overloads it.
        let mut cfg = ServeConfig::mixed(
            vec![wide, narrow.clone()],
            phased_trace(0.05 * peak, 3.0 * peak, 8.0, 16.0, 20.0),
        );
        cfg.horizon = Duration::from_secs(20);
        let mut ac = AutoscaleConfig::for_device(narrow, slo);
        ac.max_devices = 2; // overload the pair: queues exceed 16
        cfg.autoscale = Some(ac);
        let r = simulate_fleet(&cfg);
        assert_eq!(r.fleet.completed, r.admitted, "conservation across slot reuse");
        let s = r.autoscale.as_ref().unwrap();
        assert!(
            s.scale_downs >= 1 && s.scale_ups >= 1,
            "the scenario must actually drain and reuse: {s:?}"
        );
    }

    #[test]
    #[should_panic(expected = "outside the autoscale")]
    fn autoscale_rejects_initial_fleet_outside_bounds() {
        let dev = synthetic();
        let slo = dev.service_time(8) * 3;
        let mut ac = AutoscaleConfig::for_device(dev.clone(), slo);
        ac.max_devices = 2;
        let mut cfg =
            ServeConfig::uniform(dev, 4, Workload::Poisson { rate_rps: 10.0 });
        cfg.autoscale = Some(ac);
        let _ = simulate_fleet(&cfg);
    }

    /// Acceptance: a 4-device U280 fleet (sim-backed cost model) shows
    /// the saturation knee — p99 rising sharply past it.
    #[test]
    fn u280_fleet_curve_saturates() {
        let dev = crate::report::serving::demo_device(&Platform::u280());
        let peak = dev.peak_rps() * 4.0;
        let p99_at = |util: f64| {
            let mut cfg = ServeConfig::uniform(
                dev.clone(),
                4,
                Workload::Poisson { rate_rps: util * peak },
            );
            cfg.horizon = Duration::from_secs(10);
            let r = simulate_fleet(&cfg);
            assert_eq!(r.fleet.completed, r.admitted);
            r.fleet.e2e.p99()
        };
        let below = p99_at(0.4);
        let past = p99_at(1.15);
        assert!(
            past > 3 * below,
            "no saturation knee: p99 {below:?} @0.4 vs {past:?} @1.15"
        );
    }

    // ---- fault injection ---------------------------------------------

    /// Calibrated outage scenario: 3 devices at ρ = 0.6, devices 0 and
    /// 1 both down over [10 s, 11 s) — two thirds of the fleet gone for
    /// one second under real load — with a 500 ms per-attempt deadline.
    fn outage_cfg(max_attempts: u32) -> ServeConfig {
        let dev = synthetic();
        let rate = 0.6 * dev.peak_rps() * 3.0;
        let mut cfg = ServeConfig::uniform(dev, 3, Workload::Poisson { rate_rps: rate });
        cfg.horizon = Duration::from_secs(30);
        cfg.num_experts = 0;
        cfg.faults = Some(FaultConfig {
            plan: FaultPlan::new(vec![
                FaultSpan::new(0, Duration::from_secs(10), Duration::from_secs(11)),
                FaultSpan::new(1, Duration::from_secs(10), Duration::from_secs(11)),
            ]),
            deadline: Some(Duration::from_millis(500)),
            max_attempts,
            backoff_base: Duration::from_millis(100),
            backoff_cap: Duration::from_millis(400),
            ..FaultConfig::none()
        });
        cfg
    }

    #[test]
    fn retries_and_failover_preserve_goodput_through_an_outage() {
        // Acceptance: the graceful-degradation claim. Without retries
        // the outage visibly drops requests; with the retry budget the
        // same outage keeps goodput ≥ 95% of offered (measured: 100%).
        let baseline = simulate_fleet(&outage_cfg(1));
        assert!(
            baseline.dropped >= 10,
            "the outage must hurt a no-retry fleet: dropped {}",
            baseline.dropped
        );
        let sturdy = simulate_fleet(&outage_cfg(4));
        assert!(
            sturdy.goodput_fraction() >= 0.95,
            "retry + failover must preserve goodput: {}",
            sturdy.goodput_fraction()
        );
        assert!(
            sturdy.dropped < baseline.dropped,
            "retries must beat the baseline: {} !< {}",
            sturdy.dropped,
            baseline.dropped
        );
        let fs = sturdy.faults.as_ref().expect("fault run carries a summary");
        assert!(fs.retries >= 5, "the outage must force retries: {fs:?}");
        assert_eq!(fs.device_failures, 2);
        // Work stranded on the failed pair surfaces as failovers
        // (queued/in-flight requests re-dispatched) and/or lost
        // batches; demanding each individually would hinge on the
        // devices' exact occupancy at the fail instant.
        assert!(
            fs.failovers + fs.lost_batches > 0,
            "a two-device outage under load must strand work: {fs:?}"
        );
        // Conservation and accounting identities.
        assert_eq!(sturdy.fleet.completed + sturdy.dropped, sturdy.admitted);
        assert_eq!(fs.dropped, sturdy.dropped);
        // Exactly the scripted second of downtime on slots 0 and 1.
        assert_eq!(fs.downtime[0], Duration::from_secs(1));
        assert_eq!(fs.downtime[1], Duration::from_secs(1));
        assert_eq!(fs.downtime[2], Duration::ZERO);
        let end = sturdy.makespan.max(sturdy.horizon);
        assert!(fs.availability(2, end) == 1.0);
        assert!(fs.availability(0, end) < 1.0);
        // Every lost batch burns the cycles it had already consumed.
        if fs.lost_batches > 0 {
            assert!(fs.wasted_service > Duration::ZERO, "lost batches burn cycles: {fs:?}");
        }
    }

    #[test]
    fn fault_runs_are_bit_identical_per_seed() {
        let cfg = outage_cfg(4);
        assert_eq!(
            simulate_fleet(&cfg),
            simulate_fleet(&cfg),
            "fault machinery must stay deterministic"
        );
        let mut reseeded = cfg.clone();
        reseeded.seed ^= 1;
        assert_ne!(simulate_fleet(&cfg), simulate_fleet(&reseeded));
    }

    #[test]
    fn inert_fault_config_is_bit_identical_to_none() {
        let cfg = poisson_cfg(2, 0.8);
        let mut inert = cfg.clone();
        inert.faults = Some(FaultConfig::none());
        let plain = simulate_fleet(&cfg);
        let guarded = simulate_fleet(&inert);
        assert_eq!(plain, guarded, "all-knobs-off must not perturb the run");
        assert!(plain.faults.is_none(), "inert config reports no fault summary");
        assert_eq!(plain.dropped, 0);
    }

    #[test]
    fn autoscaler_restores_slo_after_a_device_failure() {
        // Acceptance: a 15 s single-device outage at ρ = 0.65. The
        // static fleet eats the capacity loss (attainment craters);
        // the autoscaled fleet spawns a replacement at the next tick
        // without operator input and holds the SLO.
        let dev = synthetic();
        let rate = 0.65 * dev.peak_rps() * 3.0;
        let slo = dev.service_time(8) * 2; // 168 ms e2e budget
        let mut cfg = ServeConfig::uniform(dev.clone(), 3, Workload::Poisson { rate_rps: rate });
        cfg.horizon = Duration::from_secs(30);
        cfg.num_experts = 0;
        cfg.faults = Some(FaultConfig {
            plan: FaultPlan::new(vec![FaultSpan::new(
                0,
                Duration::from_secs(10),
                Duration::from_secs(25),
            )]),
            ..FaultConfig::none()
        });
        let static_run = simulate_fleet(&cfg);
        let mut auto_cfg = cfg.clone();
        auto_cfg.autoscale = Some(AutoscaleConfig::for_device(dev, slo));
        let auto_run = simulate_fleet(&auto_cfg);
        let a_static = static_run.slo_attainment_admitted(slo);
        let a_auto = auto_run.slo_attainment_admitted(slo);
        assert!(
            a_auto >= 0.95,
            "autoscaler must hold the SLO through the outage: {a_auto}"
        );
        assert!(
            a_auto >= a_static + 0.10,
            "replacement capacity must visibly beat the static fleet: \
             auto {a_auto} vs static {a_static}"
        );
        let s = auto_run.autoscale.as_ref().unwrap();
        assert!(s.scale_ups >= 1, "the outage must trigger a replacement: {s:?}");
        // No deadline: nothing drops, the capacity hit only shows in
        // latency — conservation still exact on both runs.
        assert_eq!(static_run.fleet.completed, static_run.admitted);
        assert_eq!(auto_run.fleet.completed, auto_run.admitted);
    }

    #[test]
    fn seu_corruption_reruns_batches_and_stretches_the_run() {
        let mut clean = poisson_cfg(2, 0.7);
        clean.horizon = Duration::from_secs(10);
        let mut noisy = clean.clone();
        noisy.faults =
            Some(FaultConfig { seu_per_batch: 0.2, ..FaultConfig::none() });
        let a = simulate_fleet(&clean);
        let b = simulate_fleet(&noisy);
        let fs = b.faults.as_ref().expect("SEU run carries a summary");
        assert!(fs.seu_reruns > 0, "20% corruption must trigger re-runs");
        assert_eq!(b.fleet.completed, b.admitted, "re-runs lose no requests");
        // Re-executions burn real device time: strictly more busy time
        // and more executed batches than the clean run.
        assert!(b.fleet.busy > a.fleet.busy);
        assert!(b.fleet.batches > a.fleet.batches);
        assert_eq!(fs.device_failures, 0, "SEU is transient, not an outage");
    }

    #[test]
    fn hedging_duplicates_to_a_second_device() {
        // Aggressive hedge delay (well under typical e2e at ρ = 0.85)
        // so a healthy two-device run still hedges plenty.
        let mut cfg = poisson_cfg(2, 0.85);
        cfg.horizon = Duration::from_secs(10);
        cfg.faults = Some(FaultConfig {
            hedge_delay: Some(Duration::from_millis(20)),
            ..FaultConfig::none()
        });
        let r = simulate_fleet(&cfg);
        let fs = r.faults.as_ref().expect("hedged run carries a summary");
        assert!(fs.hedges > 0, "20 ms hedge delay must fire: {fs:?}");
        assert!(fs.hedge_wins <= fs.hedges);
        assert!(
            fs.hedge_wins > 0,
            "under queueing some hedge copies must win: {fs:?}"
        );
        assert_eq!(r.fleet.completed, r.admitted, "hedge losers are zombies, not losses");
        assert_eq!(r.dropped, 0, "no deadline ⇒ no drops");
    }

    #[test]
    fn total_outage_parks_requests_until_repair() {
        // Single device, scripted down over [1 s, 3 s): every arrival
        // in that window must park at fleet level and complete after
        // the repair — no deadline, so nothing may drop.
        let dev = synthetic();
        let mut cfg =
            ServeConfig::uniform(dev, 1, Workload::Poisson { rate_rps: 20.0 });
        cfg.horizon = Duration::from_secs(5);
        cfg.faults = Some(FaultConfig {
            plan: FaultPlan::new(vec![FaultSpan::new(
                0,
                Duration::from_secs(1),
                Duration::from_secs(3),
            )]),
            ..FaultConfig::none()
        });
        let r = simulate_fleet(&cfg);
        assert_eq!(r.fleet.completed, r.admitted, "parked requests must survive");
        assert_eq!(r.dropped, 0);
        let fs = r.faults.as_ref().unwrap();
        assert_eq!(fs.device_failures, 1);
        assert_eq!(fs.downtime[0], Duration::from_secs(2));
        // The outage shows up as tail latency: something waited
        // roughly the outage length.
        assert!(r.fleet.e2e.percentile(100.0) >= Duration::from_secs(1));
    }

    #[test]
    fn stochastic_mtbf_composes_with_the_scripted_plan() {
        let dev = synthetic();
        let mut cfg = ServeConfig::uniform(
            dev,
            3,
            Workload::Poisson { rate_rps: 60.0 },
        );
        cfg.horizon = Duration::from_secs(60);
        cfg.faults = Some(FaultConfig {
            plan: FaultPlan::new(vec![FaultSpan::new(
                2,
                Duration::from_secs(5),
                Duration::from_secs(6),
            )]),
            mtbf: Some(Duration::from_secs(15)),
            mttr: Duration::from_millis(500),
            ..FaultConfig::none()
        });
        let r = simulate_fleet(&cfg);
        let fs = r.faults.as_ref().unwrap();
        // The scripted second is a floor; the stochastic process must
        // add failures on top over 60 s at 15 s MTBF × 3 devices.
        assert!(
            fs.device_failures > 1,
            "stochastic process must contribute outages: {fs:?}"
        );
        assert!(fs.downtime[2] >= Duration::from_secs(1));
        assert_eq!(r.fleet.completed + r.dropped, r.admitted);
        // Determinism holds with the stochastic plan too.
        assert_eq!(simulate_fleet(&cfg), r);
    }

    #[test]
    fn closed_loop_users_survive_drops_and_keep_issuing() {
        // A dropped closed-loop request must re-activate its user
        // (think → next request), or the population silently shrinks.
        let mut cfg = closed_cfg(1, 8, Duration::from_millis(10));
        cfg.horizon = Duration::from_secs(10);
        cfg.faults = Some(FaultConfig {
            plan: FaultPlan::new(vec![FaultSpan::new(
                0,
                Duration::from_secs(2),
                Duration::from_secs(4),
            )]),
            deadline: Some(Duration::from_millis(200)),
            max_attempts: 2,
            ..FaultConfig::none()
        });
        let r = simulate_fleet(&cfg);
        assert!(r.dropped > 0, "a 2 s total outage against a 400 ms budget must drop");
        assert_eq!(r.fleet.completed + r.dropped, r.admitted);
        // Users kept going after the outage: arrivals continued in the
        // back half of the run (completion count ≫ what the pre-outage
        // window alone could admit… conservatively: more admitted than
        // could fit before the outage ended).
        let pre_outage_ceiling = 8.0 * (4.0 / 0.01);
        assert!(
            (r.admitted as f64) < pre_outage_ceiling,
            "sanity: ceiling math holds"
        );
        assert!(
            r.fleet.completed > r.dropped,
            "the fleet must still mostly serve: {} completed vs {} dropped",
            r.fleet.completed,
            r.dropped
        );
    }

    // ---- overload protection -----------------------------------------

    #[test]
    fn inert_overload_config_is_bit_identical_to_none() {
        // The PR 6 inertness contract extended to overload: all knobs
        // off must not perturb the run — not the dispatch sequence,
        // not the RNG streams, not the report.
        let cfg = poisson_cfg(2, 0.8);
        let mut inert = cfg.clone();
        inert.overload = Some(OverloadConfig::default());
        let plain = simulate_fleet(&cfg);
        let guarded = simulate_fleet(&inert);
        assert_eq!(plain, guarded, "inert overload must not perturb the run");
        assert!(plain.overload.is_none(), "inert config reports no overload summary");
        assert_eq!(plain.rejected, 0);
    }

    #[test]
    fn shadow_mode_classifies_without_enforcing() {
        // Shadow mode draws classes on a dedicated RNG stream and
        // splits the accounting, but the simulated fleet must be
        // exactly the unprotected one.
        let cfg = poisson_cfg(2, 0.8);
        let mut shadowed = cfg.clone();
        shadowed.overload = Some(OverloadConfig::shadow(ClassMix::standard()));
        let plain = simulate_fleet(&cfg);
        let shadow = simulate_fleet(&shadowed);
        assert_eq!(shadow.fleet, plain.fleet, "shadow must not change the fleet");
        assert_eq!(shadow.admitted, plain.admitted);
        assert_eq!(shadow.events, plain.events);
        assert_eq!(shadow.rejected, 0, "shadow never rejects");
        let ov = shadow.overload.as_ref().expect("shadow run carries a summary");
        let offered: u64 = ov.offered_by_class.iter().sum();
        let completed: u64 = ov.completed_by_class.iter().sum();
        assert_eq!(offered, shadow.admitted, "classes partition the offered count");
        assert_eq!(completed, shadow.fleet.completed);
        assert_eq!(ov.offered_by_class, ov.admitted_by_class);
        // The standard mix populates every class over ~hundreds of
        // arrivals.
        for (c, &n) in ov.offered_by_class.iter().enumerate() {
            assert!(n > 0, "class {c} never drawn from the standard mix");
        }
        let split: u64 = ov.e2e_by_class.iter().map(|s| s.count() as u64).sum();
        assert_eq!(split, shadow.fleet.e2e.count() as u64);
    }

    /// 3 synthetic devices offered 1.5× fleet peak under the standard
    /// class mix with priority-tiered resident limits.
    fn shed_cfg() -> ServeConfig {
        let mut cfg = poisson_cfg(3, 1.5);
        cfg.num_experts = 0;
        cfg.overload = Some(OverloadConfig {
            mix: ClassMix::standard(),
            admission: Some(AdmissionConfig::tiered(3 * 8)),
            ..OverloadConfig::default()
        });
        cfg
    }

    #[test]
    fn tiered_admission_sheds_low_priority_first_and_conserves() {
        let r = simulate_fleet(&shed_cfg());
        assert!(r.rejected > 0, "1.5× overload against tiered limits must shed");
        // Extended conservation, hard numbers: nothing vanishes.
        assert_eq!(r.fleet.completed + r.dropped + r.rejected, r.admitted);
        assert_eq!(r.dropped, 0, "no deadline ⇒ no drops, only rejects");
        let ov = r.overload.as_ref().expect("shedding run carries a summary");
        assert_eq!(ov.rejected, r.rejected);
        assert_eq!(ov.rejected, ov.rejected_by_class.iter().sum::<u64>());
        assert_eq!(ov.rejected, ov.rejected_rate + ov.rejected_queue);
        assert!(ov.rejected_queue > 0, "tiered limits are resident-count limits");
        for c in 0..NUM_CLASSES {
            assert_eq!(
                ov.admitted_by_class[c] + ov.rejected_by_class[c],
                ov.offered_by_class[c],
                "class {c} admission must partition its arrivals"
            );
        }
        // The priority point: shed fraction must be ordered by tier —
        // background sheds hardest, interactive least.
        let frac = |c: usize| ov.rejected_by_class[c] as f64 / ov.offered_by_class[c] as f64;
        assert!(
            frac(2) >= frac(1) && frac(1) >= frac(0),
            "shed fractions out of priority order: {:?}",
            [frac(0), frac(1), frac(2)]
        );
        assert!(
            frac(2) > frac(0) + 0.05,
            "background must shed visibly harder than interactive: {} vs {}",
            frac(2),
            frac(0)
        );
        // Bounded interactive queue ⇒ bounded interactive latency:
        // the class-0 p99 stays within the tier's wait budget
        // (limit − floor ≈ 16 slots ≈ 2 largest-batch services) plus
        // the batcher's own wait.
        let dev = synthetic();
        let budget = dev.service_time(8) * 4;
        assert!(
            ov.e2e_by_class[0].p99() <= budget,
            "interactive p99 {:?} blew the tiered budget {:?}",
            ov.e2e_by_class[0].p99(),
            budget
        );
        // Determinism holds with the full admission path live.
        assert_eq!(simulate_fleet(&shed_cfg()), r);
    }

    #[test]
    fn rate_caps_bound_sustained_admission() {
        // One device, 50 req/s offered, interactive capped at 20 req/s
        // (burst 10): over 10 s the bucket admits at most
        // 20·10 + burst (+1 in-flight token of slack).
        let dev = synthetic();
        let mut cfg = ServeConfig::uniform(dev, 1, Workload::Poisson { rate_rps: 50.0 });
        cfg.num_experts = 0;
        cfg.overload = Some(OverloadConfig {
            admission: Some(AdmissionConfig {
                rate_caps: [Some(20.0), None, None],
                burst: 10.0,
                ..AdmissionConfig::unlimited()
            }),
            ..OverloadConfig::default()
        });
        let r = simulate_fleet(&cfg);
        let ov = r.overload.as_ref().unwrap();
        assert!(ov.rejected_rate > 100, "a 2.5× rate cap must shed plenty");
        assert_eq!(ov.rejected_queue, 0, "no resident limits configured");
        assert!(
            ov.admitted_by_class[0] <= 20 * 10 + 11,
            "bucket leaked: admitted {}",
            ov.admitted_by_class[0]
        );
        assert_eq!(r.fleet.completed + r.rejected, r.admitted);
    }

    #[test]
    fn breakers_trip_on_timeout_streaks_and_recover() {
        // The PR 6 outage scenario (devices 0 and 1 down over
        // [10 s, 11 s) at ρ = 0.6 with a 500 ms deadline) leaves a
        // backlog whose deadline misses feed the breakers; the streak
        // must trip at least one breaker, mask the device, and the
        // half-open probe must close it again once service recovers.
        let mut cfg = outage_cfg(4);
        cfg.overload = Some(OverloadConfig {
            breaker: Some(BreakerConfig {
                trip_after: 3,
                cooldown: Duration::from_millis(100),
            }),
            ..OverloadConfig::default()
        });
        let r = simulate_fleet(&cfg);
        let ov = r.overload.as_ref().expect("breaker run carries a summary");
        assert!(ov.breaker_trips >= 1, "the outage backlog must trip a breaker: {ov:?}");
        assert!(
            ov.breaker_closes >= 1,
            "a recovered device must close its breaker: {ov:?}"
        );
        assert!(ov.breaker_closes <= ov.breaker_trips);
        assert_eq!(r.fleet.completed + r.dropped + r.rejected, r.admitted);
        assert_eq!(r.rejected, 0, "no admission knobs configured");
        // Bit-identical with the breaker state machine in the loop.
        assert_eq!(simulate_fleet(&cfg), r);
    }

    #[test]
    fn brownout_degrades_under_sustained_miss_and_recovers_capacity() {
        // Admission alone pins the resident count at the tier limits
        // and sheds ~1/3 of the offered load; with rejects counted as
        // misses the windowed attainment sits far below 0.9, so the
        // brownout controller must degrade. The 3/5-width table is
        // 5/3× faster — capacity then exceeds the 1.5× offered load,
        // so shedding visibly eases while degraded.
        let mut cfg = shed_cfg();
        let dev = synthetic();
        let window = dev.service_time(8); // 84 ms
        cfg.overload.as_mut().unwrap().brownout = Some(BrownoutConfig {
            window,
            slo: dev.service_time(8) * 3,
            enter_attainment: 0.9,
            exit_attainment: 0.98,
            enter_patience: 2,
            exit_patience: 6,
            degraded: vec![dev.degraded(3, 5); 3],
            accuracy_cost_per_request: 0.01,
        });
        let r = simulate_fleet(&cfg);
        let ov = r.overload.as_ref().expect("brownout run carries a summary");
        assert!(ov.brownout_enters >= 1, "sustained overload must trigger brownout: {ov:?}");
        assert!(ov.brownout_windows >= 2, "the fleet must dwell degraded: {ov:?}");
        assert!(ov.degraded_completions > 0, "degraded devices must serve: {ov:?}");
        assert!(
            (ov.accuracy_cost - ov.degraded_completions as f64 * 0.01).abs() < 1e-9,
            "accuracy cost is one multiply: {ov:?}"
        );
        assert_eq!(r.fleet.completed + r.dropped + r.rejected, r.admitted);
        // The graceful-degradation point: degraded capacity absorbs
        // load that admission alone had to shed.
        let shed_only = simulate_fleet(&shed_cfg());
        assert!(
            r.rejected < shed_only.rejected,
            "brownout must reduce shedding: {} !< {}",
            r.rejected,
            shed_only.rejected
        );
        assert_eq!(simulate_fleet(&cfg), r, "brownout path must stay deterministic");
    }

    #[test]
    fn closed_loop_users_survive_rejections_and_keep_issuing() {
        // A rejected closed-loop request must re-activate its user
        // (think → next request), mirroring the drop path — otherwise
        // shedding silently shrinks the population.
        let mut cfg = closed_cfg(1, 32, Duration::from_millis(10));
        cfg.overload = Some(OverloadConfig {
            mix: ClassMix::standard(),
            admission: Some(AdmissionConfig::tiered(8)),
            ..OverloadConfig::default()
        });
        let r = simulate_fleet(&cfg);
        assert!(r.rejected > 0, "32 users against limit 13 must shed");
        assert_eq!(r.fleet.completed + r.rejected, r.admitted);
        assert!(
            r.admitted > 32 * 4,
            "rejected users must keep issuing: only {} requests from 32 users",
            r.admitted
        );
        assert_eq!(r.fleet.e2e.count() as u64, r.fleet.completed);
    }

    #[test]
    fn per_class_attempt_budgets_shed_retries_by_priority() {
        // Same outage, but background gets a single attempt while
        // interactive keeps the full budget: background must account
        // for a visibly larger share of drops than its offered share.
        let mut cfg = outage_cfg(4);
        cfg.overload = Some(OverloadConfig {
            mix: ClassMix::standard(),
            admission: Some(AdmissionConfig {
                attempt_budget: [None, None, Some(1)],
                ..AdmissionConfig::unlimited()
            }),
            ..OverloadConfig::default()
        });
        let r = simulate_fleet(&cfg);
        assert!(r.dropped > 0, "the outage must drop single-attempt work");
        assert_eq!(r.fleet.completed + r.dropped + r.rejected, r.admitted);
        let ov = r.overload.as_ref().unwrap();
        // Drops per class: offered − completed − rejected.
        let drops = |c: usize| {
            ov.offered_by_class[c] - ov.completed_by_class[c] - ov.rejected_by_class[c]
        };
        let baseline = simulate_fleet(&outage_cfg(4));
        assert!(
            drops(2) > 0,
            "budget-1 background must drop through the outage"
        );
        assert!(
            drops(2) >= drops(0),
            "background (budget 1) must drop at least as much as \
             interactive (budget 4): {} vs {}",
            drops(2),
            drops(0)
        );
        assert!(
            r.dropped >= baseline.dropped,
            "tightening a class budget cannot reduce total drops: {} vs {}",
            r.dropped,
            baseline.dropped
        );
    }

    // ---- expert sharding ---------------------------------------------

    fn sharded_cfg() -> ServeConfig {
        let dev = synthetic();
        let rate = 0.5 * dev.peak_rps() * 4.0;
        let mut cfg = ServeConfig::uniform(dev, 4, Workload::Poisson { rate_rps: rate });
        cfg.horizon = Duration::from_secs(20);
        cfg.num_experts = 8;
        cfg.shard = Some(ShardConfig {
            top_k: 2,
            zipf_s: 1.2,
            replication: 2,
            hot_experts: 2,
            transfer_cost: Duration::from_micros(50),
            ..ShardConfig::default()
        });
        cfg
    }

    #[test]
    fn inert_shard_config_is_bit_identical_to_none() {
        let mut on = poisson_cfg(2, 0.8);
        on.shard = Some(ShardConfig::default()); // top_k == 0 ⇒ inert
        let off = poisson_cfg(2, 0.8);
        let a = simulate_fleet(&on);
        let b = simulate_fleet(&off);
        assert_eq!(a, b, "inert shard config must not perturb the run");
        assert!(a.shard.is_none(), "inert shard config must not produce a summary");
    }

    #[test]
    fn sharded_run_is_deterministic_and_conserves() {
        let cfg = sharded_cfg();
        let a = simulate_fleet(&cfg);
        let b = simulate_fleet(&cfg);
        assert_eq!(a, b, "sharded runs must be bit-identical per seed");
        let ss = a.shard.as_ref().expect("active shard config must produce a summary");
        assert_eq!(ss.routed, a.admitted, "every arrival is routed");
        assert_eq!(a.fleet.completed, a.admitted, "no faults, no caps: all complete");
        assert!(
            ss.transfers > 0,
            "top-2 routing over single-replica cold experts must fetch remotely"
        );
        // Sharding constrains dispatch, so the report must actually
        // differ from the same fleet without it.
        let mut unsharded = sharded_cfg();
        unsharded.shard = None;
        assert_ne!(simulate_fleet(&unsharded), a, "sharding must change the run");
    }

    #[test]
    fn capacity_factors_reroute_then_degrade() {
        // Skewed load against a tight per-expert token budget: the hot
        // expert's overflow reroutes to the request's secondary first,
        // and requests with every drawn expert over budget are served
        // degraded (expert-drop), never lost.
        let mut cfg = sharded_cfg();
        let sc = cfg.shard.as_mut().unwrap();
        sc.zipf_s = 2.0;
        sc.capacity = Some(CapacityConfig {
            window: Duration::from_millis(100),
            cap_tokens: 4,
        });
        sc.expert_drop_cost = 0.02;
        let r = simulate_fleet(&cfg);
        let ss = r.shard.as_ref().unwrap();
        assert!(ss.rerouted > 0, "overflow must reroute to secondaries first");
        assert!(ss.expert_drops > 0, "a 4-token window under skew must overflow top-2");
        assert_eq!(
            ss.degraded_completions, ss.expert_drops,
            "without faults or rejects every expert-dropped request completes degraded"
        );
        assert_eq!(r.fleet.completed, r.admitted, "degradation is not loss");
        assert!(
            (ss.accuracy_cost - ss.degraded_completions as f64 * 0.02).abs() < 1e-9,
            "accuracy proxy is one multiply over the degraded count"
        );
    }

    #[test]
    fn validate_rejects_bad_shard_configs() {
        let mut cfg = sharded_cfg();
        cfg.num_experts = 0;
        assert_eq!(cfg.validate(), Err(ServeConfigError::ShardWithoutExperts));

        let mut cfg = sharded_cfg();
        cfg.shard.as_mut().unwrap().top_k = 9;
        assert_eq!(
            cfg.validate(),
            Err(ServeConfigError::ShardTopKBounds { top_k: 9, num_experts: 8 })
        );

        let mut cfg = sharded_cfg();
        cfg.shard.as_mut().unwrap().replication = 5;
        assert_eq!(
            cfg.validate(),
            Err(ServeConfigError::ShardReplicationBounds { replication: 5, devices: 4 })
        );

        let mut cfg = sharded_cfg();
        cfg.shard.as_mut().unwrap().capacity =
            Some(CapacityConfig { window: Duration::ZERO, cap_tokens: 1 });
        assert_eq!(
            cfg.validate(),
            Err(ServeConfigError::ShardZeroWindow("capacity window"))
        );

        let mut cfg = sharded_cfg();
        cfg.autoscale =
            Some(AutoscaleConfig::for_device(synthetic(), Duration::from_millis(200)));
        assert_eq!(cfg.validate(), Err(ServeConfigError::ShardWithAutoscale));

        assert_eq!(sharded_cfg().validate(), Ok(()));
    }

    #[test]
    #[should_panic(expected = "both reshape the fleet mid-run")]
    fn brownout_plus_autoscale_panics_as_typed_config_error() {
        let dev = synthetic();
        let mut cfg = autoscaled_cfg();
        cfg.overload = Some(OverloadConfig {
            brownout: Some(BrownoutConfig {
                window: dev.service_time(8),
                slo: dev.service_time(8) * 3,
                enter_attainment: 0.9,
                exit_attainment: 0.98,
                enter_patience: 2,
                exit_patience: 6,
                degraded: vec![dev.degraded(3, 5); 1],
                accuracy_cost_per_request: 0.01,
            }),
            ..OverloadConfig::default()
        });
        simulate_fleet(&cfg);
    }

    /// Calibrated hot-expert outage: 8 devices, 8 experts (expert e
    /// homed on device e), Zipf s = 1.0 — expert 0 carries ≈ 37% of
    /// ρ = 0.5 traffic — and device 0 down over [10 s, 20 s) of a 30 s
    /// horizon.
    fn hot_outage_cfg(replication: usize) -> ServeConfig {
        let dev = synthetic();
        let rate = 0.5 * dev.peak_rps() * 8.0;
        let mut cfg = ServeConfig::uniform(dev, 8, Workload::Poisson { rate_rps: rate });
        cfg.horizon = Duration::from_secs(30);
        cfg.num_experts = 8;
        cfg.shard = Some(ShardConfig {
            top_k: 1,
            zipf_s: 1.0,
            replication,
            hot_experts: 1,
            ..ShardConfig::default()
        });
        cfg.faults = Some(FaultConfig {
            plan: FaultPlan::new(vec![FaultSpan::new(
                0,
                Duration::from_secs(10),
                Duration::from_secs(20),
            )]),
            ..FaultConfig::none()
        });
        cfg
    }

    #[test]
    fn replication_preserves_goodput_through_hot_expert_outage() {
        // Acceptance: the failover claim. With one replica, losing the
        // hot expert's home device black-holes ≈ 12% of traffic; with
        // RF = 2 the replica carries it and goodput holds ≥ 95%
        // (measured: 100%).
        let rf1 = simulate_fleet(&hot_outage_cfg(1));
        let ss1 = rf1.shard.as_ref().unwrap();
        assert!(
            rf1.goodput_fraction() < 0.95,
            "a sole replica must black-hole its expert through the outage: {}",
            rf1.goodput_fraction()
        );
        assert!(ss1.no_replica_drops > 0, "drops must be counted as no-replica");
        assert_eq!(
            rf1.dropped, ss1.no_replica_drops,
            "no deadline configured: every drop is a no-replica drop"
        );

        let rf2 = simulate_fleet(&hot_outage_cfg(2));
        assert!(
            rf2.goodput_fraction() >= 0.95,
            "RF = 2 must hold goodput through the same outage: {}",
            rf2.goodput_fraction()
        );
        assert!(
            rf2.dropped < rf1.dropped,
            "replication must beat the sole replica: {} !< {}",
            rf2.dropped,
            rf1.dropped
        );
    }

    /// Popularity-drift scenario: 4 devices, 8 experts, Zipf s = 2.0
    /// (the rank-0 expert carries ≈ 65% of ρ = 0.5 traffic — more than
    /// one device's peak), and the hot rank rotating one expert every
    /// 5 s. Only the first two experts start replicated, so from the
    /// second rotation on the hot expert sits on a single device
    /// unless the controller moves replicas under it.
    fn drift_cfg(rebalance: bool) -> ServeConfig {
        let dev = synthetic();
        let rate = 0.5 * dev.peak_rps() * 4.0;
        let mut cfg = ServeConfig::uniform(dev, 4, Workload::Poisson { rate_rps: rate });
        cfg.horizon = Duration::from_secs(30);
        cfg.num_experts = 8;
        cfg.shard = Some(ShardConfig {
            top_k: 1,
            zipf_s: 2.0,
            replication: 2,
            hot_experts: 2,
            drift: Some(DriftConfig { every: Duration::from_secs(5), shift: 1 }),
            rebalance: rebalance
                .then(|| RebalanceConfig { every: Duration::from_secs(1) }),
            ..ShardConfig::default()
        });
        cfg
    }

    #[test]
    fn rebalancing_beats_static_placement_under_drift() {
        // Acceptance: the drift claim. A static placement leaves each
        // rotation's hot expert on one device (≈ 125 req/s against a
        // ≈ 96 req/s device) for a full 5 s phase; the controller
        // re-replicates it within a second. Margin-asserted at 2×.
        let stat = simulate_fleet(&drift_cfg(false));
        let rebal = simulate_fleet(&drift_cfg(true));
        assert_eq!(stat.fleet.completed, stat.admitted, "static run still conserves");
        assert_eq!(rebal.fleet.completed, rebal.admitted, "rebalanced run conserves");
        let ss = rebal.shard.as_ref().unwrap();
        assert!(ss.rebalances > 0, "drift must trigger placement changes");
        assert!(ss.replica_adds > 0, "the hot expert must gain replicas");
        let (sp99, rp99) = (stat.fleet.e2e.p99(), rebal.fleet.e2e.p99());
        assert!(
            rp99 * 2 < sp99,
            "rebalancing must beat static placement on p99 by 2×: {:?} vs {:?}",
            rp99,
            sp99
        );
    }
}
