//! Fleet-serving study: a deterministic discrete-event simulator that
//! drives open-loop traffic through a fleet of UbiMoE accelerators.
//!
//! The paper evaluates one accelerator at single-image latency and
//! steady-state throughput (Tables I–III). A production deployment
//! faces a different question: given **open-loop arrivals** (users do
//! not wait politely for the queue to drain), dynamic batching onto
//! fixed-shape executables, and a **fleet** of devices behind a
//! dispatcher — what latency distribution does a given offered load
//! see, and where is the knee of the latency–throughput curve? This
//! module answers that on top of the existing stack:
//!
//! * each [`device::DeviceModel`] wraps an HAS-chosen configuration
//!   ([`crate::has`]) costed by the cycle-level simulator
//!   ([`crate::sim::engine`]) into a batch-size → service-time table;
//! * batch formation reuses the coordinator's dynamic batcher
//!   ([`crate::coordinator::batcher`]) verbatim, running on the DES's
//!   **virtual clock** (the [`crate::util::clock::Clock`] trait);
//! * dispatch generalizes the §III-C round-robin CU router to fleet
//!   scope ([`dispatch`]): round-robin, join-shortest-queue, and a
//!   MoE-expert-affinity policy;
//! * workloads ([`workload`]) are seeded Poisson / bursty-MMPP /
//!   replayable-trace generators;
//! * metrics ([`metrics`]) record per-device and fleet-wide queueing +
//!   service latency (p50/p99/p999), throughput, utilization, padding
//!   fraction and SLO attainment, with exact sample-level aggregation.
//!
//! Everything runs on virtual time with seeded RNG: a fixed
//! (config, seed) pair produces a bit-identical [`FleetReport`] —
//! enforced by tests here and proptests in `tests/serve_properties.rs`.

pub mod device;
pub mod dispatch;
pub mod events;
pub mod metrics;
pub mod workload;

use std::time::Duration;

use crate::util::clock::VirtualClock;
use crate::util::rng::Rng;
use device::{DeviceModel, DeviceState, InFlight};
use dispatch::{DispatchPolicy, Dispatcher};
use events::{EventKind, EventQueue};
pub use metrics::{DeviceMetrics, FleetReport};
pub use workload::Workload;

/// One fleet-serving experiment.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// The fleet (homogeneous replicas or a mixed fleet).
    pub devices: Vec<DeviceModel>,
    pub workload: Workload,
    pub dispatch: DispatchPolicy,
    /// Batcher flush timeout on every device.
    pub max_wait: Duration,
    /// Arrival horizon; the run then drains every admitted request.
    pub horizon: Duration,
    /// Seeds the workload and the expert-hint stream.
    pub seed: u64,
    /// Experts in the served model (dominant-expert hints are drawn
    /// uniformly from 0..num_experts). 0 means no experts to be
    /// affine to: hints are disabled and an ExpertAffinity dispatch
    /// falls back to join-shortest-queue (otherwise every zero hint
    /// would pin one home device).
    pub num_experts: usize,
}

impl ServeConfig {
    /// A homogeneous fleet of `n` replicas of `device` with sensible
    /// defaults: max_wait is half the unloaded batch-1 latency (so
    /// batching never adds more than ~50% of a service time to an
    /// idle-fleet request).
    pub fn uniform(device: DeviceModel, n: usize, workload: Workload) -> ServeConfig {
        assert!(n > 0);
        let max_wait = device.unloaded_latency() / 2;
        ServeConfig {
            devices: vec![device; n],
            workload,
            dispatch: DispatchPolicy::JoinShortestQueue,
            max_wait,
            horizon: Duration::from_secs(10),
            seed: 0xF1EE7,
            num_experts: 16,
        }
    }

    /// Fleet peak throughput: Σ per-device peak (the normalization
    /// for offered-load sweeps).
    pub fn fleet_peak_rps(&self) -> f64 {
        self.devices.iter().map(|d| d.peak_rps()).sum()
    }
}

fn try_start(
    st: &mut DeviceState,
    model: &DeviceModel,
    q: &mut EventQueue,
    now: Duration,
    idx: usize,
) {
    if st.in_flight.is_some() {
        return;
    }
    if let Some(batch) = st.batcher.next_batch() {
        let done = now + model.service_time(batch.batch_size);
        q.push(done, EventKind::BatchDone { device: idx });
        st.in_flight = Some(InFlight { started: now, batch });
    } else if let Some(oldest) = st.batcher.oldest_enqueued() {
        // Partial batch waiting: wake up when its oldest member hits
        // max_wait. Stale wakeups are no-ops, so dedup is only an
        // event-count optimization.
        let deadline = (oldest + st.batcher.config().max_wait).max(now);
        if st.deadline_scheduled != Some(deadline) {
            q.push(deadline, EventKind::FlushDeadline { device: idx });
            st.deadline_scheduled = Some(deadline);
        }
    }
}

/// Run the fleet simulation to completion (horizon + drain). Every
/// admitted request completes exactly once — asserted, and checked
/// again by the conservation proptests.
pub fn simulate_fleet(cfg: &ServeConfig) -> FleetReport {
    assert!(!cfg.devices.is_empty(), "empty fleet");
    let arrivals = cfg.workload.arrivals(cfg.horizon, cfg.seed);
    let offered_rps = arrivals.len() as f64 / cfg.horizon.as_secs_f64().max(1e-12);

    // Dominant-expert hint per request (a gate-profile proxy; the
    // runtime would take this from the previous frame's routing).
    let mut hint_rng = Rng::new(cfg.seed ^ 0xA551_6E0E);
    let hints: Vec<usize> = arrivals
        .iter()
        .map(|_| if cfg.num_experts > 0 { hint_rng.below(cfg.num_experts) } else { 0 })
        .collect();

    let clock = VirtualClock::new();
    let mut devices: Vec<DeviceState> = cfg
        .devices
        .iter()
        .map(|m| DeviceState::new(m, cfg.max_wait, clock.clone()))
        .collect();
    // No experts ⇒ no affinity to exploit: fall back to JSQ rather
    // than pinning every request's zero hint to device 0.
    let policy = if cfg.num_experts == 0 && cfg.dispatch == DispatchPolicy::ExpertAffinity {
        DispatchPolicy::JoinShortestQueue
    } else {
        cfg.dispatch
    };
    let mut dispatcher = Dispatcher::new(policy);
    let mut q = EventQueue::new();
    for (req, &t) in arrivals.iter().enumerate() {
        q.push(t, EventKind::Arrival { req });
    }

    let mut completed = vec![false; arrivals.len()];
    let mut makespan = Duration::ZERO;
    // Scratch for the dispatch load signal — refreshed per arrival,
    // never reallocated in the event hot loop.
    let mut loads = vec![0usize; devices.len()];

    while let Some(ev) = q.pop() {
        clock.advance_to(ev.at);
        match ev.kind {
            EventKind::Arrival { req } => {
                for (l, d) in loads.iter_mut().zip(&devices) {
                    *l = d.load();
                }
                let d = dispatcher.pick(&loads, hints[req]);
                devices[d].batcher.push(req);
                try_start(&mut devices[d], &cfg.devices[d], &mut q, ev.at, d);
            }
            EventKind::FlushDeadline { device } => {
                devices[device].deadline_scheduled = None;
                try_start(&mut devices[device], &cfg.devices[device], &mut q, ev.at, device);
            }
            EventKind::BatchDone { device } => {
                let st = &mut devices[device];
                let inf = st.in_flight.take().expect("BatchDone without a batch in flight");
                let now = ev.at;
                makespan = makespan.max(now);
                st.metrics.batches += 1;
                st.metrics.slots += inf.batch.batch_size as u64;
                st.metrics.padded_slots += inf.batch.padding as u64;
                st.metrics.busy += now - inf.started;
                for r in &inf.batch.requests {
                    let req = r.payload;
                    assert!(!completed[req], "request {req} completed twice");
                    completed[req] = true;
                    st.metrics.completed += 1;
                    // enqueued == arrival time (dispatch is immediate),
                    // so e2e decomposes exactly into wait + service.
                    debug_assert_eq!(r.enqueued, arrivals[req]);
                    st.metrics.queue_wait.record(inf.started - r.enqueued);
                    st.metrics.service.record(now - inf.started);
                    st.metrics.e2e.record(now - arrivals[req]);
                }
                try_start(&mut devices[device], &cfg.devices[device], &mut q, ev.at, device);
            }
        }
    }

    assert!(
        completed.iter().all(|&c| c),
        "DES terminated with unserved requests (batcher stall)"
    );

    let per_device: Vec<DeviceMetrics> = devices.into_iter().map(|d| d.metrics).collect();
    let mut fleet = DeviceMetrics::default();
    for d in &per_device {
        fleet.merge_from(d);
    }
    FleetReport {
        per_device,
        fleet,
        admitted: arrivals.len() as u64,
        offered_rps,
        horizon: cfg.horizon,
        makespan,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::Platform;

    fn synthetic() -> DeviceModel {
        DeviceModel::from_latencies(
            "syn".into(),
            Duration::from_millis(4),
            Duration::from_millis(10),
            &[1, 2, 4, 8],
        )
    }

    fn poisson_cfg(n_dev: usize, util: f64) -> ServeConfig {
        let dev = synthetic();
        let rate = util * dev.peak_rps() * n_dev as f64;
        ServeConfig::uniform(dev, n_dev, Workload::Poisson { rate_rps: rate })
    }

    #[test]
    fn conserves_every_request() {
        let r = simulate_fleet(&poisson_cfg(3, 0.7));
        assert_eq!(r.fleet.completed, r.admitted);
        assert_eq!(r.fleet.e2e.count() as u64, r.admitted);
        let per: u64 = r.per_device.iter().map(|d| d.completed).sum();
        assert_eq!(per, r.admitted);
        assert!(r.makespan >= r.horizon / 2);
    }

    #[test]
    fn fixed_seed_is_bit_identical() {
        let cfg = poisson_cfg(4, 0.8);
        let a = simulate_fleet(&cfg);
        let b = simulate_fleet(&cfg);
        assert_eq!(a, b, "same seed/config must give identical fleet metrics");
        let mut cfg2 = cfg.clone();
        cfg2.seed ^= 1;
        let c = simulate_fleet(&cfg2);
        assert_ne!(a, c, "different seed should perturb the run");
    }

    #[test]
    fn subcritical_load_is_served_at_offered_rate() {
        let r = simulate_fleet(&poisson_cfg(2, 0.4));
        let ratio = r.achieved_rps() / r.offered_rps;
        assert!((0.9..=1.01).contains(&ratio), "achieved/offered = {ratio}");
        // Light load: e2e stays on the scale of a few batch services
        // (service(8) = 84 ms for the synthetic device), far from the
        // seconds-scale waits of the overload tests.
        let bound = Duration::from_millis(3 * 84);
        assert!(r.fleet.e2e.p99() < bound, "p99 {:?}", r.fleet.e2e.p99());
    }

    #[test]
    fn throughput_scales_with_fleet_size() {
        // Offered load = 8x one device's peak: saturates a lone
        // device AND a 4-device fleet, so the sustained completion
        // rate must scale ~4x with the fleet.
        let one = simulate_fleet(&poisson_cfg(1, 8.0));
        let mut big = poisson_cfg(1, 8.0); // same offered load…
        big.devices = vec![synthetic(); 4]; // …4x the fleet
        let four = simulate_fleet(&big);
        let speedup = four.achieved_rps() / one.achieved_rps();
        assert!(speedup > 3.0, "fleet scaling {speedup}");
    }

    #[test]
    fn overload_queues_grow_and_tail_explodes() {
        let calm = simulate_fleet(&poisson_cfg(2, 0.4));
        let hot = simulate_fleet(&poisson_cfg(2, 1.3));
        assert!(hot.makespan > hot.horizon, "overload must drain past the horizon");
        assert!(
            hot.fleet.e2e.p99() > 3 * calm.fleet.e2e.p99(),
            "p99 {:?} !>> {:?}",
            hot.fleet.e2e.p99(),
            calm.fleet.e2e.p99()
        );
    }

    #[test]
    fn padding_appears_when_executables_are_coarse() {
        // Only a batch-4 executable: a trickle of lone requests must
        // pad 3 of every 4 slots.
        let dev = DeviceModel::from_latencies(
            "coarse".into(),
            Duration::ZERO,
            Duration::from_millis(5),
            &[4],
        );
        let mut cfg = ServeConfig::uniform(dev, 1, Workload::Poisson { rate_rps: 3.0 });
        cfg.horizon = Duration::from_secs(20);
        let r = simulate_fleet(&cfg);
        assert!(r.fleet.padding_fraction() > 0.3, "{}", r.fleet.padding_fraction());
        // And with a batch-1 executable available, padding vanishes
        // at the same load.
        let fine = DeviceModel::from_latencies(
            "fine".into(),
            Duration::ZERO,
            Duration::from_millis(5),
            &[1, 4],
        );
        let mut cfg2 = ServeConfig::uniform(fine, 1, Workload::Poisson { rate_rps: 3.0 });
        cfg2.horizon = Duration::from_secs(20);
        let r2 = simulate_fleet(&cfg2);
        assert!(r2.fleet.padding_fraction() < r.fleet.padding_fraction());
    }

    #[test]
    fn bursty_traffic_has_worse_tail_than_poisson_at_same_mean() {
        let dev = synthetic();
        let mean = 0.75 * dev.peak_rps();
        let mut poisson =
            ServeConfig::uniform(dev.clone(), 1, Workload::Poisson { rate_rps: mean });
        poisson.horizon = Duration::from_secs(30);
        let mut bursty = ServeConfig::uniform(
            dev,
            1,
            Workload::Mmpp2 {
                rate_low_rps: 0.3 * mean,
                rate_high_rps: 1.7 * mean,
                mean_dwell: Duration::from_secs(2),
            },
        );
        bursty.horizon = Duration::from_secs(30);
        let p = simulate_fleet(&poisson);
        let b = simulate_fleet(&bursty);
        assert!(
            b.fleet.e2e.p99() > p.fleet.e2e.p99(),
            "bursty p99 {:?} !> poisson p99 {:?}",
            b.fleet.e2e.p99(),
            p.fleet.e2e.p99()
        );
    }

    #[test]
    fn affinity_without_experts_falls_back_to_jsq() {
        let mut aff = poisson_cfg(3, 0.9);
        aff.dispatch = DispatchPolicy::ExpertAffinity;
        aff.num_experts = 0;
        let mut jsq = aff.clone();
        jsq.dispatch = DispatchPolicy::JoinShortestQueue;
        assert_eq!(
            simulate_fleet(&aff),
            simulate_fleet(&jsq),
            "0 experts: affinity must degrade to JSQ, not pin device 0"
        );
    }

    #[test]
    fn trace_replay_reproduces_the_poisson_run() {
        let dev = synthetic();
        let rate = 0.6 * dev.peak_rps();
        let mut cfg = ServeConfig::uniform(dev, 2, Workload::Poisson { rate_rps: rate });
        cfg.horizon = Duration::from_secs(5);
        let live = simulate_fleet(&cfg);
        let mut replay = cfg.clone();
        replay.workload = cfg.workload.to_trace(cfg.horizon, cfg.seed);
        let replayed = simulate_fleet(&replay);
        assert_eq!(live, replayed, "captured trace must replay bit-identically");
    }

    /// Acceptance: a 4-device U280 fleet (sim-backed cost model) shows
    /// the saturation knee — p99 rising sharply past it.
    #[test]
    fn u280_fleet_curve_saturates() {
        let dev = crate::report::serving::demo_device(&Platform::u280());
        let peak = dev.peak_rps() * 4.0;
        let p99_at = |util: f64| {
            let mut cfg = ServeConfig::uniform(
                dev.clone(),
                4,
                Workload::Poisson { rate_rps: util * peak },
            );
            cfg.horizon = Duration::from_secs(10);
            let r = simulate_fleet(&cfg);
            assert_eq!(r.fleet.completed, r.admitted);
            r.fleet.e2e.p99()
        };
        let below = p99_at(0.4);
        let past = p99_at(1.15);
        assert!(
            past > 3 * below,
            "no saturation knee: p99 {below:?} @0.4 vs {past:?} @1.15"
        );
    }
}
