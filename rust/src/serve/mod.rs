//! Fleet-serving study: a deterministic discrete-event simulator that
//! drives open-loop traffic through a fleet of UbiMoE accelerators.
//!
//! The paper evaluates one accelerator at single-image latency and
//! steady-state throughput (Tables I–III). A production deployment
//! faces a different question: given **open-loop arrivals** (users do
//! not wait politely for the queue to drain), dynamic batching onto
//! fixed-shape executables, and a **fleet** of devices behind a
//! dispatcher — what latency distribution does a given offered load
//! see, and where is the knee of the latency–throughput curve? This
//! module answers that on top of the existing stack:
//!
//! * each [`device::DeviceModel`] wraps an HAS-chosen configuration
//!   ([`crate::has`]) costed by the cycle-level simulator
//!   ([`crate::sim::engine`]) into a batch-size → service-time table,
//!   with a dominant-expert **residency discount** so the
//!   expert-affinity policy's weight-cache locality shows up in
//!   service times ([`device::RESIDENCY_FILL_DIV`]);
//! * batch formation reuses the coordinator's dynamic batcher
//!   ([`crate::coordinator::batcher`]) verbatim, running on the DES's
//!   **virtual clock** (the [`crate::util::clock::Clock`] trait);
//! * dispatch generalizes the §III-C round-robin CU router to fleet
//!   scope ([`dispatch`]): round-robin, join-shortest-queue, a
//!   MoE-expert-affinity policy, and heterogeneity-aware
//!   shortest-expected-delay (the tournament tree re-keyed from queue
//!   length to expected-completion ns via each device's service LUT —
//!   the ROADMAP mixed-fleet item, studied in
//!   [`crate::report::serving::mixed_fleet_table`]);
//! * workloads ([`workload`]) are seeded Poisson / bursty-MMPP /
//!   replayable-trace generators;
//! * metrics ([`metrics`]) record per-device and fleet-wide queueing +
//!   service latency (p50/p99/p999), throughput, utilization, padding
//!   fraction and SLO attainment.
//!
//! **Scale.** The hot path is built for tens-of-millions-of-request
//! horizons (`benches/serve_scale.rs` drives ≥1M requests through a
//! 16-device fleet; CI records the events/s row in BENCH_serve.json):
//!
//! * **Streaming metrics.** Latency recorders are log-bucketed
//!   streaming histograms — O(1) record, memory bounded by the value
//!   range, exact bucket-wise `merge`. Resolution contract
//!   ([`crate::coordinator::metrics::LatencyStats`]): percentiles are
//!   exact at rank 1 and rank n (so min/max/tiny-n queries lose
//!   nothing), exact below 256 µs, and otherwise land within one
//!   1/128-wide (< 1%) bucket **above** the exact nearest-rank
//!   sample; `count`, `mean` and `max` are exact. The PR-2
//!   store-all-samples recorder is retained on the test path and a
//!   proptest pins the histogram to it.
//! * **Indexed dispatch.** Device loads live in a tournament tree
//!   ([`dispatch::LoadTracker`]) updated on dispatch/completion, so
//!   an arrival costs O(log fleet), not an O(fleet) rescan; tie-breaks
//!   (lowest index) are proptested identical to the scan.
//! * **Lean, bounded event heap.** Arrivals stream from the sorted
//!   schedule instead of being preloaded; superseded flush deadlines
//!   are cancelled by generation instead of accumulating as no-op
//!   wakeups. The heap holds O(devices + in-flight) 24-byte entries
//!   regardless of the request count (regression-tested).
//!
//! Everything runs on virtual time with seeded RNG: a fixed
//! (config, seed) pair produces a bit-identical [`FleetReport`] —
//! enforced by tests here and proptests in `tests/serve_properties.rs`.

pub mod device;
pub mod dispatch;
pub mod events;
pub mod metrics;
pub mod workload;

use std::time::Duration;

use crate::coordinator::batcher::Batch;
use crate::util::clock::VirtualClock;
use crate::util::rng::Rng;
use device::{DeviceModel, DeviceState, InFlight};
use dispatch::{DispatchPolicy, Dispatcher, LoadTracker};
use events::{EventKind, EventQueue};
pub use metrics::{DeviceMetrics, FleetReport};
pub use workload::Workload;

/// One fleet-serving experiment.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// The fleet (homogeneous replicas or a mixed fleet).
    pub devices: Vec<DeviceModel>,
    pub workload: Workload,
    pub dispatch: DispatchPolicy,
    /// Batcher flush timeout on every device.
    pub max_wait: Duration,
    /// Arrival horizon; the run then drains every admitted request.
    /// Must be positive — a zero horizon makes offered load undefined
    /// and is rejected by [`simulate_fleet`].
    pub horizon: Duration,
    /// Seeds the workload and the expert-hint stream.
    pub seed: u64,
    /// Experts in the served model (dominant-expert hints are drawn
    /// uniformly from 0..num_experts). 0 means no experts to be
    /// affine to: hints are disabled, the residency discount never
    /// applies, and an ExpertAffinity dispatch falls back to
    /// join-shortest-queue (otherwise every zero hint would pin one
    /// home device).
    pub num_experts: usize,
}

impl ServeConfig {
    /// A homogeneous fleet of `n` replicas of `device` with sensible
    /// defaults: max_wait is half the unloaded batch-1 latency (so
    /// batching never adds more than ~50% of a service time to an
    /// idle-fleet request).
    pub fn uniform(device: DeviceModel, n: usize, workload: Workload) -> ServeConfig {
        assert!(n > 0);
        let max_wait = device.unloaded_latency() / 2;
        ServeConfig {
            devices: vec![device; n],
            workload,
            dispatch: DispatchPolicy::JoinShortestQueue,
            max_wait,
            horizon: Duration::from_secs(10),
            seed: 0xF1EE7,
            num_experts: 16,
        }
    }

    /// A heterogeneous fleet (e.g. a ZCU102 edge tier next to a U280
    /// core tier), same defaults as [`ServeConfig::uniform`] except
    /// max_wait is half the *fastest* device's unloaded batch-1
    /// latency, so batching never dominates an idle-fleet request on
    /// any tier.
    pub fn mixed(devices: Vec<DeviceModel>, workload: Workload) -> ServeConfig {
        assert!(!devices.is_empty());
        let max_wait = devices.iter().map(|d| d.unloaded_latency()).min().unwrap() / 2;
        ServeConfig {
            devices,
            workload,
            dispatch: DispatchPolicy::JoinShortestQueue,
            max_wait,
            horizon: Duration::from_secs(10),
            seed: 0xF1EE7,
            num_experts: 16,
        }
    }

    /// Fleet peak throughput: Σ per-device peak (the normalization
    /// for offered-load sweeps).
    pub fn fleet_peak_rps(&self) -> f64 {
        self.devices.iter().map(|d| d.peak_rps()).sum()
    }
}

/// Expert-hint context threaded through batch starts: per-request
/// dominant-expert hints, the enable flag, and a reusable scratch
/// buffer for the per-batch mode computation — the hot loop never
/// allocates for it.
struct HintCtx<'a> {
    hints: &'a [u32],
    enabled: bool,
    /// (expert, count) accumulator reused across batches.
    scratch: Vec<(u32, u32)>,
}

/// Dominant expert of a formed batch: the most frequent member hint,
/// smallest expert id on ties (deterministic). One O(B) counting pass
/// over the members (distinct hints ≤ B), not a rescan per member.
fn dominant_expert(batch: &Batch<usize>, hints: &[u32], scratch: &mut Vec<(u32, u32)>) -> u32 {
    scratch.clear();
    for r in &batch.requests {
        let h = hints[r.payload];
        match scratch.iter_mut().find(|(e, _)| *e == h) {
            Some((_, c)) => *c += 1,
            None => scratch.push((h, 1)),
        }
    }
    let mut best_count = 0u32;
    let mut best_hint = u32::MAX;
    for &(e, c) in scratch.iter() {
        if c > best_count || (c == best_count && e < best_hint) {
            best_count = c;
            best_hint = e;
        }
    }
    best_hint
}

fn try_start(
    st: &mut DeviceState,
    model: &DeviceModel,
    q: &mut EventQueue,
    now: Duration,
    idx: usize,
    hc: &mut HintCtx<'_>,
) {
    if st.in_flight.is_some() {
        return;
    }
    if let Some(batch) = st.batcher.next_batch() {
        let service = if hc.enabled {
            let dom = dominant_expert(&batch, hc.hints, &mut hc.scratch);
            let resident = st.resident_expert == Some(dom);
            st.resident_expert = Some(dom);
            model.service_time_with_residency(batch.batch_size, resident)
        } else {
            model.service_time(batch.batch_size)
        };
        q.push(now + service, EventKind::BatchDone { device: idx as u32 });
        st.in_flight = Some(InFlight { started: now, batch });
    } else if let Some(oldest) = st.batcher.oldest_enqueued() {
        // Partial batch waiting: wake up when its oldest member hits
        // max_wait. If that deadline is already scheduled, the live
        // event covers it; otherwise schedule a fresh generation —
        // any previously live event with an older generation is
        // thereby cancelled (skipped on pop), so the heap never
        // accumulates superseded deadlines.
        let deadline = (oldest + st.batcher.config().max_wait).max(now);
        let already = matches!(st.deadline, Some((d, _)) if d == deadline);
        if !already {
            let gen = st.next_deadline_gen;
            st.next_deadline_gen = st.next_deadline_gen.wrapping_add(1);
            q.push(deadline, EventKind::FlushDeadline { device: idx as u32, gen });
            st.deadline = Some((deadline, gen));
        }
    }
}

/// Run the fleet simulation to completion (horizon + drain). Every
/// admitted request completes exactly once — asserted, and checked
/// again by the conservation proptests.
pub fn simulate_fleet(cfg: &ServeConfig) -> FleetReport {
    assert!(!cfg.devices.is_empty(), "empty fleet");
    assert!(
        !cfg.horizon.is_zero(),
        "zero-horizon ServeConfig: offered load is undefined (horizon must be positive)"
    );
    let arrivals = cfg.workload.arrivals(cfg.horizon, cfg.seed);
    let offered_rps = metrics::rate_per_sec(arrivals.len() as u64, cfg.horizon);

    // Dominant-expert hint per request (a gate-profile proxy; the
    // runtime would take this from the previous frame's routing).
    let mut hint_rng = Rng::new(cfg.seed ^ 0xA551_6E0E);
    let hints: Vec<u32> = arrivals
        .iter()
        .map(|_| if cfg.num_experts > 0 { hint_rng.below(cfg.num_experts) as u32 } else { 0 })
        .collect();
    let mut hint_ctx =
        HintCtx { hints: &hints, enabled: cfg.num_experts > 0, scratch: Vec::new() };

    let clock = VirtualClock::new();
    let mut devices: Vec<DeviceState> = cfg
        .devices
        .iter()
        .map(|m| DeviceState::new(m, cfg.max_wait, clock.clone()))
        .collect();
    // No experts ⇒ no affinity to exploit: fall back to JSQ rather
    // than pinning every request's zero hint to device 0.
    let policy = if cfg.num_experts == 0 && cfg.dispatch == DispatchPolicy::ExpertAffinity {
        DispatchPolicy::JoinShortestQueue
    } else {
        cfg.dispatch
    };
    let mut dispatcher = Dispatcher::new(policy);
    let mut q = EventQueue::new();
    // Incremental load signal: +1 on dispatch, −occupancy on batch
    // completion (a batch start moves requests queue → flight, net 0).
    // Shortest-expected-delay re-keys the same tournament tree from
    // queue length to expected-completion ns derived from each
    // device's own service LUT — mixed-fleet dispatch stays O(log n)
    // per arrival while becoming capacity-aware.
    let mut loads = if policy == DispatchPolicy::ShortestExpectedDelay {
        LoadTracker::with_expected_delay(
            cfg.devices.iter().map(|d| d.expected_delay_weights()).collect(),
        )
    } else {
        LoadTracker::new(devices.len())
    };

    let mut next_arrival = 0usize;
    let mut completed = vec![false; arrivals.len()];
    let mut makespan = Duration::ZERO;
    let mut events: u64 = 0;
    let mut peak_events: u64 = 0;

    loop {
        // Merge the sorted arrival stream with the heap; arrivals win
        // ties (they carried the lowest sequence numbers when they
        // were preloaded, and still must fire first at equal times).
        let take_arrival = match (arrivals.get(next_arrival), q.next_at()) {
            (Some(&t), Some(h)) => t <= h,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => break,
        };
        if take_arrival {
            let req = next_arrival;
            let at = arrivals[req];
            next_arrival += 1;
            clock.advance_to(at);
            debug_assert!(
                devices.iter().enumerate().all(|(i, d)| loads.get(i) == d.load()),
                "load tracker drifted from device state"
            );
            let d = dispatcher.pick_indexed(&loads, hint_ctx.hints[req] as usize);
            loads.add(d, 1);
            devices[d].batcher.push(req);
            try_start(&mut devices[d], &cfg.devices[d], &mut q, at, d, &mut hint_ctx);
        } else {
            let ev = q.pop().expect("heap event vanished between peek and pop");
            let now = ev.at();
            clock.advance_to(now);
            match ev.kind {
                EventKind::Arrival { .. } => {
                    unreachable!("arrivals stream outside the heap")
                }
                EventKind::FlushDeadline { device, gen } => {
                    let device = device as usize;
                    // Generation mismatch ⇒ this deadline was
                    // superseded: cancelled, skip.
                    if devices[device].deadline.map(|(_, g)| g) == Some(gen) {
                        devices[device].deadline = None;
                        try_start(
                            &mut devices[device],
                            &cfg.devices[device],
                            &mut q,
                            now,
                            device,
                            &mut hint_ctx,
                        );
                    }
                }
                EventKind::BatchDone { device } => {
                    let device = device as usize;
                    let st = &mut devices[device];
                    let inf =
                        st.in_flight.take().expect("BatchDone without a batch in flight");
                    makespan = makespan.max(now);
                    st.metrics.batches += 1;
                    st.metrics.slots += inf.batch.batch_size as u64;
                    st.metrics.padded_slots += inf.batch.padding as u64;
                    st.metrics.busy += now - inf.started;
                    loads.sub(device, inf.batch.requests.len());
                    for r in &inf.batch.requests {
                        let req = r.payload;
                        assert!(!completed[req], "request {req} completed twice");
                        completed[req] = true;
                        st.metrics.completed += 1;
                        // enqueued == arrival time (dispatch is
                        // immediate), so e2e decomposes exactly into
                        // wait + service.
                        debug_assert_eq!(r.enqueued, arrivals[req]);
                        st.metrics.queue_wait.record(inf.started - r.enqueued);
                        st.metrics.service.record(now - inf.started);
                        st.metrics.e2e.record(now - arrivals[req]);
                    }
                    try_start(
                        &mut devices[device],
                        &cfg.devices[device],
                        &mut q,
                        now,
                        device,
                        &mut hint_ctx,
                    );
                }
            }
        }
        events += 1;
        peak_events = peak_events.max(q.len() as u64);
    }

    assert!(
        completed.iter().all(|&c| c),
        "DES terminated with unserved requests (batcher stall)"
    );

    let per_device: Vec<DeviceMetrics> = devices.into_iter().map(|d| d.metrics).collect();
    let mut fleet = DeviceMetrics::default();
    for d in &per_device {
        fleet.merge_from(d);
    }
    FleetReport {
        per_device,
        fleet,
        admitted: arrivals.len() as u64,
        offered_rps,
        horizon: cfg.horizon,
        makespan,
        events,
        peak_events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::Platform;

    fn synthetic() -> DeviceModel {
        DeviceModel::from_latencies(
            "syn".into(),
            Duration::from_millis(4),
            Duration::from_millis(10),
            &[1, 2, 4, 8],
        )
    }

    fn poisson_cfg(n_dev: usize, util: f64) -> ServeConfig {
        let dev = synthetic();
        let rate = util * dev.peak_rps() * n_dev as f64;
        ServeConfig::uniform(dev, n_dev, Workload::Poisson { rate_rps: rate })
    }

    #[test]
    fn conserves_every_request() {
        let r = simulate_fleet(&poisson_cfg(3, 0.7));
        assert_eq!(r.fleet.completed, r.admitted);
        assert_eq!(r.fleet.e2e.count() as u64, r.admitted);
        let per: u64 = r.per_device.iter().map(|d| d.completed).sum();
        assert_eq!(per, r.admitted);
        assert!(r.makespan >= r.horizon / 2);
        assert!(r.events >= r.admitted, "every arrival is an event");
    }

    #[test]
    fn fixed_seed_is_bit_identical() {
        let cfg = poisson_cfg(4, 0.8);
        let a = simulate_fleet(&cfg);
        let b = simulate_fleet(&cfg);
        assert_eq!(a, b, "same seed/config must give identical fleet metrics");
        let mut cfg2 = cfg.clone();
        cfg2.seed ^= 1;
        let c = simulate_fleet(&cfg2);
        assert_ne!(a, c, "different seed should perturb the run");
    }

    #[test]
    #[should_panic(expected = "zero-horizon")]
    fn zero_horizon_config_rejected() {
        let mut cfg = poisson_cfg(1, 0.5);
        cfg.horizon = Duration::ZERO;
        let _ = simulate_fleet(&cfg);
    }

    #[test]
    fn heap_stays_bounded_under_sustained_partial_batches() {
        // Regression for stale-deadline accumulation AND arrival
        // preloading: a coarse batch-8-only executable under a load
        // that almost never fills it forces a deadline flush per
        // batch for the whole horizon. The heap must stay
        // O(devices + in-flight), independent of the admitted count.
        let dev = DeviceModel::from_latencies(
            "partial".into(),
            Duration::ZERO,
            Duration::from_millis(2),
            &[8],
        );
        let mut cfg = ServeConfig::uniform(dev, 4, Workload::Poisson { rate_rps: 400.0 });
        cfg.horizon = Duration::from_secs(20);
        let r = simulate_fleet(&cfg);
        assert!(r.admitted > 5_000, "need sustained load, got {}", r.admitted);
        assert_eq!(r.fleet.completed, r.admitted);
        assert!(
            r.peak_events <= 6 * 4 + 8,
            "heap grew with request count: peak {} for {} admitted",
            r.peak_events,
            r.admitted
        );
    }

    #[test]
    fn residency_separates_affinity_from_jsq() {
        // The ROADMAP cache-affinity item, observable end to end:
        // with 4 experts homed on 4 devices, expert-affinity dispatch
        // repeats each device's dominant expert batch after batch, so
        // the residency discount keeps recovering fill time — total
        // busy time (Σ service) must come out strictly below JSQ,
        // which scatters experts across devices.
        let dev = DeviceModel::from_latencies(
            "aff".into(),
            Duration::from_millis(8),
            Duration::from_millis(2),
            &[1, 2, 4, 8],
        );
        let rate = 0.8 * dev.peak_rps() * 4.0;
        let mut aff = ServeConfig::uniform(dev, 4, Workload::Poisson { rate_rps: rate });
        aff.dispatch = DispatchPolicy::ExpertAffinity;
        aff.num_experts = 4;
        let mut jsq = aff.clone();
        jsq.dispatch = DispatchPolicy::JoinShortestQueue;
        let a = simulate_fleet(&aff);
        let j = simulate_fleet(&jsq);
        assert_eq!(a.fleet.completed, j.fleet.completed);
        assert!(
            a.fleet.busy < j.fleet.busy,
            "affinity busy {:?} !< jsq busy {:?} — residency discount not separating",
            a.fleet.busy,
            j.fleet.busy
        );
        assert_ne!(a, j, "policies must produce distinct reports");
    }

    #[test]
    fn sed_is_tie_identical_to_jsq_on_homogeneous_fleet() {
        // On identical replicas the expected-delay key is strictly
        // monotone in load with the same coefficients everywhere, so
        // shortest-expected-delay makes exactly join-shortest-queue's
        // choices (ties included) — the whole report must come out
        // bit-identical.
        let mut jsq = poisson_cfg(4, 0.9);
        jsq.dispatch = DispatchPolicy::JoinShortestQueue;
        let mut sed = jsq.clone();
        sed.dispatch = DispatchPolicy::ShortestExpectedDelay;
        assert_eq!(
            simulate_fleet(&jsq),
            simulate_fleet(&sed),
            "homogeneous SED must degenerate to JSQ exactly"
        );
    }

    #[test]
    fn sed_cuts_the_mixed_fleet_tail_below_jsq() {
        // A 2-edge + 2-core mixed fleet with a 10x per-image speed
        // gap. JSQ compares queue *lengths*, so it keeps feeding the
        // slow edge tier whenever its count dips below the core
        // tier's; every request it parks there pays ~85 ms of service
        // against ~9 ms on a core device, which is exactly what the
        // p99 measures. SED's expected-delay key routes to the edge
        // tier only when the core backlog genuinely costs more.
        let edge = DeviceModel::from_latencies(
            "edge".into(),
            Duration::from_millis(5),
            Duration::from_millis(10),
            &[1, 2, 4, 8],
        );
        let core = DeviceModel::from_latencies(
            "core".into(),
            Duration::from_millis(1),
            Duration::from_millis(1),
            &[1, 2, 4, 8],
        );
        let peak = 2.0 * edge.peak_rps() + 2.0 * core.peak_rps();
        let mk = |policy| {
            let mut cfg = ServeConfig::mixed(
                vec![edge.clone(), edge.clone(), core.clone(), core.clone()],
                Workload::Poisson { rate_rps: 0.7 * peak },
            );
            cfg.dispatch = policy;
            cfg.horizon = Duration::from_secs(20);
            cfg
        };
        let s = simulate_fleet(&mk(DispatchPolicy::ShortestExpectedDelay));
        let j = simulate_fleet(&mk(DispatchPolicy::JoinShortestQueue));
        assert_eq!(s.fleet.completed, j.fleet.completed, "same offered traffic");
        assert!(
            s.fleet.e2e.p99() < j.fleet.e2e.p99(),
            "SED p99 {:?} !< JSQ p99 {:?} on the mixed fleet",
            s.fleet.e2e.p99(),
            j.fleet.e2e.p99()
        );
    }

    #[test]
    fn subcritical_load_is_served_at_offered_rate() {
        let r = simulate_fleet(&poisson_cfg(2, 0.4));
        let ratio = r.achieved_rps() / r.offered_rps;
        assert!((0.9..=1.01).contains(&ratio), "achieved/offered = {ratio}");
        // Light load: e2e stays on the scale of a few batch services
        // (service(8) = 84 ms for the synthetic device), far from the
        // seconds-scale waits of the overload tests.
        let bound = Duration::from_millis(3 * 84);
        assert!(r.fleet.e2e.p99() < bound, "p99 {:?}", r.fleet.e2e.p99());
    }

    #[test]
    fn throughput_scales_with_fleet_size() {
        // Offered load = 8x one device's peak: saturates a lone
        // device AND a 4-device fleet, so the sustained completion
        // rate must scale ~4x with the fleet.
        let one = simulate_fleet(&poisson_cfg(1, 8.0));
        let mut big = poisson_cfg(1, 8.0); // same offered load…
        big.devices = vec![synthetic(); 4]; // …4x the fleet
        let four = simulate_fleet(&big);
        let speedup = four.achieved_rps() / one.achieved_rps();
        assert!(speedup > 3.0, "fleet scaling {speedup}");
    }

    #[test]
    fn overload_queues_grow_and_tail_explodes() {
        let calm = simulate_fleet(&poisson_cfg(2, 0.4));
        let hot = simulate_fleet(&poisson_cfg(2, 1.3));
        assert!(hot.makespan > hot.horizon, "overload must drain past the horizon");
        assert!(
            hot.fleet.e2e.p99() > 3 * calm.fleet.e2e.p99(),
            "p99 {:?} !>> {:?}",
            hot.fleet.e2e.p99(),
            calm.fleet.e2e.p99()
        );
    }

    #[test]
    fn padding_appears_when_executables_are_coarse() {
        // Only a batch-4 executable: a trickle of lone requests must
        // pad 3 of every 4 slots.
        let dev = DeviceModel::from_latencies(
            "coarse".into(),
            Duration::ZERO,
            Duration::from_millis(5),
            &[4],
        );
        let mut cfg = ServeConfig::uniform(dev, 1, Workload::Poisson { rate_rps: 3.0 });
        cfg.horizon = Duration::from_secs(20);
        let r = simulate_fleet(&cfg);
        assert!(r.fleet.padding_fraction() > 0.3, "{}", r.fleet.padding_fraction());
        // And with a batch-1 executable available, padding vanishes
        // at the same load.
        let fine = DeviceModel::from_latencies(
            "fine".into(),
            Duration::ZERO,
            Duration::from_millis(5),
            &[1, 4],
        );
        let mut cfg2 = ServeConfig::uniform(fine, 1, Workload::Poisson { rate_rps: 3.0 });
        cfg2.horizon = Duration::from_secs(20);
        let r2 = simulate_fleet(&cfg2);
        assert!(r2.fleet.padding_fraction() < r.fleet.padding_fraction());
    }

    #[test]
    fn bursty_traffic_has_worse_tail_than_poisson_at_same_mean() {
        let dev = synthetic();
        let mean = 0.75 * dev.peak_rps();
        let mut poisson =
            ServeConfig::uniform(dev.clone(), 1, Workload::Poisson { rate_rps: mean });
        poisson.horizon = Duration::from_secs(30);
        let mut bursty = ServeConfig::uniform(
            dev,
            1,
            Workload::Mmpp2 {
                rate_low_rps: 0.3 * mean,
                rate_high_rps: 1.7 * mean,
                mean_dwell: Duration::from_secs(2),
            },
        );
        bursty.horizon = Duration::from_secs(30);
        let p = simulate_fleet(&poisson);
        let b = simulate_fleet(&bursty);
        assert!(
            b.fleet.e2e.p99() > p.fleet.e2e.p99(),
            "bursty p99 {:?} !> poisson p99 {:?}",
            b.fleet.e2e.p99(),
            p.fleet.e2e.p99()
        );
    }

    #[test]
    fn affinity_without_experts_falls_back_to_jsq() {
        let mut aff = poisson_cfg(3, 0.9);
        aff.dispatch = DispatchPolicy::ExpertAffinity;
        aff.num_experts = 0;
        let mut jsq = aff.clone();
        jsq.dispatch = DispatchPolicy::JoinShortestQueue;
        assert_eq!(
            simulate_fleet(&aff),
            simulate_fleet(&jsq),
            "0 experts: affinity must degrade to JSQ, not pin device 0"
        );
    }

    #[test]
    fn trace_replay_reproduces_the_poisson_run() {
        let dev = synthetic();
        let rate = 0.6 * dev.peak_rps();
        let mut cfg = ServeConfig::uniform(dev, 2, Workload::Poisson { rate_rps: rate });
        cfg.horizon = Duration::from_secs(5);
        let live = simulate_fleet(&cfg);
        let mut replay = cfg.clone();
        replay.workload = cfg.workload.to_trace(cfg.horizon, cfg.seed);
        let replayed = simulate_fleet(&replay);
        assert_eq!(live, replayed, "captured trace must replay bit-identically");
    }

    /// Acceptance: a 4-device U280 fleet (sim-backed cost model) shows
    /// the saturation knee — p99 rising sharply past it.
    #[test]
    fn u280_fleet_curve_saturates() {
        let dev = crate::report::serving::demo_device(&Platform::u280());
        let peak = dev.peak_rps() * 4.0;
        let p99_at = |util: f64| {
            let mut cfg = ServeConfig::uniform(
                dev.clone(),
                4,
                Workload::Poisson { rate_rps: util * peak },
            );
            cfg.horizon = Duration::from_secs(10);
            let r = simulate_fleet(&cfg);
            assert_eq!(r.fleet.completed, r.admitted);
            r.fleet.e2e.p99()
        };
        let below = p99_at(0.4);
        let past = p99_at(1.15);
        assert!(
            past > 3 * below,
            "no saturation knee: p99 {below:?} @0.4 vs {past:?} @1.15"
        );
    }
}
