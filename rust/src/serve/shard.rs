//! Expert sharding for the serving DES: top-k routing over a skewed
//! (optionally drifting) expert-popularity distribution, per-expert
//! capacity windows, replica placement, and a rebalancing controller.
//!
//! UbiMoE streams expert weights per batch precisely because a whole
//! MoE-ViT does not fit on one device; at fleet scale the same memory
//! pressure forces *sharding* — each device hosts an expert subset,
//! and a request must land on a device holding its serving expert.
//! This module supplies the pure pieces; `serve/mod.rs` owns the event
//! loop and the side effects:
//!
//! - [`ShardConfig`] — carried as `ServeConfig::shard:
//!   Option<ShardConfig>`. Follows the PR 6/8 inertness contract: an
//!   inert config ([`ShardConfig::is_inert`], `top_k == 0`) is
//!   filtered out before the loop starts and is bit-identical to
//!   `None` (proptested).
//! - [`Popularity`] — a Zipf(`s`) distribution over expert *ranks*
//!   with an optional drift: the rank→expert mapping rotates by
//!   `shift` every `every` of virtual time, as a pure function of the
//!   timestamp (`expert = (rank + phase·shift) mod E`), so drift needs
//!   no events and stays bit-deterministic.
//! - [`CapacityConfig`] — Switch-Transformer-style per-expert capacity:
//!   at most `cap_tokens` admitted requests per expert per fixed
//!   window (`floor(t/window)`); overflow reroutes to a secondary
//!   expert or degrades via expert-drop with an accuracy-proxy cost
//!   ([`ShardConfig::expert_drop_cost`], the PR 8 idiom).
//! - [`initial_placement`] / [`plan_moves`] — deterministic placement
//!   and the pure rebalancing planner: re-home experts whose replicas
//!   all died, grow hot experts to the replication factor
//!   (add-before-drop), trim cold surplus (never below one live
//!   replica). The DES applies moves; dropping a replica only stops
//!   *new* routing to it, so batches already queued there drain
//!   normally — the PR 5 drain-before-move semantics for free.
//! - [`ShardSummary`] — run counters (`FleetReport::shard`), under the
//!   extended conservation law
//!   `completed_intact + degraded + dropped + rejected == routed`,
//!   hard-asserted by the DES.

use std::time::Duration;

/// Popularity drift: every `every` of virtual time the rank→expert
/// mapping rotates by `shift` (`expert = (rank + phase·shift) mod E`,
/// `phase = floor(t/every)`). The *distribution over ranks* never
/// changes — which experts are hot does.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DriftConfig {
    /// Phase length (must be positive).
    pub every: Duration,
    /// Expert-index rotation per phase (taken mod the expert count).
    pub shift: usize,
}

/// Per-expert capacity window: at most `cap_tokens` admitted requests
/// may select an expert per `window` of virtual time. The window is
/// fixed-boundary (`floor(t/window)`), the Switch capacity-factor
/// discretized onto the DES clock.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CapacityConfig {
    /// Window length (must be positive).
    pub window: Duration,
    /// Admitted-request budget per expert per window (≥ 1).
    pub cap_tokens: u64,
}

impl CapacityConfig {
    /// The Switch capacity-factor math: expected tokens per expert per
    /// window under a *uniform* router is `offered_rps · window / E`;
    /// a capacity factor `f` budgets `ceil(f ×)` that. A skewed router
    /// drives hot experts over this budget by design — that overflow
    /// is what reroute/expert-drop absorb.
    pub fn from_factor(
        factor: f64,
        offered_rps: f64,
        num_experts: usize,
        window: Duration,
    ) -> CapacityConfig {
        assert!(factor > 0.0 && factor.is_finite(), "capacity factor must be positive");
        assert!(offered_rps >= 0.0, "offered load cannot be negative");
        assert!(num_experts > 0, "capacity needs at least one expert");
        assert!(!window.is_zero(), "capacity window must be positive");
        let per_expert = offered_rps * window.as_secs_f64() / num_experts as f64;
        let cap = (factor * per_expert).ceil() as u64;
        CapacityConfig { window, cap_tokens: cap.max(1) }
    }
}

/// Rebalancing-controller knobs: the DES ticks the planner
/// ([`plan_moves`]) once per `every`, feeding it the per-expert routed
/// counts of the elapsed window.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RebalanceConfig {
    /// Planner tick period (must be positive).
    pub every: Duration,
}

/// Top-level expert-sharding configuration, carried as
/// `ServeConfig::shard: Option<ShardConfig>`. `None` and an inert
/// config are bit-identical (the `is_inert` contract).
#[derive(Clone, Debug, PartialEq)]
pub struct ShardConfig {
    /// Experts consulted per request (primary + `top_k − 1`
    /// secondaries). `0` marks the config inert; otherwise must be in
    /// `1..=num_experts`.
    pub top_k: usize,
    /// Zipf skew over expert ranks (`weight(rank) ∝ 1/(rank+1)^s`).
    /// `0.0` is uniform.
    pub zipf_s: f64,
    /// Replication factor for hot experts (`1..=devices`). Cold
    /// experts keep one replica.
    pub replication: usize,
    /// How many of the top-ranked experts count as hot (get
    /// `replication` copies at placement and on rebalance).
    pub hot_experts: usize,
    /// Popularity drift; `None` keeps the phase-0 mapping forever.
    pub drift: Option<DriftConfig>,
    /// Per-expert capacity windows; `None` admits without bound.
    pub capacity: Option<CapacityConfig>,
    /// Rebalancing controller; `None` keeps the initial placement
    /// static (the baseline the study measures against).
    pub rebalance: Option<RebalanceConfig>,
    /// Interconnect cost charged per *non-local* secondary expert: the
    /// picked device hosts the serving expert by construction, and
    /// each other routed expert it does not host adds one transfer to
    /// the request's end-to-end latency.
    pub transfer_cost: Duration,
    /// Accuracy-proxy cost per completion whose expert was dropped
    /// (all routed experts over capacity) — accumulated into
    /// [`ShardSummary::accuracy_cost`], the PR 8 brownout idiom.
    pub expert_drop_cost: f64,
}

impl ShardConfig {
    /// The canonical "no sharding" value.
    pub fn none() -> Option<ShardConfig> {
        None
    }

    /// Minimal live config: top-k routing with skew `zipf_s`, one
    /// replica everywhere, no capacity, no drift, no rebalancing.
    pub fn plain(top_k: usize, zipf_s: f64) -> ShardConfig {
        ShardConfig { top_k, zipf_s, ..ShardConfig::default() }
    }

    /// True iff this config cannot influence the run: with `top_k ==
    /// 0` the router never engages, no placement constraint exists,
    /// and the shard RNG stream is never drawn — the DES filters inert
    /// configs out before the loop starts, so `Some(inert)` is
    /// bit-identical to `None`.
    pub fn is_inert(&self) -> bool {
        self.top_k == 0
    }
}

impl Default for ShardConfig {
    /// Inert by construction (`top_k == 0`).
    fn default() -> Self {
        ShardConfig {
            top_k: 0,
            zipf_s: 0.0,
            replication: 1,
            hot_experts: 0,
            drift: None,
            capacity: None,
            rebalance: None,
            transfer_cost: Duration::ZERO,
            expert_drop_cost: 0.0,
        }
    }
}

/// Zipf popularity over expert ranks with optional drift. The CDF over
/// ranks is precomputed once; a draw is one uniform `f64` plus a
/// binary search, and the rank→expert mapping is a pure function of
/// the timestamp — all deterministic given the DES's seeded stream.
#[derive(Clone, Debug)]
pub struct Popularity {
    /// Normalized cumulative weights over ranks (last entry == 1.0 up
    /// to rounding; draws clamp).
    cdf: Vec<f64>,
    num_experts: usize,
    shift: usize,
    /// Drift phase length in ns; 0 = no drift.
    every_ns: u64,
}

impl Popularity {
    pub fn new(num_experts: usize, zipf_s: f64, drift: Option<&DriftConfig>) -> Popularity {
        assert!(num_experts > 0, "popularity needs at least one expert");
        assert!(zipf_s >= 0.0 && zipf_s.is_finite(), "zipf skew must be finite and >= 0");
        let mut cdf = Vec::with_capacity(num_experts);
        let mut total = 0.0;
        for rank in 0..num_experts {
            total += 1.0 / ((rank + 1) as f64).powf(zipf_s);
            cdf.push(total);
        }
        for c in cdf.iter_mut() {
            *c /= total;
        }
        let (every_ns, shift) = match drift {
            Some(d) => {
                assert!(!d.every.is_zero(), "drift phase must be positive");
                (d.every.as_nanos() as u64, d.shift % num_experts)
            }
            None => (0, 0),
        };
        Popularity { cdf, num_experts, shift, every_ns }
    }

    pub fn num_experts(&self) -> usize {
        self.num_experts
    }

    /// Drift phase at virtual time `now_ns` (0 without drift).
    pub fn phase(&self, now_ns: u64) -> u64 {
        if self.every_ns == 0 {
            0
        } else {
            now_ns / self.every_ns
        }
    }

    /// The expert occupying `rank` during `phase`:
    /// `(rank + phase·shift) mod E`. At phase 0 (and always without
    /// drift) rank *is* the expert id.
    pub fn expert_of_rank(&self, rank: usize, phase: u64) -> u32 {
        let e = self.num_experts as u64;
        ((rank as u64 + (phase % e) * self.shift as u64) % e) as u32
    }

    /// Inverse of [`Self::expert_of_rank`].
    pub fn rank_of_expert(&self, expert: u32, phase: u64) -> usize {
        let e = self.num_experts as u64;
        let off = (phase % e) * self.shift as u64 % e;
        ((expert as u64 + e - off) % e) as usize
    }

    /// Map one uniform draw `u ∈ [0,1)` to a rank by CDF inversion.
    pub fn draw_rank(&self, u: f64) -> usize {
        self.cdf.partition_point(|&c| c <= u).min(self.num_experts - 1)
    }

    /// Probability mass of `rank`.
    pub fn weight_of_rank(&self, rank: usize) -> f64 {
        let lo = if rank == 0 { 0.0 } else { self.cdf[rank - 1] };
        self.cdf[rank] - lo
    }
}

/// Deterministic initial placement: expert `e`'s first replica lives
/// on device `e mod D`, and the phase-0 hot set (`e < hot_experts`,
/// since rank == expert at phase 0) gets `replication` consecutive
/// devices. `replication <= devices` keeps replicas distinct.
pub fn initial_placement(
    num_experts: usize,
    devices: usize,
    replication: usize,
    hot_experts: usize,
) -> Vec<Vec<u32>> {
    assert!(num_experts > 0 && devices > 0, "placement needs experts and devices");
    assert!(
        (1..=devices).contains(&replication),
        "replication {replication} outside 1..={devices}"
    );
    (0..num_experts)
        .map(|e| {
            let copies = if e < hot_experts { replication } else { 1 };
            (0..copies).map(|j| ((e + j) % devices) as u32).collect()
        })
        .collect()
}

/// What kind of placement change a [`PlacementMove`] is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MoveKind {
    /// Start hosting the expert on the device (new routing target).
    Add,
    /// Stop hosting it there: new requests no longer route to this
    /// replica; work already queued drains normally
    /// (drain-before-move).
    Drop,
}

/// One placement change decided by [`plan_moves`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacementMove {
    pub expert: u32,
    pub device: usize,
    pub kind: MoveKind,
}

/// Load-estimate fixed-point scale (integer math keeps the planner
/// bit-deterministic).
const LOAD_SCALE: u64 = 1024;

/// The pure rebalancing planner. Inputs: per-expert routed counts over
/// the elapsed window (`counts`), the current placement (`replicas`,
/// expert → hosting devices), and which devices are currently taking
/// traffic (`alive`). Policy, in order:
///
/// 1. **Re-home** — every expert with zero live replicas gains one on
///    the least-loaded live device (a dead sole replica must not
///    black-hole its expert until repair).
/// 2. **Grow hot** — the `hot_experts` top experts by window count
///    (ties to the smaller id) grow to `replication` live replicas,
///    adds before any drop.
/// 3. **Trim cold** — non-hot experts shed surplus live replicas from
///    the most-loaded device down to exactly one, never below.
///
/// Device load is estimated as Σ `counts[e] / live_replicas(e)` over
/// hosted experts, in [`LOAD_SCALE`] fixed-point; all tie-breaks are
/// by smallest device index, so the plan is a pure deterministic
/// function of its inputs.
pub fn plan_moves(
    counts: &[u64],
    replicas: &[Vec<u32>],
    alive: &[bool],
    replication: usize,
    hot_experts: usize,
) -> Vec<PlacementMove> {
    let n_exp = counts.len();
    let n_dev = alive.len();
    debug_assert_eq!(replicas.len(), n_exp);
    let live_devices = alive.iter().filter(|a| **a).count();
    if n_exp == 0 || live_devices == 0 {
        return Vec::new();
    }
    // Cannot replicate onto more devices than are live.
    let rf = replication.max(1).min(live_devices);

    // Hot set: top `hot_experts` by window count, id tie-break.
    let mut order: Vec<usize> = (0..n_exp).collect();
    order.sort_by(|&a, &b| counts[b].cmp(&counts[a]).then(a.cmp(&b)));
    let mut hot = vec![false; n_exp];
    for &e in order.iter().take(hot_experts) {
        hot[e] = true;
    }

    // Working copy of the placement + estimated per-device load.
    let mut hosts: Vec<Vec<u32>> = replicas.to_vec();
    let mut load = vec![0u64; n_dev];
    for (e, hs) in hosts.iter().enumerate() {
        let live = hs.iter().filter(|&&d| alive[d as usize]).count() as u64;
        if live == 0 {
            continue;
        }
        let share = counts[e] * LOAD_SCALE / live;
        for &d in hs.iter().filter(|&&d| alive[d as usize]) {
            load[d as usize] += share;
        }
    }

    let mut moves = Vec::new();
    // Pass 1: adds (re-home dead-hosted experts, grow hot experts).
    for e in 0..n_exp {
        let target = if hot[e] { rf } else { 1 };
        loop {
            let live = hosts[e].iter().filter(|&&d| alive[d as usize]).count();
            if live >= target {
                break;
            }
            let pick = (0..n_dev)
                .filter(|&d| alive[d] && !hosts[e].contains(&(d as u32)))
                .min_by_key(|&d| (load[d], d));
            let Some(d) = pick else { break };
            hosts[e].push(d as u32);
            load[d] += counts[e] * LOAD_SCALE / target as u64;
            moves.push(PlacementMove { expert: e as u32, device: d, kind: MoveKind::Add });
        }
    }
    // Pass 2: drops (trim cold surplus; never below one live replica).
    for e in 0..n_exp {
        let target = if hot[e] { rf } else { 1 };
        loop {
            let live: Vec<usize> =
                hosts[e].iter().map(|&d| d as usize).filter(|&d| alive[d]).collect();
            if live.len() <= target {
                break;
            }
            let share = counts[e] * LOAD_SCALE / live.len() as u64;
            let d = *live.iter().max_by_key(|&&d| (load[d], d)).expect("live is non-empty");
            hosts[e].retain(|&h| h as usize != d);
            load[d] = load[d].saturating_sub(share);
            moves.push(PlacementMove { expert: e as u32, device: d, kind: MoveKind::Drop });
        }
    }
    moves
}

/// Shard-machinery counters for a run — `FleetReport::shard` is `Some`
/// iff sharding was active (a non-inert [`ShardConfig`]). The
/// conservation refinement over the PR 8 law: every routed request is
/// either an intact completion, a degraded (expert-dropped)
/// completion, a drop (chaos or no-replica), or an admission reject —
/// `completed + dropped + rejected == routed`, hard-asserted by the
/// DES with `degraded_completions` carving completions into intact vs
/// degraded.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardSummary {
    /// Requests routed (every arrival draws an assignment, admitted or
    /// not — equals the run's offered count).
    pub routed: u64,
    /// Admitted requests served by a secondary expert because the
    /// primary's capacity window was exhausted.
    pub rerouted: u64,
    /// Admitted requests whose every routed expert was over capacity:
    /// served expert-dropped (degraded), the Switch overflow semantics.
    pub expert_drops: u64,
    /// Request copies dropped because no live device hosted the
    /// serving expert (counted into `FleetReport::dropped`).
    pub no_replica_drops: u64,
    /// Non-local secondary-expert fetches charged to completions.
    pub transfers: u64,
    /// Σ interconnect time charged (ns).
    pub transfer_ns: u64,
    /// Replicas added by the rebalancer (re-home + hot growth).
    pub replica_adds: u64,
    /// Replicas dropped by the rebalancer (cold trim).
    pub replica_drops: u64,
    /// Rebalance ticks that changed the placement.
    pub rebalances: u64,
    /// Completions of expert-dropped requests.
    pub degraded_completions: u64,
    /// Σ accuracy-proxy cost over degraded completions
    /// (`degraded_completions × expert_drop_cost`).
    pub accuracy_cost: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn inertness_matches_contents() {
        assert!(ShardConfig::default().is_inert());
        assert!(!ShardConfig::plain(1, 0.0).is_inert());
        assert!(!ShardConfig::plain(2, 1.5).is_inert());
        // Knobs on an inert config stay inert: top_k == 0 never
        // engages the router, so nothing downstream can fire.
        let cfg = ShardConfig {
            replication: 3,
            hot_experts: 2,
            drift: Some(DriftConfig { every: ms(10), shift: 1 }),
            ..ShardConfig::default()
        };
        assert!(cfg.is_inert());
    }

    #[test]
    fn zipf_cdf_is_normalized_and_skew_orders_ranks() {
        let p = Popularity::new(8, 1.0, None);
        assert_eq!(p.num_experts(), 8);
        // CDF is strictly increasing and ends at 1.
        for r in 1..8 {
            assert!(p.weight_of_rank(r) > 0.0);
            assert!(p.weight_of_rank(r) < p.weight_of_rank(r - 1));
        }
        let total: f64 = (0..8).map(|r| p.weight_of_rank(r)).sum();
        assert!((total - 1.0).abs() < 1e-12);
        // s = 1: weight(rank0) = 1/H(8) ≈ 0.368.
        let h8: f64 = (1..=8).map(|k| 1.0 / k as f64).sum();
        assert!((p.weight_of_rank(0) - 1.0 / h8).abs() < 1e-12);
        // s = 0 is uniform.
        let u = Popularity::new(5, 0.0, None);
        for r in 0..5 {
            assert!((u.weight_of_rank(r) - 0.2).abs() < 1e-12);
        }
    }

    #[test]
    fn draw_rank_inverts_the_cdf() {
        let p = Popularity::new(4, 1.0, None);
        assert_eq!(p.draw_rank(0.0), 0);
        // u just under the rank-0 mass stays rank 0; just over moves on.
        let w0 = p.weight_of_rank(0);
        assert_eq!(p.draw_rank(w0 - 1e-9), 0);
        assert_eq!(p.draw_rank(w0 + 1e-9), 1);
        // The clamp keeps u ≈ 1.0 in range.
        assert_eq!(p.draw_rank(1.0 - 1e-15), 3);
        assert_eq!(p.draw_rank(1.0), 3);
    }

    #[test]
    fn drift_rotates_the_rank_to_expert_mapping() {
        let d = DriftConfig { every: ms(5), shift: 3 };
        let p = Popularity::new(8, 1.0, Some(&d));
        assert_eq!(p.phase(0), 0);
        assert_eq!(p.phase(4_999_999), 0);
        assert_eq!(p.phase(5_000_000), 1);
        assert_eq!(p.phase(15_000_000), 3);
        // Phase 0: identity. Phase 1: rank r → (r + 3) mod 8.
        assert_eq!(p.expert_of_rank(0, 0), 0);
        assert_eq!(p.expert_of_rank(0, 1), 3);
        assert_eq!(p.expert_of_rank(6, 1), 1);
        // Round-trips at every (rank, phase).
        for phase in 0..20 {
            for rank in 0..8 {
                let e = p.expert_of_rank(rank, phase);
                assert_eq!(p.rank_of_expert(e, phase), rank);
            }
        }
        // No drift: phase pinned to 0, mapping is identity forever.
        let q = Popularity::new(8, 1.0, None);
        assert_eq!(q.phase(u64::MAX), 0);
        assert_eq!(q.expert_of_rank(5, 0), 5);
    }

    #[test]
    fn capacity_factor_math() {
        // 100 req/s over 4 experts, 100 ms windows: 2.5 expected per
        // expert per window; factor 1.25 → ceil(3.125) = 4.
        let c = CapacityConfig::from_factor(1.25, 100.0, 4, ms(100));
        assert_eq!(c.cap_tokens, 4);
        // Tiny loads still budget at least one token.
        let c = CapacityConfig::from_factor(0.5, 0.1, 8, ms(10));
        assert_eq!(c.cap_tokens, 1);
    }

    #[test]
    fn initial_placement_spreads_and_replicates() {
        let p = initial_placement(8, 4, 2, 1);
        assert_eq!(p.len(), 8);
        // Hot expert 0: two distinct consecutive devices.
        assert_eq!(p[0], vec![0, 1]);
        // Cold experts: one replica at e mod D.
        for (e, hs) in p.iter().enumerate().skip(1) {
            assert_eq!(hs.len(), 1, "expert {e} is cold");
            assert_eq!(hs[0] as usize, e % 4);
        }
        // Replication never collides even at rf == devices.
        let p = initial_placement(2, 3, 3, 2);
        for hs in &p {
            let mut s = hs.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), hs.len(), "replicas must be distinct");
        }
    }

    #[test]
    fn plan_rehomes_experts_with_no_live_replica() {
        // Expert 0 hosted only on dead device 0 → re-home on the
        // least-loaded live device.
        let counts = vec![10, 5, 5];
        let replicas = vec![vec![0], vec![1], vec![2]];
        let alive = vec![false, true, true];
        let moves = plan_moves(&counts, &replicas, &alive, 1, 0);
        // Devices 1 and 2 carry equal load (5 each): the deterministic
        // tie-break picks the smaller live device id.
        assert_eq!(moves, vec![PlacementMove { expert: 0, device: 1, kind: MoveKind::Add }]);
    }

    #[test]
    fn plan_grows_hot_and_trims_cold() {
        // Expert 0 is hot (highest count) with one replica; expert 1 is
        // cold with a stale second replica. rf = 2, hot_experts = 1.
        let counts = vec![100, 10, 1];
        let replicas = vec![vec![0], vec![1, 2], vec![2]];
        let alive = vec![true, true, true];
        let moves = plan_moves(&counts, &replicas, &alive, 2, 1);
        // Adds come before drops (add-before-drop growth).
        let first_drop = moves.iter().position(|m| m.kind == MoveKind::Drop);
        let last_add = moves.iter().rposition(|m| m.kind == MoveKind::Add);
        if let (Some(fd), Some(la)) = (first_drop, last_add) {
            assert!(la < fd, "adds must precede drops: {moves:?}");
        }
        // Hot expert 0 gained a second replica; cold expert 1 lost one.
        let adds: Vec<_> = moves.iter().filter(|m| m.kind == MoveKind::Add).collect();
        let drops: Vec<_> = moves.iter().filter(|m| m.kind == MoveKind::Drop).collect();
        assert_eq!(adds.len(), 1);
        assert_eq!(adds[0].expert, 0);
        assert_eq!(drops.len(), 1);
        assert_eq!(drops[0].expert, 1);
        // Determinism: the same inputs plan the same moves.
        assert_eq!(moves, plan_moves(&counts, &replicas, &alive, 2, 1));
    }

    #[test]
    fn plan_never_drops_the_last_live_replica() {
        // Every expert cold with exactly one live replica: nothing to do.
        let counts = vec![5, 5];
        let replicas = vec![vec![0], vec![1]];
        let alive = vec![true, true];
        assert!(plan_moves(&counts, &replicas, &alive, 1, 0).is_empty());
        // A dead surplus replica is not "live surplus": no drop.
        let replicas = vec![vec![0, 1], vec![1]];
        let alive = vec![false, true];
        let moves = plan_moves(&counts, &replicas, &alive, 1, 0);
        assert!(
            moves.iter().all(|m| m.kind != MoveKind::Drop),
            "must not drop when only one live replica exists: {moves:?}"
        );
        // All devices dead: the planner stands down.
        assert!(plan_moves(&counts, &replicas, &[false, false], 2, 1).is_empty());
    }

    #[test]
    fn plan_clamps_replication_to_live_devices() {
        // rf = 3 but only 2 live devices: hot expert grows to 2, not 3.
        let counts = vec![100, 1];
        let replicas = vec![vec![0], vec![1]];
        let alive = vec![true, true, false];
        let moves = plan_moves(&counts, &replicas, &alive, 3, 1);
        let adds: Vec<_> =
            moves.iter().filter(|m| m.kind == MoveKind::Add && m.expert == 0).collect();
        assert_eq!(adds.len(), 1, "one add reaches the live-device clamp: {moves:?}");
        assert_eq!(adds[0].device, 1);
    }
}
