//! SLO-driven autoscaling: a sliding-window controller that resizes
//! the fleet mid-simulation.
//!
//! The ROADMAP closing-the-loop item: the DES can *measure* the
//! latency–throughput knee per fleet size (PRs 2–4); this module uses
//! that measurement live. Every [`AutoscaleConfig::window`] of virtual
//! time the DES fires a `ScaleTick` event, hands the controller a
//! [`WindowSignal`] — windowed SLO attainment (from
//! [`crate::coordinator::metrics::LatencyStats::fraction_leq`]),
//! windowed arrival count, instantaneous backlog and the active fleet
//! size — and applies the returned target size:
//!
//! * **Scale-up is proactive and instantaneous.** The controller sizes
//!   the fleet to `ceil(window arrival rate / (rho_target × template
//!   peak))`, takes the max with a backlog-pressure term (work already
//!   queued must clear within roughly one window), and — whenever the
//!   windowed attainment misses the target — adds at least one replica
//!   on top. Reacting to the *rate* means the fleet usually grows
//!   before the SLO is violated, not after; provisioning is modeled as
//!   instant (no boot delay), which is the optimistic bound a real
//!   deployment approaches with pre-provisioned standby devices.
//! * **Scale-down is conservative: one replica per window, after
//!   [`AutoscaleConfig::scale_down_patience`] consecutive calm
//!   windows, drain-before-remove.** A removed device first becomes
//!   *draining*: the dispatcher stops routing to it
//!   ([`crate::serve::dispatch::LoadTracker::deactivate`]) but it
//!   keeps serving its queued and in-flight work; only when empty is
//!   it retired. Request conservation therefore holds across every
//!   scale event (proptested in `rust/tests/serve_properties.rs`), and
//!   a scale-up arriving mid-drain simply cancels the drain — the
//!   still-warm device rejoins the dispatch set.
//!
//! The controller is a pure function of DES state, so autoscaled runs
//! stay bit-identical per (config, seed) like every other run.
//!
//! **Accounting.** The figure of merit is **device-seconds** —
//! integrated fleet size over the run, spawn to retirement
//! ([`crate::serve::FleetReport::device_seconds`]) — against the SLO
//! attainment achieved. The study
//! ([`crate::report::serving::autoscale_study`]) compares the
//! controller with every static fleet size on the same bursty MMPP
//! traffic: the controller must match the attainment of the smallest
//! adequate static fleet while spending strictly fewer device-seconds,
//! because it rides calm phases on a small fleet and pays for burst
//! capacity only while bursts last.
//!
//! The **brownout controller**
//! ([`crate::serve::overload::BrownoutConfig`]) is this module's
//! sibling: the same windowed-attainment signal, but instead of
//! resizing the fleet it degrades per-device service quality
//! (bit-width) under sustained overload. The two answer different
//! pressure — autoscaling buys capacity, brownout trades accuracy for
//! latency when capacity is fixed — and are mutually exclusive on one
//! run (`simulate_fleet` rejects a config with both).

use std::time::Duration;

use crate::serve::device::DeviceModel;

/// Configuration of the sliding-window autoscaling controller
/// (attach to a run via `ServeConfig::autoscale`).
#[derive(Clone, Debug)]
pub struct AutoscaleConfig {
    /// Replica template cloned on scale-up (homogeneous scaling; the
    /// initial fleet may differ, but capacity math uses the template).
    pub template: DeviceModel,
    /// Controller period: the sliding window over which attainment and
    /// arrival rate are evaluated, and the spacing of scale decisions.
    pub window: Duration,
    /// End-to-end latency target the controller defends.
    pub slo: Duration,
    /// Required fraction of a window's completions meeting `slo`
    /// (e.g. 0.99). A window below this forces a scale-up.
    pub target_attainment: f64,
    /// Fleet-size floor (serving, non-draining devices).
    pub min_devices: usize,
    /// Fleet-size ceiling.
    pub max_devices: usize,
    /// Utilization the rate-based sizing aims each device at: desired
    /// fleet = ceil(arrival rate / (rho_target × template peak)).
    /// Lower = more headroom, more device-seconds.
    pub rho_target: f64,
    /// Consecutive calm (attainment met, capacity surplus) windows
    /// required before one replica starts draining.
    pub scale_down_patience: u32,
}

impl AutoscaleConfig {
    /// Controller defaults for a device template: window = the
    /// largest-batch service time (the fleet's natural batch cadence —
    /// long enough for a usable rate estimate, short enough that one
    /// under-provisioned window stays well inside an
    /// attainable-SLO budget), target attainment 99%, ρ-target 0.7,
    /// 1–8 devices, patience 2.
    pub fn for_device(template: DeviceModel, slo: Duration) -> AutoscaleConfig {
        let largest = *template.batch_sizes.last().expect("device with no batch sizes");
        let window = template.service_time(largest);
        AutoscaleConfig {
            template,
            window,
            slo,
            target_attainment: 0.99,
            min_devices: 1,
            max_devices: 8,
            rho_target: 0.7,
            scale_down_patience: 2,
        }
    }
}

/// What the controller sees at a tick: the DES aggregates this over
/// the window just ended.
#[derive(Clone, Copy, Debug)]
pub struct WindowSignal {
    /// Requests admitted during the window.
    pub arrivals: u64,
    /// Fraction of the window's completions that met the SLO (1.0 for
    /// an idle window — no completions violate nothing).
    pub attainment: f64,
    /// Requests currently resident fleet-wide (queued + in flight).
    pub backlog: usize,
    /// Serving (non-draining) devices right now.
    pub active: usize,
}

/// The sliding-window controller: give it each window's
/// [`WindowSignal`], get the target fleet size back. Pure with respect
/// to the DES (no clock, no randomness), so autoscaled runs stay
/// deterministic.
#[derive(Clone, Debug)]
pub struct Controller {
    cfg: AutoscaleConfig,
    calm_windows: u32,
    /// Per-replica sustainable request rate: rho_target × template
    /// peak (precomputed — `desired` runs every tick).
    replica_rps: f64,
}

impl Controller {
    pub fn new(cfg: AutoscaleConfig) -> Controller {
        assert!(cfg.min_devices >= 1, "autoscale floor must keep one device");
        assert!(cfg.max_devices >= cfg.min_devices, "autoscale ceiling below floor");
        assert!(!cfg.window.is_zero(), "autoscale window must be positive");
        assert!(
            cfg.rho_target > 0.0 && cfg.rho_target <= 1.0,
            "rho_target must be in (0, 1]"
        );
        assert!(
            (0.0..=1.0).contains(&cfg.target_attainment),
            "target attainment must be a fraction"
        );
        let replica_rps = cfg.rho_target * cfg.template.peak_rps();
        Controller { cfg, calm_windows: 0, replica_rps }
    }

    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// Consecutive calm (SLO met, capacity surplus) windows observed
    /// so far — the hysteresis state behind patient scale-down,
    /// exposed so the DES's `scale_tick` trace record
    /// ([`crate::obs::trace::TraceRecord::ScaleTick`]) can show why
    /// the controller did or did not drain. Read *after*
    /// [`Controller::desired`] for the post-tick streak.
    pub fn calm_streak(&self) -> u32 {
        self.calm_windows
    }

    /// Target fleet size for the next window, clamped to
    /// [min_devices, max_devices]. See the module docs for the policy;
    /// the shape is: proactive jump-up to demand, patient one-step
    /// drain-down.
    pub fn desired(&mut self, s: &WindowSignal) -> usize {
        let window_s = self.cfg.window.as_secs_f64();
        // Rate term: devices needed to carry the window's arrival rate
        // at the utilization target.
        let rate = s.arrivals as f64 / window_s;
        let by_rate = (rate / self.replica_rps).ceil() as usize;
        // Backlog term: devices needed to clear the work already
        // queued within about one window (a healthy fleet's resident
        // count is on the order of its in-flight batches, which one
        // window absorbs; a structural backlog means capacity
        // shortfall no matter what the rate estimate says).
        let absorb_per_dev = (self.replica_rps * window_s).max(1.0);
        let by_backlog = (s.backlog as f64 / absorb_per_dev).ceil() as usize;
        let mut desired = by_rate.max(by_backlog);

        if s.attainment < self.cfg.target_attainment {
            // SLO missed: whatever the demand estimate says, grow.
            desired = desired.max(s.active + 1);
            self.calm_windows = 0;
        } else if desired < s.active {
            // Capacity surplus and SLO met: drain one replica per
            // window, after `scale_down_patience` consecutive such
            // windows (hysteresis against rate-estimate noise).
            self.calm_windows += 1;
            desired = if self.calm_windows >= self.cfg.scale_down_patience {
                self.calm_windows = 0;
                s.active - 1
            } else {
                s.active
            };
        } else {
            // Demand at or above the current fleet: follow it up
            // immediately (proactive), reset the calm streak.
            self.calm_windows = 0;
        }
        desired.clamp(self.cfg.min_devices, self.cfg.max_devices)
    }
}

/// Trajectory summary of an autoscaled run (in
/// [`crate::serve::FleetReport::autoscale`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AutoscaleSummary {
    /// Controller evaluations (ScaleTick events).
    pub ticks: u64,
    /// Replicas added (drain cancellations included).
    pub scale_ups: u64,
    /// Replicas sent draining.
    pub scale_downs: u64,
    /// Largest / smallest serving fleet observed at any tick boundary.
    pub peak_active: usize,
    pub min_active: usize,
    /// Serving devices when the run ended.
    pub final_active: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn template() -> DeviceModel {
        // peak = 8 / (2 + 8·8) ms = 8/66 ms ≈ 121 req/s.
        DeviceModel::from_latencies(
            "ctl".into(),
            Duration::from_millis(2),
            Duration::from_millis(8),
            &[1, 2, 4, 8],
        )
    }

    fn controller() -> Controller {
        Controller::new(AutoscaleConfig::for_device(template(), Duration::from_millis(200)))
    }

    fn calm(active: usize) -> WindowSignal {
        WindowSignal { arrivals: 2, attainment: 1.0, backlog: 1, active }
    }

    #[test]
    fn defaults_window_tracks_the_batch_cadence() {
        let cfg = AutoscaleConfig::for_device(template(), Duration::from_millis(200));
        // service(8) = 2 + 64 = 66 ms → window 66 ms.
        assert_eq!(cfg.window, Duration::from_millis(66));
        assert_eq!(cfg.min_devices, 1);
        assert!(cfg.max_devices >= 4);
    }

    #[test]
    fn rate_surge_scales_up_before_the_slo_breaks() {
        let mut c = controller();
        // ~2.4× one device's peak offered in one 66 ms window, SLO
        // still intact: the rate term alone must jump the fleet up.
        let arrivals = (2.4 * template().peak_rps() * 0.066) as u64;
        let want = c.desired(&WindowSignal { arrivals, attainment: 1.0, backlog: 4, active: 1 });
        assert!(want >= 3, "proactive sizing: got {want}");
    }

    #[test]
    fn slo_miss_forces_growth_even_when_rate_looks_calm() {
        let mut c = controller();
        let s = WindowSignal { arrivals: 2, attainment: 0.5, backlog: 2, active: 2 };
        assert_eq!(c.desired(&s), 3, "attainment miss must add a replica");
    }

    #[test]
    fn backlog_pressure_scales_up_without_arrivals() {
        let mut c = controller();
        // A silent window (burst just ended upstream) with a deep
        // resident backlog still demands capacity.
        let s = WindowSignal { arrivals: 0, attainment: 1.0, backlog: 60, active: 1 };
        assert!(c.desired(&s) >= 3, "backlog term must act");
    }

    #[test]
    fn scale_down_needs_patience_and_steps_by_one() {
        let mut c = controller();
        assert_eq!(c.desired(&calm(4)), 4, "first calm window: hold");
        assert_eq!(c.desired(&calm(4)), 3, "patience met: one step down");
        assert_eq!(c.desired(&calm(3)), 3, "streak reset after the step");
        assert_eq!(c.desired(&calm(3)), 2);
    }

    #[test]
    fn slo_miss_resets_the_calm_streak() {
        let mut c = controller();
        assert_eq!(c.desired(&calm(4)), 4);
        let miss = WindowSignal { arrivals: 2, attainment: 0.0, backlog: 2, active: 4 };
        assert_eq!(c.desired(&miss), 5);
        assert_eq!(c.desired(&calm(5)), 5, "streak restarted: hold first");
        assert_eq!(c.desired(&calm(5)), 4);
    }

    #[test]
    fn clamped_to_the_configured_bounds() {
        let mut cfg = AutoscaleConfig::for_device(template(), Duration::from_millis(200));
        cfg.min_devices = 2;
        cfg.max_devices = 3;
        let mut c = Controller::new(cfg);
        let flood =
            WindowSignal { arrivals: 10_000, attainment: 0.0, backlog: 9_999, active: 3 };
        assert_eq!(c.desired(&flood), 3, "ceiling");
        let mut c2 = controller();
        c2.cfg.min_devices = 2;
        for _ in 0..10 {
            let d = c2.desired(&calm(2));
            assert!(d >= 2, "floor");
        }
    }

    #[test]
    #[should_panic(expected = "floor must keep one device")]
    fn zero_floor_rejected() {
        let mut cfg = AutoscaleConfig::for_device(template(), Duration::from_millis(200));
        cfg.min_devices = 0;
        let _ = Controller::new(cfg);
    }
}
