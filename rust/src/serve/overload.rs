//! Overload protection for the serving DES: admission control,
//! priority-aware load shedding, per-device circuit breakers, and a
//! brownout (graceful-degradation) controller.
//!
//! PR 6 (`serve/faults.rs`) made the fleet survive *device* failures;
//! this module makes it survive *demand* failures. When offered load
//! exceeds capacity an unprotected open-loop fleet queues without
//! bound and every class of traffic misses the SLO together. The
//! production answer is to degrade deliberately, in order:
//!
//! 1. **Admission control** — per-class token-bucket rate caps and
//!    resident-request (queue-depth) limits at the fleet edge
//!    ([`AdmissionConfig`]). A rejected request never enters the
//!    dispatch path; it settles immediately and is counted under the
//!    extended conservation law `completed + dropped + rejected ==
//!    offered`, hard-asserted by the DES.
//! 2. **Priority-aware shedding** — requests carry a
//!    [`Priority`](crate::serve::workload::Priority) class assigned at
//!    the arrival edge from the run's
//!    [`ClassMix`](crate::serve::workload::ClassMix). Queue limits are
//!    tiered so the least important class hits its limit first
//!    ([`AdmissionConfig::tiered`]), and per-class retry budgets
//!    ([`AdmissionConfig::attempt_budget`]) shed low-priority work at
//!    the deadline-retry stage before it can starve interactive
//!    traffic.
//! 3. **Circuit breakers** — a per-device [`Breaker`] trips after a
//!    streak of attempt timeouts (fed by the PR 6 fault machinery),
//!    masks the device out of dispatch, and re-admits it through a
//!    half-open probe after a cooldown. Generation counters make
//!    stale probe events harmless (the PR 6 cancellation idiom).
//! 4. **Brownout** — a hysteresis [`BrownoutController`] (sibling of
//!    [`autoscale::Controller`](crate::serve::autoscale::Controller))
//!    watches windowed SLO attainment *with rejects counted as
//!    misses* (shedding must not mask pressure) and, under sustained
//!    miss, flips devices onto a degraded service table — the same
//!    UbiMoE device re-costed at a lower bit-width via
//!    [`DeviceModel::degraded`] — charging an accuracy-proxy cost per
//!    degraded completion into the [`OverloadSummary`]. Hysteresis is
//!    asymmetric (fast in, slow out) so the fleet does not flap.
//!
//! Everything here follows the PR 6 inertness contract: an inert
//! [`OverloadConfig`] ([`OverloadConfig::is_inert`]) is filtered out
//! before the event loop starts, so it yields a *bit-identical*
//! `FleetReport` to `overload: None` (proptested). All controller
//! state machines in this module are pure — they decide, the DES in
//! `serve/mod.rs` acts — which is what makes them unit-testable
//! without an event loop.

use std::time::Duration;

use crate::coordinator::metrics::LatencyStats;
use crate::serve::device::DeviceModel;
use crate::serve::workload::{ClassMix, NUM_CLASSES};

/// Top-level overload-protection configuration, carried as
/// `ServeConfig::overload: Option<OverloadConfig>`. `None` and an
/// inert config are bit-identical (the `is_inert` contract).
#[derive(Clone, Debug)]
pub struct OverloadConfig {
    /// Class mix drawn per arrival on a dedicated RNG stream.
    pub mix: ClassMix,
    /// Shadow mode: classify and account (per-class counters and
    /// latency splits in [`OverloadSummary`]) without enforcing
    /// anything — the "unprotected" baseline of `overload_study`
    /// still reports per-class attainment.
    pub shadow: bool,
    /// Admission control + shedding knobs; `None` admits everything.
    pub admission: Option<AdmissionConfig>,
    /// Per-device circuit breakers; `None` never masks a device.
    pub breaker: Option<BreakerConfig>,
    /// Brownout (degraded-mode) controller; `None` never degrades.
    pub brownout: Option<BrownoutConfig>,
}

impl OverloadConfig {
    /// The canonical "no overload protection" value.
    pub fn none() -> Option<OverloadConfig> {
        None
    }

    /// Shadow-only observation: classify and account, enforce nothing.
    pub fn shadow(mix: ClassMix) -> OverloadConfig {
        OverloadConfig { mix, shadow: true, admission: None, breaker: None, brownout: None }
    }

    /// True iff this config cannot influence (or even observe) the
    /// run: no shadow accounting, no effective admission limits, no
    /// breakers, no brownout. The DES filters inert configs out
    /// before the loop starts, so `Some(inert)` is bit-identical to
    /// `None` — including the class-RNG stream, which is only drawn
    /// when overload is live.
    pub fn is_inert(&self) -> bool {
        !self.shadow
            && self.admission.as_ref().is_none_or(AdmissionConfig::is_inert)
            && self.breaker.is_none()
            && self.brownout.is_none()
    }
}

impl Default for OverloadConfig {
    /// Inert by construction (classless shadow off, no limits).
    fn default() -> Self {
        OverloadConfig {
            mix: ClassMix::default(),
            shadow: false,
            admission: None,
            breaker: None,
            brownout: None,
        }
    }
}

/// Admission-control knobs, all per-class (index =
/// [`Priority::index`](crate::serve::workload::Priority::index)).
/// `None` in any slot means "unlimited" for that class.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Token-bucket rate caps in requests/s. A class with a cap
    /// admits at most `cap` req/s sustained (bursts up to `burst`).
    pub rate_caps: [Option<f64>; NUM_CLASSES],
    /// Token-bucket depth (max stored tokens), shared across classes.
    pub burst: f64,
    /// Resident-request limits: a class-`c` arrival is rejected when
    /// the fleet-wide resident count (queued + in-flight, i.e. the
    /// sum the dispatch `LoadTracker` maintains) is at or above
    /// `queue_limits[c]`. **Calibration matters:** under full service
    /// the resident count never drops below the in-flight floor
    /// `F = devices × max_batch`, so limits must sit *above* F or
    /// they reject traffic the fleet could serve ([`Self::tiered`]).
    pub queue_limits: [Option<usize>; NUM_CLASSES],
    /// Per-class retry budgets layered under
    /// `FaultConfig::max_attempts`: class `c` gets
    /// `min(max_attempts, attempt_budget[c])` attempts, so deadline
    /// pressure sheds low-priority retries first.
    pub attempt_budget: [Option<u32>; NUM_CLASSES],
}

impl AdmissionConfig {
    /// No limits anywhere (inert).
    pub fn unlimited() -> AdmissionConfig {
        AdmissionConfig {
            rate_caps: [None; NUM_CLASSES],
            burst: 1.0,
            queue_limits: [None; NUM_CLASSES],
            attempt_budget: [None; NUM_CLASSES],
        }
    }

    /// Priority-tiered resident limits calibrated above the in-flight
    /// floor `fleet_slots = devices × max_batch` (see
    /// [`Self::queue_limits`]): interactive keeps 5F/3, batch 4F/3,
    /// background 9F/8 — so as backlog grows, background is shed
    /// first, then batch, and interactive keeps a bounded queue whose
    /// wait is ≈ (limit − F)/F service times of the largest batch.
    pub fn tiered(fleet_slots: usize) -> AdmissionConfig {
        let f = fleet_slots.max(1);
        AdmissionConfig {
            queue_limits: [Some(f * 5 / 3), Some(f * 4 / 3), Some(f * 9 / 8)],
            ..AdmissionConfig::unlimited()
        }
    }

    /// True iff no limit of any kind is set.
    pub fn is_inert(&self) -> bool {
        self.rate_caps.iter().all(Option::is_none)
            && self.queue_limits.iter().all(Option::is_none)
            && self.attempt_budget.iter().all(Option::is_none)
    }
}

/// Why an arrival was rejected — carried on the `reject` trace record
/// and split out in [`OverloadSummary`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// Per-class token bucket was empty.
    RateCap,
    /// Fleet resident count was at/above the class's queue limit.
    QueueLimit,
}

impl RejectReason {
    /// Stable string used in trace records.
    pub fn label(self) -> &'static str {
        match self {
            RejectReason::RateCap => "rate",
            RejectReason::QueueLimit => "queue",
        }
    }
}

/// Deterministic token bucket on integer-ns virtual time: refills
/// continuously at `rate` tokens/s up to `burst`, spends one token
/// per admitted request. All-f64 arithmetic on deterministic inputs,
/// so the admit/reject sequence is part of the bit-determinism
/// contract.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    rate_per_s: f64,
    burst: f64,
    tokens: f64,
    last_ns: u64,
}

impl TokenBucket {
    /// Starts full (a quiet fleet admits an initial burst).
    pub fn new(rate_per_s: f64, burst: f64) -> TokenBucket {
        assert!(rate_per_s > 0.0, "token bucket rate must be positive");
        assert!(burst >= 1.0, "token bucket burst must hold at least one token");
        TokenBucket { rate_per_s, burst, tokens: burst, last_ns: 0 }
    }

    /// Refill to `now_ns` and try to spend one token. `now_ns` must
    /// be non-decreasing across calls (virtual time is).
    pub fn admit(&mut self, now_ns: u64) -> bool {
        debug_assert!(now_ns >= self.last_ns, "virtual time ran backwards");
        let dt_s = (now_ns - self.last_ns) as f64 / 1e9;
        self.last_ns = now_ns;
        self.tokens = (self.tokens + self.rate_per_s * dt_s).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Circuit-breaker knobs (per-device instances are created lazily by
/// the DES).
#[derive(Clone, Debug)]
pub struct BreakerConfig {
    /// Consecutive attempt timeouts on one device that open its
    /// breaker. Must be ≥ 1.
    pub trip_after: u32,
    /// Open-state dwell before a half-open probe re-admits traffic.
    pub cooldown: Duration,
}

impl BreakerConfig {
    pub fn validate(&self) {
        assert!(self.trip_after >= 1, "breaker trip_after must be >= 1");
        assert!(!self.cooldown.is_zero(), "breaker cooldown must be positive");
    }
}

/// Circuit-breaker state. `Open` devices are masked out of dispatch;
/// `HalfOpen` devices take traffic again but one more failure
/// re-opens them and one success closes them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

/// Pure per-device circuit-breaker state machine. The DES owns the
/// side effects (dispatch mask via `LoadTracker::deactivate` /
/// `activate`, probe scheduling via `BreakerProbe` events); the
/// breaker only decides. The generation counter makes cancelled
/// probes harmless: any transition out of `Open` bumps `gen`, so a
/// probe event carrying a stale generation is ignored — the same
/// idiom the batcher uses for `FlushDeadline`.
#[derive(Clone, Debug, Default)]
pub struct Breaker {
    state: BreakerStateInner,
    gen: u32,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BreakerStateInner {
    Closed { streak: u32 },
    Open,
    HalfOpen,
}

impl Default for BreakerStateInner {
    fn default() -> Self {
        BreakerStateInner::Closed { streak: 0 }
    }
}

impl Breaker {
    pub fn new() -> Breaker {
        Breaker::default()
    }

    pub fn state(&self) -> BreakerState {
        match self.state {
            BreakerStateInner::Closed { .. } => BreakerState::Closed,
            BreakerStateInner::Open => BreakerState::Open,
            BreakerStateInner::HalfOpen => BreakerState::HalfOpen,
        }
    }

    /// Probe-generation the next `BreakerProbe` event must carry.
    pub fn gen(&self) -> u32 {
        self.gen
    }

    /// Current failure streak (0 outside `Closed`).
    pub fn streak(&self) -> u32 {
        match self.state {
            BreakerStateInner::Closed { streak } => streak,
            _ => 0,
        }
    }

    /// An attempt timeout attributed to this device. Returns `true`
    /// iff this failure *trips* the breaker (Closed→Open on reaching
    /// the streak, or HalfOpen→Open on a failed probe period) — the
    /// caller must then mask the device and schedule a probe at
    /// `now + cooldown` carrying [`Breaker::gen`].
    pub fn on_failure(&mut self, trip_after: u32) -> bool {
        match self.state {
            BreakerStateInner::Closed { streak } => {
                let streak = streak + 1;
                if streak >= trip_after {
                    self.state = BreakerStateInner::Open;
                    true
                } else {
                    self.state = BreakerStateInner::Closed { streak };
                    false
                }
            }
            BreakerStateInner::HalfOpen => {
                self.state = BreakerStateInner::Open;
                true
            }
            // Already open: late failures from attempts that were in
            // flight when the breaker tripped change nothing.
            BreakerStateInner::Open => false,
        }
    }

    /// A completion on this device. Returns `true` iff it closes a
    /// half-open breaker (the probe succeeded).
    pub fn on_success(&mut self) -> bool {
        match self.state {
            BreakerStateInner::HalfOpen => {
                self.state = BreakerStateInner::Closed { streak: 0 };
                self.gen += 1;
                true
            }
            BreakerStateInner::Closed { .. } => {
                self.state = BreakerStateInner::Closed { streak: 0 };
                false
            }
            BreakerStateInner::Open => false,
        }
    }

    /// The cooldown probe event fired. Returns `true` iff the probe
    /// is current (generation matches) and the breaker moves
    /// Open→HalfOpen — the caller must then unmask the device.
    pub fn on_probe(&mut self, gen: u32) -> bool {
        if gen == self.gen && self.state == BreakerStateInner::Open {
            self.state = BreakerStateInner::HalfOpen;
            true
        } else {
            false
        }
    }

    /// Hard reset (the device failed outright, was retired, or its
    /// slot was re-used by the autoscaler): back to `Closed`, any
    /// in-flight probe invalidated.
    pub fn reset(&mut self) {
        self.state = BreakerStateInner::Closed { streak: 0 };
        self.gen += 1;
    }
}

/// Brownout (graceful-degradation) knobs.
#[derive(Clone, Debug)]
pub struct BrownoutConfig {
    /// Observation-window length (the controller ticks once per
    /// window on `BrownoutTick` events).
    pub window: Duration,
    /// The SLO the window signal is measured against.
    pub slo: Duration,
    /// Enter brownout after `enter_patience` consecutive windows with
    /// attainment (rejects counted as misses) below this.
    pub enter_attainment: f64,
    /// Exit brownout after `exit_patience` consecutive windows with
    /// attainment at/above this. Must exceed `enter_attainment`
    /// (hysteresis band).
    pub exit_attainment: f64,
    /// Windows of sustained miss before degrading (≥ 1).
    pub enter_patience: u32,
    /// Windows of sustained health before restoring (≥ 1). Keep this
    /// larger than `enter_patience`: fast in, slow out.
    pub exit_patience: u32,
    /// The degraded service table per device slot — the same device
    /// re-costed at a lower bit-width ([`DeviceModel::degraded`]).
    /// Must be device-for-device shape-compatible with the fleet
    /// (identical `batch_sizes`, checked by [`Self::validate`]) so an
    /// in-place swap keeps formed batches and the batcher valid.
    pub degraded: Vec<DeviceModel>,
    /// Accuracy-proxy cost charged per completion served degraded
    /// (accumulated into [`OverloadSummary::accuracy_cost`]).
    pub accuracy_cost_per_request: f64,
}

impl BrownoutConfig {
    /// Panics unless the config is self-consistent and the degraded
    /// tables are swap-compatible with `models`.
    pub fn validate(&self, models: &[DeviceModel]) {
        assert!(!self.window.is_zero(), "brownout window must be positive");
        assert!(!self.slo.is_zero(), "brownout SLO must be positive");
        assert!(
            (0.0..=1.0).contains(&self.enter_attainment)
                && (0.0..=1.0).contains(&self.exit_attainment),
            "brownout attainment thresholds must be fractions"
        );
        assert!(
            self.enter_attainment < self.exit_attainment,
            "brownout needs a hysteresis band: enter {} must be below exit {}",
            self.enter_attainment,
            self.exit_attainment
        );
        assert!(self.enter_patience >= 1 && self.exit_patience >= 1);
        assert!(self.accuracy_cost_per_request >= 0.0);
        assert_eq!(
            self.degraded.len(),
            models.len(),
            "one degraded table per device slot"
        );
        for (d, (deg, full)) in self.degraded.iter().zip(models).enumerate() {
            assert_eq!(
                deg.batch_sizes, full.batch_sizes,
                "device {d}: degraded table must keep the batch-size menu \
                 (the swap must not invalidate formed batches)"
            );
        }
    }
}

/// One window's worth of evidence for the brownout controller.
/// `rejects` are counted as SLO misses: shedding removes queueing
/// pressure from the *latency* signal, so a controller that only
/// watched completions would read a heavily-shedding fleet as
/// healthy and never degrade — exactly backwards.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BrownoutSignal {
    /// Completions in the window.
    pub completions: u64,
    /// Completions whose end-to-end latency met the SLO.
    pub met: u64,
    /// Admission rejections in the window (counted as misses).
    pub rejects: u64,
}

impl BrownoutSignal {
    /// Attainment with rejects as misses; an empty window reads as
    /// healthy (1.0) so idle fleets recover.
    pub fn attainment(&self) -> f64 {
        let total = self.completions + self.rejects;
        if total == 0 {
            1.0
        } else {
            self.met as f64 / total as f64
        }
    }
}

/// Pure hysteresis controller deciding degraded vs full-precision
/// operation — the brownout sibling of
/// [`autoscale::Controller`](crate::serve::autoscale::Controller):
/// it only reads window signals and returns transition decisions;
/// the DES performs the model swap.
#[derive(Clone, Debug, Default)]
pub struct BrownoutController {
    degraded: bool,
    miss_streak: u32,
    ok_streak: u32,
}

impl BrownoutController {
    pub fn new() -> BrownoutController {
        BrownoutController::default()
    }

    /// Whether the fleet is currently running degraded.
    pub fn degraded(&self) -> bool {
        self.degraded
    }

    /// Consume one window. Returns `Some(true)` to enter brownout,
    /// `Some(false)` to exit, `None` for no transition.
    pub fn observe(&mut self, cfg: &BrownoutConfig, sig: &BrownoutSignal) -> Option<bool> {
        let attain = sig.attainment();
        if !self.degraded {
            if attain < cfg.enter_attainment {
                self.miss_streak += 1;
            } else {
                self.miss_streak = 0;
            }
            if self.miss_streak >= cfg.enter_patience {
                self.degraded = true;
                self.miss_streak = 0;
                self.ok_streak = 0;
                return Some(true);
            }
        } else {
            if attain >= cfg.exit_attainment {
                self.ok_streak += 1;
            } else {
                self.ok_streak = 0;
            }
            if self.ok_streak >= cfg.exit_patience {
                self.degraded = false;
                self.miss_streak = 0;
                self.ok_streak = 0;
                return Some(false);
            }
        }
        None
    }
}

/// Overload-machinery counters for a run — `FleetReport::overload`
/// is `Some` iff overload protection (or shadow accounting) was
/// active. Per-class arrays are indexed by
/// [`Priority::index`](crate::serve::workload::Priority::index).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OverloadSummary {
    /// Arrivals per class (sums to the run's offered count).
    pub offered_by_class: [u64; NUM_CLASSES],
    /// Arrivals admitted past the edge, per class.
    pub admitted_by_class: [u64; NUM_CLASSES],
    /// Completions per class.
    pub completed_by_class: [u64; NUM_CLASSES],
    /// Admission rejections per class.
    pub rejected_by_class: [u64; NUM_CLASSES],
    /// End-to-end latency split per class (completions only; a
    /// rejected request has no latency — it has a rejection).
    pub e2e_by_class: [LatencyStats; NUM_CLASSES],
    /// Total admission rejections (= Σ rejected_by_class).
    pub rejected: u64,
    /// Rejections due to an empty token bucket.
    pub rejected_rate: u64,
    /// Rejections due to a resident-count limit.
    pub rejected_queue: u64,
    /// Breaker transitions to `Open`.
    pub breaker_trips: u64,
    /// Breaker transitions HalfOpen→Closed (successful probes).
    pub breaker_closes: u64,
    /// Brownout entries (full→degraded swaps).
    pub brownout_enters: u64,
    /// Windows spent degraded (brownout duty cycle numerator).
    pub brownout_windows: u64,
    /// Completions served by a degraded device.
    pub degraded_completions: u64,
    /// Σ accuracy-proxy cost over degraded completions.
    pub accuracy_cost: f64,
}

impl OverloadSummary {
    /// Class attainment on the *offered* basis: a rejected request is
    /// an SLO miss, so this is (completions meeting `slo`) / offered.
    /// The honest per-class number for overload runs — shedding must
    /// not flatter the class it sheds.
    pub fn class_attainment_offered(&self, class: usize, slo: Duration) -> f64 {
        let offered = self.offered_by_class[class];
        if offered == 0 {
            return 1.0;
        }
        let met = self.e2e_by_class[class].fraction_leq(slo)
            * self.completed_by_class[class] as f64;
        met / offered as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::workload::Priority;

    #[test]
    fn inertness_matches_contents() {
        assert!(OverloadConfig::default().is_inert());
        assert!(
            OverloadConfig {
                admission: Some(AdmissionConfig::unlimited()),
                ..OverloadConfig::default()
            }
            .is_inert(),
            "limitless admission enforces nothing"
        );
        assert!(!OverloadConfig::shadow(ClassMix::standard()).is_inert());
        assert!(!OverloadConfig {
            admission: Some(AdmissionConfig::tiered(24)),
            ..OverloadConfig::default()
        }
        .is_inert());
        assert!(!OverloadConfig {
            breaker: Some(BreakerConfig { trip_after: 3, cooldown: Duration::from_secs(1) }),
            ..OverloadConfig::default()
        }
        .is_inert());
    }

    #[test]
    fn tiered_limits_sit_above_the_in_flight_floor() {
        let a = AdmissionConfig::tiered(24);
        let lim = |p: Priority| a.queue_limits[p.index()].unwrap();
        assert_eq!(lim(Priority::Interactive), 40);
        assert_eq!(lim(Priority::Batch), 32);
        assert_eq!(lim(Priority::Background), 27);
        // Strictly tiered and strictly above F for every fleet size.
        for f in 1..200 {
            let a = AdmissionConfig::tiered(f);
            let l: Vec<usize> = a.queue_limits.iter().map(|q| q.unwrap()).collect();
            assert!(l[0] >= l[1] && l[1] >= l[2], "tiers inverted at F={f}: {l:?}");
            assert!(l[2] >= f, "background limit below the in-flight floor at F={f}");
        }
        assert!(!a.is_inert());
        assert!(AdmissionConfig::unlimited().is_inert());
    }

    #[test]
    fn token_bucket_caps_sustained_rate_but_allows_bursts() {
        // 10 req/s, burst 5: at t=0 a 5-burst passes, the 6th is shed.
        let mut tb = TokenBucket::new(10.0, 5.0);
        let admitted = (0..6).filter(|_| tb.admit(0)).count();
        assert_eq!(admitted, 5);
        // 100 ms later exactly one token has dripped in.
        assert!(tb.admit(100_000_000));
        assert!(!tb.admit(100_000_000));
        // Long quiet period refills to burst, not beyond.
        let admitted = (0..10).filter(|_| tb.admit(10_000_000_000)).count();
        assert_eq!(admitted, 5);
    }

    #[test]
    fn breaker_trips_probes_and_recovers() {
        let mut b = Breaker::new();
        assert_eq!(b.state(), BreakerState::Closed);
        // Two failures at trip_after=3: still closed, streak visible.
        assert!(!b.on_failure(3));
        assert!(!b.on_failure(3));
        assert_eq!(b.streak(), 2);
        // A success resets the streak (streaks are *consecutive*).
        assert!(!b.on_success());
        assert!(!b.on_failure(3));
        assert!(!b.on_failure(3));
        // Third consecutive failure trips.
        assert!(b.on_failure(3));
        assert_eq!(b.state(), BreakerState::Open);
        let gen = b.gen();
        // Late failures while open change nothing.
        assert!(!b.on_failure(3));
        // A stale probe (old generation) is ignored; the current one
        // half-opens.
        assert!(!b.on_probe(gen.wrapping_sub(1)));
        assert!(b.on_probe(gen));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        // Probe succeeds: closed, generation bumped (stale probes dead).
        assert!(b.on_success());
        assert_eq!(b.state(), BreakerState::Closed);
        assert_ne!(b.gen(), gen);
    }

    #[test]
    fn half_open_failure_reopens_and_reset_invalidates_probes() {
        let mut b = Breaker::new();
        assert!(b.on_failure(1), "trip_after=1 trips immediately");
        let g1 = b.gen();
        assert!(b.on_probe(g1));
        // The probe-period request times out: re-open (a fresh trip).
        assert!(b.on_failure(1));
        assert_eq!(b.state(), BreakerState::Open);
        // reset() (device retired / slot reused) invalidates the old
        // probe and returns to Closed.
        let g2 = b.gen();
        b.reset();
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(!b.on_probe(g2), "stale probe after reset must be a no-op");
        assert_eq!(b.state(), BreakerState::Closed);
    }

    fn brown_cfg() -> BrownoutConfig {
        BrownoutConfig {
            window: Duration::from_millis(100),
            slo: Duration::from_millis(50),
            enter_attainment: 0.9,
            exit_attainment: 0.97,
            enter_patience: 2,
            exit_patience: 3,
            degraded: vec![],
            accuracy_cost_per_request: 0.01,
        }
    }

    #[test]
    fn brownout_hysteresis_fast_in_slow_out() {
        let cfg = brown_cfg();
        let mut c = BrownoutController::new();
        let bad = BrownoutSignal { completions: 100, met: 50, rejects: 0 };
        let good = BrownoutSignal { completions: 100, met: 100, rejects: 0 };
        // One bad window: patience not yet exhausted.
        assert_eq!(c.observe(&cfg, &bad), None);
        assert!(!c.degraded());
        // Second consecutive bad window: enter.
        assert_eq!(c.observe(&cfg, &bad), Some(true));
        assert!(c.degraded());
        // Recovery needs exit_patience=3 consecutive good windows —
        // and a bad window in between resets the count.
        assert_eq!(c.observe(&cfg, &good), None);
        assert_eq!(c.observe(&cfg, &good), None);
        assert_eq!(c.observe(&cfg, &bad), None);
        assert_eq!(c.observe(&cfg, &good), None);
        assert_eq!(c.observe(&cfg, &good), None);
        assert_eq!(c.observe(&cfg, &good), Some(false));
        assert!(!c.degraded());
    }

    #[test]
    fn brownout_counts_rejects_as_misses() {
        let cfg = brown_cfg();
        // 90 completions all meeting the SLO + 60 rejects: attainment
        // = 90/150 = 0.6 < 0.9 even though every *completion* was
        // fast — shedding must not mask pressure.
        let shedding = BrownoutSignal { completions: 90, met: 90, rejects: 60 };
        assert!((shedding.attainment() - 0.6).abs() < 1e-12);
        let mut c = BrownoutController::new();
        assert_eq!(c.observe(&cfg, &shedding), None);
        assert_eq!(c.observe(&cfg, &shedding), Some(true));
        // Empty windows read healthy so an idle fleet recovers.
        assert_eq!(BrownoutSignal::default().attainment(), 1.0);
    }

    #[test]
    fn class_attainment_is_on_the_offered_basis() {
        let mut s = OverloadSummary::default();
        let c = Priority::Interactive.index();
        s.offered_by_class[c] = 10;
        s.admitted_by_class[c] = 8;
        s.completed_by_class[c] = 8;
        s.rejected_by_class[c] = 2;
        for ms in [10u64, 10, 10, 10, 10, 10, 200, 200] {
            s.e2e_by_class[c].record(Duration::from_millis(ms));
        }
        // 6 of 8 completions met 50 ms; 2 rejects are misses too:
        // 6/10, not 6/8.
        let got = s.class_attainment_offered(c, Duration::from_millis(50));
        assert!((got - 0.6).abs() < 1e-9, "got {got}");
        // An unused class is vacuously attained.
        assert_eq!(
            s.class_attainment_offered(Priority::Background.index(), Duration::from_millis(1)),
            1.0
        );
    }
}
