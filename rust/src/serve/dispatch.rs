//! Fleet dispatch: which device a new request queues on.
//!
//! The same idea as the §III-C round-robin CU router, one level up:
//! the CU router balances one expert's tokens across compute units
//! inside a device; the dispatcher balances requests across devices
//! of a fleet. Five policies:
//!
//! * **RoundRobin** — cyclic assignment; per-device admission counts
//!   never differ by more than one (proptested), but it is blind to
//!   queue depth, so heterogeneous backlogs (bursts) hurt its tail.
//! * **WeightedRoundRobin** — smooth weighted round-robin keyed on
//!   device period: each device's share of admissions is proportional
//!   to its steady-state throughput (1/period), so a mixed fleet's
//!   tiers are loaded in proportion to capacity instead of equally.
//!   Still blind to instantaneous queue state — the static-weights
//!   baseline the queue-aware policies are measured against
//!   (`report::serving` asserts SED strictly beats it on the mixed
//!   ZCU102+U280 fleet). With no weights configured it degenerates to
//!   plain round-robin (equal weights).
//! * **JoinShortestQueue** — send to the device with the fewest
//!   resident requests (queued + in flight), lowest index on ties.
//! * **ExpertAffinity** — requests carry a dominant-expert hint; each
//!   expert has a home device (`hint % n`), improving expert-weight
//!   cache locality across consecutive batches. To avoid hotspots the
//!   policy spills to JSQ whenever the home device's backlog exceeds
//!   the fleet minimum by more than [`AFFINITY_SLACK`]. The cost
//!   model rewards the locality: a batch whose dominant expert was
//!   resident from the device's previous batch skips the exposed
//!   weight stream
//!   ([`crate::serve::device::DeviceModel::service_time_with_residency`]).
//! * **ShortestExpectedDelay** — the heterogeneity-aware policy (the
//!   ROADMAP mixed-fleet item): instead of comparing queue *lengths*,
//!   compare expected-completion time. Each device's leaf in the
//!   [`LoadTracker`] tournament tree is keyed by its own service LUT
//!   evaluated at "backlog plus me" — `fill + (load+1)·period` in ns
//!   ([`crate::serve::device::DeviceModel::expected_delay_weights`]) —
//!   so a U280 core-tier device with a deep-but-fast queue beats a
//!   ZCU102 edge device with a short-but-slow one. On a homogeneous
//!   fleet the key is strictly monotone in load with identical
//!   coefficients, so SED is pick-for-pick (ties included) identical
//!   to JSQ — proptested below and asserted end-to-end in
//!   `report::serving`.
//!
//! The DES reads loads through [`LoadTracker`] (point updates +
//! indexed argmin) rather than rebuilding a load vector per arrival.
//!
//! ## Dynamic fleets (autoscaling)
//!
//! The autoscaling controller ([`crate::serve::autoscale`]) changes
//! fleet membership mid-run, so the tracker supports it directly:
//! [`LoadTracker::deactivate`] takes a device out of the dispatch set
//! (its tree key becomes `u64::MAX`, so no minimum-seeking policy ever
//! picks it while it drains) without disturbing its raw load
//! bookkeeping, [`LoadTracker::activate`] puts it back, and
//! [`LoadTracker::push_device`] grows the tree for a freshly spawned
//! replica (an O(n) rebuild — scale events are rare). RoundRobin and
//! the affinity home-pick skip inactive devices; on an all-active
//! fleet every policy behaves exactly as before.

use std::time::Duration;

/// Backlog slack (requests) an affinity home may carry over the fleet
/// minimum before the dispatcher spills to join-shortest-queue.
pub const AFFINITY_SLACK: usize = 8;

/// Fleet dispatch policy (see the module docs for the semantics and
/// contracts of each).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    RoundRobin,
    WeightedRoundRobin,
    JoinShortestQueue,
    ExpertAffinity,
    ShortestExpectedDelay,
}

impl DispatchPolicy {
    /// Parse a CLI policy name (see [`DispatchPolicy::name`] for the
    /// canonical spellings; short aliases accepted).
    pub fn by_name(name: &str) -> Option<DispatchPolicy> {
        Some(match name.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" => DispatchPolicy::RoundRobin,
            "wrr" | "weighted-round-robin" => DispatchPolicy::WeightedRoundRobin,
            "jsq" | "shortest" => DispatchPolicy::JoinShortestQueue,
            "affinity" | "expert-affinity" => DispatchPolicy::ExpertAffinity,
            "sed" | "shortest-expected-delay" => DispatchPolicy::ShortestExpectedDelay,
            _ => return None,
        })
    }

    /// Canonical display name (round-trips through
    /// [`DispatchPolicy::by_name`]).
    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::WeightedRoundRobin => "wrr",
            DispatchPolicy::JoinShortestQueue => "jsq",
            DispatchPolicy::ExpertAffinity => "expert-affinity",
            DispatchPolicy::ShortestExpectedDelay => "sed",
        }
    }
}

/// Indexed device-load signal: a tournament (segment) tree over
/// per-device keys, point-updated by the DES on dispatch (+1) and
/// batch completion (−batch occupancy) instead of re-scanning the
/// whole fleet per arrival.
///
/// The key is what the tree minimizes over:
///
/// * [`LoadTracker::new`] — key = resident-request count (the PR-3
///   join-shortest-queue signal);
/// * [`LoadTracker::with_expected_delay`] — key = expected-completion
///   ns, `fill + (load+1)·period` per device from its service LUT
///   (the shortest-expected-delay signal; saturating arithmetic, so
///   pathological backlogs clamp instead of wrapping).
///
/// Queries: O(1) `argmin` with **lowest index on ties** (bit-identical
/// to the linear scan — proptested below), O(1) `min_key`/`min_load`,
/// O(1) `get`; updates are O(log n). Deactivated devices (autoscale
/// drain, fault injection) key as `u64::MAX`; a raw `argmin` on an
/// all-inactive fleet would still return a `u64::MAX`-keyed slot, so
/// the DES dispatches through [`Dispatcher::try_pick_indexed`], which
/// checks [`LoadTracker::active_count`] first and reports no-capacity
/// explicitly instead of silently picking a downed victim.
#[derive(Clone, Debug)]
pub struct LoadTracker {
    n: usize,
    base: usize,
    /// 1-indexed tree; leaves at `base..base+n` hold `(key, device)`.
    /// Padding leaves hold `(u64::MAX, i)` with `i ≥ n`, so a real
    /// device wins even a saturated-key tie (lower index).
    tree: Vec<(u64, usize)>,
    /// Raw resident-request counts (the affinity policy and the DES
    /// bookkeeping read these regardless of the tree key).
    loads: Vec<usize>,
    /// Per-device (fill_ns, period_ns); `None` keys the tree by load.
    weights: Option<Vec<(u64, u64)>>,
    /// Dispatch eligibility; inactive devices key as `u64::MAX`.
    active: Vec<bool>,
    /// Count of `true` entries in `active` — O(1) no-capacity checks.
    active_n: usize,
}

impl LoadTracker {
    /// Tracker keyed by resident-request count (JSQ/affinity signal).
    pub fn new(n: usize) -> LoadTracker {
        Self::build(n, None)
    }

    /// Tracker keyed by expected-completion ns from per-device
    /// `(fill_ns, period_ns)` service-LUT coefficients (SED signal).
    pub fn with_expected_delay(weights: Vec<(u64, u64)>) -> LoadTracker {
        let n = weights.len();
        Self::build(n, Some(weights))
    }

    fn build(n: usize, weights: Option<Vec<(u64, u64)>>) -> LoadTracker {
        assert!(n > 0, "empty fleet");
        let mut t = LoadTracker {
            n,
            base: 0,
            tree: Vec::new(),
            loads: vec![0; n],
            weights,
            active: vec![true; n],
            active_n: n,
        };
        t.rebuild();
        t
    }

    /// Rebuild the whole tree from `loads`/`weights`/`active` — O(n),
    /// used at construction and when the fleet grows (scale events are
    /// rare; every per-arrival path stays O(log n)).
    fn rebuild(&mut self) {
        self.base = self.n.next_power_of_two();
        self.tree = vec![(u64::MAX, 0); 2 * self.base];
        for (i, leaf) in self.tree[self.base..].iter_mut().enumerate() {
            leaf.1 = i;
        }
        for i in 0..self.n {
            let key = self.key(i, self.loads[i]);
            self.tree[self.base + i].0 = key;
        }
        for i in (1..self.base).rev() {
            let merged = Self::min2(self.tree[2 * i], self.tree[2 * i + 1]);
            self.tree[i] = merged;
        }
    }

    /// The tree key of device `i` at `load` resident requests.
    #[inline]
    fn key(&self, i: usize, load: usize) -> u64 {
        if !self.active[i] {
            return u64::MAX;
        }
        match &self.weights {
            None => load as u64,
            Some(w) => {
                let (fill, period) = w[i];
                fill.saturating_add((load as u64).saturating_add(1).saturating_mul(period))
            }
        }
    }

    /// Lexicographic (key, index) minimum: the left (lower-index)
    /// child wins ties, matching the linear-scan argmin exactly
    /// (`std::cmp::min` returns its first argument on equality).
    #[inline]
    fn min2(a: (u64, usize), b: (u64, usize)) -> (u64, usize) {
        std::cmp::min(a, b)
    }

    /// Fleet size.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Current resident-request count of device `i`.
    #[inline]
    pub fn get(&self, i: usize) -> usize {
        self.loads[i]
    }

    /// Recompute device `i`'s key and sift it up — O(log n), the point
    /// update behind `set`/`activate`/`deactivate`/`set_weight`.
    fn refresh(&mut self, i: usize) {
        assert!(i < self.n, "device {i} out of range {}", self.n);
        let key = self.key(i, self.loads[i]);
        let mut k = self.base + i;
        self.tree[k].0 = key;
        while k > 1 {
            k /= 2;
            let merged = Self::min2(self.tree[2 * k], self.tree[2 * k + 1]);
            self.tree[k] = merged;
        }
    }

    pub fn set(&mut self, i: usize, load: usize) {
        assert!(i < self.n, "device {i} out of range {}", self.n);
        self.loads[i] = load;
        self.refresh(i);
    }

    pub fn add(&mut self, i: usize, delta: usize) {
        self.set(i, self.get(i) + delta);
    }

    pub fn sub(&mut self, i: usize, delta: usize) {
        self.set(i, self.get(i) - delta);
    }

    /// Whether device `i` is eligible for dispatch.
    #[inline]
    pub fn is_active(&self, i: usize) -> bool {
        self.active[i]
    }

    /// Take device `i` out of the dispatch set (autoscale drain,
    /// device failure, or a tripped circuit breaker —
    /// [`crate::serve::overload::Breaker`] masks a timeout-streaking
    /// device through exactly this call): its key becomes `u64::MAX`,
    /// so no minimum-seeking policy picks it; raw load bookkeeping
    /// (`get`/`add`/`sub`) keeps working while it drains. Idempotent —
    /// a failure landing on an already-draining slot is a no-op here.
    pub fn deactivate(&mut self, i: usize) {
        if !self.active[i] {
            return;
        }
        self.active[i] = false;
        self.active_n -= 1;
        self.refresh(i);
    }

    /// Put device `i` back into the dispatch set (scale-up reusing a
    /// draining or retired slot, repair of a failed one, or a
    /// half-opening circuit breaker re-admitting probe traffic).
    /// Idempotent.
    pub fn activate(&mut self, i: usize) {
        if self.active[i] {
            return;
        }
        self.active[i] = true;
        self.active_n += 1;
        self.refresh(i);
    }

    /// Number of dispatch-eligible devices; zero means the fleet has
    /// no capacity (total outage) and dispatch must park the request
    /// at fleet level instead of picking a `u64::MAX`-keyed victim.
    #[inline]
    pub fn active_count(&self) -> usize {
        self.active_n
    }

    /// Replace device `i`'s expected-delay coefficients (a retired
    /// slot being reused for a different template). Only meaningful on
    /// an expected-delay tracker.
    pub fn set_weight(&mut self, i: usize, weight: (u64, u64)) {
        let w = self
            .weights
            .as_mut()
            .expect("set_weight on a load-keyed tracker — keys carry no coefficients");
        w[i] = weight;
        self.refresh(i);
    }

    /// Grow the fleet by one device (autoscale spawn), active with
    /// load 0. `weight` must be `Some` iff the tracker is keyed by
    /// expected delay. O(n) tree rebuild — scale events are rare.
    pub fn push_device(&mut self, weight: Option<(u64, u64)>) -> usize {
        match (&mut self.weights, weight) {
            (None, None) => {}
            (Some(w), Some(x)) => w.push(x),
            (None, Some(_)) => panic!("expected-delay weight pushed onto a load-keyed tracker"),
            (Some(_), None) => panic!("expected-delay tracker needs a weight for a new device"),
        }
        self.loads.push(0);
        self.active.push(true);
        self.active_n += 1;
        self.n += 1;
        self.rebuild();
        self.n - 1
    }

    /// Smallest tree key in the fleet (load, or expected-delay ns).
    #[inline]
    pub fn min_key(&self) -> u64 {
        self.tree[1].0
    }

    /// Smallest resident-request count over *active* devices — only
    /// meaningful on a load-keyed tracker (the affinity policy's
    /// signal) with at least one active device.
    #[inline]
    pub fn min_load(&self) -> usize {
        debug_assert!(
            self.weights.is_none(),
            "min_load on an expected-delay tracker — use min_key"
        );
        self.tree[1].0 as usize
    }

    /// Device holding the smallest key, lowest index on ties.
    #[inline]
    pub fn argmin(&self) -> usize {
        self.tree[1].1
    }
}

/// Smooth weighted round-robin state (the nginx algorithm): each pick
/// adds every eligible device's weight to its running credit, picks
/// the largest credit (lowest index on ties), and debits the winner by
/// the eligible total. Admission shares converge to the weight ratios
/// while interleaving maximally; with equal weights the pick sequence
/// is exactly plain round-robin. O(n) per pick — acceptable for a
/// baseline policy on small fleets (the queue-aware policies keep the
/// O(log n) tree).
#[derive(Clone, Debug)]
struct Wrr {
    weights: Vec<u64>,
    credit: Vec<i64>,
}

impl Wrr {
    fn new(weights: Vec<u64>) -> Wrr {
        assert!(!weights.is_empty(), "empty fleet");
        assert!(weights.iter().all(|&w| w > 0), "WRR weights must be positive");
        let credit = vec![0; weights.len()];
        Wrr { weights, credit }
    }

    fn equal(n: usize) -> Wrr {
        Wrr::new(vec![1; n])
    }

    /// Throughput-proportional weight of a device with the given
    /// steady-state period: requests per second, floored to 1 so every
    /// device keeps a positive share.
    fn period_weight(period: Duration) -> u64 {
        let ns = (period.as_nanos() as u64).max(1);
        (1_000_000_000 / ns).max(1)
    }

    fn push(&mut self, weight: u64) {
        assert!(weight > 0, "WRR weights must be positive");
        self.weights.push(weight);
        self.credit.push(0);
    }

    fn set(&mut self, i: usize, weight: u64) {
        assert!(weight > 0, "WRR weights must be positive");
        self.weights[i] = weight;
        self.credit[i] = 0;
    }

    fn pick(&mut self, eligible: impl Fn(usize) -> bool) -> usize {
        let mut total = 0i64;
        let mut best: Option<usize> = None;
        for i in 0..self.weights.len() {
            if !eligible(i) {
                continue;
            }
            self.credit[i] += self.weights[i] as i64;
            total += self.weights[i] as i64;
            best = match best {
                Some(b) if self.credit[i] <= self.credit[b] => Some(b),
                _ => Some(i),
            };
        }
        let b = best.expect("weighted round-robin: no eligible device");
        self.credit[b] -= total;
        b
    }
}

/// Stateful dispatcher (round-robin keeps a cursor, weighted
/// round-robin its credit vector).
#[derive(Clone, Debug)]
pub struct Dispatcher {
    policy: DispatchPolicy,
    rr_next: usize,
    /// Present for WeightedRoundRobin; lazily initialized with equal
    /// weights (= plain RR) if the dispatcher was built without
    /// periods.
    wrr: Option<Wrr>,
}

fn argmin(loads: &[usize]) -> usize {
    let mut best = 0;
    for (i, &l) in loads.iter().enumerate() {
        if l < loads[best] {
            best = i;
        }
    }
    best
}

impl Dispatcher {
    pub fn new(policy: DispatchPolicy) -> Dispatcher {
        Dispatcher { policy, rr_next: 0, wrr: None }
    }

    /// A WeightedRoundRobin dispatcher whose per-device weights are
    /// throughput-proportional: 1/period requests per second, from
    /// each device's steady-state service period
    /// ([`crate::serve::device::DeviceModel::period`]) — the DES
    /// constructor for the WRR baseline.
    pub fn weighted_by_period(periods: &[Duration]) -> Dispatcher {
        let weights = periods.iter().map(|&p| Wrr::period_weight(p)).collect();
        Dispatcher {
            policy: DispatchPolicy::WeightedRoundRobin,
            rr_next: 0,
            wrr: Some(Wrr::new(weights)),
        }
    }

    /// Register a freshly spawned device's period with the WRR credit
    /// scheme (autoscale scale-up). No-op for other policies.
    pub fn push_period(&mut self, period: Duration) {
        if let Some(wrr) = &mut self.wrr {
            wrr.push(Wrr::period_weight(period));
        }
    }

    /// Re-weight slot `i` for a new period (a retired slot reused for
    /// a different template; credit resets). No-op for other policies.
    pub fn set_period(&mut self, i: usize, period: Duration) {
        if let Some(wrr) = &mut self.wrr {
            wrr.set(i, Wrr::period_weight(period));
        }
    }

    fn wrr_mut(&mut self, n: usize) -> &mut Wrr {
        self.wrr.get_or_insert_with(|| Wrr::equal(n))
    }

    /// Choose a device from a plain load slice. `loads[d]` = requests
    /// resident on device d (queued + in flight); `expert_hint` is the
    /// request's dominant expert (ignored except by ExpertAffinity).
    ///
    /// The slice carries no service LUTs, so ShortestExpectedDelay
    /// here degrades to JSQ (devices treated as identical — exactly
    /// what SED is on a homogeneous fleet), and a WeightedRoundRobin
    /// dispatcher built without periods runs equal weights (= plain
    /// RR). Heterogeneous SED/WRR go through
    /// [`Dispatcher::pick_indexed`] / [`Dispatcher::weighted_by_period`]
    /// — the DES path.
    pub fn pick(&mut self, loads: &[usize], expert_hint: usize) -> usize {
        assert!(!loads.is_empty(), "empty fleet");
        match self.policy {
            DispatchPolicy::RoundRobin => {
                let d = self.rr_next % loads.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                d
            }
            DispatchPolicy::WeightedRoundRobin => self.wrr_mut(loads.len()).pick(|_| true),
            DispatchPolicy::JoinShortestQueue | DispatchPolicy::ShortestExpectedDelay => {
                argmin(loads)
            }
            DispatchPolicy::ExpertAffinity => {
                let home = expert_hint % loads.len();
                let min = *loads.iter().min().unwrap();
                if loads[home] > min + AFFINITY_SLACK {
                    argmin(loads)
                } else {
                    home
                }
            }
        }
    }

    /// Indexed variant of [`Dispatcher::pick`]: the same choice for
    /// the same signal (proptested), but O(1)–O(log n) against a
    /// [`LoadTracker`] instead of an O(n) scan per arrival — the DES
    /// hot-path entry point. ShortestExpectedDelay expects a tracker
    /// built with [`LoadTracker::with_expected_delay`]; its argmin is
    /// then over expected-completion ns instead of queue length.
    /// Inactive (draining/retired/failed) devices are never picked:
    /// the minimum-seeking policies see them as `u64::MAX`, RoundRobin
    /// and WRR skip them, and an inactive affinity home spills to the
    /// active minimum. Panics when the whole fleet is inactive —
    /// fault-tolerant callers use [`Dispatcher::try_pick_indexed`].
    pub fn pick_indexed(&mut self, loads: &LoadTracker, expert_hint: usize) -> usize {
        self.try_pick_indexed(loads, expert_hint)
            .expect("dispatch over a fleet with no active device")
    }

    /// [`Dispatcher::pick_indexed`] with an explicit no-capacity
    /// outcome: `None` iff *every* device is inactive (total outage —
    /// the DES then parks the request at fleet level until a repair)
    /// instead of silently handing back a `u64::MAX`-keyed victim.
    pub fn try_pick_indexed(
        &mut self,
        loads: &LoadTracker,
        expert_hint: usize,
    ) -> Option<usize> {
        if loads.active_count() == 0 {
            return None;
        }
        let d = match self.policy {
            DispatchPolicy::RoundRobin => loop {
                let d = self.rr_next % loads.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                if loads.is_active(d) {
                    break d;
                }
            },
            DispatchPolicy::WeightedRoundRobin => {
                self.wrr_mut(loads.len()).pick(|i| loads.is_active(i))
            }
            DispatchPolicy::JoinShortestQueue | DispatchPolicy::ShortestExpectedDelay => {
                loads.argmin()
            }
            DispatchPolicy::ExpertAffinity => {
                let home = expert_hint % loads.len();
                if !loads.is_active(home)
                    || loads.get(home) > loads.min_load() + AFFINITY_SLACK
                {
                    loads.argmin()
                } else {
                    home
                }
            }
        };
        if loads.is_active(d) {
            Some(d)
        } else {
            // Saturated-key corner: an active device whose SED key
            // clamped at u64::MAX can tie with an inactive slot and
            // lose the lowest-index tie-break. Fall back to the first
            // active slot (O(n), but the corner needs a >584-year
            // expected delay).
            (0..loads.len()).find(|&i| loads.is_active(i))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn round_robin_cycles() {
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let picks: Vec<usize> = (0..7).map(|_| d.pick(&[0; 3], 0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn jsq_picks_min_lowest_index_on_tie() {
        let mut d = Dispatcher::new(DispatchPolicy::JoinShortestQueue);
        assert_eq!(d.pick(&[4, 2, 2, 9], 0), 1);
        assert_eq!(d.pick(&[0, 0, 0], 5), 0);
    }

    #[test]
    fn affinity_sticks_until_slack_exceeded() {
        let mut d = Dispatcher::new(DispatchPolicy::ExpertAffinity);
        // Home device 1 within slack → stick.
        assert_eq!(d.pick(&[0, AFFINITY_SLACK, 0], 1), 1);
        // Home device 1 beyond slack → spill to JSQ.
        assert_eq!(d.pick(&[3, AFFINITY_SLACK + 1, 0], 1), 2);
        // Same hint, balanced fleet → same home every time.
        for _ in 0..5 {
            assert_eq!(d.pick(&[1, 1, 1, 1], 6), 2);
        }
    }

    #[test]
    fn wrr_with_equal_weights_cycles_like_rr() {
        // Smooth WRR degenerates to plain RR when every weight is
        // equal — the lazily-initialized (no periods) dispatcher.
        let mut wrr = Dispatcher::new(DispatchPolicy::WeightedRoundRobin);
        let mut rr = Dispatcher::new(DispatchPolicy::RoundRobin);
        for _ in 0..20 {
            assert_eq!(wrr.pick(&[0; 3], 0), rr.pick(&[0; 3], 0));
        }
    }

    #[test]
    fn wrr_shares_are_proportional_to_inverse_period() {
        // Periods 10 ms vs 1 ms → weights 100 vs 1000: over one full
        // credit cycle (Σ weights picks) each device is admitted
        // exactly weight-many times — the smooth-WRR share property.
        let mut d = Dispatcher::weighted_by_period(&[
            Duration::from_millis(10),
            Duration::from_millis(1),
        ]);
        let mut counts = [0u32; 2];
        for _ in 0..1100 {
            counts[d.pick(&[0, 0], 0)] += 1;
        }
        assert_eq!(counts, [100, 1000], "shares must match 1/period weights");
    }

    #[test]
    fn wrr_interleaves_rather_than_bursting() {
        // 1:4 weights: the heavy device never gets the light device's
        // slot streak wrong — within any window of 5 picks the light
        // device appears exactly once.
        let mut d = Dispatcher::weighted_by_period(&[
            Duration::from_millis(4),
            Duration::from_millis(1),
        ]);
        let picks: Vec<usize> = (0..20).map(|_| d.pick(&[0, 0], 0)).collect();
        for w in picks.chunks(5) {
            assert_eq!(w.iter().filter(|&&p| p == 0).count(), 1, "picks {picks:?}");
        }
    }

    #[test]
    fn tracker_deactivate_hides_device_from_argmin() {
        let mut t = LoadTracker::new(3);
        t.set(0, 0);
        t.set(1, 5);
        t.set(2, 7);
        assert_eq!(t.argmin(), 0);
        t.deactivate(0);
        assert!(!t.is_active(0) && t.is_active(1));
        assert_eq!(t.argmin(), 1, "inactive device must not be picked");
        assert_eq!(t.min_load(), 5, "min over active devices");
        // Raw loads keep working while draining.
        t.sub(0, 0);
        assert_eq!(t.get(0), 0);
        t.activate(0);
        assert_eq!(t.argmin(), 0, "reactivated device rejoins the dispatch set");
    }

    #[test]
    fn tracker_push_device_grows_and_stays_consistent() {
        let mut t = LoadTracker::new(2);
        t.set(0, 3);
        t.set(1, 4);
        let slot = t.push_device(None);
        assert_eq!(slot, 2);
        assert_eq!(t.len(), 3);
        assert_eq!(t.argmin(), 2, "fresh device starts at load 0");
        t.add(2, 9);
        assert_eq!(t.argmin(), 0);
        // Grow past a power-of-two boundary (2 → 4 → 5 leaves).
        t.push_device(None);
        t.push_device(None);
        assert_eq!(t.len(), 5);
        assert_eq!(t.argmin(), 3, "lowest index among the load-0 newcomers");
    }

    #[test]
    fn tracker_set_weight_rekeys_expected_delay() {
        let mut t = LoadTracker::with_expected_delay(vec![(0, 10), (0, 20)]);
        assert_eq!(t.argmin(), 0);
        t.set_weight(0, (0, 50));
        assert_eq!(t.argmin(), 1, "re-templated slot must re-key the tree");
        let slot = t.push_device(Some((0, 5)));
        assert_eq!(t.argmin(), slot, "spawned fast device wins");
    }

    #[test]
    fn round_robin_skips_inactive_devices() {
        let mut t = LoadTracker::new(3);
        t.deactivate(1);
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let picks: Vec<usize> = (0..4).map(|_| d.pick_indexed(&t, 0)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn all_inactive_fleet_reports_no_capacity() {
        // The satellite regression: a fleet whose devices are all
        // inactive (drained or failed) must yield an explicit
        // no-capacity outcome for every policy — never a silent
        // u64::MAX-keyed victim.
        for policy in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::WeightedRoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::ExpertAffinity,
            DispatchPolicy::ShortestExpectedDelay,
        ] {
            let mut t = LoadTracker::new(3);
            for i in 0..3 {
                t.deactivate(i);
            }
            assert_eq!(t.active_count(), 0);
            let mut d = Dispatcher::new(policy);
            assert_eq!(d.try_pick_indexed(&t, 1), None, "{policy:?}");
            // One repair restores capacity, and only the repaired
            // slot is pickable.
            t.activate(1);
            assert_eq!(d.try_pick_indexed(&t, 0), Some(1), "{policy:?}");
        }
    }

    #[test]
    #[should_panic(expected = "no active device")]
    fn pick_indexed_panics_on_total_outage() {
        let mut t = LoadTracker::new(2);
        t.deactivate(0);
        t.deactivate(1);
        let _ = Dispatcher::new(DispatchPolicy::JoinShortestQueue).pick_indexed(&t, 0);
    }

    #[test]
    fn activation_is_idempotent_and_counted() {
        let mut t = LoadTracker::new(4);
        assert_eq!(t.active_count(), 4);
        t.deactivate(2);
        t.deactivate(2); // second failure on a drained slot: no-op
        assert_eq!(t.active_count(), 3);
        t.activate(2);
        t.activate(2);
        assert_eq!(t.active_count(), 4);
        assert_eq!(t.push_device(None), 4);
        assert_eq!(t.active_count(), 5, "spawned devices join active");
    }

    #[test]
    fn inactive_affinity_home_spills_to_active_min() {
        let mut t = LoadTracker::new(3);
        t.set(0, 2);
        t.set(2, 1);
        t.deactivate(1);
        let mut d = Dispatcher::new(DispatchPolicy::ExpertAffinity);
        // Hint 1 homes on the draining device — must spill to the
        // active minimum (device 2), not the drain.
        assert_eq!(d.pick_indexed(&t, 1), 2);
    }

    #[test]
    fn sed_prefers_the_faster_device_under_equal_backlog() {
        // Device 0: edge tier (fill 5 ms, period 10 ms); device 1:
        // core tier (fill 1 ms, period 2 ms). Equal loads → the core
        // device completes sooner; JSQ would tie-break to device 0.
        let mut t = LoadTracker::with_expected_delay(vec![
            (5_000_000, 10_000_000),
            (1_000_000, 2_000_000),
        ]);
        let mut d = Dispatcher::new(DispatchPolicy::ShortestExpectedDelay);
        assert_eq!(d.pick_indexed(&t, 0), 1, "empty fleet: core wins");
        // Core absorbs backlog until its expected delay reaches the
        // idle edge device: 1 + (l+1)·2 ≥ 5 + 1·10 ⇔ l ≥ 6 (the l = 6
        // case is an exact tie, which the lower index — edge — wins).
        for l in 0..6 {
            t.set(1, l);
            assert_eq!(d.pick_indexed(&t, 0), 1, "core still wins at load {l}");
        }
        t.set(1, 6);
        assert_eq!(d.pick_indexed(&t, 0), 0, "tie at equal expected delay → lowest index");
    }

    #[test]
    fn prop_round_robin_admissions_balanced_within_one() {
        // Fleet-level analog of the CU router invariant: for any
        // request count and fleet size, per-device admission counts
        // differ by at most one, regardless of the load vector.
        check(300, |g| {
            let n_dev = g.usize(1, 16);
            let n_req = g.usize(0, 400);
            let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
            let mut counts = vec![0usize; n_dev];
            for _ in 0..n_req {
                // Adversarial load vector: RR must ignore it.
                let loads = g.vec_usize(n_dev, 0, 50);
                counts[d.pick(&loads, g.usize(0, 64))] += 1;
            }
            let max = *counts.iter().max().unwrap();
            let min = *counts.iter().min().unwrap();
            prop_assert(max - min <= 1, format!("unbalanced {counts:?}"))
        });
    }

    #[test]
    fn prop_jsq_never_picks_above_min() {
        check(300, |g| {
            let n_dev = g.usize(1, 12);
            let loads = g.vec_usize(n_dev, 0, 100);
            let mut d = Dispatcher::new(DispatchPolicy::JoinShortestQueue);
            let pick = d.pick(&loads, 0);
            let min = *loads.iter().min().unwrap();
            prop_assert(loads[pick] == min, format!("picked {pick} of {loads:?}"))
        });
    }

    #[test]
    fn prop_affinity_bounded_imbalance_at_pick_time() {
        // Whatever device affinity picks, its backlog never exceeds
        // the fleet minimum by more than the slack.
        check(300, |g| {
            let n_dev = g.usize(1, 12);
            let loads = g.vec_usize(n_dev, 0, 100);
            let mut d = Dispatcher::new(DispatchPolicy::ExpertAffinity);
            let pick = d.pick(&loads, g.usize(0, 1000));
            let min = *loads.iter().min().unwrap();
            prop_assert(
                loads[pick] <= min + AFFINITY_SLACK,
                format!("picked load {} min {min}", loads[pick]),
            )
        });
    }

    #[test]
    fn prop_load_tracker_matches_linear_scan() {
        // Random add/sub sequences against a shadow vector: get,
        // min_load and argmin (lowest index on ties) must agree with
        // the O(n) scan after every update.
        check(200, |g| {
            let n = g.usize(1, 17);
            let mut t = LoadTracker::new(n);
            let mut shadow = vec![0usize; n];
            for _ in 0..g.usize(1, 60) {
                let i = g.usize(0, n - 1);
                if g.bool() || shadow[i] == 0 {
                    let d = g.usize(1, 5);
                    t.add(i, d);
                    shadow[i] += d;
                } else {
                    let d = g.usize(1, shadow[i]);
                    t.sub(i, d);
                    shadow[i] -= d;
                }
                let want_arg = argmin(&shadow);
                prop_assert(
                    t.argmin() == want_arg
                        && t.min_load() == shadow[want_arg]
                        && (0..n).all(|j| t.get(j) == shadow[j]),
                    format!("tracker {:?} vs shadow {shadow:?}", (t.argmin(), t.min_load())),
                )?;
            }
            prop_assert(t.len() == n && !t.is_empty(), "len/is_empty")
        });
    }

    #[test]
    fn prop_expected_delay_tree_matches_key_scan() {
        // The SED-keyed tree must agree with an O(n) scan of the
        // expected-delay keys (lowest index on ties) after every
        // update, for arbitrary per-device (fill, period) LUTs.
        check(200, |g| {
            let n = g.usize(1, 13);
            let weights: Vec<(u64, u64)> = (0..n)
                .map(|_| (g.usize(0, 20) as u64 * 500_000, g.usize(1, 20) as u64 * 500_000))
                .collect();
            let mut t = LoadTracker::with_expected_delay(weights.clone());
            let mut shadow = vec![0usize; n];
            let key = |i: usize, l: usize| {
                weights[i].0 + (l as u64 + 1) * weights[i].1
            };
            for _ in 0..g.usize(1, 50) {
                let i = g.usize(0, n - 1);
                if g.bool() || shadow[i] == 0 {
                    let d = g.usize(1, 5);
                    t.add(i, d);
                    shadow[i] += d;
                } else {
                    let d = g.usize(1, shadow[i]);
                    t.sub(i, d);
                    shadow[i] -= d;
                }
                let mut want = 0usize;
                for j in 1..n {
                    if key(j, shadow[j]) < key(want, shadow[want]) {
                        want = j;
                    }
                }
                prop_assert(
                    t.argmin() == want
                        && t.min_key() == key(want, shadow[want])
                        && (0..n).all(|j| t.get(j) == shadow[j]),
                    format!(
                        "tree ({}, {}) vs scan ({want}, {}) loads {shadow:?} w {weights:?}",
                        t.argmin(),
                        t.min_key(),
                        key(want, shadow[want])
                    ),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sed_on_uniform_weights_is_tie_identical_to_jsq() {
        // The homogeneous-fleet contract: with identical (fill,
        // period) on every device the SED key is strictly monotone in
        // load with the same coefficients everywhere, so the SED
        // tracker's argmin — ties included — is exactly the JSQ
        // tracker's argmin for every load vector.
        check(200, |g| {
            let n = g.usize(1, 12);
            let fill = g.usize(0, 10) as u64 * 1_000_000;
            let period = g.usize(1, 10) as u64 * 1_000_000;
            let mut sed = LoadTracker::with_expected_delay(vec![(fill, period); n]);
            let mut jsq = LoadTracker::new(n);
            for _ in 0..g.usize(1, 40) {
                let loads = g.vec_usize(n, 0, 30);
                for (i, &l) in loads.iter().enumerate() {
                    sed.set(i, l);
                    jsq.set(i, l);
                }
                prop_assert(
                    sed.argmin() == jsq.argmin(),
                    format!("sed {} != jsq {} for {loads:?}", sed.argmin(), jsq.argmin()),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_pick_indexed_matches_pick() {
        // The DES hot path and the reference slice path must make the
        // identical choice for every policy, load vector and hint —
        // including the round-robin cursor across successive picks.
        // (SED against a load-keyed tracker is its homogeneous-fleet
        // degeneration, which the slice path mirrors as JSQ.)
        check(200, |g| {
            let n = g.usize(1, 12);
            for policy in [
                DispatchPolicy::RoundRobin,
                DispatchPolicy::WeightedRoundRobin,
                DispatchPolicy::JoinShortestQueue,
                DispatchPolicy::ExpertAffinity,
                DispatchPolicy::ShortestExpectedDelay,
            ] {
                let mut by_scan = Dispatcher::new(policy);
                let mut by_tree = Dispatcher::new(policy);
                for _ in 0..g.usize(1, 20) {
                    let loads = g.vec_usize(n, 0, 40);
                    let mut t = LoadTracker::new(n);
                    for (i, &l) in loads.iter().enumerate() {
                        t.set(i, l);
                    }
                    let hint = g.usize(0, 1000);
                    let a = by_scan.pick(&loads, hint);
                    let b = by_tree.pick_indexed(&t, hint);
                    prop_assert(
                        a == b,
                        format!("{policy:?}: scan {a} != indexed {b} for {loads:?} hint {hint}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::WeightedRoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::ExpertAffinity,
            DispatchPolicy::ShortestExpectedDelay,
        ] {
            assert_eq!(DispatchPolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(DispatchPolicy::by_name("rr"), Some(DispatchPolicy::RoundRobin));
        assert_eq!(
            DispatchPolicy::by_name("weighted-round-robin"),
            Some(DispatchPolicy::WeightedRoundRobin)
        );
        assert_eq!(
            DispatchPolicy::by_name("sed"),
            Some(DispatchPolicy::ShortestExpectedDelay)
        );
        assert!(DispatchPolicy::by_name("nope").is_none());
    }
}
