//! Fleet dispatch: which device a new request queues on.
//!
//! The same idea as the §III-C round-robin CU router, one level up:
//! the CU router balances one expert's tokens across compute units
//! inside a device; the dispatcher balances requests across devices
//! of a fleet. Four policies:
//!
//! * **RoundRobin** — cyclic assignment; per-device admission counts
//!   never differ by more than one (proptested), but it is blind to
//!   queue depth, so heterogeneous backlogs (bursts) hurt its tail.
//! * **JoinShortestQueue** — send to the device with the fewest
//!   resident requests (queued + in flight), lowest index on ties.
//! * **ExpertAffinity** — requests carry a dominant-expert hint; each
//!   expert has a home device (`hint % n`), improving expert-weight
//!   cache locality across consecutive batches. To avoid hotspots the
//!   policy spills to JSQ whenever the home device's backlog exceeds
//!   the fleet minimum by more than [`AFFINITY_SLACK`]. The cost
//!   model rewards the locality: a batch whose dominant expert was
//!   resident from the device's previous batch skips the exposed
//!   weight stream
//!   ([`crate::serve::device::DeviceModel::service_time_with_residency`]).
//! * **ShortestExpectedDelay** — the heterogeneity-aware policy (the
//!   ROADMAP mixed-fleet item): instead of comparing queue *lengths*,
//!   compare expected-completion time. Each device's leaf in the
//!   [`LoadTracker`] tournament tree is keyed by its own service LUT
//!   evaluated at "backlog plus me" — `fill + (load+1)·period` in ns
//!   ([`crate::serve::device::DeviceModel::expected_delay_weights`]) —
//!   so a U280 core-tier device with a deep-but-fast queue beats a
//!   ZCU102 edge device with a short-but-slow one. On a homogeneous
//!   fleet the key is strictly monotone in load with identical
//!   coefficients, so SED is pick-for-pick (ties included) identical
//!   to JSQ — proptested below and asserted end-to-end in
//!   `report::serving`.
//!
//! The DES reads loads through [`LoadTracker`] (point updates +
//! indexed argmin) rather than rebuilding a load vector per arrival.

/// Backlog slack (requests) an affinity home may carry over the fleet
/// minimum before the dispatcher spills to join-shortest-queue.
pub const AFFINITY_SLACK: usize = 8;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    RoundRobin,
    JoinShortestQueue,
    ExpertAffinity,
    ShortestExpectedDelay,
}

impl DispatchPolicy {
    pub fn by_name(name: &str) -> Option<DispatchPolicy> {
        Some(match name.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" => DispatchPolicy::RoundRobin,
            "jsq" | "shortest" => DispatchPolicy::JoinShortestQueue,
            "affinity" | "expert-affinity" => DispatchPolicy::ExpertAffinity,
            "sed" | "shortest-expected-delay" => DispatchPolicy::ShortestExpectedDelay,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::JoinShortestQueue => "jsq",
            DispatchPolicy::ExpertAffinity => "expert-affinity",
            DispatchPolicy::ShortestExpectedDelay => "sed",
        }
    }
}

/// Indexed device-load signal: a tournament (segment) tree over
/// per-device keys, point-updated by the DES on dispatch (+1) and
/// batch completion (−batch occupancy) instead of re-scanning the
/// whole fleet per arrival.
///
/// The key is what the tree minimizes over:
///
/// * [`LoadTracker::new`] — key = resident-request count (the PR-3
///   join-shortest-queue signal);
/// * [`LoadTracker::with_expected_delay`] — key = expected-completion
///   ns, `fill + (load+1)·period` per device from its service LUT
///   (the shortest-expected-delay signal; saturating arithmetic, so
///   pathological backlogs clamp instead of wrapping).
///
/// Queries: O(1) `argmin` with **lowest index on ties** (bit-identical
/// to the linear scan — proptested below), O(1) `min_key`/`min_load`,
/// O(1) `get`; updates are O(log n).
#[derive(Clone, Debug)]
pub struct LoadTracker {
    n: usize,
    base: usize,
    /// 1-indexed tree; leaves at `base..base+n` hold `(key, device)`.
    /// Padding leaves hold `(u64::MAX, i)` with `i ≥ n`, so a real
    /// device wins even a saturated-key tie (lower index).
    tree: Vec<(u64, usize)>,
    /// Raw resident-request counts (the affinity policy and the DES
    /// bookkeeping read these regardless of the tree key).
    loads: Vec<usize>,
    /// Per-device (fill_ns, period_ns); `None` keys the tree by load.
    weights: Option<Vec<(u64, u64)>>,
}

impl LoadTracker {
    /// Tracker keyed by resident-request count (JSQ/affinity signal).
    pub fn new(n: usize) -> LoadTracker {
        Self::build(n, None)
    }

    /// Tracker keyed by expected-completion ns from per-device
    /// `(fill_ns, period_ns)` service-LUT coefficients (SED signal).
    pub fn with_expected_delay(weights: Vec<(u64, u64)>) -> LoadTracker {
        let n = weights.len();
        Self::build(n, Some(weights))
    }

    fn build(n: usize, weights: Option<Vec<(u64, u64)>>) -> LoadTracker {
        assert!(n > 0, "empty fleet");
        let base = n.next_power_of_two();
        let mut t = LoadTracker {
            n,
            base,
            tree: vec![(u64::MAX, 0); 2 * base],
            loads: vec![0; n],
            weights,
        };
        for (i, leaf) in t.tree[base..].iter_mut().enumerate() {
            leaf.1 = i;
        }
        for i in 0..n {
            let key = t.key(i, 0);
            t.tree[base + i].0 = key;
        }
        for i in (1..base).rev() {
            let merged = Self::min2(t.tree[2 * i], t.tree[2 * i + 1]);
            t.tree[i] = merged;
        }
        t
    }

    /// The tree key of device `i` at `load` resident requests.
    #[inline]
    fn key(&self, i: usize, load: usize) -> u64 {
        match &self.weights {
            None => load as u64,
            Some(w) => {
                let (fill, period) = w[i];
                fill.saturating_add((load as u64).saturating_add(1).saturating_mul(period))
            }
        }
    }

    /// Lexicographic (key, index) minimum: the left (lower-index)
    /// child wins ties, matching the linear-scan argmin exactly
    /// (`std::cmp::min` returns its first argument on equality).
    #[inline]
    fn min2(a: (u64, usize), b: (u64, usize)) -> (u64, usize) {
        std::cmp::min(a, b)
    }

    /// Fleet size.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Current resident-request count of device `i`.
    #[inline]
    pub fn get(&self, i: usize) -> usize {
        self.loads[i]
    }

    pub fn set(&mut self, i: usize, load: usize) {
        assert!(i < self.n, "device {i} out of range {}", self.n);
        self.loads[i] = load;
        let key = self.key(i, load);
        let mut k = self.base + i;
        self.tree[k].0 = key;
        while k > 1 {
            k /= 2;
            let merged = Self::min2(self.tree[2 * k], self.tree[2 * k + 1]);
            self.tree[k] = merged;
        }
    }

    pub fn add(&mut self, i: usize, delta: usize) {
        self.set(i, self.get(i) + delta);
    }

    pub fn sub(&mut self, i: usize, delta: usize) {
        self.set(i, self.get(i) - delta);
    }

    /// Smallest tree key in the fleet (load, or expected-delay ns).
    #[inline]
    pub fn min_key(&self) -> u64 {
        self.tree[1].0
    }

    /// Smallest resident-request count — only meaningful on a
    /// load-keyed tracker (the affinity policy's signal).
    #[inline]
    pub fn min_load(&self) -> usize {
        debug_assert!(
            self.weights.is_none(),
            "min_load on an expected-delay tracker — use min_key"
        );
        self.tree[1].0 as usize
    }

    /// Device holding the smallest key, lowest index on ties.
    #[inline]
    pub fn argmin(&self) -> usize {
        self.tree[1].1
    }
}

/// Stateful dispatcher (round-robin keeps a cursor).
#[derive(Clone, Debug)]
pub struct Dispatcher {
    policy: DispatchPolicy,
    rr_next: usize,
}

fn argmin(loads: &[usize]) -> usize {
    let mut best = 0;
    for (i, &l) in loads.iter().enumerate() {
        if l < loads[best] {
            best = i;
        }
    }
    best
}

impl Dispatcher {
    pub fn new(policy: DispatchPolicy) -> Dispatcher {
        Dispatcher { policy, rr_next: 0 }
    }

    /// Choose a device from a plain load slice. `loads[d]` = requests
    /// resident on device d (queued + in flight); `expert_hint` is the
    /// request's dominant expert (ignored except by ExpertAffinity).
    ///
    /// The slice carries no service LUTs, so ShortestExpectedDelay
    /// here degrades to JSQ (devices treated as identical — exactly
    /// what SED is on a homogeneous fleet). Heterogeneous SED goes
    /// through [`Dispatcher::pick_indexed`] with a
    /// [`LoadTracker::with_expected_delay`] tracker — the DES path.
    pub fn pick(&mut self, loads: &[usize], expert_hint: usize) -> usize {
        assert!(!loads.is_empty(), "empty fleet");
        match self.policy {
            DispatchPolicy::RoundRobin => {
                let d = self.rr_next % loads.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                d
            }
            DispatchPolicy::JoinShortestQueue | DispatchPolicy::ShortestExpectedDelay => {
                argmin(loads)
            }
            DispatchPolicy::ExpertAffinity => {
                let home = expert_hint % loads.len();
                let min = *loads.iter().min().unwrap();
                if loads[home] > min + AFFINITY_SLACK {
                    argmin(loads)
                } else {
                    home
                }
            }
        }
    }

    /// Indexed variant of [`Dispatcher::pick`]: the same choice for
    /// the same signal (proptested), but O(1)–O(log n) against a
    /// [`LoadTracker`] instead of an O(n) scan per arrival — the DES
    /// hot-path entry point. ShortestExpectedDelay expects a tracker
    /// built with [`LoadTracker::with_expected_delay`]; its argmin is
    /// then over expected-completion ns instead of queue length.
    pub fn pick_indexed(&mut self, loads: &LoadTracker, expert_hint: usize) -> usize {
        match self.policy {
            DispatchPolicy::RoundRobin => {
                let d = self.rr_next % loads.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                d
            }
            DispatchPolicy::JoinShortestQueue | DispatchPolicy::ShortestExpectedDelay => {
                loads.argmin()
            }
            DispatchPolicy::ExpertAffinity => {
                let home = expert_hint % loads.len();
                if loads.get(home) > loads.min_load() + AFFINITY_SLACK {
                    loads.argmin()
                } else {
                    home
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn round_robin_cycles() {
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let picks: Vec<usize> = (0..7).map(|_| d.pick(&[0; 3], 0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn jsq_picks_min_lowest_index_on_tie() {
        let mut d = Dispatcher::new(DispatchPolicy::JoinShortestQueue);
        assert_eq!(d.pick(&[4, 2, 2, 9], 0), 1);
        assert_eq!(d.pick(&[0, 0, 0], 5), 0);
    }

    #[test]
    fn affinity_sticks_until_slack_exceeded() {
        let mut d = Dispatcher::new(DispatchPolicy::ExpertAffinity);
        // Home device 1 within slack → stick.
        assert_eq!(d.pick(&[0, AFFINITY_SLACK, 0], 1), 1);
        // Home device 1 beyond slack → spill to JSQ.
        assert_eq!(d.pick(&[3, AFFINITY_SLACK + 1, 0], 1), 2);
        // Same hint, balanced fleet → same home every time.
        for _ in 0..5 {
            assert_eq!(d.pick(&[1, 1, 1, 1], 6), 2);
        }
    }

    #[test]
    fn sed_prefers_the_faster_device_under_equal_backlog() {
        // Device 0: edge tier (fill 5 ms, period 10 ms); device 1:
        // core tier (fill 1 ms, period 2 ms). Equal loads → the core
        // device completes sooner; JSQ would tie-break to device 0.
        let mut t = LoadTracker::with_expected_delay(vec![
            (5_000_000, 10_000_000),
            (1_000_000, 2_000_000),
        ]);
        let mut d = Dispatcher::new(DispatchPolicy::ShortestExpectedDelay);
        assert_eq!(d.pick_indexed(&t, 0), 1, "empty fleet: core wins");
        // Core absorbs backlog until its expected delay reaches the
        // idle edge device: 1 + (l+1)·2 ≥ 5 + 1·10 ⇔ l ≥ 6 (the l = 6
        // case is an exact tie, which the lower index — edge — wins).
        for l in 0..6 {
            t.set(1, l);
            assert_eq!(d.pick_indexed(&t, 0), 1, "core still wins at load {l}");
        }
        t.set(1, 6);
        assert_eq!(d.pick_indexed(&t, 0), 0, "tie at equal expected delay → lowest index");
    }

    #[test]
    fn prop_round_robin_admissions_balanced_within_one() {
        // Fleet-level analog of the CU router invariant: for any
        // request count and fleet size, per-device admission counts
        // differ by at most one, regardless of the load vector.
        check(300, |g| {
            let n_dev = g.usize(1, 16);
            let n_req = g.usize(0, 400);
            let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
            let mut counts = vec![0usize; n_dev];
            for _ in 0..n_req {
                // Adversarial load vector: RR must ignore it.
                let loads = g.vec_usize(n_dev, 0, 50);
                counts[d.pick(&loads, g.usize(0, 64))] += 1;
            }
            let max = *counts.iter().max().unwrap();
            let min = *counts.iter().min().unwrap();
            prop_assert(max - min <= 1, format!("unbalanced {counts:?}"))
        });
    }

    #[test]
    fn prop_jsq_never_picks_above_min() {
        check(300, |g| {
            let n_dev = g.usize(1, 12);
            let loads = g.vec_usize(n_dev, 0, 100);
            let mut d = Dispatcher::new(DispatchPolicy::JoinShortestQueue);
            let pick = d.pick(&loads, 0);
            let min = *loads.iter().min().unwrap();
            prop_assert(loads[pick] == min, format!("picked {pick} of {loads:?}"))
        });
    }

    #[test]
    fn prop_affinity_bounded_imbalance_at_pick_time() {
        // Whatever device affinity picks, its backlog never exceeds
        // the fleet minimum by more than the slack.
        check(300, |g| {
            let n_dev = g.usize(1, 12);
            let loads = g.vec_usize(n_dev, 0, 100);
            let mut d = Dispatcher::new(DispatchPolicy::ExpertAffinity);
            let pick = d.pick(&loads, g.usize(0, 1000));
            let min = *loads.iter().min().unwrap();
            prop_assert(
                loads[pick] <= min + AFFINITY_SLACK,
                format!("picked load {} min {min}", loads[pick]),
            )
        });
    }

    #[test]
    fn prop_load_tracker_matches_linear_scan() {
        // Random add/sub sequences against a shadow vector: get,
        // min_load and argmin (lowest index on ties) must agree with
        // the O(n) scan after every update.
        check(200, |g| {
            let n = g.usize(1, 17);
            let mut t = LoadTracker::new(n);
            let mut shadow = vec![0usize; n];
            for _ in 0..g.usize(1, 60) {
                let i = g.usize(0, n - 1);
                if g.bool() || shadow[i] == 0 {
                    let d = g.usize(1, 5);
                    t.add(i, d);
                    shadow[i] += d;
                } else {
                    let d = g.usize(1, shadow[i]);
                    t.sub(i, d);
                    shadow[i] -= d;
                }
                let want_arg = argmin(&shadow);
                prop_assert(
                    t.argmin() == want_arg
                        && t.min_load() == shadow[want_arg]
                        && (0..n).all(|j| t.get(j) == shadow[j]),
                    format!("tracker {:?} vs shadow {shadow:?}", (t.argmin(), t.min_load())),
                )?;
            }
            prop_assert(t.len() == n && !t.is_empty(), "len/is_empty")
        });
    }

    #[test]
    fn prop_expected_delay_tree_matches_key_scan() {
        // The SED-keyed tree must agree with an O(n) scan of the
        // expected-delay keys (lowest index on ties) after every
        // update, for arbitrary per-device (fill, period) LUTs.
        check(200, |g| {
            let n = g.usize(1, 13);
            let weights: Vec<(u64, u64)> = (0..n)
                .map(|_| (g.usize(0, 20) as u64 * 500_000, g.usize(1, 20) as u64 * 500_000))
                .collect();
            let mut t = LoadTracker::with_expected_delay(weights.clone());
            let mut shadow = vec![0usize; n];
            let key = |i: usize, l: usize| {
                weights[i].0 + (l as u64 + 1) * weights[i].1
            };
            for _ in 0..g.usize(1, 50) {
                let i = g.usize(0, n - 1);
                if g.bool() || shadow[i] == 0 {
                    let d = g.usize(1, 5);
                    t.add(i, d);
                    shadow[i] += d;
                } else {
                    let d = g.usize(1, shadow[i]);
                    t.sub(i, d);
                    shadow[i] -= d;
                }
                let mut want = 0usize;
                for j in 1..n {
                    if key(j, shadow[j]) < key(want, shadow[want]) {
                        want = j;
                    }
                }
                prop_assert(
                    t.argmin() == want
                        && t.min_key() == key(want, shadow[want])
                        && (0..n).all(|j| t.get(j) == shadow[j]),
                    format!(
                        "tree ({}, {}) vs scan ({want}, {}) loads {shadow:?} w {weights:?}",
                        t.argmin(),
                        t.min_key(),
                        key(want, shadow[want])
                    ),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sed_on_uniform_weights_is_tie_identical_to_jsq() {
        // The homogeneous-fleet contract: with identical (fill,
        // period) on every device the SED key is strictly monotone in
        // load with the same coefficients everywhere, so the SED
        // tracker's argmin — ties included — is exactly the JSQ
        // tracker's argmin for every load vector.
        check(200, |g| {
            let n = g.usize(1, 12);
            let fill = g.usize(0, 10) as u64 * 1_000_000;
            let period = g.usize(1, 10) as u64 * 1_000_000;
            let mut sed = LoadTracker::with_expected_delay(vec![(fill, period); n]);
            let mut jsq = LoadTracker::new(n);
            for _ in 0..g.usize(1, 40) {
                let loads = g.vec_usize(n, 0, 30);
                for (i, &l) in loads.iter().enumerate() {
                    sed.set(i, l);
                    jsq.set(i, l);
                }
                prop_assert(
                    sed.argmin() == jsq.argmin(),
                    format!("sed {} != jsq {} for {loads:?}", sed.argmin(), jsq.argmin()),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_pick_indexed_matches_pick() {
        // The DES hot path and the reference slice path must make the
        // identical choice for every policy, load vector and hint —
        // including the round-robin cursor across successive picks.
        // (SED against a load-keyed tracker is its homogeneous-fleet
        // degeneration, which the slice path mirrors as JSQ.)
        check(200, |g| {
            let n = g.usize(1, 12);
            for policy in [
                DispatchPolicy::RoundRobin,
                DispatchPolicy::JoinShortestQueue,
                DispatchPolicy::ExpertAffinity,
                DispatchPolicy::ShortestExpectedDelay,
            ] {
                let mut by_scan = Dispatcher::new(policy);
                let mut by_tree = Dispatcher::new(policy);
                for _ in 0..g.usize(1, 20) {
                    let loads = g.vec_usize(n, 0, 40);
                    let mut t = LoadTracker::new(n);
                    for (i, &l) in loads.iter().enumerate() {
                        t.set(i, l);
                    }
                    let hint = g.usize(0, 1000);
                    let a = by_scan.pick(&loads, hint);
                    let b = by_tree.pick_indexed(&t, hint);
                    prop_assert(
                        a == b,
                        format!("{policy:?}: scan {a} != indexed {b} for {loads:?} hint {hint}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::ExpertAffinity,
            DispatchPolicy::ShortestExpectedDelay,
        ] {
            assert_eq!(DispatchPolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(DispatchPolicy::by_name("rr"), Some(DispatchPolicy::RoundRobin));
        assert_eq!(
            DispatchPolicy::by_name("sed"),
            Some(DispatchPolicy::ShortestExpectedDelay)
        );
        assert!(DispatchPolicy::by_name("nope").is_none());
    }
}
