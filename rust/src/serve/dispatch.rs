//! Fleet dispatch: which device a new request queues on.
//!
//! The same idea as the §III-C round-robin CU router, one level up:
//! the CU router balances one expert's tokens across compute units
//! inside a device; the dispatcher balances requests across devices
//! of a fleet. Three policies:
//!
//! * **RoundRobin** — cyclic assignment; per-device admission counts
//!   never differ by more than one (proptested), but it is blind to
//!   queue depth, so heterogeneous backlogs (bursts) hurt its tail.
//! * **JoinShortestQueue** — send to the device with the fewest
//!   resident requests (queued + in flight), lowest index on ties.
//! * **ExpertAffinity** — requests carry a dominant-expert hint; each
//!   expert has a home device (`hint % n`), improving expert-weight
//!   cache locality across consecutive batches. To avoid hotspots the
//!   policy spills to JSQ whenever the home device's backlog exceeds
//!   the fleet minimum by more than [`AFFINITY_SLACK`]. (The cost
//!   model does not yet *reward* locality — wiring a reuse-aware
//!   service-time discount is a ROADMAP open item; the policy's
//!   dispatch mechanics and spill behaviour are what this models.)

/// Backlog slack (requests) an affinity home may carry over the fleet
/// minimum before the dispatcher spills to join-shortest-queue.
pub const AFFINITY_SLACK: usize = 8;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    RoundRobin,
    JoinShortestQueue,
    ExpertAffinity,
}

impl DispatchPolicy {
    pub fn by_name(name: &str) -> Option<DispatchPolicy> {
        Some(match name.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" => DispatchPolicy::RoundRobin,
            "jsq" | "shortest" => DispatchPolicy::JoinShortestQueue,
            "affinity" | "expert-affinity" => DispatchPolicy::ExpertAffinity,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::JoinShortestQueue => "jsq",
            DispatchPolicy::ExpertAffinity => "expert-affinity",
        }
    }
}

/// Stateful dispatcher (round-robin keeps a cursor).
#[derive(Clone, Debug)]
pub struct Dispatcher {
    policy: DispatchPolicy,
    rr_next: usize,
}

fn argmin(loads: &[usize]) -> usize {
    let mut best = 0;
    for (i, &l) in loads.iter().enumerate() {
        if l < loads[best] {
            best = i;
        }
    }
    best
}

impl Dispatcher {
    pub fn new(policy: DispatchPolicy) -> Dispatcher {
        Dispatcher { policy, rr_next: 0 }
    }

    /// Choose a device. `loads[d]` = requests resident on device d
    /// (queued + in flight); `expert_hint` is the request's dominant
    /// expert (ignored except by ExpertAffinity).
    pub fn pick(&mut self, loads: &[usize], expert_hint: usize) -> usize {
        assert!(!loads.is_empty(), "empty fleet");
        match self.policy {
            DispatchPolicy::RoundRobin => {
                let d = self.rr_next % loads.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                d
            }
            DispatchPolicy::JoinShortestQueue => argmin(loads),
            DispatchPolicy::ExpertAffinity => {
                let home = expert_hint % loads.len();
                let min = *loads.iter().min().unwrap();
                if loads[home] > min + AFFINITY_SLACK {
                    argmin(loads)
                } else {
                    home
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn round_robin_cycles() {
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let picks: Vec<usize> = (0..7).map(|_| d.pick(&[0; 3], 0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn jsq_picks_min_lowest_index_on_tie() {
        let mut d = Dispatcher::new(DispatchPolicy::JoinShortestQueue);
        assert_eq!(d.pick(&[4, 2, 2, 9], 0), 1);
        assert_eq!(d.pick(&[0, 0, 0], 5), 0);
    }

    #[test]
    fn affinity_sticks_until_slack_exceeded() {
        let mut d = Dispatcher::new(DispatchPolicy::ExpertAffinity);
        // Home device 1 within slack → stick.
        assert_eq!(d.pick(&[0, AFFINITY_SLACK, 0], 1), 1);
        // Home device 1 beyond slack → spill to JSQ.
        assert_eq!(d.pick(&[3, AFFINITY_SLACK + 1, 0], 1), 2);
        // Same hint, balanced fleet → same home every time.
        for _ in 0..5 {
            assert_eq!(d.pick(&[1, 1, 1, 1], 6), 2);
        }
    }

    #[test]
    fn prop_round_robin_admissions_balanced_within_one() {
        // Fleet-level analog of the CU router invariant: for any
        // request count and fleet size, per-device admission counts
        // differ by at most one, regardless of the load vector.
        check(300, |g| {
            let n_dev = g.usize(1, 16);
            let n_req = g.usize(0, 400);
            let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
            let mut counts = vec![0usize; n_dev];
            for _ in 0..n_req {
                // Adversarial load vector: RR must ignore it.
                let loads = g.vec_usize(n_dev, 0, 50);
                counts[d.pick(&loads, g.usize(0, 64))] += 1;
            }
            let max = *counts.iter().max().unwrap();
            let min = *counts.iter().min().unwrap();
            prop_assert(max - min <= 1, format!("unbalanced {counts:?}"))
        });
    }

    #[test]
    fn prop_jsq_never_picks_above_min() {
        check(300, |g| {
            let n_dev = g.usize(1, 12);
            let loads = g.vec_usize(n_dev, 0, 100);
            let mut d = Dispatcher::new(DispatchPolicy::JoinShortestQueue);
            let pick = d.pick(&loads, 0);
            let min = *loads.iter().min().unwrap();
            prop_assert(loads[pick] == min, format!("picked {pick} of {loads:?}"))
        });
    }

    #[test]
    fn prop_affinity_bounded_imbalance_at_pick_time() {
        // Whatever device affinity picks, its backlog never exceeds
        // the fleet minimum by more than the slack.
        check(300, |g| {
            let n_dev = g.usize(1, 12);
            let loads = g.vec_usize(n_dev, 0, 100);
            let mut d = Dispatcher::new(DispatchPolicy::ExpertAffinity);
            let pick = d.pick(&loads, g.usize(0, 1000));
            let min = *loads.iter().min().unwrap();
            prop_assert(
                loads[pick] <= min + AFFINITY_SLACK,
                format!("picked load {} min {min}", loads[pick]),
            )
        });
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::ExpertAffinity,
        ] {
            assert_eq!(DispatchPolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(DispatchPolicy::by_name("rr"), Some(DispatchPolicy::RoundRobin));
        assert!(DispatchPolicy::by_name("nope").is_none());
    }
}
