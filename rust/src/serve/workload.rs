//! Workload generators: the traffic the fleet is driven with.
//!
//! Two families with different physics:
//!
//! * **Open-loop** ([`Workload::Poisson`], [`Workload::Mmpp2`],
//!   [`Workload::Trace`]) — arrivals do not react to service: when the
//!   fleet saturates, the queue grows without bound, which is exactly
//!   the regime the latency–throughput curves probe past the knee.
//!   These generators are seeded ([`crate::util::rng::Rng`]) and
//!   produce a concrete, sorted arrival schedule up front — the
//!   schedule *is* the workload, so any run can be captured with
//!   [`Workload::to_trace`] and replayed bit-identically (or edited by
//!   hand for what-if studies).
//! * **Closed-loop** ([`Workload::ClosedLoop`]) — N simulated users,
//!   each cycling request → completion → exponential think time →
//!   next request. Arrivals *do* react to service (a slow fleet slows
//!   its users down), so the schedule cannot be precomputed: the DES
//!   generates it live off `UserThink` events on the same event heap,
//!   with per-user seeded RNG streams, so determinism and the
//!   insertion-order tie-break invariants are identical to the
//!   open-loop path. Closed-loop runs answer "how many users can this
//!   fleet carry at the SLO?" ([`crate::report::serving::max_users_at_slo`])
//!   rather than "what happens at offered load X".

use std::fmt;
use std::time::Duration;

use crate::util::rng::Rng;

/// Why a workload could not produce a precomputed arrival schedule —
/// the typed alternative to the panic these accessors used to raise,
/// so callers (the CLI, studies) can degrade gracefully.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadError {
    /// Closed-loop arrivals depend on completions and cannot be
    /// precomputed; drive them through
    /// [`crate::serve::simulate_fleet`] instead.
    ClosedLoop,
}

impl fmt::Display for WorkloadError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WorkloadError::ClosedLoop => write!(
                f,
                "closed-loop workloads have no precomputable arrival schedule \
                 (arrivals depend on completions); drive them through simulate_fleet"
            ),
        }
    }
}

impl std::error::Error for WorkloadError {}

/// Priority class a request carries through the fleet. Classes are
/// ordered by importance: under overload the admission layer
/// ([`crate::serve::overload`]) sheds the *highest-numbered* class
/// first, so `Interactive` traffic is the last to be rejected.
///
/// Classes are assigned at the arrival edge by drawing from the
/// run's [`ClassMix`] on a dedicated seeded RNG stream, so the same
/// (config, seed) always labels the same arrivals identically —
/// class assignment is part of the deterministic schedule, not a
/// property of the dispatch path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// User-facing traffic: protected first, shed last.
    Interactive = 0,
    /// Throughput-oriented traffic that tolerates queueing.
    Batch = 1,
    /// Best-effort traffic: first to be shed under pressure.
    Background = 2,
}

/// Number of priority classes (array-index domain of per-class state).
pub const NUM_CLASSES: usize = 3;

impl Priority {
    /// All classes, most- to least-important.
    pub const ALL: [Priority; NUM_CLASSES] =
        [Priority::Interactive, Priority::Batch, Priority::Background];

    /// Dense array index (0 = most important).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Inverse of [`Priority::index`]; panics on an out-of-range index.
    pub fn from_index(i: usize) -> Priority {
        Self::ALL[i]
    }

    /// Short stable label used in traces and tables.
    pub fn label(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
            Priority::Background => "background",
        }
    }
}

/// Mix of priority classes in the arrival stream: relative weights
/// (normalized at draw time, so they need not sum to 1) for each
/// class. The workload layer owns class assignment; the overload
/// layer only *reads* the class a request arrived with.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClassMix {
    pub interactive: f64,
    pub batch: f64,
    pub background: f64,
}

impl ClassMix {
    /// Everything interactive — the degenerate mix that reproduces
    /// the pre-overload single-class behaviour.
    pub fn interactive_only() -> ClassMix {
        ClassMix { interactive: 1.0, batch: 0.0, background: 0.0 }
    }

    /// The canonical study mix: half interactive, the rest split
    /// toward batch (used by `report::serving::overload_study`).
    pub fn standard() -> ClassMix {
        ClassMix { interactive: 0.5, batch: 0.3, background: 0.2 }
    }

    /// Draw one class from the normalized mix. One `rng.f64()` call
    /// per draw, always — the draw count is part of the determinism
    /// contract (class streams must not desynchronize across configs
    /// that share a seed).
    pub fn draw(&self, rng: &mut Rng) -> Priority {
        let (wi, wb, wg) = (
            self.interactive.max(0.0),
            self.batch.max(0.0),
            self.background.max(0.0),
        );
        let total = wi + wb + wg;
        let u = rng.f64();
        if total <= 0.0 {
            return Priority::Interactive;
        }
        let x = u * total;
        if x < wi {
            Priority::Interactive
        } else if x < wi + wb {
            Priority::Batch
        } else {
            Priority::Background
        }
    }
}

impl Default for ClassMix {
    fn default() -> Self {
        ClassMix::interactive_only()
    }
}

/// Arrival-process model.
#[derive(Clone, Debug)]
pub enum Workload {
    /// Memoryless arrivals at a constant mean rate (exponential
    /// inter-arrival gaps) — the classic open-loop baseline.
    Poisson { rate_rps: f64 },
    /// Bursty traffic: a 2-state Markov-modulated Poisson process.
    /// The process dwells exponentially in a calm state at
    /// `rate_low_rps` (mean dwell `dwell_low`), then in a burst state
    /// at `rate_high_rps` (mean dwell `dwell_high`), alternating, so
    /// the long-run burst-time fraction is
    /// `dwell_high / (dwell_low + dwell_high)`. Burstiness is what
    /// separates p99 behaviour from the Poisson mean-rate story;
    /// *asymmetric* dwells (rare-but-hard bursts) are what make
    /// autoscaling pay — see
    /// [`crate::report::serving::autoscale_study`].
    Mmpp2 {
        rate_low_rps: f64,
        rate_high_rps: f64,
        dwell_low: Duration,
        dwell_high: Duration,
    },
    /// Replay an explicit arrival schedule (offsets from t=0,
    /// ascending). Produced by [`Workload::to_trace`] or loaded from a
    /// production capture.
    Trace { arrivals: Vec<Duration> },
    /// Closed-loop traffic: `users` simulated users, each issuing a
    /// request, waiting for its completion plus an exponentially
    /// distributed think time (mean `think_time`), then repeating
    /// until the arrival horizon. A user's first request arrives
    /// after one initial think draw, so `think_time == 0` means every
    /// user fires at t = 0 and re-fires the instant its previous
    /// request completes — the fleet then runs permanently at `users`
    /// requests in flight, which is how a closed-loop run saturates
    /// (tested against the open-loop knee in `serve/mod.rs`).
    ///
    /// No schedule can be precomputed (arrivals depend on service), so
    /// [`Workload::arrivals`] and [`Workload::to_trace`] return
    /// [`WorkloadError::ClosedLoop`] for this variant; the DES drives
    /// it via `UserThink` events instead.
    ClosedLoop { users: usize, think_time: Duration },
}

fn exp_gap(rng: &mut Rng, rate_per_s: f64) -> f64 {
    debug_assert!(rate_per_s > 0.0);
    -(1.0 - rng.f64()).ln() / rate_per_s
}

impl Workload {
    /// The concrete arrival schedule on `[0, horizon)`, sorted
    /// ascending. Deterministic in (self, horizon, seed); `Trace`
    /// ignores the seed and clips to the horizon.
    ///
    /// # Errors
    /// [`WorkloadError::ClosedLoop`] for [`Workload::ClosedLoop`]:
    /// closed-loop arrivals depend on completions and cannot be
    /// precomputed.
    pub fn arrivals(
        &self,
        horizon: Duration,
        seed: u64,
    ) -> Result<Vec<Duration>, WorkloadError> {
        let h = horizon.as_secs_f64();
        Ok(match self {
            Workload::Poisson { rate_rps } => {
                assert!(*rate_rps > 0.0, "Poisson rate must be positive");
                let mut rng = Rng::new(seed);
                let mut out = Vec::with_capacity((rate_rps * h) as usize + 16);
                let mut t = exp_gap(&mut rng, *rate_rps);
                while t < h {
                    out.push(Duration::from_secs_f64(t));
                    t += exp_gap(&mut rng, *rate_rps);
                }
                out
            }
            Workload::Mmpp2 { rate_low_rps, rate_high_rps, dwell_low, dwell_high } => {
                assert!(*rate_low_rps > 0.0 && *rate_high_rps > 0.0);
                let (dl, dh) = (dwell_low.as_secs_f64(), dwell_high.as_secs_f64());
                assert!(dl > 0.0 && dh > 0.0, "MMPP dwells must be positive");
                let mut rng = Rng::new(seed);
                let mut out = Vec::new();
                let mut t = 0.0f64;
                let mut burst = false;
                let mut next_switch = exp_gap(&mut rng, 1.0 / dl);
                loop {
                    let rate = if burst { *rate_high_rps } else { *rate_low_rps };
                    let cand = t + exp_gap(&mut rng, rate);
                    if cand < next_switch {
                        // Arrival inside the current state.
                        t = cand;
                        if t >= h {
                            break;
                        }
                        out.push(Duration::from_secs_f64(t));
                    } else {
                        // State switch first; the exponential gap is
                        // memoryless, so restarting the draw at the
                        // switch point is exact.
                        t = next_switch;
                        if t >= h {
                            break;
                        }
                        burst = !burst;
                        let dwell = if burst { dh } else { dl };
                        next_switch = t + exp_gap(&mut rng, 1.0 / dwell);
                    }
                }
                out
            }
            Workload::Trace { arrivals } => {
                debug_assert!(
                    arrivals.windows(2).all(|w| w[0] <= w[1]),
                    "trace arrivals must be sorted"
                );
                arrivals.iter().copied().filter(|&a| a < horizon).collect()
            }
            Workload::ClosedLoop { .. } => return Err(WorkloadError::ClosedLoop),
        })
    }

    /// Capture this workload's schedule as a replayable trace.
    ///
    /// # Errors
    /// [`WorkloadError::ClosedLoop`] for [`Workload::ClosedLoop`] (see
    /// [`Workload::arrivals`]).
    pub fn to_trace(&self, horizon: Duration, seed: u64) -> Result<Workload, WorkloadError> {
        Ok(Workload::Trace { arrivals: self.arrivals(horizon, seed)? })
    }

    /// Mean offered load of the schedule this workload generates
    /// (rate math centralized in [`crate::serve::metrics::rate_per_sec`]).
    ///
    /// # Errors
    /// [`WorkloadError::ClosedLoop`] for [`Workload::ClosedLoop`] (see
    /// [`Workload::arrivals`]).
    pub fn offered_rps(&self, horizon: Duration, seed: u64) -> Result<f64, WorkloadError> {
        Ok(crate::serve::metrics::rate_per_sec(
            self.arrivals(horizon, seed)?.len() as u64,
            horizon,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const H: Duration = Duration::from_secs(60);

    #[test]
    fn poisson_hits_target_rate() {
        let w = Workload::Poisson { rate_rps: 200.0 };
        let n = w.arrivals(H, 7).unwrap().len() as f64;
        let want = 200.0 * 60.0;
        // 3 standard deviations of a Poisson count.
        assert!((n - want).abs() < 3.0 * want.sqrt(), "n={n} want≈{want}");
    }

    #[test]
    fn arrivals_sorted_within_horizon() {
        for w in [
            Workload::Poisson { rate_rps: 50.0 },
            Workload::Mmpp2 {
                rate_low_rps: 20.0,
                rate_high_rps: 300.0,
                dwell_low: Duration::from_secs(2),
                dwell_high: Duration::from_secs(2),
            },
        ] {
            let a = w.arrivals(H, 3).unwrap();
            assert!(!a.is_empty());
            assert!(a.windows(2).all(|x| x[0] <= x[1]), "unsorted: {w:?}");
            assert!(*a.last().unwrap() < H);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let w = Workload::Mmpp2 {
            rate_low_rps: 10.0,
            rate_high_rps: 100.0,
            dwell_low: Duration::from_secs(1),
            dwell_high: Duration::from_secs(1),
        };
        assert_eq!(w.arrivals(H, 42).unwrap(), w.arrivals(H, 42).unwrap());
        assert_ne!(w.arrivals(H, 42).unwrap(), w.arrivals(H, 43).unwrap());
    }

    #[test]
    fn mmpp_mean_rate_between_states() {
        let w = Workload::Mmpp2 {
            rate_low_rps: 10.0,
            rate_high_rps: 200.0,
            dwell_low: Duration::from_secs(1),
            dwell_high: Duration::from_secs(1),
        };
        // Symmetric dwell → long-run mean ≈ (10+200)/2 = 105 rps.
        let rps = w.offered_rps(Duration::from_secs(300), 11).unwrap();
        assert!((60.0..160.0).contains(&rps), "mean rate {rps}");
    }

    #[test]
    fn asymmetric_dwell_skews_time_toward_the_long_state() {
        // dwell_low = 9× dwell_high → ~90% of the time at the low
        // rate: long-run mean ≈ 0.9·10 + 0.1·200 = 29 rps, far below
        // the symmetric midpoint of 105.
        let w = Workload::Mmpp2 {
            rate_low_rps: 10.0,
            rate_high_rps: 200.0,
            dwell_low: Duration::from_secs(9),
            dwell_high: Duration::from_secs(1),
        };
        let rps = w.offered_rps(Duration::from_secs(300), 11).unwrap();
        assert!((15.0..60.0).contains(&rps), "asymmetric mean rate {rps}");
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        // Squared coefficient of variation of inter-arrival gaps:
        // exactly 1 for Poisson, > 1 for a bursty MMPP.
        let cv2 = |a: &[Duration]| {
            let gaps: Vec<f64> = a.windows(2).map(|w| (w[1] - w[0]).as_secs_f64()).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var / (mean * mean)
        };
        let p = Workload::Poisson { rate_rps: 105.0 }.arrivals(H, 5).unwrap();
        let m = Workload::Mmpp2 {
            rate_low_rps: 10.0,
            rate_high_rps: 200.0,
            dwell_low: Duration::from_secs(1),
            dwell_high: Duration::from_secs(1),
        }
        .arrivals(H, 5).unwrap();
        assert!(cv2(&m) > 1.5 * cv2(&p), "mmpp cv²={} poisson cv²={}", cv2(&m), cv2(&p));
    }

    #[test]
    fn closed_loop_schedule_is_a_typed_error() {
        // The satellite bugfix: no panic — a typed error with an
        // actionable message, so the CLI can print it and move on.
        let w = Workload::ClosedLoop { users: 1, think_time: Duration::ZERO };
        assert_eq!(w.arrivals(H, 0), Err(WorkloadError::ClosedLoop));
        assert!(w.to_trace(H, 0).is_err());
        assert_eq!(w.offered_rps(H, 0), Err(WorkloadError::ClosedLoop));
        let msg = WorkloadError::ClosedLoop.to_string();
        assert!(
            msg.contains("no precomputable arrival schedule")
                && msg.contains("simulate_fleet"),
            "{msg}"
        );
    }

    #[test]
    fn class_mix_draw_is_deterministic_and_respects_weights() {
        let mix = ClassMix::standard();
        let draw_all = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..10_000).map(|_| mix.draw(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(draw_all(7), draw_all(7), "class stream must be seed-deterministic");
        let counts = draw_all(7).iter().fold([0usize; NUM_CLASSES], |mut c, p| {
            c[p.index()] += 1;
            c
        });
        // 10k draws: each empirical share within ±3σ of its weight.
        for (i, want) in [0.5, 0.3, 0.2].iter().enumerate() {
            let got = counts[i] as f64 / 10_000.0;
            assert!((got - want).abs() < 0.02, "class {i}: got {got} want {want}");
        }
        // Degenerate mixes stay total (one draw, never a panic).
        let mut rng = Rng::new(1);
        let zero = ClassMix { interactive: 0.0, batch: 0.0, background: 0.0 };
        assert_eq!(zero.draw(&mut rng), Priority::Interactive);
        let only = ClassMix::interactive_only();
        assert!((0..100).all(|_| only.draw(&mut rng) == Priority::Interactive));
    }

    #[test]
    fn priority_index_roundtrip_and_order() {
        for (i, p) in Priority::ALL.iter().enumerate() {
            assert_eq!(p.index(), i);
            assert_eq!(Priority::from_index(i), *p);
        }
        // Shedding order relies on Ord: higher index = less important.
        assert!(Priority::Interactive < Priority::Batch);
        assert!(Priority::Batch < Priority::Background);
        assert_eq!(Priority::Background.label(), "background");
    }

    #[test]
    fn trace_replays_and_clips() {
        let w = Workload::Poisson { rate_rps: 80.0 };
        let trace = w.to_trace(H, 9).unwrap();
        assert_eq!(trace.arrivals(H, 999), w.arrivals(H, 9), "seed-independent replay");
        let half = Duration::from_secs(30);
        let clipped = trace.arrivals(half, 0).unwrap();
        assert!(clipped.iter().all(|&a| a < half));
        assert!(clipped.len() < w.arrivals(H, 9).unwrap().len());
    }
}
