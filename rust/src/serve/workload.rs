//! Open-loop workload generators: the arrival schedules the fleet is
//! driven with.
//!
//! All generators are seeded ([`crate::util::rng::Rng`]) and produce a
//! concrete, sorted arrival schedule up front — the schedule *is* the
//! workload, so any run can be captured with [`Workload::to_trace`]
//! and replayed bit-identically (or edited by hand for what-if
//! studies). Open-loop means arrivals do not react to service: when
//! the fleet saturates, the queue grows — exactly the regime the
//! latency–throughput curves probe past the knee.

use std::time::Duration;

use crate::util::rng::Rng;

/// Arrival-process model.
#[derive(Clone, Debug)]
pub enum Workload {
    /// Memoryless arrivals at a constant mean rate (exponential
    /// inter-arrival gaps) — the classic open-loop baseline.
    Poisson { rate_rps: f64 },
    /// Bursty traffic: a 2-state Markov-modulated Poisson process.
    /// The process dwells exponentially (mean `mean_dwell`) in a calm
    /// state at `rate_low_rps`, then a burst state at `rate_high_rps`,
    /// alternating. Burstiness is what separates p99 behaviour from
    /// the Poisson mean-rate story.
    Mmpp2 { rate_low_rps: f64, rate_high_rps: f64, mean_dwell: Duration },
    /// Replay an explicit arrival schedule (offsets from t=0,
    /// ascending). Produced by [`Workload::to_trace`] or loaded from a
    /// production capture.
    Trace { arrivals: Vec<Duration> },
}

fn exp_gap(rng: &mut Rng, rate_per_s: f64) -> f64 {
    debug_assert!(rate_per_s > 0.0);
    -(1.0 - rng.f64()).ln() / rate_per_s
}

impl Workload {
    /// The concrete arrival schedule on `[0, horizon)`, sorted
    /// ascending. Deterministic in (self, horizon, seed); `Trace`
    /// ignores the seed and clips to the horizon.
    pub fn arrivals(&self, horizon: Duration, seed: u64) -> Vec<Duration> {
        let h = horizon.as_secs_f64();
        match self {
            Workload::Poisson { rate_rps } => {
                assert!(*rate_rps > 0.0, "Poisson rate must be positive");
                let mut rng = Rng::new(seed);
                let mut out = Vec::with_capacity((rate_rps * h) as usize + 16);
                let mut t = exp_gap(&mut rng, *rate_rps);
                while t < h {
                    out.push(Duration::from_secs_f64(t));
                    t += exp_gap(&mut rng, *rate_rps);
                }
                out
            }
            Workload::Mmpp2 { rate_low_rps, rate_high_rps, mean_dwell } => {
                assert!(*rate_low_rps > 0.0 && *rate_high_rps > 0.0);
                let dwell = mean_dwell.as_secs_f64();
                assert!(dwell > 0.0, "MMPP dwell must be positive");
                let mut rng = Rng::new(seed);
                let mut out = Vec::new();
                let mut t = 0.0f64;
                let mut burst = false;
                let mut next_switch = exp_gap(&mut rng, 1.0 / dwell);
                loop {
                    let rate = if burst { *rate_high_rps } else { *rate_low_rps };
                    let cand = t + exp_gap(&mut rng, rate);
                    if cand < next_switch {
                        // Arrival inside the current state.
                        t = cand;
                        if t >= h {
                            break;
                        }
                        out.push(Duration::from_secs_f64(t));
                    } else {
                        // State switch first; the exponential gap is
                        // memoryless, so restarting the draw at the
                        // switch point is exact.
                        t = next_switch;
                        if t >= h {
                            break;
                        }
                        burst = !burst;
                        next_switch = t + exp_gap(&mut rng, 1.0 / dwell);
                    }
                }
                out
            }
            Workload::Trace { arrivals } => {
                debug_assert!(
                    arrivals.windows(2).all(|w| w[0] <= w[1]),
                    "trace arrivals must be sorted"
                );
                arrivals.iter().copied().filter(|&a| a < horizon).collect()
            }
        }
    }

    /// Capture this workload's schedule as a replayable trace.
    pub fn to_trace(&self, horizon: Duration, seed: u64) -> Workload {
        Workload::Trace { arrivals: self.arrivals(horizon, seed) }
    }

    /// Mean offered load of the schedule this workload generates
    /// (rate math centralized in [`crate::serve::metrics::rate_per_sec`]).
    pub fn offered_rps(&self, horizon: Duration, seed: u64) -> f64 {
        crate::serve::metrics::rate_per_sec(self.arrivals(horizon, seed).len() as u64, horizon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const H: Duration = Duration::from_secs(60);

    #[test]
    fn poisson_hits_target_rate() {
        let w = Workload::Poisson { rate_rps: 200.0 };
        let n = w.arrivals(H, 7).len() as f64;
        let want = 200.0 * 60.0;
        // 3 standard deviations of a Poisson count.
        assert!((n - want).abs() < 3.0 * want.sqrt(), "n={n} want≈{want}");
    }

    #[test]
    fn arrivals_sorted_within_horizon() {
        for w in [
            Workload::Poisson { rate_rps: 50.0 },
            Workload::Mmpp2 {
                rate_low_rps: 20.0,
                rate_high_rps: 300.0,
                mean_dwell: Duration::from_secs(2),
            },
        ] {
            let a = w.arrivals(H, 3);
            assert!(!a.is_empty());
            assert!(a.windows(2).all(|x| x[0] <= x[1]), "unsorted: {w:?}");
            assert!(*a.last().unwrap() < H);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let w = Workload::Mmpp2 {
            rate_low_rps: 10.0,
            rate_high_rps: 100.0,
            mean_dwell: Duration::from_secs(1),
        };
        assert_eq!(w.arrivals(H, 42), w.arrivals(H, 42));
        assert_ne!(w.arrivals(H, 42), w.arrivals(H, 43));
    }

    #[test]
    fn mmpp_mean_rate_between_states() {
        let w = Workload::Mmpp2 {
            rate_low_rps: 10.0,
            rate_high_rps: 200.0,
            mean_dwell: Duration::from_secs(1),
        };
        // Symmetric dwell → long-run mean ≈ (10+200)/2 = 105 rps.
        let rps = w.offered_rps(Duration::from_secs(300), 11);
        assert!((60.0..160.0).contains(&rps), "mean rate {rps}");
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        // Squared coefficient of variation of inter-arrival gaps:
        // exactly 1 for Poisson, > 1 for a bursty MMPP.
        let cv2 = |a: &[Duration]| {
            let gaps: Vec<f64> = a.windows(2).map(|w| (w[1] - w[0]).as_secs_f64()).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var / (mean * mean)
        };
        let p = Workload::Poisson { rate_rps: 105.0 }.arrivals(H, 5);
        let m = Workload::Mmpp2 {
            rate_low_rps: 10.0,
            rate_high_rps: 200.0,
            mean_dwell: Duration::from_secs(1),
        }
        .arrivals(H, 5);
        assert!(cv2(&m) > 1.5 * cv2(&p), "mmpp cv²={} poisson cv²={}", cv2(&m), cv2(&p));
    }

    #[test]
    fn trace_replays_and_clips() {
        let w = Workload::Poisson { rate_rps: 80.0 };
        let trace = w.to_trace(H, 9);
        assert_eq!(trace.arrivals(H, 999), w.arrivals(H, 9), "seed-independent replay");
        let half = Duration::from_secs(30);
        let clipped = trace.arrivals(half, 0);
        assert!(clipped.iter().all(|&a| a < half));
        assert!(clipped.len() < w.arrivals(H, 9).len());
    }
}
