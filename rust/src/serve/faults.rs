//! Fault injection for the serving DES: when devices break, requests
//! time out, and batches corrupt.
//!
//! Real FPGA fleets are not the perfect world the baseline DES models.
//! Edge deployments lose devices (power, thermal, network partition),
//! a CHOSEN-style repair is a slow partial-reconfiguration rather than
//! a reboot, and SEU soft errors silently corrupt a batch that then
//! has to be re-executed. This module is the *configuration* side of
//! that story; the mechanics (failover re-dispatch, retry with capped
//! backoff, hedging, drop accounting) live in the DES event loop
//! (`serve/mod.rs`), and the outcome lands in
//! [`FaultSummary`] on the [`crate::serve::FleetReport`].
//!
//! Two fault sources compose:
//!
//! * **Scripted** outages — an explicit [`FaultPlan`] of per-device
//!   down-spans, for calibrated chaos scenarios and regression tests
//!   ("devices 0 and 1 down from 10 s to 11 s").
//! * **Stochastic** failure/repair processes — seeded exponential
//!   MTBF/MTTR per device ([`FaultPlan::stochastic`]), merged into the
//!   scripted plan at simulation start. Sampling is *state-independent*
//!   (a span is down-time scheduled on the wall clock, not on device
//!   activity), which is what lets the whole plan be precomputed and
//!   normalized up front — and keeps runs bit-identical per
//!   (config, seed).
//!
//! A normalized plan satisfies the invariants the proptests pin:
//! per-device spans are sorted, strictly positive-length, and
//! non-overlapping (overlapping or touching spans coalesce into one
//! continuous outage), so fail/repair events strictly alternate per
//! device and `availability = 1 − downtime/horizon` is well-defined.
//!
//! Every fault-path transition is also visible to the tracer
//! ([`crate::obs`]): `device_fail` / `device_repair`,
//! `attempt_timeout` / `retry` / `drop` and `seu_rerun` records carry
//! the same quantities the [`FaultSummary`] aggregates, so
//! `ubimoe trace analyze` can align its incident timeline with the
//! per-request latency spans ([`crate::obs::analyze`]) instead of
//! reporting fleet-wide totals only.
//!
//! The per-attempt timeout counters this module drives also feed the
//! per-device **circuit breakers**
//! ([`crate::serve::overload::BreakerConfig`]): a streak of
//! consecutive timeouts on one device trips its breaker and masks it
//! out of dispatch until a half-open probe succeeds — the
//! overload-protection layer's consumer of the fault machinery.

use std::time::Duration;

use crate::util::rng::{Rng, SplitMix64};

/// One scheduled outage: `device` is down on `[from, to)`. Spans are
/// validated against the *initial* fleet (autoscale-spawned replicas
/// do not fail — they model freshly provisioned capacity).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpan {
    pub device: usize,
    pub from: Duration,
    pub to: Duration,
}

impl FaultSpan {
    pub fn new(device: usize, from: Duration, to: Duration) -> FaultSpan {
        FaultSpan { device, from, to }
    }
}

/// A normalized schedule of device outages (see the module docs for
/// the invariants). Construct with [`FaultPlan::new`] (scripted),
/// [`FaultPlan::stochastic`] (seeded MTBF/MTTR), or compose both with
/// [`FaultPlan::merged`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Canonical order: (device, from) ascending.
    spans: Vec<FaultSpan>,
}

impl FaultPlan {
    /// The no-faults plan.
    pub fn empty() -> FaultPlan {
        FaultPlan { spans: Vec::new() }
    }

    /// Normalize a scripted span list: sort per device and coalesce
    /// overlapping or touching spans into one continuous outage, so
    /// fail/repair events strictly alternate per device.
    ///
    /// # Panics
    /// On a zero- or negative-length span (`from >= to`).
    pub fn new(mut spans: Vec<FaultSpan>) -> FaultPlan {
        for s in &spans {
            assert!(s.from < s.to, "fault span must have positive length: {s:?}");
        }
        spans.sort_by_key(|s| (s.device, s.from, s.to));
        let mut out: Vec<FaultSpan> = Vec::with_capacity(spans.len());
        for s in spans {
            match out.last_mut() {
                Some(p) if p.device == s.device && s.from <= p.to => p.to = p.to.max(s.to),
                _ => out.push(s),
            }
        }
        FaultPlan { spans: out }
    }

    /// Seeded exponential failure/repair processes: each device of the
    /// initial fleet draws time-to-failure ~ Exp(1/mtbf) and
    /// time-to-repair ~ Exp(1/mttr) from its own SplitMix-derived
    /// stream, alternating until the failure clock passes `horizon`.
    /// Per-device streams make device u's k-th outage independent of
    /// the rest of the fleet — the same construction as the DES's
    /// closed-loop user streams.
    pub fn stochastic(
        n_devices: usize,
        mtbf: Duration,
        mttr: Duration,
        horizon: Duration,
        seed: u64,
    ) -> FaultPlan {
        assert!(mtbf > Duration::ZERO, "MTBF must be positive");
        assert!(mttr > Duration::ZERO, "MTTR must be positive");
        let h = horizon.as_secs_f64();
        // Exponential draw, floored away from zero so spans keep
        // strictly positive length after Duration rounding.
        fn exp_draw(rng: &mut Rng, mean_s: f64) -> f64 {
            (-(1.0 - rng.f64()).ln() * mean_s).max(1e-9)
        }
        let mut sm = SplitMix64::new(seed ^ 0xFA01_7A1E);
        let mut spans = Vec::new();
        for device in 0..n_devices {
            let mut rng = Rng::new(sm.next_u64());
            let mut t = exp_draw(&mut rng, mtbf.as_secs_f64());
            while t < h {
                let up = t + exp_draw(&mut rng, mttr.as_secs_f64());
                spans.push(FaultSpan::new(
                    device,
                    Duration::from_secs_f64(t),
                    Duration::from_secs_f64(up),
                ));
                t = up + exp_draw(&mut rng, mtbf.as_secs_f64());
            }
        }
        FaultPlan::new(spans)
    }

    /// Compose two plans (scripted + stochastic): the union of their
    /// outages, re-normalized.
    pub fn merged(&self, other: &FaultPlan) -> FaultPlan {
        let mut spans = self.spans.clone();
        spans.extend(other.spans.iter().copied());
        FaultPlan::new(spans)
    }

    /// The normalized spans, (device, from)-ascending.
    pub fn spans(&self) -> &[FaultSpan] {
        &self.spans
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Largest device index any span targets (plan validation against
    /// the initial fleet size).
    pub fn max_device(&self) -> Option<usize> {
        self.spans.iter().map(|s| s.device).max()
    }

    /// Scheduled downtime of `device`, clipped to the observation
    /// window `[0, end)`.
    pub fn downtime(&self, device: usize, end: Duration) -> Duration {
        self.spans
            .iter()
            .filter(|s| s.device == device)
            .map(|s| s.to.min(end).saturating_sub(s.from.min(end)))
            .sum()
    }

    /// `1 − downtime/end` for `device` over `[0, end)`; an empty
    /// window reports full availability.
    pub fn availability(&self, device: usize, end: Duration) -> f64 {
        if end.is_zero() {
            return 1.0;
        }
        1.0 - self.downtime(device, end).as_secs_f64() / end.as_secs_f64()
    }
}

/// All fault-injection and graceful-degradation knobs of a run,
/// attached via `ServeConfig::faults`. Every knob at its inert value
/// ([`FaultConfig::is_inert`]) makes the DES behave bit-identically to
/// a run with no fault config at all (proptested).
#[derive(Clone, Debug)]
pub struct FaultConfig {
    /// Scripted outages (merged with the stochastic process, if any).
    pub plan: FaultPlan,
    /// Mean time between failures per device; `None` disables the
    /// stochastic failure process (scripted plan only).
    pub mtbf: Option<Duration>,
    /// Mean time to repair for stochastic failures (must be positive
    /// when `mtbf` is set).
    pub mttr: Duration,
    /// Probability that an executed batch is SEU-corrupted and must
    /// re-execute (burning its service time). Must be in `[0, 1)` —
    /// probability 1 would re-execute forever.
    pub seu_per_batch: f64,
    /// Per-attempt client deadline: a request whose attempt has not
    /// completed this long after dispatch times out and retries (or
    /// drops once the budget is spent). `None` disables deadlines,
    /// retries and drops.
    pub deadline: Option<Duration>,
    /// Total attempt budget per request (first attempt included); the
    /// request is *dropped* — counted, never silently completed — when
    /// attempt `max_attempts` also times out. Must be ≥ 1.
    pub max_attempts: u32,
    /// Capped exponential backoff between attempts: attempt k waits
    /// `min(backoff_base · 2^(k−1), backoff_cap)` after its timeout.
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// Hedged requests: this long after a request's first dispatch (a
    /// p99-derived delay in the chaos studies), send a duplicate to a
    /// second device; first completion wins, the loser is cancelled by
    /// the settled check. `None` disables hedging.
    pub hedge_delay: Option<Duration>,
}

impl FaultConfig {
    /// The all-knobs-off config (useful as a base to enable one
    /// mechanism at a time).
    pub fn none() -> FaultConfig {
        FaultConfig {
            plan: FaultPlan::empty(),
            mtbf: None,
            mttr: Duration::from_secs(1),
            seu_per_batch: 0.0,
            deadline: None,
            max_attempts: 1,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(100),
            hedge_delay: None,
        }
    }

    /// True when every fault mechanism is disabled — the DES then runs
    /// its unperturbed hot path (bit-identical to `faults: None`).
    pub fn is_inert(&self) -> bool {
        self.plan.is_empty()
            && self.mtbf.is_none()
            && self.seu_per_batch == 0.0
            && self.deadline.is_none()
            && self.hedge_delay.is_none()
    }
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig::none()
    }
}

/// Fault-machinery outcome of one run — `Some` on the
/// [`crate::serve::FleetReport`] iff fault injection was active.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultSummary {
    /// DeviceFail events that hit a live (serving or draining) slot.
    pub device_failures: u64,
    /// Batches in service lost to a failure (members re-dispatched).
    pub lost_batches: u64,
    /// Service time burned by lost batches (charged to device busy
    /// time — failures waste real cycles).
    pub wasted_service: Duration,
    /// Request copies re-dispatched off a failed device (queued +
    /// in-flight members still live at failure time).
    pub failovers: u64,
    /// Retry attempts dispatched after a deadline timeout.
    pub retries: u64,
    /// Requests dropped after exhausting the attempt budget.
    pub dropped: u64,
    /// SEU-corrupted batch executions that forced a re-run.
    pub seu_reruns: u64,
    /// Hedge duplicates dispatched.
    pub hedges: u64,
    /// Requests whose hedge copy finished first.
    pub hedge_wins: u64,
    /// Per-slot scheduled downtime, clipped to the run end
    /// (`max(makespan, horizon)`); autoscale-spawned slots report
    /// zero. `1 − downtime/end` is the slot's availability.
    pub downtime: Vec<Duration>,
}

impl FaultSummary {
    /// Availability of `slot` over a run that ended at `end`.
    pub fn availability(&self, slot: usize, end: Duration) -> f64 {
        if end.is_zero() {
            return 1.0;
        }
        let down = self.downtime.get(slot).copied().unwrap_or(Duration::ZERO);
        1.0 - down.as_secs_f64() / end.as_secs_f64()
    }

    /// Mean per-slot availability over a run that ended at `end`.
    pub fn mean_availability(&self, end: Duration) -> f64 {
        if self.downtime.is_empty() {
            return 1.0;
        }
        let sum: f64 =
            (0..self.downtime.len()).map(|i| self.availability(i, end)).sum();
        sum / self.downtime.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(x: u64) -> Duration {
        Duration::from_secs(x)
    }

    #[test]
    fn new_sorts_and_coalesces_overlaps() {
        let p = FaultPlan::new(vec![
            FaultSpan::new(1, s(5), s(7)),
            FaultSpan::new(0, s(1), s(3)),
            FaultSpan::new(0, s(2), s(4)), // overlaps the [1,3) span
            FaultSpan::new(0, s(4), s(6)), // touches → one continuous outage
            FaultSpan::new(1, s(9), s(10)),
        ]);
        assert_eq!(
            p.spans(),
            &[
                FaultSpan::new(0, s(1), s(6)),
                FaultSpan::new(1, s(5), s(7)),
                FaultSpan::new(1, s(9), s(10)),
            ]
        );
    }

    #[test]
    fn per_device_spans_alternate_and_never_overlap() {
        let p = FaultPlan::new(vec![
            FaultSpan::new(0, s(1), s(2)),
            FaultSpan::new(0, s(4), s(5)),
            FaultSpan::new(1, s(1), s(9)),
        ]);
        for w in p.spans().windows(2) {
            if w[0].device == w[1].device {
                assert!(w[0].to < w[1].from, "repair strictly precedes next failure");
            }
        }
    }

    #[test]
    #[should_panic(expected = "positive length")]
    fn zero_length_span_rejected() {
        let _ = FaultPlan::new(vec![FaultSpan::new(0, s(3), s(3))]);
    }

    #[test]
    fn downtime_clips_to_the_window() {
        let p = FaultPlan::new(vec![
            FaultSpan::new(0, s(2), s(4)),
            FaultSpan::new(0, s(8), s(20)),
        ]);
        assert_eq!(p.downtime(0, s(10)), s(4), "2 + clipped 2");
        assert_eq!(p.downtime(0, s(100)), s(14));
        assert_eq!(p.downtime(1, s(100)), Duration::ZERO);
        let avail = p.availability(0, s(10));
        assert!((avail - 0.6).abs() < 1e-12, "1 - 4/10 = 0.6, got {avail}");
        assert_eq!(p.availability(0, Duration::ZERO), 1.0);
    }

    #[test]
    fn merged_unions_and_renormalizes() {
        let a = FaultPlan::new(vec![FaultSpan::new(0, s(1), s(3))]);
        let b = FaultPlan::new(vec![
            FaultSpan::new(0, s(2), s(5)),
            FaultSpan::new(2, s(7), s(8)),
        ]);
        let m = a.merged(&b);
        assert_eq!(
            m.spans(),
            &[FaultSpan::new(0, s(1), s(5)), FaultSpan::new(2, s(7), s(8))]
        );
        assert_eq!(m.max_device(), Some(2));
        assert_eq!(FaultPlan::empty().max_device(), None);
    }

    #[test]
    fn stochastic_is_seed_deterministic_and_normalized() {
        let mk = |seed| {
            FaultPlan::stochastic(3, s(20), s(2), s(600), seed)
        };
        let a = mk(7);
        assert_eq!(a, mk(7), "same seed, same plan");
        assert_ne!(a, mk(8), "different seed perturbs the plan");
        assert!(!a.is_empty(), "600 s horizon at 20 s MTBF must fail sometimes");
        // Every span is strictly positive and the per-device sequence
        // alternates (normalization invariant).
        for sp in a.spans() {
            assert!(sp.from < sp.to);
        }
        for w in a.spans().windows(2) {
            if w[0].device == w[1].device {
                assert!(w[0].to < w[1].from);
            }
        }
        // Failures only start inside the horizon (repairs may land
        // past it — the DES drains through them).
        assert!(a.spans().iter().all(|sp| sp.from < s(600)));
    }

    #[test]
    fn inert_config_detection() {
        let mut f = FaultConfig::none();
        assert!(f.is_inert());
        assert!(FaultConfig::default().is_inert());
        f.seu_per_batch = 0.01;
        assert!(!f.is_inert());
        let mut g = FaultConfig::none();
        g.plan = FaultPlan::new(vec![FaultSpan::new(0, s(1), s(2))]);
        assert!(!g.is_inert());
        let mut h = FaultConfig::none();
        h.deadline = Some(Duration::from_millis(500));
        assert!(!h.is_inert());
        let mut i = FaultConfig::none();
        i.mtbf = Some(s(100));
        assert!(!i.is_inert());
        let mut j = FaultConfig::none();
        j.hedge_delay = Some(Duration::from_millis(90));
        assert!(!j.is_inert());
    }

    #[test]
    fn summary_availability_math() {
        let sm = FaultSummary {
            downtime: vec![s(2), Duration::ZERO],
            ..Default::default()
        };
        assert!((sm.availability(0, s(10)) - 0.8).abs() < 1e-12);
        assert_eq!(sm.availability(1, s(10)), 1.0);
        assert_eq!(sm.availability(9, s(10)), 1.0, "unknown slot: no downtime");
        assert!((sm.mean_availability(s(10)) - 0.9).abs() < 1e-12);
        assert_eq!(FaultSummary::default().mean_availability(s(10)), 1.0);
    }
}
