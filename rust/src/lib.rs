//! # UbiMoE — Mixture-of-Experts Vision Transformer accelerator
//!
//! Full-system reproduction of *UbiMoE: A Ubiquitous Mixture-of-Experts
//! Vision Transformer Accelerator With Hybrid Computation Pattern on
//! FPGA* as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time, python/)** — the streaming-attention and
//!   reusable-linear Pallas kernels plus the M3ViT-style model, AOT-
//!   lowered to HLO-text artifacts.
//! * **L3 (this crate)** — the accelerator study and the runtime:
//!   * [`sim`] — cycle-level model of the paper's hybrid-pattern
//!     accelerator (Eq. 2–4, double buffering, HBM/DDR, SLR placement,
//!     power);
//!   * [`has`] — the 2-stage Hardware Accelerator Search (Algorithm 1:
//!     GA + binary search);
//!   * [`baselines`] — GPU roofline, Edge-MoE, HeatViT, TECS'23
//!     comparators for Tables II–III;
//!   * [`runtime`] — PJRT executor for the AOT artifacts;
//!   * [`coordinator`] — the Fig. 3 double-buffered block pipeline,
//!     round-robin CU router, request batcher;
//!   * [`obs`] — observability: zero-cost-when-off virtual-time
//!     event tracing, windowed time-series sampling, the offline
//!     trace analyzer, and the process work-counter registry;
//!   * [`serve`] — deterministic discrete-event fleet-serving
//!     simulator: open-loop (Poisson/bursty-MMPP/trace) and
//!     closed-loop (N users × think time) traffic over multi-FPGA
//!     deployments, dynamic batching, dispatch policies (RR/WRR/JSQ/
//!     expert-affinity/SED), SLO-driven autoscaling with
//!     drain-before-remove, tail-latency and SLO metrics;
//!   * [`report`] — regenerates every table and figure in the paper,
//!     plus the serving studies: latency–throughput curves, the
//!     mixed-fleet policy table, autoscaling-vs-static device-seconds
//!     economics, and closed-loop max-users-at-SLO capacity.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod has;
pub mod models;
pub mod obs;
pub mod report;
pub mod resources;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Version of the artifact format this crate expects.
pub const ARTIFACT_FORMAT: u32 = 1;
