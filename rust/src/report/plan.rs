//! `ubimoe plan` report layer: runs the fleet↔hardware co-design
//! search ([`crate::has::fleet`]) and renders its Pareto frontier, plus
//! the scoped-thread scenario-grid runner ([`run_grid`]) the replay
//! path and tests share.
//!
//! Two canned specs:
//!
//! * [`small_spec`] — a 2-template, 4-point genome space on a 4-request
//!   trace, enumerated exhaustively. Every objective value is
//!   hand-checkable (the arithmetic is spelled out in the function
//!   docs), which is what makes the byte-exact golden
//!   (`rust/tests/golden/plan_small.txt`) reviewable without running
//!   anything.
//! * [`demo_spec`] — cycle-model-backed ZCU102/U280 templates at two
//!   bit-width tiers (power via [`design_power`], timing via
//!   `Platform::with_bitwidth_timing` — the Table III rule), dispatch
//!   and autoscale-preset choices, on steady + bursty traffic. Its
//!   1024-genome space exceeds [`crate::has::fleet::EXHAUSTIVE_LIMIT`],
//!   so this is the GA path, one run per scalarization weight profile.
//!
//! Both are deterministic per spec; a memo-warm rerun (same
//! design-cache dir) performs zero DES event loops — CI asserts this
//! with counter deltas and `cmp` on the stdout.

use std::time::Duration;

use crate::has::cache::DesignCache;
use crate::has::fleet::{
    fleet_configs, AutoscalePreset, FleetPlanOutcome, FleetSpec, PlanTemplate, PlanVariant,
    Scenario,
};
use crate::has::ga::GaParams;
use crate::models::m3vit_small;
use crate::resources::Platform;
use crate::serve::device::DeviceModel;
use crate::serve::dispatch::DispatchPolicy;
use crate::serve::{FleetReport, ServeConfig, Workload};
use crate::sim::power::design_power;
use crate::util::table::{f2, f3, Table};

/// Run every config of a scenario grid through the fleet-report memo
/// concurrently on scoped threads, results in input order. Each run is
/// independent and deterministic, so this is identical to the
/// sequential loop ([`DesignCache::get_or_compute_fleet`] per config)
/// — the `deploy_many` idiom one layer up the stack.
pub fn run_grid(cache: &DesignCache, cfgs: &[ServeConfig]) -> Vec<FleetReport> {
    if cfgs.len() <= 1 {
        return cfgs.iter().map(|c| cache.get_or_compute_fleet(c)).collect();
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = cfgs
            .iter()
            .map(|c| scope.spawn(move || cache.get_or_compute_fleet(c)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("grid worker panicked"))
            .collect()
    })
}

/// The hand-checkable plan fixture behind `ubimoe plan --small` and the
/// golden test. Two synthetic single-batch templates:
///
/// * `edge`: fill 1 ms, period 2 ms (service(1) = 3 ms), 5 W;
/// * `core`: fill 1 ms, period 1 ms (service(1) = 2 ms), 9 W;
///
/// max one of each, JSQ only, no autoscale, one scenario: a fixed
/// 4-request trace at t = 0, 1, 2, 3 ms with a 20 ms horizon. The
/// 4-genome space is exhaustive, all three non-empty compositions are
/// feasible and mutually non-dominated, and every number in the golden
/// table follows by hand:
///
/// * `{core}`: completions at 2, 3, 4, 5 ms → e2e 2, 2, 2, 2… no —
///   serial queueing: 2, 3, 4, 5 ms minus arrivals 0, 1, 2, 3 = 2 ms
///   each?  The batcher launches a batch of 1 immediately, so request
///   r1 waits for r0: starts at 2, done 4 (e2e 3); r2 done 6 (e2e 4);
///   r3 done 8 (e2e 5). p99 (n = 4 < 100 ⇒ exact max) = **5 ms**;
///   makespan 8 ms < horizon ⇒ device-seconds = 1 × 0.020 = **0.020**;
///   energy = 0.020 × 9 = **0.180 J**.
/// * `{edge}`: service 3 ms ⇒ completions 3, 6, 9, 12 ⇒ worst e2e
///   **9 ms**; device-seconds **0.020**, energy 0.020 × 5 = **0.100 J**.
/// * `{edge, core}` under JSQ (lowest index wins ties): r0→edge,
///   r1→core, r2→edge (queued), r3→core ⇒ worst e2e **4 ms**;
///   device-seconds 2 × 0.020 = **0.040**, energy 0.040 × 7 =
///   **0.280 J**.
pub fn small_spec() -> FleetSpec {
    let dev = |name: &str, fill_ms: u64, period_ms: u64| {
        DeviceModel::from_latencies(
            name.into(),
            Duration::from_millis(fill_ms),
            Duration::from_millis(period_ms),
            &[1],
        )
    };
    FleetSpec {
        name: "small".into(),
        templates: vec![
            PlanTemplate {
                name: "edge".into(),
                variants: vec![PlanVariant { label: "w16".into(), device: dev("edge", 1, 2), watts: 5.0 }],
                max_count: 1,
            },
            PlanTemplate {
                name: "core".into(),
                variants: vec![PlanVariant { label: "w16".into(), device: dev("core", 1, 1), watts: 9.0 }],
                max_count: 1,
            },
        ],
        scenarios: vec![Scenario {
            label: "trace4".into(),
            workload: Workload::Trace {
                arrivals: vec![
                    Duration::from_millis(0),
                    Duration::from_millis(1),
                    Duration::from_millis(2),
                    Duration::from_millis(3),
                ],
            },
            horizon: Duration::from_millis(20),
            seed: 7,
        }],
        policies: vec![DispatchPolicy::JoinShortestQueue],
        autoscale_presets: vec![],
        num_experts: 0,
        ga: GaParams::default(),
        weight_profiles: vec![[1.0, 1.0, 1.0]],
    }
}

/// One cycle-model template: the pinned demo design
/// ([`crate::report::serving::demo_device`] fixture class) at W16A32,
/// plus a W16A16 tier on the retimed platform (the Table III rule:
/// U280 reaches 250 MHz at a_bits ≤ 16). Board watts via
/// [`design_power`] over the design's resource footprint with every
/// memory channel active — a labeled estimate, same model as the
/// `ubimoe power` tables.
fn demo_template(platform: &Platform, max_count: usize) -> PlanTemplate {
    let model = m3vit_small();
    let name = if platform.name.contains("U280") { "u280" } else { "zcu102" };
    let mut variants = Vec::new();
    for (label, a_bits) in [("w16a32", 32u32), ("w16a16", 16u32)] {
        let retimed = platform.clone().with_bitwidth_timing(a_bits);
        let mut hw = crate::report::serving::demo_hw(&retimed);
        hw.a_bits = a_bits;
        let device = DeviceModel::with_hw(&model, &retimed, hw, &[1, 2, 4, 8]);
        let watts = design_power(
            &retimed,
            &hw.resources(model.heads, model.patches, model.dim),
            retimed.mem_channels,
        );
        variants.push(PlanVariant { label: label.into(), device, watts });
    }
    PlanTemplate { name: name.into(), variants, max_count }
}

/// The `ubimoe plan` demo problem: ZCU102 and U280 templates (≤ 3
/// devices each, two bit-width tiers), JSQ vs shortest-expected-delay,
/// an optional conservative autoscale preset, over a steady Poisson
/// scenario and an asymmetric-burst MMPP scenario sized off the
/// ZCU102 tier-0 peak. 1024 genomes ⇒ GA mode, four weight profiles
/// (balanced + one leaning on each objective).
pub fn demo_spec() -> FleetSpec {
    let zcu = demo_template(&Platform::zcu102(), 3);
    let u280 = demo_template(&Platform::u280(), 3);
    let base = zcu.variants[0].device.peak_rps();
    FleetSpec {
        name: "demo".into(),
        templates: vec![zcu, u280],
        scenarios: vec![
            Scenario {
                label: "steady".into(),
                workload: Workload::Poisson { rate_rps: 1.5 * base },
                horizon: Duration::from_millis(1200),
                seed: 11,
            },
            Scenario {
                label: "burst".into(),
                workload: Workload::Mmpp2 {
                    rate_low_rps: 0.8 * base,
                    rate_high_rps: 2.5 * base,
                    dwell_low: Duration::from_millis(400),
                    dwell_high: Duration::from_millis(100),
                },
                horizon: Duration::from_millis(1000),
                seed: 12,
            },
        ],
        policies: vec![DispatchPolicy::JoinShortestQueue, DispatchPolicy::ShortestExpectedDelay],
        autoscale_presets: vec![AutoscalePreset {
            label: "as-cons".into(),
            slo_factor: 3,
            rho_target: 0.7,
            target_attainment: 0.99,
            scale_down_patience: 2,
            min_devices: 1,
            max_devices: 4,
        }],
        num_experts: m3vit_small().num_experts,
        ga: GaParams { population: 12, generations: 8, ..GaParams::default() },
        weight_profiles: vec![[1.0, 1.0, 1.0], [3.0, 1.0, 1.0], [1.0, 3.0, 1.0], [1.0, 1.0, 3.0]],
    }
}

/// Render the frontier as the `ubimoe plan` table — the byte-exact
/// surface of the `plan_small` golden.
pub fn frontier_table(spec: &FleetSpec, out: &FleetPlanOutcome) -> Table {
    let mut t = Table::new(
        "fleet plan: frontier",
        &["fleet", "policy", "scale", "dev-s", "p99 ms", "energy J"],
    );
    for p in &out.frontier {
        t.row(&[
            p.candidate.label(spec),
            spec.policies[p.candidate.policy].name().to_string(),
            p.candidate.scale_label(spec),
            f3(p.objectives.device_seconds),
            f2(p.objectives.p99_ms),
            f3(p.objectives.energy_j),
        ]);
    }
    t
}

/// Replay every frontier point's scenario grid through the memo
/// ([`run_grid`]) and tabulate per-scenario tails — warm by
/// construction right after a search, and the CLI surface that makes
/// "the frontier reconciles with the DES" visible.
pub fn replay_table(cache: &DesignCache, spec: &FleetSpec, out: &FleetPlanOutcome) -> Table {
    let mut t = Table::new(
        "fleet plan: frontier replay",
        &["fleet", "scenario", "requests", "p99 ms", "dev-s"],
    );
    for p in &out.frontier {
        let (cfgs, _) = match fleet_configs(spec, &p.candidate) {
            Some(x) => x,
            None => continue,
        };
        let reports = run_grid(cache, &cfgs);
        for (sc, r) in spec.scenarios.iter().zip(&reports) {
            t.row(&[
                p.candidate.label(spec),
                sc.label.clone(),
                r.fleet.completed.to_string(),
                f2(r.fleet.e2e.p99().as_secs_f64() * 1e3),
                f3(r.device_seconds),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::has::fleet::plan_fleet;
    use crate::serve::simulate_fleet;

    #[test]
    fn small_spec_frontier_matches_hand_computation() {
        let spec = small_spec();
        assert_eq!(spec.space_size(), 4);
        let out = plan_fleet(&spec, &DesignCache::disabled()).unwrap();
        assert!(out.exhaustive);
        assert_eq!(out.frontier.len(), 3);
        let rows: Vec<(String, f64, f64, f64)> = out
            .frontier
            .iter()
            .map(|p| {
                (
                    p.candidate.label(&spec),
                    p.objectives.device_seconds,
                    p.objectives.p99_ms,
                    p.objectives.energy_j,
                )
            })
            .collect();
        assert_eq!(rows[0].0, "1xcore/w16");
        assert!((rows[0].1 - 0.020).abs() < 1e-12 && (rows[0].2 - 5.0).abs() < 1e-9);
        assert_eq!(rows[1].0, "1xedge/w16");
        assert!((rows[1].2 - 9.0).abs() < 1e-9 && (rows[1].3 - 0.100).abs() < 1e-9);
        assert_eq!(rows[2].0, "1xedge/w16+1xcore/w16");
        assert!((rows[2].1 - 0.040).abs() < 1e-12 && (rows[2].2 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn run_grid_matches_sequential_and_direct_simulation() {
        let spec = small_spec();
        let out = plan_fleet(&spec, &DesignCache::disabled()).unwrap();
        let cache = DesignCache::disabled();
        for p in &out.frontier {
            let (cfgs, _) = fleet_configs(&spec, &p.candidate).unwrap();
            let grid = run_grid(&cache, &cfgs);
            for (cfg, r) in cfgs.iter().zip(&grid) {
                let direct = simulate_fleet(cfg);
                assert_eq!(r.fleet.completed, direct.fleet.completed);
                assert_eq!(
                    r.device_seconds.to_bits(),
                    direct.device_seconds.to_bits(),
                    "grid runner must be bit-identical to a direct run"
                );
                assert_eq!(r.fleet.e2e.p99(), direct.fleet.e2e.p99());
            }
        }
    }

    #[test]
    fn frontier_table_is_stable() {
        let spec = small_spec();
        let out = plan_fleet(&spec, &DesignCache::disabled()).unwrap();
        let a = frontier_table(&spec, &out).render();
        let b = frontier_table(&spec, &plan_fleet(&spec, &DesignCache::disabled()).unwrap())
            .render();
        assert_eq!(a, b);
        assert!(a.contains("1xedge/w16+1xcore/w16"));
        assert!(a.contains("jsq"));
    }

    #[test]
    fn demo_spec_is_ga_sized_and_valid() {
        let spec = demo_spec();
        assert!(spec.validate().is_ok());
        assert!(
            spec.space_size() > crate::has::fleet::EXHAUSTIVE_LIMIT,
            "demo must exercise the GA path (space = {})",
            spec.space_size()
        );
        // Both tiers of both templates are real devices with real
        // power figures.
        for t in &spec.templates {
            assert_eq!(t.variants.len(), 2, "{}", t.name);
            for v in &t.variants {
                assert!(v.watts > 0.0, "{}/{}", t.name, v.label);
                assert!(v.device.peak_rps() > 0.0);
            }
        }
        // The Table III retiming rule must separate the U280 tiers.
        let u280 = &spec.templates[1];
        assert!(
            u280.variants[1].device.peak_rps() > u280.variants[0].device.peak_rps(),
            "w16a16 runs at 250 MHz and must out-throughput w16a32"
        );
    }

    #[test]
    fn replay_table_covers_every_frontier_point() {
        let spec = small_spec();
        let cache = DesignCache::disabled();
        let out = plan_fleet(&spec, &cache).unwrap();
        let t = replay_table(&cache, &spec, &out);
        assert_eq!(t.rows.len(), out.frontier.len() * spec.scenarios.len());
        let s = t.render();
        assert!(s.contains("trace4"));
    }
}
