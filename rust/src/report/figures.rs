//! Figure regenerators: Fig. 3b (double-buffer timeline), Fig. 4
//! (patch-reorder traffic), Fig. 5 (SLR floorplan).

use crate::models::{m3vit_small, ModelConfig};
use crate::report::deploy;
use crate::resources::{Platform, Resources};
use crate::sim::attention::{naive_kv_traffic_bytes, reordered_kv_traffic_bytes};
use crate::sim::engine::{simulate, simulate_sequential, SimConfig};
use crate::sim::placement::{place, render as render_plan, Block, Floorplan};
use crate::sim::timeline::Timeline;
use crate::util::table::Table;

/// Fig. 3b: the double-buffered timeline of the first MoE-ViT layers,
/// plus the sequential ablation for contrast. Returns (overlapped,
/// sequential, overlap speedup).
pub fn fig3_timeline(platform: &Platform) -> (Timeline, Timeline, f64) {
    let model = m3vit_small();
    let d = deploy(&model, platform, 16, 32);
    let sc = SimConfig::new(model, platform.clone(), d.has.hw);
    let overlapped = simulate(&sc);
    let sequential = simulate_sequential(&sc);
    let speedup = sequential.total_cycles / overlapped.total_cycles;
    (overlapped.timeline, sequential.timeline, speedup)
}

/// Fig. 4: off-chip K/V traffic, naive single-q vs patch-reordered, as
/// a function of N_a. Returns a table with one row per N_a.
pub fn fig4_reorder(model: &ModelConfig, a_bits: u32) -> Table {
    let mut t = Table::new(
        "Fig. 4: K/V off-chip traffic, naive vs patch-reordered (MB per MSA block)",
        &["N_a", "naive (MB)", "reordered (MB)", "reduction"],
    );
    let naive = naive_kv_traffic_bytes(model.patches, model.dim, a_bits) as f64 / 1e6;
    for n_a in [1usize, 2, 4, 8, 16, 32] {
        let reord =
            reordered_kv_traffic_bytes(model.patches, model.dim, a_bits, n_a) as f64 / 1e6;
        t.row(&[
            n_a.to_string(),
            format!("{naive:.2}"),
            format!("{reord:.2}"),
            format!("{:.2}x", naive / reord),
        ]);
    }
    t
}

/// Fig. 5: implementation floorplan of M3ViT on a platform. Returns
/// the rendered plan plus the raw assignment.
pub fn fig5_placement(platform: &Platform) -> (String, Floorplan) {
    let model = m3vit_small();
    let d = deploy(&model, platform, 16, 32);
    let r = &d.has.resources;
    // Split the design's resources across its architectural blocks in
    // proportion to their kernel DSP footprints. The MoE kernel's N_L
    // CUs are independent units and are floorplanned individually —
    // that is exactly how a multi-SLR design splits a large kernel.
    let attn_dsp = crate::resources::attn_dsp_w(
        &d.has.hw.attn,
        d.has.hw.q_bits,
        d.has.hw.a_bits,
        model.heads,
    );
    let lin_dsp =
        crate::resources::linear_dsp_w(&d.has.hw.lin, d.has.hw.q_bits, d.has.hw.a_bits);
    let stream_dsp = (r.dsp - attn_dsp - lin_dsp).max(0.0);
    // Proportional split keeps Σ blocks ≤ the design total.
    let frac = |dsp: f64| -> Resources {
        let k = dsp / r.dsp.max(1e-9);
        Resources { dsp, bram18: r.bram18 * k, lut: r.lut * k, ff: r.ff * k }
    };
    let ops = crate::models::ops::model_ops(&model, 16, 32);
    let moe_traffic = ops.per_layer_moe.weight_bytes as f64 * ops.num_moe_layers as f64;
    // Any block larger than ~60% of one SLR is split into sub-blocks
    // (HLS kernels partition naturally: per CU, per PE group).
    let cap = platform.budget().dsp / platform.slrs.max(1) as f64 * 0.6;
    let mut blocks = Vec::new();
    let mut add_split = |name: &str, dsp: f64, traffic: f64, min_parts: usize| {
        let parts = min_parts.max((dsp / cap).ceil() as usize).max(1);
        for p in 0..parts {
            blocks.push(Block {
                name: if parts == 1 { name.to_string() } else { format!("{name}.{p}") },
                demand: frac(dsp * 0.97 / parts as f64),
                mem_traffic: traffic / parts as f64,
            });
        }
    };
    add_split("MSA(attn)", attn_dsp, ops.per_layer_msa.act_bytes as f64, 1);
    add_split(
        "MSA(stream-linear)",
        stream_dsp,
        ops.per_layer_msa.weight_bytes as f64,
        1,
    );
    add_split("MoE.cu", lin_dsp, moe_traffic, d.has.hw.lin.n_l.max(1));
    blocks.push(Block {
        name: "host-io".into(),
        demand: frac(r.dsp * 0.01),
        mem_traffic: ops.embed.weight_bytes as f64,
    });
    let plan = place(platform, &blocks).expect("design fits after HAS");
    (render_plan(platform, &blocks, &plan), plan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_overlap_speedup_positive() {
        let (ov, _seq, speedup) = fig3_timeline(&Platform::zcu102());
        assert!(speedup > 1.0, "speedup {speedup}");
        // The Fig. 3b property: MSA and MoE lanes overlap in time.
        assert!(ov.overlap("MSA", "MoE") > 0.0);
    }

    #[test]
    fn fig4_reduction_grows_with_na() {
        let t = fig4_reorder(&m3vit_small(), 32);
        assert_eq!(t.rows.len(), 6);
        // Reduction at N_a=32 must exceed reduction at N_a=2.
        let red = |i: usize| -> f64 {
            t.rows[i][3].trim_end_matches('x').parse::<f64>().unwrap()
        };
        assert!(red(5) > red(1), "{} !> {}", red(5), red(1));
    }

    #[test]
    fn fig5_u280_moe_on_hbm_slr() {
        let (txt, plan) = fig5_placement(&Platform::u280());
        assert!(txt.contains("[MEM]"));
        // At least the hottest MoE CU must sit on SLR0 (HBM) — the
        // §III-A placement rule.
        let moe_on_mem = txt
            .lines()
            .filter(|l| l.contains("[MEM]"))
            .any(|l| l.contains("MoE.cu"));
        assert!(moe_on_mem, "{txt}\n{plan:?}");
    }

    #[test]
    fn fig5_zcu102_single_die() {
        let (_, plan) = fig5_placement(&Platform::zcu102());
        assert_eq!(plan.crossings, 0);
    }
}
