//! Serving study: latency–throughput curves for UbiMoE fleets — the
//! deployment-scale figure set the paper stops short of (Tables I–III
//! are single-device, single-image).
//!
//! For each (platform, fleet size) the study sweeps offered load as a
//! fraction of the fleet's peak throughput and reports the tail
//! latency, utilization, padding and SLO attainment at every point.
//! The knee of the curve — p99 rising sharply once offered load
//! crosses sustainable throughput — is the number capacity planning
//! actually needs, and none of it is visible in per-batch latency.
//!
//! SLO convention (see EXPERIMENTS.md §Serving): the end-to-end SLO
//! for a deployment is **3× the unloaded batch-1 service latency** of
//! its device; attainment is the fraction of requests meeting it.

use std::time::Duration;

use crate::models::m3vit_small;
use crate::resources::{AttnParams, LinearParams, Platform, PlatformKind};
use crate::serve::device::DeviceModel;
use crate::serve::dispatch::DispatchPolicy;
use crate::serve::{simulate_fleet, FleetReport, ServeConfig, Workload};
use crate::sim::HwChoice;
use crate::util::table::{f1, f2, Table};

/// Offered-load fractions of fleet peak swept by default: dense around
/// the knee, one point well past it.
pub const DEFAULT_UTILS: &[f64] = &[0.3, 0.5, 0.7, 0.85, 0.95, 1.05, 1.2];

/// SLO = `SLO_FACTOR` × unloaded batch-1 latency.
pub const SLO_FACTOR: u32 = 3;

/// A pinned, Table-I-class m3vit-small demo design for `platform` —
/// the single fixture shared by `serve_smoke`, the serving tests and
/// the DES acceptance test, so smoke and tests can never silently
/// assert against different devices. No HAS cost; production paths
/// use [`DeviceModel::from_search`].
pub fn demo_device(platform: &Platform) -> DeviceModel {
    let hw = match platform.kind {
        PlatformKind::AlveoU280 => HwChoice {
            num: 3,
            attn: AttnParams { t_a: 16, n_a: 16 },
            lin: LinearParams { t_in: 16, t_out: 16, n_l: 6 },
            q_bits: 16,
            a_bits: 32,
        },
        _ => HwChoice {
            num: 2,
            attn: AttnParams { t_a: 8, n_a: 8 },
            lin: LinearParams { t_in: 16, t_out: 16, n_l: 2 },
            q_bits: 16,
            a_bits: 32,
        },
    };
    DeviceModel::with_hw(&m3vit_small(), platform, hw, &[1, 2, 4, 8])
}

/// One point of a latency–throughput curve. (`PartialEq` backs the
/// parallel-vs-sequential equivalence test: points are produced by
/// identical deterministic computations, so exact float equality is
/// the right assertion.)
#[derive(Clone, Debug, PartialEq)]
pub struct CurvePoint {
    /// Offered load as a fraction of fleet peak throughput.
    pub util_target: f64,
    pub offered_rps: f64,
    pub achieved_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    /// Mean device busy fraction over the makespan.
    pub device_util: f64,
    pub padding_fraction: f64,
    pub slo_ms: f64,
    pub slo_attainment: f64,
}

/// Assemble a [`CurvePoint`] from a finished fleet run — the single
/// place report metrics are read off a [`FleetReport`], shared by the
/// homogeneous curves and the mixed-fleet table.
fn point_from_report(u: f64, r: &FleetReport, slo: Duration) -> CurvePoint {
    let [p50, p99, p999] = match r.fleet.e2e.percentiles(&[50.0, 99.0, 99.9])[..] {
        [a, b, c] => [a, b, c],
        _ => unreachable!(),
    };
    CurvePoint {
        util_target: u,
        offered_rps: r.offered_rps,
        achieved_rps: r.achieved_rps(),
        p50_ms: p50.as_secs_f64() * 1e3,
        p99_ms: p99.as_secs_f64() * 1e3,
        p999_ms: p999.as_secs_f64() * 1e3,
        device_util: r.mean_utilization(),
        padding_fraction: r.fleet.padding_fraction(),
        slo_ms: slo.as_secs_f64() * 1e3,
        slo_attainment: r.slo_attainment(slo),
    }
}

/// One point of the sweep — the shared kernel of the parallel and
/// sequential paths, so their results are identical by construction.
fn curve_point(
    device: &DeviceModel,
    n_devices: usize,
    policy: DispatchPolicy,
    num_experts: usize,
    u: f64,
    horizon: Duration,
    seed: u64,
) -> CurvePoint {
    let peak = device.peak_rps() * n_devices as f64;
    let slo = device.unloaded_latency() * SLO_FACTOR;
    let mut cfg = ServeConfig::uniform(
        device.clone(),
        n_devices,
        Workload::Poisson { rate_rps: u * peak },
    );
    cfg.dispatch = policy;
    cfg.num_experts = num_experts;
    cfg.horizon = horizon;
    cfg.seed = seed;
    point_from_report(u, &simulate_fleet(&cfg), slo)
}

/// Sweep a homogeneous fleet of `n_devices` replicas of `device` over
/// Poisson loads at `utils` × fleet peak. `num_experts` is the served
/// model's expert count (feeds the dominant-expert hint stream; 0 for
/// plain transformers). Deterministic in `seed`.
///
/// Points are independent DES runs, so they execute concurrently on
/// scoped threads (the `report::deploy_many` pattern) and return in
/// input order, bit-identical to [`fleet_curve_seq`] — enforced by an
/// equivalence test.
pub fn fleet_curve(
    device: &DeviceModel,
    n_devices: usize,
    policy: DispatchPolicy,
    num_experts: usize,
    utils: &[f64],
    horizon: Duration,
    seed: u64,
) -> Vec<CurvePoint> {
    if utils.len() <= 1 {
        return fleet_curve_seq(device, n_devices, policy, num_experts, utils, horizon, seed);
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = utils
            .iter()
            .map(|&u| {
                scope.spawn(move || {
                    curve_point(device, n_devices, policy, num_experts, u, horizon, seed)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("curve worker panicked"))
            .collect()
    })
}

/// The retained sequential sweep (reference path for the
/// parallel-equivalence test; also what single-point sweeps use).
pub fn fleet_curve_seq(
    device: &DeviceModel,
    n_devices: usize,
    policy: DispatchPolicy,
    num_experts: usize,
    utils: &[f64],
    horizon: Duration,
    seed: u64,
) -> Vec<CurvePoint> {
    utils
        .iter()
        .map(|&u| curve_point(device, n_devices, policy, num_experts, u, horizon, seed))
        .collect()
}

/// Render a curve as a report table.
pub fn curve_table(title: &str, pts: &[CurvePoint]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "load/peak",
            "offered (req/s)",
            "achieved (req/s)",
            "p50 (ms)",
            "p99 (ms)",
            "p999 (ms)",
            "util",
            "padding",
            "SLO met",
        ],
    );
    for p in pts {
        t.row(&[
            f2(p.util_target),
            f1(p.offered_rps),
            f1(p.achieved_rps),
            f2(p.p50_ms),
            f2(p.p99_ms),
            f2(p.p999_ms),
            format!("{:.0}%", 100.0 * p.device_util),
            format!("{:.1}%", 100.0 * p.padding_fraction),
            format!("{:.1}%", 100.0 * p.slo_attainment),
        ]);
    }
    t
}

/// Offered-load fractions the mixed-fleet study probes: one
/// comfortable point and one near the knee, where routing quality
/// decides the tail.
pub const MIXED_FLEET_UTILS: &[f64] = &[0.6, 0.85];

/// One mixed-fleet run per util for one policy — the ROADMAP
/// "heterogeneous fleets" study kernel: a slow edge tier next to a
/// fast core tier behind one dispatcher. JSQ compares queue *lengths*
/// and keeps feeding the slow edge tier whenever its count dips below
/// the core tier's; SED keys the same tournament tree by
/// expected-completion ns from each device's own service LUT, so the
/// edge tier is used only when the core backlog genuinely costs more
/// — which is what cuts the p99 (asserted in the tests below).
///
/// `num_experts` is the served model's expert count (0 for plain
/// transformers — disables hints and the residency discount). The SLO
/// is [`SLO_FACTOR`] × the *edge* (slowest) unloaded batch-1 latency,
/// so attainment is comparable across policies and achievable on
/// either tier.
#[allow(clippy::too_many_arguments)]
pub fn mixed_fleet_points(
    edge: &DeviceModel,
    n_edge: usize,
    core: &DeviceModel,
    n_core: usize,
    policy: DispatchPolicy,
    num_experts: usize,
    utils: &[f64],
    horizon: Duration,
    seed: u64,
) -> Vec<CurvePoint> {
    let mut devices = vec![edge.clone(); n_edge];
    devices.extend((0..n_core).map(|_| core.clone()));
    let peak: f64 = devices.iter().map(|d| d.peak_rps()).sum();
    let slo = edge.unloaded_latency().max(core.unloaded_latency()) * SLO_FACTOR;
    utils
        .iter()
        .map(|&u| {
            let mut cfg = ServeConfig::mixed(
                devices.clone(),
                Workload::Poisson { rate_rps: u * peak },
            );
            cfg.dispatch = policy;
            cfg.num_experts = num_experts;
            cfg.horizon = horizon;
            cfg.seed = seed;
            point_from_report(u, &simulate_fleet(&cfg), slo)
        })
        .collect()
}

/// Render the mixed-fleet RR vs JSQ vs SED comparison as one table (a
/// row per (load, policy)) — what `serving_study` / `ubimoe serve
/// --study` append after the homogeneous curves. The (util × policy)
/// cells are independent DES runs and execute on scoped threads (the
/// [`fleet_curve`] pattern); rows land in grid order.
#[allow(clippy::too_many_arguments)]
pub fn mixed_fleet_table(
    edge: &DeviceModel,
    n_edge: usize,
    core: &DeviceModel,
    n_core: usize,
    num_experts: usize,
    utils: &[f64],
    horizon: Duration,
    seed: u64,
) -> Table {
    let policies = [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::JoinShortestQueue,
        DispatchPolicy::ShortestExpectedDelay,
    ];
    let grid: Vec<(f64, DispatchPolicy)> = utils
        .iter()
        .flat_map(|&u| policies.into_iter().map(move |policy| (u, policy)))
        .collect();
    let points: Vec<CurvePoint> = std::thread::scope(|scope| {
        let handles: Vec<_> = grid
            .iter()
            .map(|&(u, policy)| {
                scope.spawn(move || {
                    mixed_fleet_points(
                        edge, n_edge, core, n_core, policy, num_experts, &[u], horizon, seed,
                    )
                    .remove(0)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("mixed-fleet worker panicked"))
            .collect()
    });
    let mut t = Table::new(
        &format!(
            "Serving: mixed fleet — {} x{n_edge} edge + {} x{n_core} core (RR vs JSQ vs SED)",
            edge.name, core.name
        ),
        &[
            "load/peak",
            "policy",
            "offered (req/s)",
            "achieved (req/s)",
            "p50 (ms)",
            "p99 (ms)",
            "p999 (ms)",
            "util",
            "SLO met",
        ],
    );
    for ((_, policy), p) in grid.iter().zip(points) {
        t.row(&[
            f2(p.util_target),
            policy.name().to_string(),
            f1(p.offered_rps),
            f1(p.achieved_rps),
            f2(p.p50_ms),
            f2(p.p99_ms),
            f2(p.p999_ms),
            format!("{:.0}%", 100.0 * p.device_util),
            format!("{:.1}%", 100.0 * p.slo_attainment),
        ]);
    }
    t
}

/// The full serving figure set: HAS-chosen designs for m3vit-small on
/// ZCU102 and U280 (through the persistent design cache — a warm
/// process pays zero GA evaluations and zero cycle sims here), fleets
/// of `fleet_sizes` devices, each swept over [`DEFAULT_UTILS`], plus
/// the mixed-fleet policy table.
///
/// Parallelism: the per-platform HAS searches (the expensive part)
/// run concurrently on scoped threads, and every curve's util points
/// fan out inside [`fleet_curve`] — so the whole platform × fleet ×
/// util grid is concurrent while the output order stays fixed.
pub fn serving_study(fleet_sizes: &[usize], horizon: Duration) -> Vec<Table> {
    let model = m3vit_small();
    let platforms = [Platform::zcu102(), Platform::u280()];
    let devices: Vec<DeviceModel> = std::thread::scope(|scope| {
        let handles: Vec<_> = platforms
            .iter()
            .map(|platform| {
                let model = &model;
                scope.spawn(move || {
                    DeviceModel::from_search(model, platform, 16, 32, &[1, 2, 4, 8])
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("search worker panicked"))
            .collect()
    });
    let mut out = Vec::new();
    for (platform, device) in platforms.iter().zip(&devices) {
        for &n in fleet_sizes {
            let pts = fleet_curve(
                device,
                n,
                DispatchPolicy::JoinShortestQueue,
                model.num_experts,
                DEFAULT_UTILS,
                horizon,
                0xF1EE7,
            );
            let title = format!(
                "Serving: {} x{n} fleet, {} (b1 {:.2} ms, peak {:.1} req/s/device)",
                platform.name,
                model.name,
                device.unloaded_latency().as_secs_f64() * 1e3,
                device.peak_rps(),
            );
            out.push(curve_table(&title, &pts));
        }
    }
    // Mixed-fleet policy table on the same searched designs (no extra
    // search: devices[0] is the ZCU102 edge design, devices[1] the
    // U280 core design).
    out.push(mixed_fleet_table(
        &devices[0],
        4,
        &devices[1],
        2,
        model.num_experts,
        MIXED_FLEET_UTILS,
        horizon,
        0xF1EE7,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn u280_device() -> DeviceModel {
        demo_device(&Platform::u280())
    }

    #[test]
    fn curve_shows_saturation_knee() {
        let pts = fleet_curve(
            &u280_device(),
            4,
            DispatchPolicy::JoinShortestQueue,
            16,
            &[0.4, 0.8, 1.15],
            Duration::from_secs(8),
            7,
        );
        assert_eq!(pts.len(), 3);
        // Below the knee: achieved tracks offered, SLO mostly met.
        assert!(pts[0].achieved_rps / pts[0].offered_rps > 0.9);
        assert!(pts[0].slo_attainment > 0.8, "{}", pts[0].slo_attainment);
        // Past the knee: p99 blows up, achieved saturates below
        // offered, SLO collapses.
        assert!(pts[2].p99_ms > 3.0 * pts[0].p99_ms, "{} vs {}", pts[2].p99_ms, pts[0].p99_ms);
        assert!(pts[2].achieved_rps < 0.95 * pts[2].offered_rps);
        assert!(pts[2].slo_attainment < pts[0].slo_attainment);
        // Tail ordering within a point.
        for p in &pts {
            assert!(p.p50_ms <= p.p99_ms && p.p99_ms <= p.p999_ms);
        }
    }

    #[test]
    fn parallel_curve_matches_sequential() {
        // The acceptance equivalence: fanning the util points out on
        // scoped threads must be bit-identical (exact float equality)
        // to the retained sequential sweep, in the same order.
        let d = u280_device();
        let utils = [0.4, 0.9, 1.15];
        let horizon = Duration::from_secs(3);
        for policy in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::ExpertAffinity,
        ] {
            let par = fleet_curve(&d, 2, policy, 16, &utils, horizon, 11);
            let seq = fleet_curve_seq(&d, 2, policy, 16, &utils, horizon, 11);
            assert_eq!(par, seq, "parallel sweep diverged for {policy:?}");
        }
    }

    #[test]
    fn curve_is_deterministic() {
        let a = fleet_curve(
            &u280_device(),
            2,
            DispatchPolicy::RoundRobin,
            16,
            &[0.7],
            Duration::from_secs(5),
            42,
        );
        let b = fleet_curve(
            &u280_device(),
            2,
            DispatchPolicy::RoundRobin,
            16,
            &[0.7],
            Duration::from_secs(5),
            42,
        );
        assert_eq!(a[0].p99_ms, b[0].p99_ms);
        assert_eq!(a[0].achieved_rps, b[0].achieved_rps);
    }

    #[test]
    fn mixed_fleet_sed_strictly_cuts_p99_vs_jsq() {
        // The ROADMAP heterogeneous-fleets acceptance bar: on the
        // ZCU102-edge + U280-core fleet near the knee, expected-delay
        // dispatch strictly reduces the p99 e2e against both
        // queue-length (JSQ) and blind (RR) routing.
        let edge = demo_device(&Platform::zcu102());
        let core = u280_device();
        let horizon = Duration::from_secs(20);
        let run = |policy| {
            mixed_fleet_points(&edge, 4, &core, 2, policy, 16, &[0.85], horizon, 7)
                .remove(0)
        };
        let sed = run(DispatchPolicy::ShortestExpectedDelay);
        let jsq = run(DispatchPolicy::JoinShortestQueue);
        let rr = run(DispatchPolicy::RoundRobin);
        assert!(
            sed.p99_ms < jsq.p99_ms,
            "SED p99 {} !< JSQ p99 {} on the mixed fleet",
            sed.p99_ms,
            jsq.p99_ms
        );
        assert!(
            sed.p99_ms < rr.p99_ms,
            "SED p99 {} !< RR p99 {} on the mixed fleet",
            sed.p99_ms,
            rr.p99_ms
        );
        // Same offered traffic across policies.
        assert_eq!(sed.offered_rps, jsq.offered_rps);
        assert_eq!(sed.offered_rps, rr.offered_rps);
    }

    #[test]
    fn mixed_fleet_table_renders_all_policy_rows() {
        let t = mixed_fleet_table(
            &demo_device(&Platform::zcu102()),
            2,
            &u280_device(),
            1,
            16,
            &[0.6],
            Duration::from_secs(5),
            1,
        );
        assert_eq!(t.rows.len(), 3, "one row per policy");
        let text = t.render();
        assert!(text.contains("sed") && text.contains("jsq") && text.contains("round-robin"));
        assert!(text.contains("p99 (ms)"));
    }

    #[test]
    fn table_renders_all_points() {
        let pts = fleet_curve(
            &u280_device(),
            1,
            DispatchPolicy::JoinShortestQueue,
            16,
            &[0.5, 1.1],
            Duration::from_secs(4),
            1,
        );
        let t = curve_table("Serving: test", &pts);
        assert_eq!(t.rows.len(), 2);
        assert!(t.render().contains("p99 (ms)"));
        assert!(!t.to_csv().is_empty());
    }
}
